"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from importlib import import_module
from typing import Dict, List

from ..models.config import ArchConfig

ARCH_IDS: List[str] = [
    "mixtral_8x7b",
    "arctic_480b",
    "xlstm_1_3b",
    "paligemma_3b",
    "recurrentgemma_9b",
    "stablelm_1_6b",
    "minicpm3_4b",
    "starcoder2_15b",
    "phi3_medium_14b",
    "musicgen_medium",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(name: str) -> ArchConfig:
    """Accepts registry ids (stablelm_1_6b) and display names (stablelm-1.6b)."""
    mod_name = _ALIAS.get(name, name).replace("-", "_").replace(".", "_")
    mod = import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {i: get_config(i) for i in ARCH_IDS}


from .shapes import SHAPE_NAMES, input_specs, shape_applicable  # noqa: E402,F401
