"""xLSTM 1.3B [arXiv:2405.04517; unverified]: 48 blocks, d=2048, 4 heads,
sLSTM + mLSTM mix (1 sLSTM per 8 blocks ~= the paper's 7:1 mLSTM:sLSTM).
d_ff=0: xLSTM blocks carry their own up/down projections. Pure recurrent
state decode => long_500k-capable."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("S", "M", "M", "M", "M", "M", "M", "M"),
    ffn_type="none",
    subquadratic=True,
)
