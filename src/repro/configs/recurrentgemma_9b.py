"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified]: 38 blocks,
d=4096, 16H MQA (kv=1) on the attention layers, d_ff=12288, vocab=256000,
RG-LRU recurrent blocks : local attention (window 2048) in a 2:1 pattern.
38 % 3 != 0, so the pattern is expressed as a period-19 cycle
(R,R,A)x6 + R — same 2:1 ratio, 2 scan groups (documented deviation).
Recurrent state + windowed attention => long_500k-capable."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("R", "R", "A") * 6 + ("R",),
    attention_type="local",
    window=2048,
    ffn_type="swiglu",
    rnn_width=4096,
    subquadratic=True,
)
