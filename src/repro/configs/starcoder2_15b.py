"""StarCoder2-15B [arXiv:2402.19173; hf]: 40L, d=6144, 48H (GQA kv=4),
d_ff=24576, vocab=49152, RoPE, GeLU MLP, LayerNorm."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    attention_type="full",
    ffn_type="gelu",
    norm_type="layernorm",
    subquadratic=False,
)
