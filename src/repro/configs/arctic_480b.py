"""Snowflake Arctic (480B total) [hf:Snowflake/snowflake-arctic-base; hf]:
35L, d=7168, 56H (GQA kv=8), MoE d_ff=4864 with 128 experts top-2 PLUS a
dense residual FFN in parallel (Arctic's dense-MoE hybrid). Full attention
=> long_500k skipped (DESIGN.md)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    attention_type="full",
    ffn_type="moe",
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    subquadratic=False,
)
