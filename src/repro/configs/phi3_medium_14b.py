"""Phi-3-medium-14B [arXiv:2404.14219; unverified]: 40L, d=5120, 40H (GQA
kv=10), d_ff=17920, vocab=100352, RoPE, SwiGLU, RMSNorm."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    attention_type="full",
    ffn_type="swiglu",
    subquadratic=False,
)
