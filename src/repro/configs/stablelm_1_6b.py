"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b; unverified]: 24L, d=2048,
32H MHA (kv=32), d_ff=5632, vocab=100352, partial rotary (25%), LayerNorm."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    attention_type="full",
    ffn_type="swiglu",
    rope_fraction=0.25,
    norm_type="layernorm",
    subquadratic=False,
)
