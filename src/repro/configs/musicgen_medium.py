"""MusicGen-medium [arXiv:2306.05284; hf]: 48L decoder over EnCodec tokens,
d=1536, 24H MHA (kv=24), d_ff=6144, vocab=2048 (per-codebook). The EnCodec
audio frontend is a STUB per the assignment: input_specs provides precomputed
frame embeddings; the LM backbone predicts codebook tokens."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    attention_type="full",
    ffn_type="gelu",
    norm_type="layernorm",
    input_mode="embeddings",
    subquadratic=False,
)
