"""Mixtral 8x7B [arXiv:2401.04088; hf]: 32L, d=4096, 32H (GQA kv=8),
d_ff=14336, vocab=32000, MoE 8 experts top-2, sliding-window attention 4096.
SWA bounds the decode KV cache => long_500k-capable."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    attention_type="swa",
    window=4096,
    ffn_type="moe",
    n_experts=8,
    top_k=2,
    rope_theta=1e6,
    subquadratic=True,
)
