"""PaliGemma-3B [arXiv:2407.07726; hf]: SigLIP vision frontend (STUB —
input_specs provides 256 precomputed patch embeddings) + an 18L Gemma-style
decoder, d=2048, 8H MQA (kv=1), d_ff=16384, vocab=257216, prefix-LM masking
over the image prefix, tied embeddings."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    attention_type="full",
    ffn_type="swiglu",  # Gemma's GeGLU ~ gated MLP (documented approximation)
    input_mode="embeddings",
    prefix_lm=True,
    n_prefix=256,
    tie_embeddings=True,
    subquadratic=False,
)
