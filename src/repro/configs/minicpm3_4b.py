"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B; hf]: 62L, d=2560, 40H, d_ff=6400,
vocab=73448, Multi-head Latent Attention (q_lora 768, kv_lora 256,
qk_nope 64 + qk_rope 32, v 64). The latent KV cache is tiny (288/token) but
attention is still full => long_500k skipped."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    ffn_type="swiglu",
    subquadratic=False,
)
