"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Four shapes per architecture (40 cells):

  train_4k    seq 4096,   global_batch 256  -> train_step
  prefill_32k seq 32768,  global_batch 32   -> prefill_step (forward)
  decode_32k  cache 32768, global_batch 128 -> serve_step (1 new token)
  long_500k   cache 524288, global_batch 1  -> serve_step; requires
              sub-quadratic decode state => runs only for archs with
              cfg.subquadratic (mixtral SWA / xlstm / recurrentgemma);
              skips are recorded as N/A in the roofline table.

Modality stubs: paligemma gets 256 precomputed patch embeddings
(B, 256, d_model) + text tokens; musicgen gets precomputed EnCodec frame
embeddings (B, S, d_model) + codebook labels.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import init_caches
from ..models.config import ArchConfig

SHAPE_NAMES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

SHAPE_DEFS = {
    "train_4k": {"seq": 4096, "batch": 256, "step": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "step": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "step": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "step": "decode"},
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "pure full attention: 500k dense KV decode excluded (DESIGN.md §4)"
    return True, ""


def _bdt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _token_batch(cfg: ArchConfig, batch: int, seq: int, with_labels: bool) -> Dict:
    """Token / embedding stand-ins for one forward pass of length ``seq``."""
    out: Dict = {}
    if cfg.input_mode == "embeddings":
        if cfg.prefix_lm and cfg.n_prefix:
            # image prefix + text tokens (paligemma)
            s_text = seq - cfg.n_prefix
            out["embeds"] = _sds((batch, cfg.n_prefix, cfg.d_model), _bdt(cfg))
            out["tokens"] = _sds((batch, s_text), jnp.int32)
            if with_labels:
                out["labels"] = _sds((batch, s_text), jnp.int32)
        else:
            # frame embeddings only (musicgen)
            out["embeds"] = _sds((batch, seq, cfg.d_model), _bdt(cfg))
            if with_labels:
                out["labels"] = _sds((batch, seq), jnp.int32)
    else:
        out["tokens"] = _sds((batch, seq), jnp.int32)
        if with_labels:
            out["labels"] = _sds((batch, seq), jnp.int32)
    return out


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict:
    """Returns {"step": train|prefill|decode, "batch": {...},
    "caches": ... (decode only)} — all ShapeDtypeStructs, no allocation."""
    d = SHAPE_DEFS[shape_name]
    step, seq, batch = d["step"], d["seq"], d["batch"]
    if step == "train":
        return {"step": "train", "batch": _token_batch(cfg, batch, seq, True)}
    if step == "prefill":
        return {"step": "prefill", "batch": _token_batch(cfg, batch, seq, False)}
    # decode: one new token against a cache of length `seq`
    caches = jax.eval_shape(lambda: init_caches(cfg, batch, seq))
    if cfg.input_mode == "embeddings" and not (cfg.prefix_lm and cfg.n_prefix):
        tok = {"embeds": _sds((batch, 1, cfg.d_model), _bdt(cfg))}
    else:
        tok = {"tokens": _sds((batch, 1), jnp.int32)}
    return {"step": "decode", "batch": tok, "caches": caches}
