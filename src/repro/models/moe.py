"""Top-k Mixture-of-Experts with a Reflex-style capacity resizer.

Dispatch follows the capacity-factor formulation (einsum dispatch/combine
tensors — robust under pjit, shards cleanly for both EP and TP layouts):

    capacity C = ceil(tokens * top_k / n_experts * cf)

The **CapacityResizer** is the paper's mechanism transplanted (DESIGN.md §5):
the fully-"oblivious" buffer is C_full = tokens (cf = E/top_k — no token ever
dropped regardless of routing skew, shape-independent of the data); Reflex
trims it to C = T_est + eta where T_est = tokens*top_k/E is the balanced load
and eta is slack from a pluggable policy (const ≙ ConstantNoise,
reflex_tlap/reflex_beta reuse core.noise distributions at planning time).
Smaller C shrinks the EP all-to-all / all-gather volume linearly — the §Perf
hillclimb lever for the MoE cells. No privacy claim is attached (plaintext
training); what transfers is controlled intermediate-buffer trimming.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["moe_init", "moe_apply", "resolve_capacity"]


def resolve_capacity(cfg, n_tokens: int) -> int:
    """Reflex-style capacity policy (static: planning-time decision)."""
    e, k = cfg.n_experts, cfg.top_k
    t_est = n_tokens * k / e  # balanced true load per expert
    if cfg.capacity_policy == "full":  # fully oblivious: no drops possible
        cap = float(n_tokens)
    elif cfg.capacity_policy == "const":
        cap = t_est * cfg.capacity_factor
    elif cfg.capacity_policy == "reflex_tlap":
        from ..core.noise import TruncatedLaplace

        noise = TruncatedLaplace(eps=0.5, delta=5e-5, sensitivity=max(t_est / 64, 1))
        cap = t_est + noise.mean(n_tokens, int(t_est))
    elif cfg.capacity_policy == "reflex_beta":
        from ..core.noise import BetaNoise

        noise = BetaNoise(2, 6)
        cap = t_est + noise.mean(int(n_tokens * k / e * 2), int(t_est))
    else:
        raise ValueError(cfg.capacity_policy)
    cap = int(min(max(math.ceil(cap), 8), n_tokens))
    return ((cap + 7) // 8) * 8  # pad to a lane-friendly multiple


def moe_init(key, cfg) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(ks[3], (e, f, d)),
    }
    if cfg.moe_dense_residual:
        from .layers import mlp_init

        p["dense_residual"] = mlp_init(ks[4], d, cfg.d_ff, "swiglu")
    return p


def _route(params, cfg, xt):
    """Router: top-k gates + per-assignment (expert, position) slots."""
    dt = xt.dtype
    n_tok = xt.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    cap = resolve_capacity(cfg, n_tok)
    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch/Mixtral style)
    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(gate_idx[:, 0], e).mean(axis=0)
    aux = e * jnp.sum(me * ce)
    # position-in-expert per assignment (integer prefix counts; k waves)
    fill = jnp.zeros((e,), jnp.int32)
    pos_list = []
    for rank in range(k):
        onehot = jax.nn.one_hot(gate_idx[:, rank], e, dtype=jnp.int32)  # (T,E)
        pos_in_wave = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_in_wave + fill[None, :], gate_idx[:, rank : rank + 1], axis=1)[:, 0]
        fill = fill + onehot.sum(axis=0)
        pos_list.append(pos)
    pos_tk = jnp.stack(pos_list, axis=1)  # (T, k)
    return gate_vals, gate_idx, pos_tk, cap, aux


def _expert_ffn(params, cfg, ein):
    dt = ein.dtype
    g = jnp.einsum("ecd,edf->ecf", ein, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", ein, params["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))


def moe_apply(params: Dict, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Two dispatch implementations:

    * ``einsum`` — one-hot dispatch/combine matmuls (Mesh-TF style). Robust,
      but the dispatch matmul costs 2*T*E*C*D FLOPs — at mixtral train_4k
      scale that DWARFS the expert FFNs (the §Perf baseline pathology).
    * ``gather`` (default) — slot bookkeeping with integer prefix sums, then
      pure gather/scatter data movement: expert-FFN FLOPs only. This is the
      beyond-paper optimization validated in §Perf.
    """
    dt = x.dtype
    b, s, d = x.shape
    n_tok = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(n_tok, d)
    gate_vals, gate_idx, pos_tk, cap, aux = _route(params, cfg, xt)

    if cfg.moe_impl == "einsum":
        dispatch = jnp.zeros((n_tok, e, cap), dtype=dt)
        combine = jnp.zeros((n_tok, e, cap), dtype=jnp.float32)
        for rank in range(k):
            keep = pos_tk[:, rank] < cap
            oh_e = jax.nn.one_hot(gate_idx[:, rank], e, dtype=dt)
            oh_c = jax.nn.one_hot(
                jnp.where(keep, pos_tk[:, rank], cap), cap + 1, dtype=dt
            )[:, :cap]
            d_r = oh_e[:, :, None] * oh_c[:, None, :]
            dispatch = dispatch + d_r
            combine = combine + d_r.astype(jnp.float32) * gate_vals[:, rank][:, None, None]
        ein = jnp.einsum("tec,td->ecd", dispatch, xt)
        eo = _expert_ffn(params, cfg, ein)
        y = jnp.einsum("ecd,tec->td", eo, combine.astype(dt)).reshape(b, s, d)
    else:  # gather
        slot = gate_idx * cap + jnp.minimum(pos_tk, cap - 1)  # (T, k)
        keep = pos_tk < cap
        spill = e * cap  # dropped assignments write/read a zero slot
        slot = jnp.where(keep, slot, spill)
        # buffer: slot -> token row (scatter), zero row for empty/spilled
        buf_tok = jnp.full((e * cap + 1,), n_tok, jnp.int32)
        buf_tok = buf_tok.at[slot.reshape(-1)].set(
            jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), k), mode="drop"
        )
        buf_tok = buf_tok.at[spill].set(n_tok)
        x_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)
        ein = jnp.take(x_pad, buf_tok[: e * cap], axis=0).reshape(e, cap, d)
        eo = _expert_ffn(params, cfg, ein)
        eo_flat = jnp.concatenate(
            [eo.reshape(e * cap, d), jnp.zeros((1, d), dt)], axis=0
        )
        picked = jnp.take(eo_flat, slot, axis=0)  # (T, k, D)
        y = jnp.sum(picked * gate_vals[..., None].astype(dt), axis=1).reshape(b, s, d)

    if cfg.moe_dense_residual:
        from .layers import apply_mlp

        y = y + apply_mlp(params["dense_residual"], x, "swiglu")
    return y, aux
