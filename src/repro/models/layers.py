"""Shared layers: norms, RoPE, dense FFNs, embeddings, initializers.

Parameters are plain nested dicts (pytrees); every initializer returns
(params, apply) pairs closed over the config so `jax.eval_shape` can derive
abstract parameter trees for the dry-run without allocating.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init",
    "norm_init",
    "apply_norm",
    "rope",
    "mlp_init",
    "apply_mlp",
]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (params kept f32; compute casts)."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def norm_init(d: int) -> Dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(params: Dict, x: jax.Array, kind: str = "rmsnorm") -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
    else:  # layernorm (bias-free)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    return (y * params["scale"]).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float, fraction: float = 1.0):
    """Rotary embedding on the leading ``fraction`` of head dims.

    x: (..., S, H, Dh); positions: broadcastable to (..., S).
    """
    dh = x.shape[-1]
    rot = int(dh * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def mlp_init(key, d_model: int, d_ff: int, kind: str) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(k1, (d_model, d_ff)),
            "w_up": dense_init(k2, (d_model, d_ff)),
            "w_down": dense_init(k3, (d_ff, d_model)),
        }
    return {  # gelu
        "w_up": dense_init(k1, (d_model, d_ff)),
        "w_down": dense_init(k2, (d_ff, d_model)),
    }


def apply_mlp(params: Dict, x: jax.Array, kind: str) -> jax.Array:
    dt = x.dtype
    if kind == "swiglu":
        g = x @ params["w_gate"].astype(dt)
        u = x @ params["w_up"].astype(dt)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
        return h @ params["w_down"].astype(dt)
    h = jax.nn.gelu((x @ params["w_up"].astype(dt)).astype(jnp.float32)).astype(dt)
    return h @ params["w_down"].astype(dt)
