"""Attention variants: GQA full / sliding-window / local / MLA (+ KV caches).

All variants share one masked-softmax core; masks are built per mode:

* ``full``   — causal
* ``swa``    — causal within a sliding window (mixtral)
* ``local``  — causal within a local window (recurrentgemma's attn layers)
* ``prefix`` — bidirectional over the first n_prefix positions (paligemma)
* ``mla``    — multi-head latent attention (minicpm3): KV compressed to a
               latent of rank kv_lora_rank + a shared RoPE key; the decode
               cache stores only the latent (the long-context win).

Decode caches are fixed-capacity rings for swa/local and flat buffers for
full/mla; ``decode`` performs one-token attention against the cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, rope

__all__ = ["attn_init", "attn_apply", "attn_init_cache", "attn_decode"]

NEG = -1e9


# -----------------------------------------------------------------------------
# init
# -----------------------------------------------------------------------------

def attn_init(key, cfg) -> Dict:
    d = cfg.d_model
    dh = cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    if cfg.attention_type == "mla":
        rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return {
            "w_dq": dense_init(ks[0], (d, rq)),
            "w_uq": dense_init(ks[1], (rq, h, dn + dr)),
            "w_dkv": dense_init(ks[2], (d, rkv)),
            "w_kr": dense_init(ks[3], (d, dr)),  # shared rope key
            "w_uk": dense_init(ks[4], (rkv, h, dn)),
            "w_uv": dense_init(ks[5], (rkv, h, dv)),
            "w_o": dense_init(ks[6], (h, dv, d)),
        }
    return {
        "w_q": dense_init(ks[0], (d, h, dh)),
        "w_k": dense_init(ks[1], (d, hkv, dh)),
        "w_v": dense_init(ks[2], (d, hkv, dh)),
        "w_o": dense_init(ks[3], (h, dh, d)),
    }


# -----------------------------------------------------------------------------
# masks
# -----------------------------------------------------------------------------

def _mask(cfg, s_q: int, s_k: int, q_offset: int = 0) -> jax.Array:
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_k)[None, :]
    m = kpos <= qpos  # causal
    if cfg.attention_type in ("swa", "local") and cfg.window:
        m &= kpos > qpos - cfg.window
    if cfg.prefix_lm and cfg.n_prefix:
        both_prefix = (qpos < cfg.n_prefix) & (kpos < cfg.n_prefix)
        m |= both_prefix  # bidirectional over the image prefix
    return m


def _sdpa(q, k, v, mask) -> jax.Array:
    """q: (B,S,H,Dh), k/v: (B,T,Hkv,Dh[v]) with H % Hkv == 0."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, s, hkv, rep, dh)
    scores = jnp.einsum("bshrd,bthd->bhrst", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(dh)
    scores = jnp.where(mask[None, None, None], scores, NEG)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrst,bthd->bshrd", p, v)
    return out.reshape(b, s, h, v.shape[-1])


def _mask_chunk(cfg, s_q: int, t0: int, c: int) -> jax.Array:
    """(s_q, c) mask for key columns [t0, t0+c) — never materializes SxT."""
    qpos = jnp.arange(s_q)[:, None]
    kpos = t0 + jnp.arange(c)[None, :]
    m = kpos <= qpos
    if cfg.attention_type in ("swa", "local") and cfg.window:
        m &= kpos > qpos - cfg.window
    if cfg.prefix_lm and cfg.n_prefix:
        m |= (qpos < cfg.n_prefix) & (kpos < cfg.n_prefix)
    return m


def _sdpa_chunked(cfg, q, k_fn, v_shape_t, n_t: int) -> jax.Array:
    """Flash-style online-softmax attention: iterates KV chunks, keeping only
    (B,S,chunk) score tiles live — the fix for dense S x T temp blow-up at
    32k+ prefill (§Perf: temp_size 699 GB/device -> fits). The loop is a
    *python* (unrolled) loop so per-chunk costs stay visible to
    cost_analysis (a lax.scan body would be counted once — see dryrun.py).

    ``k_fn(t0, c) -> (k_chunk, v_chunk)`` lets MLA build per-head K/V from the
    latent chunk on the fly (never materializing the full per-head K).
    """
    b, s, h, dh = q.shape
    chunk = min(cfg.attn_chunk, n_t)
    n_chunks = (n_t + chunk - 1) // chunk
    qf = q
    m = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l = jnp.zeros((b, h, s), jnp.float32)
    acc = None
    for ci in range(n_chunks):
        t0 = ci * chunk
        c = min(chunk, n_t - t0)
        k_c, v_c = k_fn(t0, c)  # (B,c,Hkv,dh), (B,c,Hkv,dv)
        hkv = k_c.shape[2]
        rep = h // hkv
        qg = qf.reshape(b, s, hkv, rep, dh)
        sc = jnp.einsum("bshrd,bthd->bhrst", qg, k_c).astype(jnp.float32)
        sc = sc.reshape(b, h, s, c) / np.sqrt(dh)
        msk = _mask_chunk(cfg, s, t0, c)
        sc = jnp.where(msk[None, None], sc, -jnp.inf)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        # fully-masked-so-far rows (e.g. SWA rows before their window) keep
        # m = -inf; shift against a safe max so exp never sees inf - inf
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.exp(m - m_safe)
        p = jnp.exp(sc - m_safe[..., None])
        l = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum(
            "bhrst,bthd->bshrd",
            p.reshape(b, hkv, rep, s, c).astype(q.dtype),
            v_c,
        ).reshape(b, s, h, v_c.shape[-1])
        if acc is None:
            acc = pv * 0.0
        acc = acc * jnp.transpose(alpha, (0, 2, 1))[..., None].astype(q.dtype) + pv
        m = m_new
    den = jnp.transpose(l, (0, 2, 1))[..., None]  # (B,S,H,1)
    return (acc / jnp.maximum(den, 1e-20).astype(acc.dtype)).astype(q.dtype)


# -----------------------------------------------------------------------------
# forward (train / prefill)
# -----------------------------------------------------------------------------

def _sp_constrain(cfg, q: jax.Array) -> jax.Array:
    """Sequence-parallel attention: shard query rows over "model". Rescues
    archs whose head count doesn't divide the model axis (phi3 40H,
    minicpm3 40H, musicgen 24H on a 16-way axis), where SPMD otherwise
    replicates the (B,H,S,S) score temporaries on every device (§Perf)."""
    if not cfg.attn_sp:
        return q
    try:
        from jax.sharding import PartitionSpec as P

        axes = jax.sharding.get_abstract_mesh().axis_names
        dp = tuple(a for a in axes if a in ("pod", "data"))
        return jax.lax.with_sharding_constraint(q, P(dp, "model", None, None))
    except Exception:
        return q


def attn_apply(
    params: Dict,
    cfg,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S)
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    dt = x.dtype
    b, s, d = x.shape
    if cfg.attention_type == "mla":
        return _mla_apply(params, cfg, x, positions, return_cache)
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"].astype(dt))
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    q = _sp_constrain(cfg, q)
    if cfg.attn_impl == "chunked":
        out = _sdpa_chunked(
            cfg, q, lambda t0, c: (k[:, t0 : t0 + c], v[:, t0 : t0 + c]), None, s
        )
    else:
        mask = _mask(cfg, s, s)
        out = _sdpa(q, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, params["w_o"].astype(dt))
    cache = None
    if return_cache:
        cache = _cache_from_prefill(cfg, k, v, s)
    return y, cache


def _mla_apply(params, cfg, x, positions, return_cache):
    dt = x.dtype
    b, s, d = x.shape
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h = cfg.n_heads
    cq = x @ params["w_dq"].astype(dt)  # (B,S,rq)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    ckv = x @ params["w_dkv"].astype(dt)  # (B,S,rkv) — the latent
    kr = (x @ params["w_kr"].astype(dt))[:, :, None, :]  # (B,S,1,dr) shared key
    kr = rope(kr, positions, cfg.rope_theta)
    qc = _sp_constrain(cfg, jnp.concatenate([q_nope, q_rope], axis=-1))
    if cfg.attn_impl == "chunked":
        # build per-head K/V from the latent chunk on the fly: the full
        # (B,S,H,dn+dr) K is never materialized (§Perf memory fix)
        def kv_chunk(t0, c):
            ckv_c = ckv[:, t0 : t0 + c]
            k_nope_c = jnp.einsum("bsr,rhk->bshk", ckv_c, params["w_uk"].astype(dt))
            v_c = jnp.einsum("bsr,rhk->bshk", ckv_c, params["w_uv"].astype(dt))
            kr_c = jnp.broadcast_to(kr[:, t0 : t0 + c], (b, c, h, dr))
            return jnp.concatenate([k_nope_c, kr_c], axis=-1), v_c

        out = _sdpa_chunked(cfg, qc, kv_chunk, None, s)
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uk"].astype(dt))
        v = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uv"].astype(dt))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(kr, (b, s, h, dr))], axis=-1)
        mask = _mask(cfg, s, s)
        out = _sdpa(qc, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, params["w_o"].astype(dt))
    cache = None
    if return_cache:
        cache = {"ckv": ckv, "kr": kr[:, :, 0, :], "idx": jnp.asarray(s, jnp.int32)}
    return y, cache


# -----------------------------------------------------------------------------
# decode caches
# -----------------------------------------------------------------------------

def attn_init_cache(cfg, batch: int, max_len: int, dtype) -> Dict:
    """Abstract-init-friendly cache allocation (zeros)."""
    dh = cfg.resolved_head_dim
    if cfg.attention_type == "mla":
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
            "idx": jnp.zeros((), jnp.int32),
        }
    cap = min(max_len, cfg.window) if cfg.attention_type in ("swa", "local") and cfg.window else max_len
    if cfg.kv_quant:
        # int8 symmetric quantization, one scale per (batch, pos, kv-head):
        # halves decode's dominant HBM traffic (§Perf)
        return {
            "k": jnp.zeros((batch, cap, cfg.n_kv_heads, dh), jnp.int8),
            "v": jnp.zeros((batch, cap, cfg.n_kv_heads, dh), jnp.int8),
            "k_scale": jnp.zeros((batch, cap, cfg.n_kv_heads), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, cap, cfg.n_kv_heads), jnp.bfloat16),
            "idx": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, cap, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, cap, cfg.n_kv_heads, dh), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def _quantize_kv(x):
    """x: (B,1,H,dh) -> int8 values + bf16 scale per (B,1,H)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _cache_from_prefill(cfg, k, v, s):
    if cfg.attention_type in ("swa", "local") and cfg.window and s > cfg.window:
        k, v = k[:, -cfg.window :], v[:, -cfg.window :]
    return {"k": k, "v": v, "idx": jnp.asarray(s, jnp.int32)}


def attn_decode(
    params: Dict, cfg, x: jax.Array, cache: Dict
) -> Tuple[jax.Array, Dict]:
    """One-token decode: x (B, 1, D) against the cache."""
    dt = x.dtype
    b = x.shape[0]
    idx = cache["idx"]
    pos = jnp.full((b, 1), idx, jnp.int32)
    if cfg.attention_type == "mla":
        return _mla_decode(params, cfg, x, cache, pos)
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"].astype(dt))
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["w_k"].astype(dt))
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["w_v"].astype(dt))
    q = rope(q, pos, cfg.rope_theta, cfg.rope_fraction)
    k_new = rope(k_new, pos, cfg.rope_theta, cfg.rope_fraction)

    cap = cache["k"].shape[1]
    slot = jnp.mod(idx, cap)  # ring for swa/local; flat when cap == max_len
    if cfg.kv_quant:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        kc = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        ksc = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, slot, 0))
        vsc = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, slot, 0))
        k = (kc.astype(dt)) * ksc[..., None].astype(dt)
        v = (vc.astype(dt)) * vsc[..., None].astype(dt)
        new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc, "idx": idx + 1}
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        new_cache = None  # built below (k, v reused)

    kpos_abs = jnp.arange(cap)
    n_seen = idx + 1
    if cfg.attention_type in ("swa", "local") and cfg.window and cap == cfg.window:
        valid = kpos_abs < jnp.minimum(n_seen, cap)  # whole ring once warm
    else:
        valid = kpos_abs < n_seen
    mask = valid[None, :]  # (1, cap) -> broadcast (s_q=1)

    if cfg.decode_score_dtype == "bf16":
        # §Perf lever: keep the (B,H,cap) score tensor in bf16 with an
        # additive mask — halves the dominant decode HBM traffic; the softmax
        # reduction still accumulates in f32
        out = _sdpa_decode_bf16(q, k, v, mask)
    else:
        out = _sdpa(q, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, params["w_o"].astype(dt))
    if new_cache is None:
        new_cache = {"k": k, "v": v, "idx": idx + 1}
    return y, new_cache


def _sdpa_decode_bf16(q, k, v, mask):
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, s, hkv, rep, dh)
    scores = jnp.einsum("bshrd,bthd->bhrst", qg, k) / np.sqrt(dh)  # bf16
    addmask = jnp.where(mask[None, None, None], 0.0, NEG).astype(scores.dtype)
    scores = scores + addmask
    m = jnp.max(scores, axis=-1, keepdims=True)
    ex = jnp.exp((scores - m).astype(jnp.float32)).astype(scores.dtype)
    den = jnp.sum(ex.astype(jnp.float32), axis=-1, keepdims=True)
    p = (ex / den.astype(ex.dtype)).astype(q.dtype)
    out = jnp.einsum("bhrst,bthd->bshrd", p, v)
    return out.reshape(b, s, h, v.shape[-1])


def _mla_decode(params, cfg, x, cache, pos):
    dt = x.dtype
    b = x.shape[0]
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h = cfg.n_heads
    idx = cache["idx"]
    cq = x @ params["w_dq"].astype(dt)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, pos, cfg.rope_theta)

    ckv_new = x @ params["w_dkv"].astype(dt)  # (B,1,rkv)
    kr_new = rope((x @ params["w_kr"].astype(dt))[:, :, None, :], pos, cfg.rope_theta)[
        :, :, 0, :
    ]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, idx, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"], kr_new, (0, idx, 0))

    # absorb the up-projections into the query side (the MLA decode trick):
    # score = q_nope . (ckv W_uk) + q_rope . kr  ==  (q_nope W_uk^T) . ckv + ...
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(dt))
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, ckv)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, kr)
    scores = (s_lat + s_rope).astype(jnp.float32) / np.sqrt(dn + dr)
    valid = jnp.arange(ckv.shape[1]) < (idx + 1)
    scores = jnp.where(valid[None, None, None, :], scores, NEG)
    p = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bhst,btr->bshr", p, ckv)  # context in latent space
    out = jnp.einsum("bshr,rhk->bshk", ctx, params["w_uv"].astype(dt))
    y = jnp.einsum("bshk,hkd->bsd", out, params["w_o"].astype(dt))
    return y, {"ckv": ckv, "kr": kr, "idx": idx + 1}
