"""Architecture configuration for the assigned model zoo.

One frozen dataclass drives every architecture: a repeating ``block_pattern``
selects the sequence mixer per layer ("A" attention / "R" RG-LRU / "M" mLSTM /
"S" sLSTM), and attention/FFN variants are switched by fields. ``reduced()``
derives the CPU smoke-test configuration (same family, tiny widths).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // n_heads

    # sequence mixer layout: cycled over layers
    block_pattern: Tuple[str, ...] = ("A",)
    attention_type: str = "full"  # full | swa | local | mla
    window: Optional[int] = None  # swa / local window size

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # FFN
    ffn_type: str = "swiglu"  # swiglu | gelu | moe | none
    n_experts: int = 0
    top_k: int = 2
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    capacity_policy: str = "const"  # const | full | reflex_tlap | reflex_beta

    # recurrent blocks
    rnn_width: Optional[int] = None  # RG-LRU recurrence width (default d_model)
    conv_width: int = 4
    mlstm_chunk: int = 256

    # embeddings / frontend
    input_mode: str = "tokens"  # tokens | embeddings (vlm / audio stub)
    prefix_lm: bool = False  # paligemma: bidirectional prefix attention
    n_prefix: int = 0  # number of prefix positions (image patches)
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # stablelm-2: partial rotary (25%)
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False

    # runtime
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    ce_impl: str = "gather"  # gather | einsum (vocab-sharded CE, see §Perf)
    zero1: bool = True  # ZeRO-1 optimizer-moment sharding over "data"
    moe_impl: str = "einsum"  # einsum | gather (dispatch impl, see §Perf)
    mla_shard: str = "feature"  # feature | rank (MLA projection TP axis)
    constrain_acts: bool = False  # with_sharding_constraint on residual stream
    decode_score_dtype: str = "f32"  # f32 | bf16 decode attention scores
    kv_quant: bool = False  # int8 KV cache (per-position/head scales)
    attn_impl: str = "dense"  # dense | chunked (flash-style online softmax)
    attn_chunk: int = 2048  # KV chunk for attn_impl="chunked"
    attn_sp: bool = False  # shard query rows over "model" (sequence parallel
    # attention — the fix when heads % model != 0 leaves S x S scores replicated)
    # whether the arch supports the long_500k shape (sub-quadratic decode)
    subquadratic: bool = False

    # ---------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % self.pattern_period]

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.pattern_period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {self.pattern_period}"
        )
        return self.n_layers // self.pattern_period

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        from .lm import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from .lm import count_params_analytic

        return count_params_analytic(self, active_only=True)

    # ---------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        period = self.pattern_period
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        d_model = 64
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=period * 2,
            d_model=d_model,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=0 if self.d_ff == 0 else 96,
            vocab_size=min(self.vocab_size, 256),
            window=min(self.window, 16) if self.window else None,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_nope_dim=8 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            rnn_width=64 if self.rnn_width else None,
            mlstm_chunk=16,
            n_prefix=4 if self.n_prefix else 0,
            dtype="float32",
            remat=False,
            scan_layers=False,
        )
