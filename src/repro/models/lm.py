"""TransformerLM: assembles the 10 assigned architectures from one skeleton.

Pre-norm residual blocks; the per-layer sequence mixer is selected by
``cfg.block_pattern`` ("A" attention, "R" RG-LRU, "M" mLSTM, "S" sLSTM);
attention blocks and RG-LRU blocks are followed by an FFN (swiglu / gelu /
MoE), xLSTM blocks carry their projections inside the mixer.

Layer stacking: layers are grouped by pattern position and *stacked* along a
leading group axis, so the forward pass is a ``lax.scan`` over groups — O(1)
HLO size regardless of depth (essential to keep 40 dry-run compiles cheap) and
the idiomatic TPU pattern. ``cfg.scan_layers=False`` (smoke tests) walks the
same stacked params with a Python loop.

Modality frontends (paligemma's SigLIP, musicgen's EnCodec) are STUBS per the
assignment: ``batch["embeds"]`` carries precomputed patch/frame embeddings.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import recurrent as rec
from .layers import apply_mlp, apply_norm, dense_init, mlp_init, norm_init
from .moe import moe_apply, moe_init

__all__ = [
    "init_params",
    "abstract_params",
    "forward",
    "loss_fn",
    "init_caches",
    "decode_step",
    "count_params_analytic",
]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _has_ffn(cfg, kind: str) -> bool:
    return kind in ("A", "R") and cfg.ffn_type != "none" and cfg.d_ff > 0


# =============================================================================
# init
# =============================================================================

def _block_init(key, cfg, kind: str) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict = {"norm1": norm_init(cfg.d_model)}
    if kind == "A":
        p["mixer"] = attn.attn_init(ks[0], cfg)
    elif kind == "R":
        p["mixer"] = rec.rglru_init(ks[0], cfg)
    elif kind == "M":
        p["mixer"] = rec.mlstm_init(ks[0], cfg)
    elif kind == "S":
        p["mixer"] = rec.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, kind):
        p["norm2"] = norm_init(cfg.d_model)
        if cfg.ffn_type == "moe":
            p["ffn"] = moe_init(ks[1], cfg)
        else:
            p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_type)
    return p


def init_params(cfg, key: jax.Array) -> Dict:
    ks = jax.random.split(key, cfg.n_layers + 3)
    period, groups = cfg.pattern_period, cfg.n_groups
    layers: Dict[str, Dict] = {}
    for pos in range(period):
        kind = cfg.block_pattern[pos]
        per_group = [
            _block_init(ks[g * period + pos], cfg, kind) for g in range(groups)
        ]
        layers[str(pos)] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    params = {
        "layers": layers,
        "final_norm": norm_init(cfg.d_model),
        "embed": dense_init(ks[-1], (cfg.vocab_size, cfg.d_model), scale=0.02),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[-2], (cfg.d_model, cfg.vocab_size))
    return params


def abstract_params(cfg) -> Dict:
    """ShapeDtypeStruct tree (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# =============================================================================
# forward
# =============================================================================

def _constrain(cfg, x):
    """Optional residual-stream sharding constraint: batch over DP axes,
    features replicated — pins SPMD's propagation so attention-internal
    shardings don't leak into the residual stream (a §Perf lever)."""
    if not cfg.constrain_acts:
        return x
    from jax.sharding import PartitionSpec as P

    try:
        import jax as _jax

        axes = _jax.sharding.get_abstract_mesh().axis_names
        dp = tuple(a for a in axes if a in ("pod", "data"))
        return jax.lax.with_sharding_constraint(x, P(dp, None, None))
    except Exception:
        return x


def _apply_block(cfg, p, kind, x, positions, return_cache=False):
    x = _constrain(cfg, x)
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    if kind == "A":
        mixed, cache = attn.attn_apply(p["mixer"], cfg, h, positions, return_cache)
    elif kind == "R":
        mixed, cache = rec.rglru_apply(p["mixer"], cfg, h, positions, return_cache)
    elif kind == "M":
        mixed, cache = rec.mlstm_apply(p["mixer"], cfg, h, positions, return_cache)
    else:
        mixed, cache = rec.slstm_apply(p["mixer"], cfg, h, positions, return_cache)
    x = x + mixed
    aux = jnp.zeros((), jnp.float32)
    if _has_ffn(cfg, kind):
        h2 = apply_norm(p["norm2"], x, cfg.norm_type)
        if cfg.ffn_type == "moe":
            y, aux = moe_apply(p["ffn"], cfg, h2)
        else:
            y = apply_mlp(p["ffn"], h2, cfg.ffn_type)
        x = x + y
    return x, aux, cache


def _embed_inputs(cfg, params, batch) -> Tuple[jax.Array, jax.Array]:
    dt = _dtype(cfg)
    parts = []
    if "embeds" in batch and batch["embeds"] is not None:
        parts.append(batch["embeds"].astype(dt))
    if "tokens" in batch and batch["tokens"] is not None:
        parts.append(jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def forward(cfg, params, batch) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, aux_loss)."""
    x, positions = _embed_inputs(cfg, params, batch)

    def group_body(carry, group_params):
        x, aux = carry
        for pos in range(cfg.pattern_period):
            x, a, _ = _apply_block(
                cfg, group_params[str(pos)], cfg.block_pattern[pos], x, positions
            )
            aux = aux + a
        return (x, aux), None

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)

    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda p: p[g], params["layers"])
            (x, aux), _ = body((x, aux), gp)

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, aux


def loss_fn(cfg, params, batch) -> Tuple[jax.Array, Dict]:
    """Next-token cross entropy; labels < 0 are masked (e.g. image prefix)."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    # logits may cover prefix positions that have no labels: align to the tail
    s_lab = labels.shape[1]
    logits = logits[:, -s_lab:]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    if cfg.ce_impl == "einsum":
        # vocab-sharded-friendly CE: contract the vocab axis locally (one-hot
        # einsum + logsumexp partial reductions) instead of gathering logits
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
        target = jnp.einsum("bsv,bsv->bs", logits, onehot)
        nll = lse - target
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


# =============================================================================
# decode
# =============================================================================

def _mixer_cache_init(cfg, kind, batch, max_len, dtype):
    if kind == "A":
        return attn.attn_init_cache(cfg, batch, max_len, dtype)
    if kind == "R":
        return rec.rglru_init_cache(cfg, batch, max_len, dtype)
    if kind == "M":
        return rec.mlstm_init_cache(cfg, batch, max_len, dtype)
    return rec.slstm_init_cache(cfg, batch, max_len, dtype)


def init_caches(cfg, batch: int, max_len: int) -> Dict:
    """Stacked (per pattern position, leading group axis) decode caches."""
    dt = _dtype(cfg)
    caches: Dict[str, Dict] = {}
    for pos in range(cfg.pattern_period):
        kind = cfg.block_pattern[pos]
        one = _mixer_cache_init(cfg, kind, batch, max_len, dt)
        caches[str(pos)] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape), one
        )
    return caches


def _decode_block(cfg, p, kind, x, cache):
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    if kind == "A":
        mixed, new = attn.attn_decode(p["mixer"], cfg, h, cache)
    elif kind == "R":
        mixed, new = rec.rglru_decode(p["mixer"], cfg, h, cache)
    elif kind == "M":
        mixed, new = rec.mlstm_decode(p["mixer"], cfg, h, cache)
    else:
        mixed, new = rec.slstm_decode(p["mixer"], cfg, h, cache)
    x = x + mixed
    if _has_ffn(cfg, kind):
        h2 = apply_norm(p["norm2"], x, cfg.norm_type)
        if cfg.ffn_type == "moe":
            y, _ = moe_apply(p["ffn"], cfg, h2)
        else:
            y = apply_mlp(p["ffn"], h2, cfg.ffn_type)
        x = x + y
    return x, new


def decode_step(cfg, params, caches, batch) -> Tuple[jax.Array, Dict]:
    """One-token decode. batch: {"tokens": (B, 1)} or {"embeds": (B, 1, D)}.

    Returns (logits (B, 1, V), new caches).
    """
    x, _ = _embed_inputs(cfg, params, batch)

    def group_body(x, scans):
        gp, gc = scans
        new_caches = {}
        for pos in range(cfg.pattern_period):
            x, nc = _decode_block(
                cfg, gp[str(pos)], cfg.block_pattern[pos], x, gc[str(pos)]
            )
            new_caches[str(pos)] = nc
        return x, new_caches

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(group_body, x, (params["layers"], caches))
    else:
        outs = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda p: p[g], params["layers"])
            gc = jax.tree.map(lambda c: c[g], caches)
            x, nc = group_body(x, (gp, gc))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, new_caches


# =============================================================================
# accounting
# =============================================================================

def count_params_analytic(cfg, active_only: bool = False) -> int:
    tree = abstract_params(cfg)

    def leaf_count(path, leaf):
        n = 1
        for d in leaf.shape:
            n *= d
        joined = "/".join(str(p) for p in path)
        if active_only and cfg.ffn_type == "moe" and (
            "w_gate" in joined or "w_up" in joined or "w_down" in joined
        ) and "dense_residual" not in joined and "ffn" in joined:
            n = n * cfg.top_k // max(cfg.n_experts, 1)
        return n

    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        total += leaf_count([getattr(p, "key", getattr(p, "idx", "")) for p in path], leaf)
    return total
