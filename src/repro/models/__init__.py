from .config import ArchConfig  # noqa: F401
from .lm import (  # noqa: F401
    abstract_params,
    decode_step,
    forward,
    init_caches,
    init_params,
    loss_fn,
)
