"""Recurrent sequence mixers: RG-LRU (RecurrentGemma), mLSTM and sLSTM (xLSTM).

TPU adaptation notes (DESIGN.md §3/§4): RG-LRU and the mLSTM cross-chunk state
are first-order linear recurrences h_t = a_t * h_{t-1} + b_t — we evaluate
them with ``jax.lax.associative_scan`` (log-depth, MXU-friendly) instead of a
sequential loop; the sLSTM's nonlinear recurrence is inherently sequential and
uses ``lax.scan`` (this is faithful: the xLSTM paper itself notes sLSTM is not
parallelizable). mLSTM training uses the stabilized quadratic form (as in the
xLSTM paper's kernels); decode uses the O(1)/token matrix-memory recurrence —
which is what makes xlstm-1.3b long_500k-capable.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init

__all__ = [
    "rglru_init",
    "rglru_apply",
    "rglru_init_cache",
    "rglru_decode",
    "mlstm_init",
    "mlstm_apply",
    "mlstm_init_cache",
    "mlstm_decode",
    "slstm_init",
    "slstm_apply",
    "slstm_init_cache",
    "slstm_decode",
]

C_RGLRU = 8.0


# =============================================================================
# RG-LRU recurrent block (RecurrentGemma)
# =============================================================================

def rglru_init(key, cfg) -> Dict:
    d = cfg.d_model
    dr = cfg.rnn_width or d
    ks = jax.random.split(key, 7)
    lam = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    return {
        "w_gate_branch": dense_init(ks[0], (d, dr)),
        "w_x_branch": dense_init(ks[1], (d, dr)),
        "conv_w": dense_init(ks[2], (cfg.conv_width, dr), scale=0.1),
        "w_input_gate": dense_init(ks[3], (dr, dr)),
        "w_rec_gate": dense_init(ks[4], (dr, dr)),
        # Lambda parametrized so sigmoid(lam_logit) = lam
        "lam_logit": jnp.log(lam) - jnp.log1p(-lam),
        "w_out": dense_init(ks[6], (dr, d)),
    }


def _rglru_core(params, z, h0):
    """z: (B, S, Dr) post-conv; returns (h, h_last)."""
    dt = z.dtype
    zf = z.astype(jnp.float32)
    r = jax.nn.sigmoid(zf @ params["w_rec_gate"])
    i = jax.nn.sigmoid(zf @ params["w_input_gate"])
    log_a = -C_RGLRU * jax.nn.softplus(params["lam_logit"]) * r  # (B,S,Dr) <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * zf)
    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0.astype(jnp.float32)[:, None], b], axis=1)

    def combine(l, rr):
        al, bl = l
        ar, br = rr
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(dt), h[:, -1].astype(dt)


def _causal_conv(z, w, state=None):
    """Depthwise causal conv, width K. state: (B, K-1, Dr) history or None."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(z[:, : k - 1])
    else:
        pad = state
    zp = jnp.concatenate([pad, z], axis=1)
    out = sum(zp[:, i : i + z.shape[1]] * w[i] for i in range(k))
    return out, zp[:, -(k - 1) :]


def rglru_apply(params, cfg, x, positions, return_cache=False):
    dt = x.dtype
    gate = jax.nn.gelu((x @ params["w_gate_branch"].astype(dt)).astype(jnp.float32)).astype(dt)
    z = x @ params["w_x_branch"].astype(dt)
    z, conv_state = _causal_conv(z, params["conv_w"].astype(dt))
    h, h_last = _rglru_core(params, z, None)
    y = (gate * h) @ params["w_out"].astype(dt)
    cache = None
    if return_cache:
        cache = {"h": h_last, "conv": conv_state, "idx": jnp.asarray(x.shape[1], jnp.int32)}
    return y, cache


def rglru_init_cache(cfg, batch, max_len, dtype):
    dr = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, dr), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def rglru_decode(params, cfg, x, cache):
    dt = x.dtype
    gate = jax.nn.gelu((x @ params["w_gate_branch"].astype(dt)).astype(jnp.float32)).astype(dt)
    z = x @ params["w_x_branch"].astype(dt)
    z, conv_state = _causal_conv(z, params["conv_w"].astype(dt), cache["conv"])
    h, h_last = _rglru_core(params, z, cache["h"])
    y = (gate * h) @ params["w_out"].astype(dt)
    return y, {"h": h_last, "conv": conv_state, "idx": cache["idx"] + 1}


# =============================================================================
# mLSTM (xLSTM): matrix memory, exp gating
# =============================================================================

def mlstm_init(key, cfg) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d)),
        "w_q": dense_init(ks[1], (d, h, dh)),
        "w_k": dense_init(ks[2], (d, h, dh)),
        "w_v": dense_init(ks[3], (d, h, dh)),
        "w_i": dense_init(ks[4], (d, h), scale=0.01),
        "w_f": dense_init(ks[5], (d, h), scale=0.01),
        "b_f": jnp.full((h,), 3.0, jnp.float32),  # forget-gate bias ~ keep
        "w_down": dense_init(ks[6], (d, d)),
    }


def mlstm_apply(params, cfg, x, positions, return_cache=False):
    """Stabilized quadratic (training) form."""
    dt = x.dtype
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    up = x @ params["w_up"].astype(dt)
    u, gate = up[..., :d], up[..., d:]
    q = jnp.einsum("bsd,dhk->bshk", u, params["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", u, params["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", u, params["w_v"].astype(dt))
    uf = u.astype(jnp.float32)
    log_i = uf @ params["w_i"]  # (B,S,H)
    log_f = jax.nn.log_sigmoid(uf @ params["w_f"] + params["b_f"])
    cf = jnp.cumsum(log_f, axis=1)  # F_t
    # D[t, s] = F_t - F_s + log_i_s  (s <= t)
    dmat = cf[:, :, None, :] - cf[:, None, :, :] + log_i[:, None, :, :]
    tpos = jnp.arange(s)
    causal = tpos[:, None] >= tpos[None, :]
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)  # (B,S,1,H)
    w = jnp.exp(dmat - m)  # (B,S,S,H)
    scores = jnp.einsum("bshk,bthk->bsth", q, k).astype(jnp.float32) / np.sqrt(dh)
    ww = w * scores
    num = jnp.einsum("bsth,bthk->bshk", ww.astype(dt), v)
    den = jnp.abs(jnp.sum(ww, axis=2))  # (B,S,H)
    den = jnp.maximum(den, jnp.exp(-m[:, :, 0, :]))
    out = num / den[..., None].astype(dt)
    mixed = out.reshape(b, s, d)
    y = (mixed * jax.nn.silu(gate.astype(jnp.float32)).astype(dt)) @ params[
        "w_down"
    ].astype(dt)
    cache = None
    if return_cache:
        cache = _mlstm_state_from_seq(params, cfg, u, q, k, v, log_i, log_f)
    return y, cache


def _mlstm_state_from_seq(params, cfg, u, q, k, v, log_i, log_f):
    """Fold a whole prefix into the recurrent (C, n, m) state (for prefill)."""
    b, s, h, dh = k.shape
    cf = jnp.cumsum(log_f, axis=1)
    ftot = cf[:, -1]  # (B,H)
    # weight of step t in the final state: exp(F_S - F_t + log_i_t - m)
    logw = ftot[:, None] - cf + log_i  # (B,S,H)
    m = jnp.maximum(jnp.max(logw, axis=1), 0.0)  # (B,H); 0 guards the n floor
    w = jnp.exp(logw - m[:, None])
    c = jnp.einsum("bsh,bshk,bshl->bhkl", w.astype(k.dtype), k, v)
    n = jnp.einsum("bsh,bshk->bhk", w.astype(k.dtype), k)
    return {"c": c, "n": n, "m": m, "idx": jnp.asarray(s, jnp.int32)}


def mlstm_init_cache(cfg, batch, max_len, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    return {
        "c": jnp.zeros((batch, h, dh, dh), dtype),
        "n": jnp.zeros((batch, h, dh), dtype),
        "m": jnp.zeros((batch, h), jnp.float32),
        "idx": jnp.zeros((), jnp.int32),
    }


def mlstm_decode(params, cfg, x, cache):
    dt = x.dtype
    b, s, d = x.shape  # s == 1
    h = cfg.n_heads
    dh = d // h
    up = x @ params["w_up"].astype(dt)
    u, gate = up[..., :d], up[..., d:]
    q = jnp.einsum("bsd,dhk->bshk", u, params["w_q"].astype(dt))[:, 0]
    k = jnp.einsum("bsd,dhk->bshk", u, params["w_k"].astype(dt))[:, 0]
    v = jnp.einsum("bsd,dhk->bshk", u, params["w_v"].astype(dt))[:, 0]
    uf = u[:, 0].astype(jnp.float32)
    log_i = uf @ params["w_i"]  # (B,H)
    log_f = jax.nn.log_sigmoid(uf @ params["w_f"] + params["b_f"])
    m_new = jnp.maximum(log_f + cache["m"], log_i)
    fs = jnp.exp(log_f + cache["m"] - m_new).astype(dt)  # (B,H)
    is_ = jnp.exp(log_i - m_new).astype(dt)
    c = cache["c"] * fs[..., None, None] + is_[..., None, None] * jnp.einsum(
        "bhk,bhl->bhkl", k, v
    )
    n = cache["n"] * fs[..., None] + is_[..., None] * k
    num = jnp.einsum("bhkl,bhk->bhl", c, q)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q))
    den = jnp.maximum(den, jnp.exp(-m_new).astype(dt))
    out = (num / den[..., None]).reshape(b, 1, d)
    y = (out * jax.nn.silu(gate.astype(jnp.float32)).astype(dt)) @ params["w_down"].astype(dt)
    return y, {"c": c, "n": n, "m": m_new, "idx": cache["idx"] + 1}


# =============================================================================
# sLSTM (xLSTM): scalar memory, strictly sequential (lax.scan)
# =============================================================================

def slstm_init(key, cfg) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 9)
    p = {"w_out": dense_init(ks[8], (d, d))}
    for i, g in enumerate(["z", "i", "f", "o"]):
        p[f"w_{g}"] = dense_init(ks[i], (d, h, dh))
        p[f"r_{g}"] = dense_init(ks[4 + i], (h, dh, dh), scale=0.3 / np.sqrt(dh))
    return p


def _slstm_step(params, carry, xt):
    """xt: (B, H, Dh) pre-projected inputs for the 4 gates stacked later."""
    c, n, hprev, m = carry
    wz, wi, wf, wo = xt
    f32 = jnp.float32
    rz = jnp.einsum("bhk,hkl->bhl", hprev, params["r_z"]).astype(f32)
    ri = jnp.einsum("bhk,hkl->bhl", hprev, params["r_i"]).astype(f32)
    rf = jnp.einsum("bhk,hkl->bhl", hprev, params["r_f"]).astype(f32)
    ro = jnp.einsum("bhk,hkl->bhl", hprev, params["r_o"]).astype(f32)
    z = jnp.tanh(wz.astype(f32) + rz)
    log_i = wi.astype(f32) + ri
    log_f = jax.nn.log_sigmoid(wf.astype(f32) + rf)
    o = jax.nn.sigmoid(wo.astype(f32) + ro)
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = jnp.maximum(f_s * n + i_s, jnp.exp(-m_new))
    h_new = o * (c_new / n_new)
    return (c_new, n_new, h_new.astype(hprev.dtype), m_new), h_new


def slstm_apply(params, cfg, x, positions, return_cache=False):
    dt = x.dtype
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    gates = [
        jnp.einsum("bsd,dhk->sbhk", x, params[f"w_{g}"].astype(dt))
        for g in ["z", "i", "f", "o"]
    ]
    f32 = jnp.float32
    carry0 = (
        jnp.zeros((b, h, dh), f32),
        jnp.ones((b, h, dh), f32),
        jnp.zeros((b, h, dh), dt),
        jnp.zeros((b, h, dh), f32),
    )
    carry, hs = jax.lax.scan(
        lambda c, xt: _slstm_step(params, c, xt), carry0, tuple(gates)
    )
    hs = jnp.transpose(hs, (1, 0, 2, 3)).reshape(b, s, d).astype(dt)
    y = hs @ params["w_out"].astype(dt)
    cache = None
    if return_cache:
        c, n, hl, m = carry
        cache = {"c": c, "n": n, "h": hl, "m": m, "idx": jnp.asarray(s, jnp.int32)}
    return y, cache


def slstm_init_cache(cfg, batch, max_len, dtype):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return {
        "c": jnp.zeros((batch, h, dh), jnp.float32),
        "n": jnp.ones((batch, h, dh), jnp.float32),
        "h": jnp.zeros((batch, h, dh), dtype),
        "m": jnp.zeros((batch, h, dh), jnp.float32),
        "idx": jnp.zeros((), jnp.int32),
    }


def slstm_decode(params, cfg, x, cache):
    dt = x.dtype
    b, s, d = x.shape
    gates = tuple(
        jnp.einsum("bsd,dhk->bhk", x, params[f"w_{g}"].astype(dt))
        for g in ["z", "i", "f", "o"]
    )
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, hl, m), hnew = _slstm_step(params, carry, gates)
    y = hnew.astype(dt).reshape(b, 1, d) @ params["w_out"].astype(dt)
    return y, {"c": c, "n": n, "h": hl, "m": m, "idx": cache["idx"] + 1}
