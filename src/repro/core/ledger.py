"""Static communication-cost ledger for the simulated 3-party protocols.

The paper's evaluation is communication-bound ("the expectation is that runtime
will be dominated by communication cost", §4.5), so alongside the bit-exact
share simulation we keep an *analytic* ledger of what a real deployment would
send: for every protocol primitive we record the number of synchronous
communication rounds and the bytes each party transmits.

Costs depend only on static shapes, so they can be captured by tracing: the
Python body of every protocol runs under ``jax.eval_shape`` (or eagerly / under
``jit`` tracing) and logs as it goes. Use::

    with CommLedger() as led:
        jax.eval_shape(protocol_fn, *abstract_args)
    print(led.tally())

When no ledger is active, logging is a no-op, so jitted hot paths pay nothing.

``fused(rounds=r)`` coalesces the entries logged inside it into a single entry
with ``r`` rounds (used by circuits whose constituent ANDs run in parallel
within a round — e.g. the 5-level equality tree logs 5 rounds, not 5×#words).

Exchange boundaries (networked mode)
------------------------------------
In the multi-party runtime (DESIGN.md §16) every ledger entry that lands in
``CommLedger.entries`` IS a real message exchange: a party process installs an
*exchange driver* (:func:`exchange_scope`) and the ledger calls it exactly
once per top-level entry — per :meth:`CommLedger.log` call outside ``fused()``
and once per merged ``fused()`` block — with that entry's op, rounds, byte
count, and (when the protocol layer provided one via ``payload=``) the share
array being exchanged. Wire bytes == ledger bytes per op by construction,
because the driver sends exactly ``bytes_per_party`` bytes per entry. When no
driver is installed (single-process mode, the default and the test oracle),
logging stays a pure tally and jitted hot paths pay nothing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections import defaultdict
from typing import Dict, List, Optional

__all__ = [
    "CommLedger",
    "log_comm",
    "active_ledger",
    "fused_scope",
    "measure_comm",
    "batched_tally",
    "exchange_scope",
    "active_exchange",
]

_STATE = threading.local()


def _stack() -> List["CommLedger"]:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


def active_exchange():
    """The exchange driver installed on this thread, or None (single-process
    mode). The driver is any object with an
    ``exchange(op, rounds, nbytes, payload)`` method."""
    return getattr(_STATE, "exchange", None)


@contextlib.contextmanager
def exchange_scope(driver):
    """Install ``driver`` as this thread's exchange boundary for the duration
    of the block. Every top-level ledger entry logged inside becomes one
    ``driver.exchange(...)`` call. Must wrap eager execution only — jit
    re-executions skip the Python body and would skip exchanges with it
    (the networked runtime pins ``jit_ops=False`` for exactly this reason)."""
    prev = getattr(_STATE, "exchange", None)
    _STATE.exchange = driver
    try:
        yield driver
    finally:
        _STATE.exchange = prev


@dataclasses.dataclass
class CommEntry:
    op: str
    rounds: int
    bytes_per_party: int
    count: int = 1


class CommLedger:
    """Accumulates (rounds, bytes/party) per protocol op."""

    def __init__(self) -> None:
        self.entries: List[CommEntry] = []
        self._fuse_depth = 0
        self._fuse_buffer: List[CommEntry] = []

    # -- context management -------------------------------------------------
    def __enter__(self) -> "CommLedger":
        _stack().append(self)
        return self

    def __exit__(self, *exc) -> None:
        top = _stack().pop()
        assert top is self, "CommLedger stack corrupted"

    # -- logging -------------------------------------------------------------
    @staticmethod
    def _append(target: List[CommEntry], entry: CommEntry) -> None:
        """Append, coalescing runs of identical ops: a loop that logs the same
        (op, rounds, bytes) N times yields ONE entry with ``count=N`` instead
        of N entries — ``count`` is the real repetition count, so ``by_op()``
        reports true call counts and total costs, not log-entry counts."""
        if target:
            last = target[-1]
            if (
                last.op == entry.op
                and last.rounds == entry.rounds
                and last.bytes_per_party == entry.bytes_per_party
            ):
                last.count += entry.count
                return
        target.append(entry)

    def log(
        self, op: str, rounds: int, bytes_per_party: int, payload=None
    ) -> None:
        entry = CommEntry(op, rounds, bytes_per_party)
        if self._fuse_depth > 0:
            # inside a fused round block the constituent messages ride one
            # exchange, fired (payload-less) when the merged entry lands
            self._append(self._fuse_buffer, entry)
        else:
            drv = active_exchange()
            if drv is not None:
                drv.exchange(op, rounds, bytes_per_party, payload)
            self._append(self.entries, entry)

    @contextlib.contextmanager
    def fused(self, op: str, rounds: int):
        """Coalesce nested logs into one entry with the given round count."""
        self._fuse_depth += 1
        mark = len(self._fuse_buffer)
        try:
            yield
        finally:
            self._fuse_depth -= 1
            sub = self._fuse_buffer[mark:]
            del self._fuse_buffer[mark:]
            total_bytes = sum(e.bytes_per_party * e.count for e in sub)
            entry = CommEntry(op, rounds, total_bytes)
            if self._fuse_depth > 0:
                self._append(self._fuse_buffer, entry)
            else:
                drv = active_exchange()
                if drv is not None:
                    drv.exchange(op, rounds, total_bytes, None)
                self._append(self.entries, entry)

    # -- reporting -----------------------------------------------------------
    def tally(self) -> Dict[str, float]:
        total_bytes = sum(e.bytes_per_party * e.count for e in self.entries)
        total_rounds = sum(e.rounds * e.count for e in self.entries)
        return {"bytes_per_party": total_bytes, "rounds": total_rounds}

    def by_op(self) -> Dict[str, Dict[str, int]]:
        agg: Dict[str, Dict[str, int]] = defaultdict(
            lambda: {"rounds": 0, "bytes_per_party": 0, "calls": 0}
        )
        for e in self.entries:
            agg[e.op]["rounds"] += e.rounds * e.count
            agg[e.op]["bytes_per_party"] += e.bytes_per_party * e.count
            agg[e.op]["calls"] += e.count
        return dict(agg)

    def reset(self) -> None:
        self.entries.clear()


def active_ledger() -> Optional[CommLedger]:
    stack = _stack()
    return stack[-1] if stack else None


def log_comm(op: str, rounds: int, bytes_per_party: int, payload=None) -> None:
    """Log one sync point. ``payload`` (optional) is the canonical 3-share
    array being exchanged at this boundary — ignored by the tally, consumed
    by a networked exchange driver to ship (and cross-verify) the real share
    slice instead of deterministic filler."""
    led = active_ledger()
    if led is not None:
        led.log(op, rounds, bytes_per_party, payload)


def fused_scope(op: str, rounds: int):
    """``active_ledger().fused(...)`` or a no-op when no ledger is active —
    the common pattern of every circuit that batches its gates into rounds."""
    led = active_ledger()
    if led is None:
        return contextlib.nullcontext()
    return led.fused(op, rounds)


def batched_tally(per_slot: Dict[str, float], slots: int) -> Dict[str, float]:
    """Physical cost of a ``slots``-wide batched launch, given the per-slot
    tally the trace logged once.

    A vmapped protocol traces its Python body a single time with per-slot
    shapes, so the active ledger records what ONE slot sends. Physically,
    every slot's share bytes are still transmitted (bytes scale by ``slots``),
    but the synchronous round trips are shared across the whole batch — the
    messages of all slots ride the same exchanges. That round amortization is
    the point of query admission batching (DESIGN.md §11): K queries pay one
    query's latency-bound round count.
    """
    return {
        "bytes_per_party": per_slot.get("bytes_per_party", 0) * slots,
        "rounds": per_slot.get("rounds", 0),
    }


def measure_comm(fn, *args, **kwargs) -> Dict[str, float]:
    """Capture the communication profile of ``fn`` without running compute.

    Uses ``jax.eval_shape`` so only the Python body (and hence ledger logging)
    executes; no FLOPs are spent. Shapes fully determine cost.
    """
    import jax

    with CommLedger() as led:
        jax.eval_shape(lambda *a: fn(*a, **kwargs), *args)
    return led.tally()
