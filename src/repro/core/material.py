"""Ambient correlated-randomness material source (offline/online split).

Every piece of correlated randomness this codebase consumes is a *pure
function* of (pair-key content, derivation op, static args): PRF folds,
replicated draws, zero-sharings, and shuffle-hop permutations are all
deterministic derivations from a :class:`~repro.core.prf.PRFSetup`. A
material source is therefore a **cache in front of the derivation
primitives**: ``fetch(op, pair_keys, args, compute)`` either serves a
precomputed value (offline pool hit) or falls through to ``compute()`` —
the exact on-demand derivation — so pooled and on-demand streams are
bit-identical by construction. There is no second randomness path to
keep in sync.

The active source is ambient (thread-local), installed by
:func:`material_scope` around an engine execution; call sites in
``core/prf.py`` and ``core/shuffle.py`` consult it via
:func:`active_if_concrete`, which steps aside whenever any input is a
jax Tracer: under a jit trace the derivation is baked into the compiled
program (and replayed by XLA, not Python), so there is nothing to
intercept — the pool accelerates the *eager* dispatch path, which is
where stateful operators (Resize) and jit_ops=False engines pay for
their randomness. Under an eager ``vmap`` the closed-over pair keys are
concrete, so batched executions consult the pool normally.

Content addressing: a fetch key is ``(op, pair_keys.tobytes(), args)``.
Keying on key *content* (rather than on how the keys were derived) makes
serving a stale or mismatched entry structurally impossible — a pool
entry can only ever be found by the exact derivation that produced it.
See ``repro/offline`` for the pool, planner, and provisioner built on
this hook, and DESIGN.md §15 for the ownership/fallback rules.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "MaterialSource",
    "active_source",
    "active_if_concrete",
    "material_scope",
    "content_key",
]

_STATE = threading.local()


class MaterialSource:
    """Interface a correlated-randomness cache implements.

    ``fetch`` must return a value bit-identical to ``compute()`` — the
    only freedom an implementation has is *when* that value was computed
    (offline vs on the critical path). Implementations also expose
    monotone ``hits`` / ``misses`` counters so the engine can attribute
    hot-vs-cold per plan node.
    """

    hits: int = 0
    misses: int = 0

    def fetch(
        self,
        op: str,
        pair_keys: jax.Array,
        args: Tuple[Any, ...],
        compute: Callable[[], jax.Array],
    ) -> jax.Array:
        raise NotImplementedError


def active_source() -> Optional[MaterialSource]:
    """The source installed by the innermost :func:`material_scope`, or None."""
    return getattr(_STATE, "source", None)


def active_if_concrete(*arrays) -> Optional[MaterialSource]:
    """The active source, unless any input is a jax Tracer (jit/grad trace):
    traced derivations compile into the program and must not be intercepted."""
    src = getattr(_STATE, "source", None)
    if src is None:
        return None
    for a in arrays:
        if isinstance(a, jax.core.Tracer):
            return None
    return src


@contextlib.contextmanager
def material_scope(source: Optional[MaterialSource]):
    """Install ``source`` as the ambient material source for this thread."""
    prev = getattr(_STATE, "source", None)
    _STATE.source = source
    try:
        yield source
    finally:
        _STATE.source = prev


def content_key(op: str, pair_keys, args: Tuple[Any, ...]) -> tuple:
    """Canonical content-addressed key for one derivation event."""
    return (op, np.asarray(pair_keys).tobytes(), args)
