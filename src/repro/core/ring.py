"""Modular ring helpers for secret sharing.

Reflex (and its MP-SPDZ substrate) computes over the ring Z_{2^k}. We default to
k = 32 (``uint32``) which wraps naturally in JAX/XLA without needing
``jax_enable_x64``; k = 64 is available when x64 is enabled.

All shares are stored in the ring dtype; arithmetic wraps mod 2^k by
construction, and boolean (XOR) sharing packs k bits per lane.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = ["Ring", "RING32", "RING64", "default_ring"]


@dataclasses.dataclass(frozen=True)
class Ring:
    """The ring Z_{2^bits} used for both arithmetic and boolean sharing."""

    bits: int

    @property
    def dtype(self):
        return jnp.uint32 if self.bits == 32 else jnp.uint64

    @property
    def np_dtype(self):
        return np.uint32 if self.bits == 32 else np.uint64

    @property
    def mask(self) -> int:
        return (1 << self.bits) - 1

    @property
    def bytes(self) -> int:
        return self.bits // 8

    @property
    def signbit(self) -> int:
        return 1 << (self.bits - 1)

    def wrap(self, x) -> jnp.ndarray:
        """Cast an integer array into the ring (wrapping)."""
        return jnp.asarray(x).astype(self.dtype)

    def to_signed(self, x: jnp.ndarray) -> jnp.ndarray:
        """Interpret ring elements as signed two's-complement integers."""
        sdtype = jnp.int32 if self.bits == 32 else jnp.int64
        return x.astype(sdtype)

    def const(self, value: int, shape=()) -> jnp.ndarray:
        return jnp.full(shape, value & self.mask, dtype=self.dtype)


RING32 = Ring(32)
RING64 = Ring(64)


def default_ring() -> Ring:
    return RING32
