"""Pluggable noise-generation strategies for the Resizer (§4.3).

A strategy answers three questions:

* ``sample_eta(key, N, T)`` — a noise budget (filler-tuple count) for the
  *sequential* addition design,
* ``sample_p(key, N, T)`` — a coin-toss success probability for the
  *parallel* design (Beta samples p directly and never needs T; others derive
  p = clip(eta / (N - T), 0, 1)),
* moments — mean/variance of eta, used by the CRT metric (§3.3) and by the
  planner's cost model.

Implemented strategies: truncated Laplace (Shrinkwrap's (eps, delta)-DP
noise), Beta / Beta-Binomial, Uniform, Constant, Reveal (trim everything ==
SecretFlow-SCQL), and NoTrim (fully oblivious).

Secrecy note (documented in DESIGN.md): in a real deployment the realized
eta / p must remain hidden from the computing parties (otherwise S - eta
reveals T); the draw is made from joint randomness and consumed inside MPC.
In this simulation the realization is materialized host-side to drive the
protocol, and the runtime clip eta <- min(eta, N - T) uses the plaintext T
exactly where the paper's runtime adjustment does.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import numpy as np

__all__ = [
    "NoiseStrategy",
    "TruncatedLaplace",
    "BetaNoise",
    "UniformNoise",
    "ConstantNoise",
    "RevealNoise",
    "NoTrim",
    "shrinkwrap_default",
]


class NoiseStrategy:
    name: str = "base"

    # -- sampling -------------------------------------------------------------
    def sample_eta(self, key: jax.Array, n: int, t: int) -> int:
        raise NotImplementedError

    def sample_p(self, key: jax.Array, n: int, t: int) -> float:
        """Success probability for the parallel (Binomial) design."""
        free = max(n - t, 1)
        eta = self.sample_eta(key, n, t)
        return float(np.clip(eta / free, 0.0, 1.0))

    # -- moments of eta (for CRT / planning) ----------------------------------
    def mean(self, n: int, t: int) -> float:
        raise NotImplementedError

    def var(self, n: int, t: int) -> float:
        raise NotImplementedError

    def var_parallel(self, n: int, t: int) -> float:
        """Var(S) when this strategy drives the parallel coin-toss design.

        S = T + Binomial(N - T, eta/(N - T)). Law of total variance:
        Var(S) = E[eta] - E[eta^2]/(N - T) + Var(eta).
        """
        free = max(n - t, 1)
        m, v = self.mean(n, t), self.var(n, t)
        e2 = v + m * m
        return max(m - e2 / free + v, 0.0)


# -----------------------------------------------------------------------------
# Truncated Laplace — Shrinkwrap's DP noise
# -----------------------------------------------------------------------------

@dataclasses.dataclass
class TruncatedLaplace(NoiseStrategy):
    """Lap(mu, b) truncated to [0, inf), b = sensitivity / eps,
    mu = -b * ln(2 * delta) so that the untruncated mass below zero is delta
    (Shrinkwrap's calibration; the paper's example eps=0.5, delta=5e-5,
    sens=1000 gives mean ~ 18.4k, matching the quoted ~18336)."""

    eps: float = 0.5
    delta: float = 0.00005
    sensitivity: float = 1.0
    name: str = "tlap"
    # moments cache: the grid integration costs 200k points and mean()/var()
    # are called in loops by the cost model and the privacy accountant.
    _moments_cache: Optional[Tuple[float, float]] = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )
    # how many grid integrations this instance has run (regression-tested:
    # repeated mean()/var() calls must not re-integrate)
    integrations: int = dataclasses.field(
        default=0, init=False, repr=False, compare=False
    )

    @property
    def b(self) -> float:
        return self.sensitivity / self.eps

    @property
    def mu(self) -> float:
        return -self.b * math.log(2.0 * self.delta)

    # Laplace CDF / inverse, truncated to [0, inf)
    def _cdf0(self) -> float:
        # F(0) for Lap(mu, b); mu > 0 in all sane configs
        return 0.5 * math.exp(-self.mu / self.b)

    def sample_eta(self, key: jax.Array, n: int, t: int) -> int:
        u = float(jax.random.uniform(key, minval=self._cdf0(), maxval=1.0))
        x = self._inv_cdf(u)
        return int(np.clip(round(x), 0, max(n - t, 0)))

    def _inv_cdf(self, u: float) -> float:
        if u <= 0.5:
            return self.mu + self.b * math.log(2.0 * u)
        return self.mu - self.b * math.log(2.0 * (1.0 - u))

    def _moments(self) -> Tuple[float, float]:
        # numeric moments of the truncated distribution (grid integration),
        # computed once per instance — parameters are set at construction
        if self._moments_cache is not None:
            return self._moments_cache
        self.integrations += 1
        lo, hi = 0.0, self.mu + 40.0 * self.b
        xs = np.linspace(lo, hi, 200001)
        pdf = np.exp(-np.abs(xs - self.mu) / self.b) / (2.0 * self.b)
        z = np.trapezoid(pdf, xs)
        pdf /= z
        m = float(np.trapezoid(xs * pdf, xs))
        v = float(np.trapezoid((xs - m) ** 2 * pdf, xs))
        self._moments_cache = (m, v)
        return m, v

    def mean(self, n: int, t: int) -> float:
        return self._moments()[0]

    def var(self, n: int, t: int) -> float:
        return self._moments()[1]


# -----------------------------------------------------------------------------
# Beta — samples p directly (Beta-Binomial when combined with parallel design)
# -----------------------------------------------------------------------------

@dataclasses.dataclass
class BetaNoise(NoiseStrategy):
    alpha: float = 2.0
    beta: float = 6.0
    name: str = "beta"

    def sample_p(self, key: jax.Array, n: int, t: int) -> float:
        return float(jax.random.beta(key, self.alpha, self.beta))

    def sample_eta(self, key: jax.Array, n: int, t: int) -> int:
        # scaled-Beta variant for the sequential design (§4.3)
        p = self.sample_p(key, n, t)
        return int(round(p * max(n - t, 0)))

    def mean(self, n: int, t: int) -> float:
        return self.alpha / (self.alpha + self.beta) * max(n - t, 0)

    def var(self, n: int, t: int) -> float:
        a, b = self.alpha, self.beta
        free = max(n - t, 0)
        return a * b / ((a + b) ** 2 * (a + b + 1)) * free**2

    def var_parallel(self, n: int, t: int) -> float:
        # Beta-Binomial(n=N-T, alpha, beta) closed form
        a, b, free = self.alpha, self.beta, max(n - t, 0)
        if free == 0:
            return 0.0
        return free * a * b * (a + b + free) / ((a + b) ** 2 * (a + b + 1))


@dataclasses.dataclass
class UniformNoise(NoiseStrategy):
    lo_frac: float = 0.0
    hi_frac: float = 1.0
    name: str = "uniform"

    def sample_eta(self, key: jax.Array, n: int, t: int) -> int:
        free = max(n - t, 0)
        lo, hi = self.lo_frac * free, self.hi_frac * free
        return int(jax.random.uniform(key, minval=lo, maxval=hi))

    def mean(self, n: int, t: int) -> float:
        free = max(n - t, 0)
        return 0.5 * (self.lo_frac + self.hi_frac) * free

    def var(self, n: int, t: int) -> float:
        free = max(n - t, 0)
        return ((self.hi_frac - self.lo_frac) * free) ** 2 / 12.0


@dataclasses.dataclass
class ConstantNoise(NoiseStrategy):
    """Deterministic filler count (fraction of N). Zero variance — CRT = 1
    round: a degenerate strategy useful as a caveat demo (§5.4)."""

    frac: float = 0.1
    name: str = "const"

    def sample_eta(self, key: jax.Array, n: int, t: int) -> int:
        return int(np.clip(round(self.frac * n), 0, max(n - t, 0)))

    def mean(self, n: int, t: int) -> float:
        return min(self.frac * n, max(n - t, 0))

    def var(self, n: int, t: int) -> float:
        return 0.0


@dataclasses.dataclass
class RevealNoise(NoiseStrategy):
    """eta = 0: trim away every filler (SecretFlow-SCQL's disclosure)."""

    name: str = "reveal"

    def sample_eta(self, key: jax.Array, n: int, t: int) -> int:
        return 0

    def mean(self, n: int, t: int) -> float:
        return 0.0

    def var(self, n: int, t: int) -> float:
        return 0.0


@dataclasses.dataclass
class NoTrim(NoiseStrategy):
    """Keep everything: the Resizer degenerates to a no-op (fully oblivious)."""

    name: str = "notrim"

    def sample_eta(self, key: jax.Array, n: int, t: int) -> int:
        return max(n - t, 0)

    def mean(self, n: int, t: int) -> float:
        return max(n - t, 0)

    def var(self, n: int, t: int) -> float:
        return 0.0


def shrinkwrap_default(sensitivity: float = 1.0) -> TruncatedLaplace:
    """The paper's evaluation configuration: TLap(eps=0.5, delta=5e-5)."""
    return TruncatedLaplace(eps=0.5, delta=0.00005, sensitivity=sensitivity)
