"""Cardinality Recovery Threshold (CRT) — the paper's security metric (§3.3).

CRT = the number r of *equivalent repetitions* of an operator an attacker must
observe to estimate the true intermediate size T within +-err at confidence
alpha, given that each observation is S_k = T + eta_k with eta_k i.i.d. from a
known distribution:

    r >= z_{alpha/2}^2 * sigma_S^2 / err^2          (Eq. 1)

sigma_S^2 depends on both the noise *generation* distribution and the
*addition* design:

* sequential: sigma_S^2 = Var(eta)
* parallel:   sigma_S^2 = Var(T + Binomial(N-T, eta/(N-T)))
              = E[eta] - E[eta^2]/(N-T) + Var(eta)   (law of total variance)
* Beta + parallel = Beta-Binomial closed form.

Also provides a Monte-Carlo attacker that performs the §3.3 estimation
empirically (used to validate Eq. 1 and reproduce Figs. 10/11).
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from .noise import NoiseStrategy

__all__ = ["z_score", "crt_rounds", "sigma_s2", "attacker_estimate"]


def z_score(confidence: float = 0.999) -> float:
    """Two-sided z for the given confidence level (e.g. 0.999 -> 3.291)."""
    from jax.scipy.special import ndtri

    return float(ndtri(0.5 + confidence / 2.0))


def sigma_s2(noise: NoiseStrategy, addition: str, n: int, t: int) -> float:
    if addition == "sequential":
        return noise.var(n, t)
    if addition == "parallel":
        return noise.var_parallel(n, t)
    raise ValueError(addition)


def crt_rounds(
    noise: NoiseStrategy,
    addition: str,
    n: int,
    t: int,
    err: float = 1.0,
    confidence: float = 0.999,
) -> float:
    """Equation (1). err=1 reproduces the paper's 21.66 * sigma^2 bound."""
    z = z_score(confidence)
    return max(z * z * sigma_s2(noise, addition, n, t) / (err * err), 1.0)


def attacker_estimate(
    noise: NoiseStrategy,
    addition: str,
    n: int,
    t: int,
    rounds: int,
    key: jax.Array,
) -> Dict[str, float]:
    """Monte-Carlo §3.3 attacker: observe `rounds` noisy sizes, average, and
    subtract the (known) noise mean. Returns the estimate and its error."""
    keys = jax.random.split(key, rounds)
    obs = np.empty(rounds)
    for i, k in enumerate(keys):
        if addition == "sequential":
            eta = noise.sample_eta(k, n, t)
            obs[i] = t + min(eta, n - t)
        else:
            p = noise.sample_p(k, n, t)
            draw = np.random.default_rng(int(jax.random.bits(k, dtype=np.uint32)))
            obs[i] = t + draw.binomial(max(n - t, 0), min(max(p, 0.0), 1.0))
    mu_eta = (
        noise.mean(n, t)
        if addition == "sequential"
        else noise.mean(n, t)  # E[Binomial] = E[eta] for both designs
    )
    t_hat = obs.mean() - mu_eta
    return {
        "t_hat": float(t_hat),
        "abs_err": float(abs(t_hat - t)),
        "mean_s": float(obs.mean()),
        "sigma_s_emp": float(obs.std(ddof=1)) if rounds > 1 else 0.0,
    }
