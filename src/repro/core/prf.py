"""Correlated randomness for 3-party replicated secret sharing.

Setup (standard RSS, Araki et al. CCS'16): during a one-time setup each
adjacent pair of parties (P_i, P_{i+1}) agrees on a PRF key ``k_i``. Then,
without any interaction, the parties can derive:

* **zero sharings**: ``alpha_i = F(k_i, ctr) - F(k_{i-1}, ctr)`` satisfies
  ``sum_i alpha_i = 0`` (arithmetic) — or with XOR, ``xor_i alpha_i = 0``
  (boolean). These re-randomize multiplication outputs for free.
* **replicated random values**: ``r = sum_i F(k_i, ctr)`` is a random ring
  element of which party i knows the two "legs" F(k_i), F(k_{i+1}) — i.e. a
  valid RSS sharing of a random value, generated with zero communication.

In the JAX simulation the three pair keys live in a small pytree; every use
site folds in a fresh counter derived from a user-provided ``jax.random`` key,
mirroring the monotone PRF counter of a real deployment.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import material
from .ring import Ring, default_ring

__all__ = ["PRFSetup", "setup_prf", "zero_share_add", "zero_share_xor", "rand_replicated"]


# Module-level jitted helpers: ``jax.vmap`` retraces its callee on every call,
# which made each fold/draw cost milliseconds of pure dispatch overhead — the
# dominant cost of round-heavy circuits (bitonic sort does thousands of PRF
# derivations). Compiled once per shape here, they are single cached dispatches
# thereafter, and the derived values are bit-identical to the eager path.

@jax.jit
def _fold_keys(pair_keys: jnp.ndarray, tag) -> jnp.ndarray:
    folded = jax.vmap(lambda k: jax.random.fold_in(k, tag))(
        jax.vmap(jax.random.wrap_key_data)(pair_keys)
    )
    return jax.vmap(jax.random.key_data)(folded)


@functools.partial(jax.jit, static_argnames=("shape", "dtype"))
def _draw_bits(pair_keys: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    keys = jax.vmap(jax.random.wrap_key_data)(pair_keys)
    bits = jax.vmap(
        lambda k: jax.random.bits(k, shape=shape, dtype=jnp.uint32)
    )(keys)
    return bits.astype(dtype)


@functools.partial(jax.jit, static_argnames=("shape",))
def _draw_uniform(pair_keys: jnp.ndarray, shape) -> jnp.ndarray:
    keys = jax.vmap(jax.random.wrap_key_data)(pair_keys)
    return jax.vmap(lambda k: jax.random.uniform(k, shape=shape))(keys)


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "xor"))
def _zero_share(pair_keys: jnp.ndarray, shape, dtype, xor: bool) -> jnp.ndarray:
    f = _draw_bits(pair_keys, shape, dtype)
    g = jnp.roll(f, 1, axis=0)
    return f ^ g if xor else f - g


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PRFSetup:
    """Three pairwise PRF keys: pair_keys[i] is shared by parties i and i+1."""

    pair_keys: jnp.ndarray  # (3, 2) uint32 jax PRNG keys (raw key data)

    def tree_flatten(self):
        return (self.pair_keys,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    def fold(self, tag: jnp.ndarray | int) -> "PRFSetup":
        """Derive fresh per-use keys (the PRF counter)."""
        src = material.active_if_concrete(self.pair_keys, tag)
        if src is None:
            return PRFSetup(_fold_keys(self.pair_keys, tag))
        return PRFSetup(
            src.fetch(
                "fold",
                self.pair_keys,
                (int(tag),),
                lambda: _fold_keys(self.pair_keys, tag),
            )
        )

    def draw(self, shape: Tuple[int, ...], ring: Ring) -> jnp.ndarray:
        """F(k_i, .) for each pair key -> (3, *shape) ring elements."""
        src = material.active_if_concrete(self.pair_keys)
        if src is None:
            return _draw_bits(self.pair_keys, tuple(shape), ring.dtype)
        return src.fetch(
            "draw",
            self.pair_keys,
            (tuple(int(s) for s in shape), jnp.dtype(ring.dtype).name),
            lambda: _draw_bits(self.pair_keys, tuple(shape), ring.dtype),
        )

    def draw_uniform(self, shape: Tuple[int, ...]) -> jnp.ndarray:
        """Per-pair-key uniform [0,1) floats -> (3, *shape) float32."""
        src = material.active_if_concrete(self.pair_keys)
        if src is None:
            return _draw_uniform(self.pair_keys, tuple(shape))
        return src.fetch(
            "uniform",
            self.pair_keys,
            (tuple(int(s) for s in shape),),
            lambda: _draw_uniform(self.pair_keys, tuple(shape)),
        )


def setup_prf(key: jax.Array) -> PRFSetup:
    """One-time key agreement between the three adjacent party pairs."""
    keys = jax.random.split(key, 3)
    return PRFSetup(jax.vmap(jax.random.key_data)(keys))


def _zero_share_hooked(prf: PRFSetup, shape, ring: Ring, xor: bool) -> jnp.ndarray:
    src = material.active_if_concrete(prf.pair_keys)
    if src is None:
        return _zero_share(prf.pair_keys, tuple(shape), ring.dtype, xor=xor)
    return src.fetch(
        "zero_xor" if xor else "zero_add",
        prf.pair_keys,
        (tuple(int(s) for s in shape), jnp.dtype(ring.dtype).name),
        lambda: _zero_share(prf.pair_keys, tuple(shape), ring.dtype, xor=xor),
    )


def zero_share_add(prf: PRFSetup, shape, ring: Ring | None = None) -> jnp.ndarray:
    """(3, *shape) additive sharing of zero: alpha_i = F(k_i) - F(k_{i-1})."""
    return _zero_share_hooked(prf, shape, ring or default_ring(), xor=False)


def zero_share_xor(prf: PRFSetup, shape, ring: Ring | None = None) -> jnp.ndarray:
    """(3, *shape) XOR sharing of zero: alpha_i = F(k_i) ^ F(k_{i-1})."""
    return _zero_share_hooked(prf, shape, ring or default_ring(), xor=True)


def rand_replicated(prf: PRFSetup, shape, ring: Ring | None = None) -> jnp.ndarray:
    """(3, *shape) canonical shares of a fresh random ring element (no comm)."""
    ring = ring or default_ring()
    return prf.draw(tuple(shape), ring)
