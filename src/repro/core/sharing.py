"""3-party replicated secret sharing (RSS) over Z_{2^k}, simulated in JAX.

Representation
--------------
A secret ``x`` is the canonical share triple ``(s0, s1, s2)`` stored in a
leading axis of size 3, with ``x = s0 + s1 + s2 (mod 2^k)`` for arithmetic
(:class:`AShare`) or ``x = s0 ^ s1 ^ s2`` for boolean (:class:`BShare`)
sharing. Party ``P_i`` holds the replicated pair ``(s_i, s_{i+1})`` — the
simulation keeps the canonical triple and implements every protocol as the
exact message pattern a real deployment would run, logging each round's bytes
to the active :class:`~repro.core.ledger.CommLedger`.

Protocols implemented here (all standard, Araki et al. CCS'16 / ABY3):

* local: add / sub / const-mul (AShare), xor / not / shifts (BShare)
* ``mul`` / ``and_``: 1 round, one ring element sent per party per lane,
  re-randomized with a PRF zero-sharing, followed by the resharing hop
* ``reveal``: 1 round (each party sends its first share to the party missing
  it)

Security note: this is a *simulation* for systems research — shares co-reside
in one address space. The protocol logic, randomness structure, and
communication pattern are faithful; the isolation boundary of a real MPC
deployment is not provided (and not needed for performance analysis).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels import kernels_enabled
from .ledger import log_comm
from .prf import PRFSetup, _zero_share, rand_replicated, zero_share_add, zero_share_xor
from .ring import Ring, default_ring

__all__ = [
    "AShare",
    "BShare",
    "share_a",
    "share_b",
    "reveal_a",
    "reveal_b",
    "mul",
    "and_",
    "NUM_PARTIES",
]

NUM_PARTIES = 3


def _ring_of(x: jnp.ndarray) -> Ring:
    return Ring(32) if x.dtype == jnp.uint32 else Ring(64)


def _as_ring(c, ring: Ring) -> jnp.ndarray:
    """Coerce a public constant (Python int / numpy / jax array) into the ring,
    wrapping mod 2^k (plain ``jnp.asarray`` would overflow on e.g. 0xFFFFFFFF)."""
    import numpy as _np

    if isinstance(c, int):
        return jnp.asarray(_np.asarray(c & ring.mask, dtype=ring.np_dtype))
    c = jnp.asarray(c)
    if c.dtype != ring.dtype:
        c = c.astype(ring.dtype)
    return c


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class _ShareBase:
    shares: jnp.ndarray  # (3, *shape) ring dtype

    # -- pytree --------------------------------------------------------------
    def tree_flatten(self):
        return (self.shares,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    # -- structure -----------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.shares.shape[1:])

    @property
    def size(self) -> int:
        s = 1
        for d in self.shape:
            s *= d
        return s

    @property
    def ring(self) -> Ring:
        return _ring_of(self.shares)

    def map_shares(self, fn: Callable[[jnp.ndarray], jnp.ndarray]):
        """Apply a share-local (linear / structural) transform to all shares."""
        return type(self)(fn(self.shares))

    # Structural helpers (all local: identical re-layout at every party).
    def reshape(self, *shape):
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        return self.map_shares(lambda s: s.reshape((3,) + tuple(shape)))

    def __getitem__(self, idx):
        if not isinstance(idx, tuple):
            idx = (idx,)
        return self.map_shares(lambda s: s[(slice(None),) + idx])

    def take(self, indices, axis: int = 0):
        return self.map_shares(lambda s: jnp.take(s, indices, axis=axis + 1))

    def broadcast_to(self, shape):
        return self.map_shares(lambda s: jnp.broadcast_to(s, (3,) + tuple(shape)))

    def repeat(self, n: int, axis: int = 0):
        return self.map_shares(lambda s: jnp.repeat(s, n, axis=axis + 1))

    def tile(self, reps: Sequence[int]):
        return self.map_shares(lambda s: jnp.tile(s, (1,) + tuple(reps)))

    @classmethod
    def concat(cls, parts: Sequence["_ShareBase"], axis: int = 0):
        return cls(jnp.concatenate([p.shares for p in parts], axis=axis + 1))

    @classmethod
    def stack(cls, parts: Sequence["_ShareBase"], axis: int = 0):
        return cls(jnp.stack([p.shares for p in parts], axis=axis + 1))

    def pad_rows(self, n_rows: int, value_shares=None):
        """Pad axis 0 (rows) up to ``n_rows`` with zero shares (a valid
        sharing of 0; callers pair this with a public/shared valid column)."""
        cur = self.shape[0]
        if n_rows == cur:
            return self
        pad = [(0, 0)] * self.shares.ndim
        pad[1] = (0, n_rows - cur)
        return self.map_shares(lambda s: jnp.pad(s, pad))


@jax.tree_util.register_pytree_node_class
class AShare(_ShareBase):
    """Additive replicated sharing: value = s0 + s1 + s2 mod 2^k."""

    # -- local linear ops ------------------------------------------------
    def __add__(self, other):
        if isinstance(other, AShare):
            return AShare(self.shares + other.shares)
        return self.add_public(other)

    __radd__ = __add__

    def __sub__(self, other):
        if isinstance(other, AShare):
            return AShare(self.shares - other.shares)
        return self.add_public(_as_ring(0, self.ring) - _as_ring(other, self.ring))

    def __neg__(self):
        return AShare(jnp.zeros_like(self.shares) - self.shares)

    def add_public(self, c) -> "AShare":
        """Add a public constant: by convention share 0 absorbs it."""
        c = _as_ring(c, self.ring)
        return AShare(_absorb_add(self.shares, c))

    def mul_public(self, c) -> "AShare":
        c = _as_ring(c, self.ring)
        return AShare(self.shares * c)

    def __mul__(self, other):
        if isinstance(other, AShare):
            raise TypeError("secret x secret multiply requires mul(x, y, prf)")
        return self.mul_public(other)

    __rmul__ = __mul__

    def sum(self, axis=0) -> "AShare":
        """Local reduction (additions are free under additive sharing)."""
        return AShare(jnp.sum(self.shares, axis=axis + 1))

    def cumsum(self, axis=0) -> "AShare":
        return AShare(jnp.cumsum(self.shares, axis=axis + 1))

    def dot(self, public_vec) -> "AShare":
        v = jnp.asarray(public_vec).astype(self.ring.dtype)
        return AShare(jnp.einsum("p...n,n->p...", self.shares, v))


@jax.tree_util.register_pytree_node_class
class BShare(_ShareBase):
    """XOR replicated sharing over k-bit words: value = s0 ^ s1 ^ s2."""

    def __xor__(self, other):
        if isinstance(other, BShare):
            return BShare(self.shares ^ other.shares)
        return self.xor_public(other)

    __rxor__ = __xor__

    def xor_public(self, c) -> "BShare":
        c = _as_ring(c, self.ring)
        return BShare(_absorb_xor(self.shares, c))

    def __invert__(self) -> "BShare":
        return self.xor_public(self.ring.mask)

    def __lshift__(self, n: int) -> "BShare":
        return BShare(self.shares << n)

    def __rshift__(self, n: int) -> "BShare":
        return BShare(self.shares >> n)

    def and_public(self, c) -> "BShare":
        c = _as_ring(c, self.ring)
        return BShare(self.shares & c)

    def lsb_mask(self) -> "BShare":
        """Replicate the LSB of each lane across all k bit positions (local:
        each share's LSB extends independently; XOR of extensions extends the
        XOR)."""
        lsb = self.shares & self.ring.const(1)
        # 0 - lsb in the unsigned ring == all-ones iff lsb == 1
        return BShare(jnp.zeros_like(lsb) - lsb)

    def bit(self, j: int) -> "BShare":
        """Extract bit j into the LSB position."""
        return BShare((self.shares >> j) & self.ring.const(1))


# Jitted share-0 absorption: the eager ``.at[0]`` scatter costs ~1ms per call
# and public-constant absorption sits inside every circuit level.

@jax.jit
def _absorb_add(shares: jnp.ndarray, c) -> jnp.ndarray:
    return shares.at[0].add(c)


@jax.jit
def _absorb_xor(shares: jnp.ndarray, c) -> jnp.ndarray:
    return shares.at[0].set(shares[0] ^ c)


# -----------------------------------------------------------------------------
# Share / reveal
# -----------------------------------------------------------------------------

def share_a(x, key: jax.Array, ring: Ring | None = None) -> AShare:
    """Data-owner arithmetic sharing of plaintext ``x`` (input upload)."""
    ring = ring or default_ring()
    x = ring.wrap(x)
    k0, k1 = jax.random.split(key)
    s0 = jax.random.bits(k0, shape=x.shape, dtype=jnp.uint32).astype(ring.dtype)
    s1 = jax.random.bits(k1, shape=x.shape, dtype=jnp.uint32).astype(ring.dtype)
    s2 = x - s0 - s1
    return AShare(jnp.stack([s0, s1, s2]))


def share_b(x, key: jax.Array, ring: Ring | None = None) -> BShare:
    """Data-owner boolean (XOR) sharing of plaintext ``x``."""
    ring = ring or default_ring()
    x = ring.wrap(x)
    k0, k1 = jax.random.split(key)
    s0 = jax.random.bits(k0, shape=x.shape, dtype=jnp.uint32).astype(ring.dtype)
    s1 = jax.random.bits(k1, shape=x.shape, dtype=jnp.uint32).astype(ring.dtype)
    s2 = x ^ s0 ^ s1
    return BShare(jnp.stack([s0, s1, s2]))


def reveal_a(x: AShare) -> jnp.ndarray:
    """Open an arithmetic sharing (1 round; each party sends one share)."""
    log_comm("reveal", 1, x.size * x.ring.bytes, payload=x.shares)
    return x.shares[0] + x.shares[1] + x.shares[2]


def reveal_b(x: BShare) -> jnp.ndarray:
    log_comm("reveal", 1, x.size * x.ring.bytes, payload=x.shares)
    return x.shares[0] ^ x.shares[1] ^ x.shares[2]


# -----------------------------------------------------------------------------
# Multiplication / AND — the only interactive gates (1 round each)
# -----------------------------------------------------------------------------

def _cross_terms_add(xs: jnp.ndarray, ys: jnp.ndarray) -> jnp.ndarray:
    """z'_i = x_i*y_i + x_i*y_{i+1} + x_{i+1}*y_i (covers all 9 cross terms)."""
    xn = jnp.roll(xs, -1, axis=0)  # x_{i+1}
    yn = jnp.roll(ys, -1, axis=0)
    return xs * ys + xs * yn + xn * ys


def _cross_terms_xor(xs: jnp.ndarray, ys: jnp.ndarray) -> jnp.ndarray:
    xn = jnp.roll(xs, -1, axis=0)
    yn = jnp.roll(ys, -1, axis=0)
    return (xs & ys) ^ (xs & yn) ^ (xn & ys)


@functools.partial(jax.jit, static_argnames=("boolean", "dtype"))
def _gate_words(xs, ys, pair_keys, boolean: bool, dtype):
    """Full non-kernel gate payload (zero-share + cross terms + rerandomize)
    compiled as one dispatch — the per-gate eager op chain dominated wall time
    for round-heavy circuits (bitonic sort)."""
    alpha = _zero_share(pair_keys, xs.shape[1:], dtype, xor=boolean)
    if boolean:
        return _cross_terms_xor(xs, ys) ^ alpha
    return _cross_terms_add(xs, ys) + alpha


def _kernel_gate(xs, ys, alpha, boolean: bool):
    """Single-gate kernel dispatch (the *fused* multi-gate circuits route in
    core/circuits.py instead and never reach this per-gate path)."""
    if not kernels_enabled():
        return None
    from ..kernels.rss_gate.ops import gate

    return gate(xs, ys, alpha, boolean=boolean)


def mul(x: AShare, y: AShare, prf: PRFSetup) -> AShare:
    """Secret x secret multiply: 1 round, one ring element per party per lane.

    Each party computes its local cross terms + PRF zero-share, then sends the
    result to its predecessor to restore replication (the resharing hop).
    """
    ring = x.ring
    if kernels_enabled():
        # broadcast BEFORE the kernel: gate() flattens lanes, so mismatched
        # operand shapes (e.g. a (n,2) pair scanned against a (n,1) flag)
        # would silently misalign; alpha is drawn at the broadcast shape
        xs, ys = jnp.broadcast_arrays(x.shares, y.shares)
        alpha = zero_share_add(prf, xs.shape[1:], ring)
        z = _kernel_gate(xs, ys, alpha, boolean=False)
    else:
        z = _gate_words(x.shares, y.shares, prf.pair_keys, False, ring.dtype)
    log_comm("mul", 1, x.size * ring.bytes, payload=z)
    return AShare(z)


def and_(x: BShare, y: BShare, prf: PRFSetup) -> BShare:
    """Secret AND (bitwise over k-bit lanes): 1 round, k bits per lane/party."""
    ring = x.ring
    if kernels_enabled():
        xs, ys = jnp.broadcast_arrays(x.shares, y.shares)
        alpha = zero_share_xor(prf, xs.shape[1:], ring)
        z = _kernel_gate(xs, ys, alpha, boolean=True)
    else:
        z = _gate_words(x.shares, y.shares, prf.pair_keys, True, ring.dtype)
    log_comm("and", 1, x.size * ring.bytes, payload=z)
    return BShare(z)


def or_(x: BShare, y: BShare, prf: PRFSetup) -> BShare:
    """x OR y = ~(~x AND ~y) — one interactive AND."""
    return ~and_(~x, ~y, prf)


def select(cond_mask: BShare, x: BShare, y: BShare, prf: PRFSetup) -> BShare:
    """cond ? x : y, with ``cond_mask`` a full-width mask (see lsb_mask)."""
    d = and_(cond_mask, x ^ y, prf)
    return y ^ d


def rand_ashare(prf: PRFSetup, shape, ring: Ring | None = None) -> AShare:
    return AShare(rand_replicated(prf, shape, ring or default_ring()))


def rand_bshare(prf: PRFSetup, shape, ring: Ring | None = None) -> BShare:
    return BShare(rand_replicated(prf, shape, ring or default_ring()))


def zeros_a(shape, ring: Ring | None = None) -> AShare:
    ring = ring or default_ring()
    return AShare(jnp.zeros((3,) + tuple(shape), dtype=ring.dtype))


def zeros_b(shape, ring: Ring | None = None) -> BShare:
    ring = ring or default_ring()
    return BShare(jnp.zeros((3,) + tuple(shape), dtype=ring.dtype))


def const_a(value, shape=(), ring: Ring | None = None) -> AShare:
    """Trivial (public-constant) arithmetic sharing: share 0 carries it."""
    ring = ring or default_ring()
    z = zeros_a(shape, ring)
    return z.add_public(jnp.broadcast_to(jnp.asarray(value), shape))


def const_b(value, shape=(), ring: Ring | None = None) -> BShare:
    ring = ring or default_ring()
    z = zeros_b(shape, ring)
    return z.xor_public(jnp.broadcast_to(jnp.asarray(value), shape))
