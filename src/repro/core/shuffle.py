"""Secure multi-party shuffle (MPS) — permutation-composition protocol.

Reflex shuffles the Resizer's output (after noise addition, before
reveal-and-trim) to break linkage between input and output positions (§4.4).

We implement the honest-majority 3-party shuffle in the style of Araki et al. /
Asharov et al. [CCS'22] (the protocol family MP-SPDZ's shuffle also belongs
to): the global permutation is the composition ``pi = pi_2 ∘ pi_1 ∘ pi_0``
where ``pi_j`` is derived from pair key ``j`` and hence known to exactly two
parties; the third party receives freshly re-randomized shares after each hop
and cannot link positions. Since every party is ignorant of at least one
``pi_j``, nobody knows the composed permutation.

Costs (Table 1 of the paper): 3 rounds (constant), each hop moves the whole
table once => ``3 * N * M`` bytes per party for N rows of M bytes. The
computational cost of *applying* a permutation is a row gather — the hot loop
that ``repro.kernels.shuffle_gather`` implements as a blocked Pallas kernel
(HBM -> VMEM row tiles); the jnp fallback is ``jnp.take``.
"""
from __future__ import annotations

from typing import Dict, Union

import jax
import jax.numpy as jnp

from . import material
from .ledger import fused_scope, log_comm
from .prf import PRFSetup, zero_share_add, zero_share_xor
from .sharing import AShare, BShare

__all__ = [
    "secure_shuffle",
    "inverse_shuffle",
    "apply_secret_perm",
    "composed_permutation",
    "HOPS",
]

HOPS = 3

Share = Union[AShare, BShare]


def _hop_perm(prf: PRFSetup, hop: int, n: int) -> jnp.ndarray:
    """Permutation for hop ``hop`` — derived from pair key ``hop``, i.e. known
    to parties hop and hop+1 only."""
    sub = prf.fold(1000 + hop)

    def compute():
        key = jax.random.wrap_key_data(sub.pair_keys[hop])
        return jax.random.permutation(key, n)

    src = material.active_if_concrete(sub.pair_keys)
    if src is None:
        return compute()
    return src.fetch("perm", sub.pair_keys, (int(hop), int(n)), compute)


def composed_permutation(prf: PRFSetup, n: int) -> jnp.ndarray:
    """The (secret) composed permutation — exposed for tests/oracles only."""
    pi = jnp.arange(n)
    for hop in range(HOPS):
        pi = jnp.take(pi, _hop_perm(prf, hop, n), axis=0)
    return pi


def _rerandomize(col: Share, prf: PRFSetup, tag: int) -> Share:
    p = prf.fold(tag)
    if isinstance(col, AShare):
        return AShare(col.shares + zero_share_add(p, col.shape, col.ring))
    return BShare(col.shares ^ zero_share_xor(p, col.shape, col.ring))


def secure_shuffle(
    cols: Dict[str, Share],
    prf: PRFSetup,
    gather_fn=None,
) -> Dict[str, Share]:
    """Shuffle all columns of a table with one hidden common permutation.

    ``gather_fn(shares, perm)`` may be supplied to route the row gather through
    the Pallas kernel; default is ``jnp.take`` along the row axis.
    """
    if not cols:
        return cols
    first = next(iter(cols.values()))
    n = first.shape[0]
    row_bytes = sum(
        c.ring.bytes * (c.size // max(c.shape[0], 1)) for c in cols.values()
    )
    if gather_fn is None:
        from ..kernels import kernels_enabled

        if kernels_enabled():
            from ..kernels.shuffle_gather.ops import gather_rows

            def gather_fn(shares, perm):
                # shares: (3, N, ...) -> flatten trailing dims into columns
                flat = shares.reshape(3, shares.shape[1], -1)
                out = jnp.stack([gather_rows(flat[i], perm) for i in range(3)])
                return out.reshape(shares.shape)

    take = gather_fn or (lambda shares, perm: jnp.take(shares, perm, axis=1))

    with fused_scope("shuffle", rounds=HOPS):
        out = dict(cols)
        for hop in range(HOPS):
            perm = _hop_perm(prf, hop, n)
            new = {}
            for idx, (name, col) in enumerate(out.items()):
                moved = col.map_shares(lambda s, p=perm: take(s, p))
                new[name] = _rerandomize(moved, prf, 5000 + 17 * hop + idx)
            out = new
            # one resharing hop: the pi_j-ignorant party receives fresh shares
            log_comm("shuffle_hop", 1, n * row_bytes)
    return out


def inverse_shuffle(
    cols: Dict[str, Share],
    prf: PRFSetup,
    gather_fn=None,
) -> Dict[str, Share]:
    """Undo ``secure_shuffle(cols, prf)``: apply the hop permutations inverted
    and in reverse order. Same round/byte pattern as the forward shuffle (each
    hop is one table move + resharing); the re-randomization tags differ so
    forward and inverse hops never reuse a zero-sharing.
    """
    if not cols:
        return cols
    first = next(iter(cols.values()))
    n = first.shape[0]
    row_bytes = sum(
        c.ring.bytes * (c.size // max(c.shape[0], 1)) for c in cols.values()
    )
    if gather_fn is None:
        from ..kernels import kernels_enabled

        if kernels_enabled():
            from ..kernels.shuffle_gather.ops import gather_rows

            def gather_fn(shares, perm):
                flat = shares.reshape(3, shares.shape[1], -1)
                out = jnp.stack([gather_rows(flat[i], perm) for i in range(3)])
                return out.reshape(shares.shape)

    take = gather_fn or (lambda shares, perm: jnp.take(shares, perm, axis=1))

    with fused_scope("shuffle", rounds=HOPS):
        out = dict(cols)
        for hop in reversed(range(HOPS)):
            perm = jnp.argsort(_hop_perm(prf, hop, n))
            new = {}
            for idx, (name, col) in enumerate(out.items()):
                moved = col.map_shares(lambda s, p=perm: take(s, p))
                new[name] = _rerandomize(moved, prf, 5500 + 17 * hop + idx)
            out = new
            log_comm("shuffle_hop", 1, n * row_bytes)
    return out


def apply_secret_perm(
    cols: Dict[str, Share], pi: "BShare", prf: PRFSetup
) -> Dict[str, Share]:
    """Gather rows of ``cols`` by a secret-shared permutation: out_i = cols_{pi(i)}.

    Shuffle-and-reveal (Asharov et al. style): shuffle the shared index vector
    ``pi`` by a hidden permutation sigma, open ``r = pi ∘ sigma`` — a uniformly
    random permutation, so the opening leaks nothing about ``pi`` — gather the
    payload by the public ``r`` (free), then inverse-shuffle the result to peel
    sigma back off. Only sound when ``pi`` is a true permutation of 0..n-1
    (e.g. a sorted row-index column); arbitrary index vectors would leak their
    multiplicity pattern through ``r``.

    Cost: one 1-column shuffle + one n-word reveal + one W-column inverse
    shuffle — O(n) bytes per payload column, vs. O(n log^2 n) for carrying the
    payload through a sorting network.
    """
    from .sharing import reveal_b

    shuffled = secure_shuffle({"__pi": pi}, prf)
    r = reveal_b(shuffled["__pi"])
    moved = {name: col.take(r, axis=0) for name, col in cols.items()}
    return inverse_shuffle(moved, prf)
