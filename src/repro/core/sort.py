"""Oblivious bitonic sorting network over secret-shared tables.

Used by: OrderBy, GroupBy (sort as pre-pass), Distinct, and the Shrinkwrap
"sort&cut" baseline that Reflex compares against (sort valid tuples to the
front, then cut at the DP size).

A bitonic network on N = 2^m rows has m(m+1)/2 compare-exchange stages; each
stage costs one oblivious ``lt`` over N lanes (6 rounds, 11 AND-words) plus one
oblivious select per payload column (1 AND-word). Total rounds
O(log^2 N) — vs. the shuffle's O(1), which is exactly the paper's argument for
replacing Shrinkwrap's sort with a shuffle (Fig. 5a / Fig. 8).

The per-stage compare-exchange is the compute hot spot; it is also provided as
a Pallas kernel (``repro.kernels.bitonic_stage``) with this module's jnp path
as the oracle.
"""
from __future__ import annotations

import math
from typing import Dict, Union

import jax.numpy as jnp

from .circuits import lt
from .ledger import active_ledger
from .prf import PRFSetup
from .sharing import AShare, BShare, and_

__all__ = ["bitonic_sort", "bitonic_stages", "sort_valid_first"]

Share = Union[AShare, BShare]


def bitonic_stages(n: int):
    """Yield (k, j) for the standard iterative bitonic network on n = 2^m."""
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            yield k, j
            j //= 2
        k *= 2


def _stage(
    cols: Dict[str, BShare],
    key_col: str,
    k: int,
    j: int,
    prf: PRFSetup,
    descending: bool,
) -> Dict[str, BShare]:
    keyb = cols[key_col]
    n = keyb.shape[0]
    idx = jnp.arange(n)
    partner = idx ^ j
    is_lo = idx < partner  # public lane predicate
    asc = (idx & k) == 0  # public direction per pair (bit k equal for both)
    if descending:
        asc = ~asc

    a = keyb  # own value
    b = keyb.take(partner, axis=0)  # partner value
    # lo/hi views on public masks (local): lo = value at the lower lane index
    lo_key = BShare(jnp.where(is_lo, a.shares, b.shares))
    hi_key = BShare(jnp.where(is_lo, b.shares, a.shares))
    # swap decision, identical at both lanes of the pair (ties don't swap)
    s = lt(hi_key, lo_key, prf.fold(7 * k + j))  # hi < lo -> out of order (asc)
    # descending pairs invert the decision (local XOR with a public bit)
    s = s.xor_public(jnp.where(asc, 0, 1).astype(s.ring.dtype))
    mask = s.lsb_mask()

    out = {}
    for idx_c, (name, col) in enumerate(cols.items()):
        own = col
        other = col.take(partner, axis=0)
        d = and_(mask, own ^ other, prf.fold(9000 + 31 * k + 7 * j + idx_c))
        out[name] = own ^ d
    return out


def bitonic_sort(
    cols: Dict[str, BShare],
    key_col: str,
    prf: PRFSetup,
    descending: bool = False,
) -> Dict[str, BShare]:
    """Sort all columns by ``key_col`` (32-bit unsigned order). N must be a
    power of two (the engine's bucketing guarantees this)."""
    n = next(iter(cols.values())).shape[0]
    if n & (n - 1):
        raise ValueError(f"bitonic_sort requires power-of-two rows, got {n}")
    m = int(math.log2(n))
    led = active_ledger()
    import contextlib

    n_stages = m * (m + 1) // 2
    scope = (
        led.fused("bitonic_sort", rounds=7 * n_stages)
        if led is not None
        else contextlib.nullcontext()
    )
    with scope:
        for k, j in bitonic_stages(n):
            cols = _stage(cols, key_col, k, j, prf, descending)
    return cols


def sort_valid_first(
    cols: Dict[str, BShare], valid_col: str, prf: PRFSetup
) -> Dict[str, BShare]:
    """Shrinkwrap's pre-cut sort: true tuples (valid=1) to the front.

    Sorting descending on the single-bit valid column suffices; equal keys
    keep arbitrary relative order (the network is not stable, which is fine —
    and is why Shrinkwrap needs no tie-breaking either).
    """
    return bitonic_sort(cols, valid_col, prf, descending=True)
