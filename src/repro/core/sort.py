"""Oblivious bitonic sorting network over secret-shared tables.

Used by: OrderBy, GroupBy (sort as pre-pass), Distinct, and the Shrinkwrap
"sort&cut" baseline that Reflex compares against (sort valid tuples to the
front, then cut at the DP size).

A bitonic network on N = 2^m rows has m(m+1)/2 compare-exchange stages; each
stage costs one oblivious ``lt`` over N lanes (6 rounds, 11 AND-words) plus one
oblivious select per payload column (1 AND-word). Total rounds
O(log^2 N) — vs. the shuffle's O(1), which is exactly the paper's argument for
replacing Shrinkwrap's sort with a shuffle (Fig. 5a / Fig. 8).

The per-stage compare-exchange is the compute hot spot; it is also provided as
a Pallas kernel (``repro.kernels.bitonic_stage``) with this module's jnp path
as the oracle.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Union

import jax.numpy as jnp

from .circuits import and_bit, eq, lt, or_bit
from .ledger import active_ledger
from .prf import PRFSetup
from .sharing import AShare, BShare, and_, const_b

__all__ = [
    "bitonic_sort",
    "bitonic_sort_narrow",
    "bitonic_stages",
    "sort_valid_first",
]

Share = Union[AShare, BShare]


def bitonic_stages(n: int):
    """Yield (k, j) for the standard iterative bitonic network on n = 2^m."""
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            yield k, j
            j //= 2
        k *= 2


def _lex_lt(
    his: List[BShare], los: List[BShare], prf: PRFSetup
) -> BShare:
    """Lexicographic ``his < los`` over parallel key columns: column 0
    decides unless it ties, in which case column 1 decides, and so on —
    lt_0 OR (eq_0 AND lt_1) OR (eq_0 AND eq_1 AND lt_2) ...

    All columns' lt circuits (and all tie eq circuits) are independent, so
    they run as one batched call each — the rounds the ledger already models;
    only the shallow combine chain stays sequential."""
    if len(his) == 1:
        return lt(his[0], los[0], prf.fold(0))
    h = BShare(jnp.stack([c.shares for c in his], axis=1))  # (3, K, n)
    lo = BShare(jnp.stack([c.shares for c in los], axis=1))
    lts = lt(h, lo, prf.fold(0))
    eqs = eq(BShare(h.shares[:, :-1]), BShare(lo.shares[:, :-1]), prf.fold(6))
    res = BShare(lts.shares[:, 0])
    ties = None
    for i in range(1, len(his)):
        p = prf.fold(i)
        e = BShare(eqs.shares[:, i - 1])
        ties = e if ties is None else and_bit(ties, e, p.fold(2))
        lt_i = BShare(lts.shares[:, i])
        res = or_bit(res, and_bit(ties, lt_i, p.fold(4)), p.fold(5))
    return res


def _stage(
    cols: Dict[str, BShare],
    key_cols: Sequence[str],
    k: int,
    j: int,
    prf: PRFSetup,
    descending: bool,
) -> Dict[str, BShare]:
    keyb = cols[key_cols[0]]
    n = keyb.shape[0]
    idx = jnp.arange(n)
    partner = idx ^ j
    is_lo = idx < partner  # public lane predicate
    asc = (idx & k) == 0  # public direction per pair (bit k equal for both)
    if descending:
        asc = ~asc

    # lo/hi views on public masks (local): lo = value at the lower lane index
    def lo_hi(col: BShare):
        a = col  # own value
        b = col.take(partner, axis=0)  # partner value
        return (
            BShare(jnp.where(is_lo, a.shares, b.shares)),
            BShare(jnp.where(is_lo, b.shares, a.shares)),
        )

    los, his = zip(*(lo_hi(cols[kc]) for kc in key_cols))
    # swap decision, identical at both lanes of the pair (ties don't swap)
    p = prf.fold(7 * k + j)
    if len(key_cols) == 1:
        s = lt(his[0], los[0], p)  # hi < lo -> out of order (asc)
    else:
        s = _lex_lt(list(his), list(los), p)
    # descending pairs invert the decision (local XOR with a public bit)
    s = s.xor_public(jnp.where(asc, 0, 1).astype(s.ring.dtype))
    mask = s.lsb_mask()

    # conditional swap of every column in one batched AND (per-column selects
    # are independent; same words, one dispatch)
    names = list(cols)
    own = BShare(jnp.stack([cols[nm].shares for nm in names], axis=1))  # (3,C,n)
    other = own.take(partner, axis=1)
    m3 = BShare(jnp.broadcast_to(mask.shares[:, None, :], own.shares.shape))
    d = and_(m3, own ^ other, prf.fold(9000 + 31 * k + 7 * j))
    new = own ^ d
    return {nm: BShare(new.shares[:, i]) for i, nm in enumerate(names)}


def bitonic_sort(
    cols: Dict[str, BShare],
    key_col: Union[str, Sequence[str]],
    prf: PRFSetup,
    descending: bool = False,
) -> Dict[str, BShare]:
    """Sort all columns by ``key_col`` (32-bit unsigned order) — a single
    column name or a sequence of names compared lexicographically (composite
    GROUP BY keys). N must be a power of two (the engine's bucketing
    guarantees this)."""
    key_cols = [key_col] if isinstance(key_col, str) else list(key_col)
    n = next(iter(cols.values())).shape[0]
    if n & (n - 1):
        raise ValueError(f"bitonic_sort requires power-of-two rows, got {n}")
    m = int(math.log2(n))
    led = active_ledger()
    import contextlib

    n_stages = m * (m + 1) // 2
    # per-stage rounds: 6 (lt, all key columns in parallel) + 2 combining
    # levels per extra key (tie-AND + OR) + 1 select
    rounds_per_stage = 7 + 2 * (len(key_cols) - 1)
    scope = (
        led.fused("bitonic_sort", rounds=rounds_per_stage * n_stages)
        if led is not None
        else contextlib.nullcontext()
    )
    with scope:
        for k, j in bitonic_stages(n):
            cols = _stage(cols, key_cols, k, j, prf, descending)
    return cols


def bitonic_sort_narrow(
    cols: Dict[str, Share],
    key_col: Union[str, Sequence[str]],
    prf: PRFSetup,
    descending: bool = False,
) -> Dict[str, Share]:
    """``bitonic_sort`` with payload narrowing: only the key columns plus a
    shared row-index column ride the compare-exchange network; the remaining
    (payload) columns are gathered once post-sort by the sorted index — a
    secret permutation — via shuffle-and-reveal (``apply_secret_perm``).

    Network traffic per payload column drops from O(n log^2 n) select words to
    O(n) shuffle words. The index column itself costs one network column, so
    narrowing only pays for >= 2 payload columns; below that we fall back to
    the classic full-payload network (identical output either way).
    """
    key_cols = [key_col] if isinstance(key_col, str) else list(key_col)
    payload = {n_: c for n_, c in cols.items() if n_ not in key_cols}
    if len(payload) < 2:
        return bitonic_sort(cols, key_col, prf, descending)
    from .shuffle import apply_secret_perm

    n = next(iter(cols.values())).shape[0]
    net = {kc: cols[kc] for kc in key_cols}
    assert "__idx" not in cols, "__idx is reserved by bitonic_sort_narrow"
    net["__idx"] = const_b(jnp.arange(n, dtype=jnp.uint32), (n,))
    net = bitonic_sort(net, key_cols, prf, descending)
    idx = net.pop("__idx")
    moved = apply_secret_perm(payload, idx, prf.fold(686))
    # reassemble in the caller's original column order
    return {n_: (net[n_] if n_ in net else moved[n_]) for n_ in cols}


def sort_valid_first(
    cols: Dict[str, BShare], valid_col: str, prf: PRFSetup
) -> Dict[str, BShare]:
    """Shrinkwrap's pre-cut sort: true tuples (valid=1) to the front.

    Sorting descending on the single-bit valid column suffices; equal keys
    keep arbitrary relative order (the network is not stable, which is fine —
    and is why Shrinkwrap needs no tie-breaking either).
    """
    return bitonic_sort_narrow(cols, valid_col, prf, descending=True)
