"""The Resizer operator (rho) — the paper's core contribution (§4).

Pipeline (Fig. 3): noise generation -> noise addition (mark eta filler tuples
in a secret column k alongside the true-tuple column c) -> secure shuffle
(break linkage) -> reveal-and-trim (open k, keep rows with k=1; the only
disclosure is the noisy size S = T + eta).

Two noise-addition designs (§4.2):

* ``sequential`` (Alg. 1): exactly eta fillers, deterministic. We implement it
  as an *arithmetic prefix-sum + one vectorized secure comparison* — additions
  are free under additive sharing, so the secure counter parallelizes; this is
  a beyond-paper optimization over MP-SPDZ's unbatchable per-tuple loop. The
  ledger can optionally model the paper's N-round sequential cost
  (``paper_round_model=True``) for like-for-like comparison (Fig. 5a).
* ``parallel`` (Alg. 2): a coin toss per tuple. Parties contribute private
  fixed-point uniforms; the per-tuple sum is compared to a threshold over
  secret shares (one a2b + comparison), then OR-ed with c — matching the
  "online comparison and a logical OR gate" cost the paper reports (§5.2).

Coin-toss fidelity (documented in DESIGN.md): Algorithm 2 as written compares
the *sum* of m uniforms to m*p, i.e. P(IrwinHall_m < m*p) != p in general —
a bias we reproduce under ``coin_mode="paper"``. The default
``coin_mode="corrected"`` compares the *fractional part* of the sum (uniform
on [0,1), still maskingly secure) to p, giving an exact Bernoulli(p).

Reveal-and-trim opens k (public), so the trimmed size S becomes public — the
controlled disclosure. Optional bucketing rounds S up to a bucket boundary:
coarser disclosure, fewer downstream compilation shapes (beyond-paper).

Lazy payload (DESIGN.md §7.2): when the input table carries
:class:`~repro.ops.table.LazyGather` columns (the lazy join's un-expanded
payload views), only the physical columns (k, valid, and any already-material
columns) flow through the secure shuffle; the deferred payload is gathered
directly from its base tables for the S surviving rows only — O(S * cols)
instead of O(N * cols) host memory — then freshly re-randomized. The ledger
still logs the full shuffle traffic for the deferred columns
(``shuffle_deferred_payload``): in a real deployment the payload must ride
the same 3-hop resharing, so the communication profile is unchanged; only the
simulation's materialization is deferred. The trim-side linkage uses the
simulation-side ``composed_permutation`` oracle, which a real deployment
realizes by running the recorded hops on the payload columns.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..ops.table import SecretTable
from .circuits import a2b, bit2a, lt_public, or_bit
from .ledger import log_comm
from .noise import NoiseStrategy, NoTrim
from .prf import PRFSetup
from .sharing import AShare, BShare
from .shuffle import secure_shuffle

__all__ = ["ResizerConfig", "Resizer", "oracle_true_count"]

FP_BITS = 16  # fixed-point fraction bits for the coin toss
FP_ONE = 1 << FP_BITS


def oracle_true_count(table: SecretTable) -> int:
    """Plaintext T — simulation oracle only (used for the paper's runtime clip
    eta <- min(eta, N - T) and for tests; never enters the protocol view)."""
    v = np.asarray(table.valid.shares)
    return int(((v[0] ^ v[1] ^ v[2]) & 1).sum())


@dataclasses.dataclass
class ResizerConfig:
    noise: NoiseStrategy
    addition: str = "parallel"  # "parallel" | "sequential"
    coin_mode: str = "corrected"  # "corrected" | "paper"
    bucket: int = 1  # round the trimmed size up to a multiple of this
    paper_round_model: bool = False  # ledger sequential Alg.1 as N rounds
    use_sort: bool = False  # Shrinkwrap "sort&cut" baseline: bitonic sort on
    # the keep-bit instead of the secure shuffle (O(log^2 N) rounds vs O(1))

    def describe(self) -> str:
        tag = "sortcut" if self.use_sort else self.addition
        return f"rho({self.noise.name},{tag})"


class Resizer:
    """Stateless executor for one Resizer instance; see module docstring."""

    def __init__(self, cfg: ResizerConfig):
        self.cfg = cfg

    # -- noise addition: mark k ------------------------------------------------

    def _coins_parallel(
        self, n: int, p: float, prf: PRFSetup, key: jax.Array
    ) -> BShare:
        """Secret-shared Bernoulli coins via m private fixed-point uniforms.

        Each party's draw is a trivial arithmetic sharing; the sum is local.
        One a2b + one comparison per tuple, fully vectorized (1 round-trip
        pattern), matching Table 1's O(N) communication.
        """
        draws = jax.random.bits(key, shape=(3, n), dtype=jnp.uint32) & jnp.uint32(
            FP_ONE - 1
        )
        legs = jnp.zeros((3, 3, n), dtype=jnp.uint32)
        for i in range(3):
            legs = legs.at[i, i].set(draws[i])
        total = AShare(legs[0]) + AShare(legs[1]) + AShare(legs[2])

        if self.cfg.coin_mode == "corrected":
            # frac(sum) uniform on [0,1): exact Bernoulli(p)
            sum_b = a2b(total, prf.fold(801), width=FP_BITS + 2)
            frac = sum_b.and_public(FP_ONE - 1)
            thresh = int(round(p * FP_ONE))
            return lt_public(frac, thresh, prf.fold(802), width=FP_BITS)
        elif self.cfg.coin_mode == "paper":
            # Algorithm 2 verbatim: sum of m uniforms vs m*p (Irwin-Hall bias)
            sum_b = a2b(total, prf.fold(801), width=FP_BITS + 2)
            thresh = int(round(3 * p * FP_ONE))
            return lt_public(sum_b, thresh, prf.fold(802), width=FP_BITS + 2)
        raise ValueError(self.cfg.coin_mode)

    def _mark_parallel(
        self, table: SecretTable, p: float, prf: PRFSetup, key: jax.Array
    ) -> BShare:
        coin = self._coins_parallel(table.n, p, prf, key)
        return or_bit(table.valid, coin, prf.fold(803))

    def _mark_sequential(
        self, table: SecretTable, eta: int, prf: PRFSetup
    ) -> BShare:
        """Alg. 1 semantics: keep the first eta fillers (by position).

        filler prefix-count via bit2a + local cumsum; one vectorized secure
        comparison against the budget. (Beyond-paper parallelization; the
        original's N sequential rounds can be modeled in the ledger.)
        """
        c = table.valid
        not_c = c.xor_public(c.ring.const(1))
        fa = bit2a(not_c, prf.fold(811))
        cum = fa.cumsum(axis=0)
        cum_b = a2b(cum, prf.fold(812))
        within = lt_public(cum_b, eta + 1, prf.fold(813))  # cum <= eta
        k = or_bit(c, within, prf.fold(814))
        if self.cfg.paper_round_model:
            # MP-SPDZ's unbatchable secure counter: N dependent rounds
            log_comm("seq_round_model_extra", table.n, 0)
        return k

    # -- full resize -----------------------------------------------------------

    def __call__(
        self,
        table: SecretTable,
        prf: PRFSetup,
        key: jax.Array,
        bucket_fn: Optional[Callable[[int], int]] = None,
    ) -> Tuple[SecretTable, Dict]:
        cfg = self.cfg
        n = table.n
        t = oracle_true_count(table)

        if isinstance(cfg.noise, NoTrim):
            return table, {"n": n, "t": t, "s": n, "skipped": True}

        k_noise, k_shuf = jax.random.split(key)

        # 1-2. noise generation + addition
        if cfg.addition == "parallel":
            p = cfg.noise.sample_p(k_noise, n, t)
            k_col = self._mark_parallel(table, p, prf, k_noise)
            info_noise = {"p": p}
        elif cfg.addition == "sequential":
            eta = int(np.clip(cfg.noise.sample_eta(k_noise, n, t), 0, max(n - t, 0)))
            k_col = self._mark_sequential(table, eta, prf)
            info_noise = {"eta": eta}
        else:
            raise ValueError(cfg.addition)

        # 3. break linkage: secure shuffle (Reflex) or Shrinkwrap's bitonic
        #    sort on the keep-bit (sort&cut baseline; keeps true+filler rows
        #    at the front so revealing the sorted k discloses only S).
        #    Lazy (join-view) columns skip the physical shuffle: their shares
        #    are gathered from the base tables only for the S kept rows below;
        #    their shuffle traffic is still ledgered (comm is protocol-
        #    determined — see module docstring). AShare-backed views are
        #    excluded: the eager path a2b-converts them at full size before
        #    shuffling, and deferring that conversion would change the ledger.
        from ..ops.table import LazyGather

        lazy_cols = {
            name: c
            for name, c in table.cols.items()
            if isinstance(c, LazyGather)
            and isinstance(c.base, BShare)
            and not cfg.use_sort
        }
        cols = {"__k": k_col, "__valid": table.valid}
        cols.update(
            {
                name: table.bshare_col(name, prf)
                for name in table.cols
                if name not in lazy_cols
            }
        )
        if cfg.use_sort:
            from .sort import bitonic_sort_narrow
            from ..ops.groupby import pad_pow2

            padded = pad_pow2(SecretTable({k: v for k, v in cols.items() if k not in ("__k", "__valid")}, table.valid))
            # re-assemble with the padded keep column (pad rows keep=0);
            # only the keep bit + a row index ride the sorting network — the
            # payload is gathered once post-sort (bitonic_sort_narrow)
            k_pad = k_col.pad_rows(padded.n)
            cols = {"__k": k_pad, "__valid": padded.valid}
            cols.update(padded.cols)
            shuffled = bitonic_sort_narrow(cols, "__k", prf.fold(821), descending=True)
            n = padded.n
        else:
            shuffled = secure_shuffle(cols, prf.fold(821))
            if lazy_cols:
                from .shuffle import HOPS

                lazy_row_bytes = sum(
                    c.ring.bytes * (c.size // max(c.shape[0], 1))
                    for c in lazy_cols.values()
                )
                log_comm("shuffle_deferred_payload", 0, HOPS * n * lazy_row_bytes)
        k_col = shuffled.pop("__k")
        valid = shuffled.pop("__valid")

        # 4. reveal-and-trim: open k (the only disclosure), drop k=0 rows
        k_open = np.asarray(
            (k_col.shares[0] ^ k_col.shares[1] ^ k_col.shares[2]) & 1
        )
        log_comm("reveal_k", 1, n * k_col.ring.bytes, payload=k_col.shares)
        s = int(k_open.sum())
        keep = np.nonzero(k_open)[0]

        s_padded = s
        if bucket_fn is not None:
            s_padded = max(bucket_fn(s), s)
        elif cfg.bucket > 1:
            s_padded = ((s + cfg.bucket - 1) // cfg.bucket) * cfg.bucket
        s_padded = min(max(s_padded, 1), n)

        keep = jnp.asarray(keep)
        out = SecretTable(dict(shuffled), valid).gather_rows(keep)
        if lazy_cols:
            # Deferred payload: map the kept (shuffled) positions back through
            # the composed permutation to product rows, gather exactly S rows
            # from each base table, and re-randomize (the resharing the
            # payload would have received in the shuffle hops).
            from .shuffle import _rerandomize, composed_permutation

            orig_rows = jnp.take(composed_permutation(prf.fold(821), n), keep)
            for i, (name, lc) in enumerate(lazy_cols.items()):
                out.cols[name] = _rerandomize(
                    lc.gather(orig_rows), prf.fold(823), 860 + i
                )
        if s_padded > s:
            out = out.pad_rows(s_padded)

        info = {"n": n, "t": t, "s": s, "s_padded": s_padded, **info_noise}
        return out, info
