"""Waksman permutation-network control-bit generation.

MP-SPDZ implements its secure shuffle by evaluating a Waksman network [25]
whose control bits encode the secret permutation. Our default shuffle is the
3-hop permutation-composition protocol (fewer rounds — see core/shuffle.py),
but we provide the Waksman routing for completeness / cross-checking against
the MP-SPDZ cost model: a network on n = 2^m inputs has n·log2(n) - n + 1
switches; evaluating it obliviously costs one select (1 AND-word) per switch.

``route(perm)`` returns the layered switch settings; ``apply(bits, xs)``
evaluates the network on plaintext (the oracle used in tests and cost
calibration — the oblivious evaluation would replace each switch with the
share-level ``select``).
"""
from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["route", "apply_network", "n_switches"]


def n_switches(n: int) -> int:
    if n <= 1:
        return 0
    if n == 2:
        return 1
    half = n // 2
    return (n - 1) + 2 * n_switches(half)  # n/2-1 + n/2 outer + two subnets


def route(perm: np.ndarray) -> List:
    """Recursively compute switch settings for an AS-Waksman network.

    Returns a nested structure: (in_bits, (sub_top, sub_bottom), out_bits)
    for n > 2; a single bool for n == 2; None for n == 1.
    perm maps output position -> input position (out[i] = in[perm[i]]).
    """
    perm = np.asarray(perm)
    n = len(perm)
    if n == 1:
        return None
    if n == 2:
        return bool(perm[0] == 1)
    half = n // 2
    assert n % 2 == 0, "power-of-two sizes only (engine pads)"

    in_bits = [False] * half  # input switch i handles inputs (2i, 2i+1)
    out_bits = [False] * half  # output switch i handles outputs (2i, 2i+1)
    top = [-1] * half  # sub-permutations being constructed
    bot = [-1] * half
    out_done = [False] * half

    # Loop-based routing: alternate constraints between output and input
    # switches. Convention: output switch i unset (bit False) sends top
    # subnet -> output 2i; the LAST output switch is fixed straight (Waksman).
    out_bits[half - 1] = False
    inv = np.empty(n, dtype=int)
    inv[perm] = np.arange(n)

    def set_path_from_output(out_pos: int, use_top: bool):
        """Fix the route of output ``out_pos`` through the given subnet and
        propagate the implied constraints around the cycle."""
        while True:
            osw, olane = divmod(out_pos, 2)
            sub = 0 if use_top == (not olane) else 0  # placeholder
            # output switch bit: which subnet feeds lane ``olane``
            # bit False: top->lane0, bottom->lane1; bit True: swapped
            bit = (use_top and olane == 1) or (not use_top and olane == 0)
            # i.e. top feeding lane1 or bottom feeding lane0 requires swap
            out_bits[osw] = bool(bit)
            out_done[osw] = True
            subnet = top if use_top else bot
            in_pos = perm[out_pos]
            isw, ilane = divmod(in_pos, 2)
            # input switch: route in_pos to this subnet
            # bit False: lane0->top, lane1->bottom; True: swapped
            ibit = (use_top and ilane == 1) or (not use_top and ilane == 0)
            in_bits[isw] = bool(ibit)
            subnet[osw] = isw
            # the sibling input lane must go to the other subnet
            sib_in = isw * 2 + (1 - ilane)
            sib_out = inv[sib_in]
            other = bot if use_top else top
            ssw = sib_out // 2
            other[ssw] = isw
            s_bit = ((not use_top) and (sib_out % 2 == 1)) or (use_top and (sib_out % 2 == 0))
            if out_done[ssw]:
                break
            out_bits[ssw] = bool(s_bit)
            out_done[ssw] = True
            # continue the cycle from the sibling output's partner lane
            nxt_out = ssw * 2 + (1 - (sib_out % 2))
            out_pos = nxt_out
            # which subnet must feed nxt_out given out_bits[ssw]?
            lane = nxt_out % 2
            use_top = (lane == 0) == (not out_bits[ssw])
            if out_done[nxt_out // 2] and top[nxt_out // 2] >= 0 and bot[nxt_out // 2] >= 0:
                break

    for start in range(half - 1, -1, -1):
        if top[start] >= 0 and bot[start] >= 0:
            continue
        # route output 2*start through per current out_bits convention
        lane0 = 2 * start
        use_top = not out_bits[start]
        set_path_from_output(lane0, use_top)
        if bot[start] < 0 or top[start] < 0:
            lane1 = 2 * start + 1
            set_path_from_output(lane1, out_bits[start])

    return (in_bits, (route(np.array(top)), route(np.array(bot))), out_bits)


def apply_network(bits, xs: np.ndarray) -> np.ndarray:
    """Plaintext evaluation (oracle): out = xs permuted per the routing."""
    xs = np.asarray(xs)
    n = len(xs)
    if n == 1:
        return xs.copy()
    if n == 2:
        return xs[::-1].copy() if bits else xs.copy()
    in_bits, (sub_t, sub_b), out_bits = bits
    half = n // 2
    top_in = np.empty(half, dtype=xs.dtype)
    bot_in = np.empty(half, dtype=xs.dtype)
    for i in range(half):
        a, b = xs[2 * i], xs[2 * i + 1]
        if in_bits[i]:
            a, b = b, a
        top_in[i], bot_in[i] = a, b
    top_out = apply_network(sub_t, top_in)
    bot_out = apply_network(sub_b, bot_in)
    out = np.empty(n, dtype=xs.dtype)
    for i in range(half):
        a, b = top_out[i], bot_out[i]
        if out_bits[i]:
            a, b = b, a
        out[2 * i], out[2 * i + 1] = a, b
    return out
