"""Boolean circuits over XOR-replicated shares (Secrecy/ABY3-style).

Comparisons dominate oblivious SQL operators (filters, joins, sorts). Following
Secrecy [Liagouris et al., NSDI'23] we keep table data in boolean (XOR) sharing
and evaluate comparisons as shallow circuits; only the interactive AND gates
cost communication (1 round each; independent ANDs within a level are batched
into the same round).

Circuit inventory (k = ring width, default 32):

==============  ========================  ==========================
circuit         rounds                    AND-words / lane
==============  ========================  ==========================
eq / eq_public  log2 k            (5)     log2 k            (5)
lt / le         1 + log2 k        (6)     1 + 2 log2 k      (11)
lt_public       log2 k            (5)     2 log2 k          (10)
ks_add          1 + log2 k        (6)     1 + 2 log2 k      (11)
bit2a           2                         2 (ring mults)
b2a             2 (parallel bits)         2k
a2b             2 ks_add          (12)    2 + 4 log2 k      (22)
==============  ========================  ==========================

Execution paths: when ``repro.kernels.fusion_enabled()``, the gate loops route
through the single-launch fused Pallas kernels (``ks_prefix`` for the
Kogge-Stone levels and the equality AND-fold, ``a2b_fused`` for the full
conversion / bit injection) — one kernel dispatch instead of one ``rss_gate``
dispatch per level. The fused wrappers derive the per-level zero-sharings with
the *same* PRF folds and log the *same* per-gate ledger entries as the
gate-by-gate path below, so shares and (rounds, bytes/party) are bit-identical
across paths; only launch count and memory traffic change (DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import fusion_enabled, kernels_enabled
from .ledger import fused_scope, log_comm
from .prf import PRFSetup, _fold_keys, _zero_share
from .sharing import AShare, BShare, _cross_terms_xor, and_, mul

__all__ = [
    "eq",
    "eq_public",
    "lt",
    "le",
    "lt_public",
    "le_public",
    "ks_add",
    "bit2a",
    "b2a",
    "a2b",
    "and_bit",
    "or_bit",
]


def _fused(name: str, rounds: int):
    return fused_scope(name, rounds)


def _and_pair(a1: BShare, b1: BShare, a2: BShare, b2: BShare, prf: PRFSetup):
    """Two independent ANDs evaluated in a single communication round."""
    x = BShare(jnp.stack([a1.shares, a2.shares], axis=1))
    y = BShare(jnp.stack([b1.shares, b2.shares], axis=1))
    z = and_(x, y, prf)
    return BShare(z.shares[:, 0]), BShare(z.shares[:, 1])


# Whole-level jitted gate payloads for the non-fused path: one dispatch per
# communication round instead of a chain of eager share ops. The PRF fold,
# zero-sharing, and cross terms are the same computations the gate-by-gate
# path runs, so shares and ledger entries are bit-identical.

@functools.partial(jax.jit, static_argnames=("d",))
def _ks_level_words(g, p, pair_keys, tag, d: int):
    keys = _fold_keys(pair_keys, tag)
    alpha = _zero_share(keys, (2,) + g.shape[1:], g.dtype, xor=True)
    x = jnp.stack([p, p], axis=1)
    y = jnp.stack([g << d, p << d], axis=1)
    z = _cross_terms_xor(x, y) ^ alpha
    return g ^ z[:, 0], z[:, 1]


@functools.partial(jax.jit, static_argnames=("d",))
def _eq_fold_words(v, pair_keys, d: int):
    keys = _fold_keys(pair_keys, d)
    alpha = _zero_share(keys, v.shape[1:], v.dtype, xor=True)
    return _cross_terms_xor(v, v >> d) ^ alpha


# -----------------------------------------------------------------------------
# Equality
# -----------------------------------------------------------------------------

def _and_reduce_bits(v: BShare, prf: PRFSetup, width: int) -> BShare:
    """AND all ``width`` bits of each lane into the LSB (log2(width) rounds)."""
    if fusion_enabled():
        from ..kernels.ks_prefix.ops import and_fold_fused

        return and_fold_fused(v, prf, width).and_public(v.ring.const(1))
    d = width // 2
    while d >= 1:
        if kernels_enabled():
            v = and_(v, v >> d, prf.fold(d))
        else:
            log_comm("and", 1, v.size * v.ring.bytes)
            v = BShare(_eq_fold_words(v.shares, prf.pair_keys, d))
        d //= 2
    return v.and_public(v.ring.const(1))


def eq(x: BShare, y: BShare, prf: PRFSetup, width: int | None = None) -> BShare:
    """x == y -> single-bit BShare in the LSB. XOR is local, so secret-secret
    equality costs the same as secret-public: a log2(k)-deep AND tree."""
    width = width or x.ring.bits
    with _fused("eq", rounds=width.bit_length() - 1):
        v = ~(x ^ y)
        return _and_reduce_bits(v, prf, width)


def eq_public(x: BShare, c, prf: PRFSetup, width: int | None = None) -> BShare:
    width = width or x.ring.bits
    with _fused("eq", rounds=width.bit_length() - 1):
        v = ~(x.xor_public(c))
        return _and_reduce_bits(v, prf, width)


# -----------------------------------------------------------------------------
# Comparison: unsigned borrow-lookahead (Kogge-Stone prefix)
# -----------------------------------------------------------------------------

def _ks_levels(
    g: BShare, p: BShare, prf: PRFSetup, width: int, fold_base: int
) -> BShare:
    """All Kogge-Stone levels of the (g, p) prefix recurrence; returns the
    final g. One fused kernel launch, or one batched AND pair per level."""
    if fusion_enabled():
        from ..kernels.ks_prefix.ops import ks_levels_fused

        return ks_levels_fused(g, p, prf, width, fold_base)
    d = 1
    while d < width:
        if kernels_enabled():
            pg, pp = _and_pair(p, g << d, p, p << d, prf.fold(fold_base + d))
            g = g ^ pg
            p = pp
        else:
            log_comm("and", 1, 2 * g.size * g.ring.bytes)
            gs, ps = _ks_level_words(
                g.shares, p.shares, prf.pair_keys, fold_base + d, d
            )
            g, p = BShare(gs), BShare(ps)
        d *= 2
    return g


def _borrow_prefix(g: BShare, p: BShare, prf: PRFSetup, width: int) -> BShare:
    """Inclusive prefix of the borrow recurrence B_j = g_j | (p_j & B_{j-1}).

    g and p are bit-disjoint so | == ^. Each Kogge-Stone level performs two
    independent ANDs, batched into one round.
    """
    return _ks_levels(g, p, prf, width, fold_base=100)


def lt(x: BShare, y: BShare, prf: PRFSetup, width: int | None = None) -> BShare:
    """Unsigned x < y -> single-bit BShare (borrow-out of x - y)."""
    width = width or x.ring.bits
    levels = width.bit_length() - 1
    with _fused("lt", rounds=1 + levels):
        g = and_(~x, y, prf.fold(7))  # borrow generate: x_j=0, y_j=1
        p = ~(x ^ y)  # borrow propagate: x_j == y_j (local)
        b = _borrow_prefix(g, p, prf, width)
        return (b >> (width - 1)).and_public(b.ring.const(1))


def lt_public(x: BShare, c, prf: PRFSetup, width: int | None = None) -> BShare:
    """x < c with public c: the generate AND becomes local (saves a round)."""
    width = width or x.ring.bits
    levels = width.bit_length() - 1
    if isinstance(c, int):
        c = c & x.ring.mask  # wrap without overflowing jnp's int32 default
    with _fused("lt", rounds=levels):
        g = (~x).and_public(c)  # local: c is public
        p = ~(x.xor_public(c))
        b = _borrow_prefix(g, p, prf, width)
        return (b >> (width - 1)).and_public(b.ring.const(1))


def le(x: BShare, y: BShare, prf: PRFSetup, width: int | None = None) -> BShare:
    """x <= y  ==  not (y < x)."""
    return _not_bit(lt(y, x, prf, width))


def le_public(x: BShare, c, prf: PRFSetup, width: int | None = None) -> BShare:
    """x <= c (public c)  ==  x < c+1 for c < 2^k - 1."""
    if isinstance(c, int):
        return lt_public(x, (c + 1) & x.ring.mask, prf, width)
    return lt_public(x, jnp.asarray(c).astype(x.ring.dtype) + 1, prf, width)


def gt_public(x: BShare, c, prf: PRFSetup, width: int | None = None) -> BShare:
    """x > c (public c) == not(x < c+1)."""
    if isinstance(c, int):
        return _not_bit(lt_public(x, (c + 1) & x.ring.mask, prf, width))
    return _not_bit(lt_public(x, jnp.asarray(c).astype(x.ring.dtype) + 1, prf, width))


def _not_bit(b: BShare) -> BShare:
    """Negate a single-bit share (flip only the LSB)."""
    return b.xor_public(b.ring.const(1))


def and_bit(a: BShare, b: BShare, prf: PRFSetup) -> BShare:
    return and_(a, b, prf)


def or_bit(a: BShare, b: BShare, prf: PRFSetup) -> BShare:
    return _not_bit(and_(_not_bit(a), _not_bit(b), prf))


# -----------------------------------------------------------------------------
# Kogge–Stone adder (boolean addition; used by a2b)
# -----------------------------------------------------------------------------

def ks_add(x: BShare, y: BShare, prf: PRFSetup, width: int | None = None) -> BShare:
    width = width or x.ring.bits
    levels = width.bit_length() - 1
    with _fused("ks_add", rounds=1 + levels):
        g = and_(x, y, prf.fold(11))
        p = x ^ y
        g = _ks_levels(g, p, prf, width, fold_base=200)
        carry = g << 1
        return x ^ y ^ carry


# -----------------------------------------------------------------------------
# Share conversions
# -----------------------------------------------------------------------------

def _trivial_a(share_bits: jnp.ndarray, slot: int) -> AShare:
    """Arithmetic sharing (0,..,v,..,0) with v at ``slot`` — locally
    constructible by the two parties that hold that share leg."""
    z = jnp.zeros((3,) + share_bits.shape, dtype=share_bits.dtype)
    return AShare(z.at[slot].set(share_bits))


def _trivial_b(share_word: jnp.ndarray, slot: int) -> BShare:
    z = jnp.zeros((3,) + share_word.shape, dtype=share_word.dtype)
    return BShare(z.at[slot].set(share_word))


def bit2a(b: BShare, prf: PRFSetup) -> AShare:
    """Convert a single-bit XOR sharing to an arithmetic sharing of {0,1}.

    b = b0 ^ b1 ^ b2; XOR is emulated arithmetically twice:
    u ^ v = u + v - 2uv. Two ring multiplications, 2 rounds.
    """
    ring = b.ring
    with _fused("bit2a", rounds=2):
        if fusion_enabled():
            from ..kernels.a2b_fused.ops import bit2a_fused

            return bit2a_fused(b, prf)
        bits = b.shares & ring.const(1)
        a0, a1, a2 = (_trivial_a(bits[i], i) for i in range(3))
        t = a0 + a1 - mul(a0, a1, prf.fold(21)).mul_public(2)
        return t + a2 - mul(t, a2, prf.fold(22)).mul_public(2)


def b2a(x: BShare, prf: PRFSetup, width: int | None = None) -> AShare:
    """Full-word boolean -> arithmetic via parallel per-bit injection.

    All k bit2a instances run in the same 2 rounds (they are independent);
    the weighted recombination is local.
    """
    ring = x.ring
    width = width or ring.bits
    with _fused("b2a", rounds=2):
        planes = BShare(
            jnp.stack([(x.shares >> j) & ring.const(1) for j in range(width)], axis=-1)
        )
        bits_a = bit2a(planes, prf)
        import numpy as _np

        weights = jnp.asarray(
            (_np.uint64(1) << _np.arange(width, dtype=_np.uint64)).astype(ring.np_dtype)
        )
        return AShare(jnp.sum(bits_a.shares * weights, axis=-1, dtype=ring.dtype))


def a2b(x: AShare, prf: PRFSetup, width: int | None = None) -> BShare:
    """Arithmetic -> boolean: boolean-share each arithmetic leg trivially,
    then two Kogge-Stone additions (2 * (1 + log2 k) rounds). One fused
    kernel launch, or 2 * (1 + log2 k) gate launches."""
    width = width or x.ring.bits
    with _fused("a2b", rounds=2 * (1 + width.bit_length() - 1)):
        if fusion_enabled():
            from ..kernels.a2b_fused.ops import a2b_fused

            return a2b_fused(x, prf, width)
        legs = [_trivial_b(x.shares[i], i) for i in range(3)]
        s = ks_add(legs[0], legs[1], prf.fold(31), width)
        return ks_add(s, legs[2], prf.fold(32), width)
