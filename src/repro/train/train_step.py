"""Train step factory: loss -> grads -> AdamW, with gradient-accumulation
microbatching (a lax.scan over microbatches — constant memory in the number
of accumulation steps) and donation-friendly signature."""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import loss_fn
from .optimizer import AdamWConfig, adamw_update

__all__ = ["make_train_step"]


def make_train_step(cfg, opt_cfg: AdamWConfig, grad_accum: int = 1) -> Callable:
    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch) -> Tuple[Dict, Dict, Dict]:
        if grad_accum <= 1:
            loss, metrics, grads = compute_grads(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                g_acc, l_acc = acc
                loss, _, grads = compute_grads(params, mb)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            (g_sum, loss_sum), _ = jax.lax.scan(body, (zero, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, g_sum)
            loss = loss_sum / grad_accum
            metrics = {"ce": loss, "aux": jnp.zeros(())}

        new_params, new_state, opt_metrics = adamw_update(
            opt_cfg, grads, params, opt_state
        )
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_state, out_metrics

    return train_step
