"""Fault-tolerant checkpointing.

Design (1000+-node posture, DESIGN.md §6):

* **atomic**: arrays + manifest are written to ``step_N.tmp/`` and the
  directory is os.rename()d into place — a crash mid-save never corrupts the
  latest checkpoint.
* **async**: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a background thread, overlapping I/O with the next training steps.
* **keep-last-k** garbage collection.
* **elastic restore**: arrays are stored logically (full, unsharded); restore
  takes the *new* mesh's shardings and device_puts accordingly, so a 2-pod run
  can restart on 1 pod (or a different DP/TP split) without conversion — the
  checkpoint is mesh-agnostic by construction.
* **bitwise resume**: save captures params/opt_state/step/data-pipeline
  cursor; tests assert interrupted-and-resumed == uninterrupted.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        ]
        return max(steps) if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Dict) -> None:
        """Synchronous atomic save. ``state`` is any pytree of arrays plus
        json-able scalars under the "meta" key."""
        meta = state.pop("meta", {})
        leaves, treedef = _flatten(state)
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **{str(i): a for i, a in enumerate(leaves)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(
                {
                    "step": step,
                    "treedef": jax.tree_util.tree_structure(state).__repr__(),
                    "n_leaves": len(leaves),
                    "meta": meta,
                },
                f,
            )
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        state["meta"] = meta
        self._gc()

    def save_async(self, step: int, state: Dict) -> None:
        """Snapshot to host now, write in the background."""
        snapshot = {"meta": dict(state.get("meta", {}))}
        arrays = {k: v for k, v in state.items() if k != "meta"}
        leaves, treedef = jax.tree_util.tree_flatten(arrays)
        host = [np.asarray(x) for x in leaves]  # device->host copy (blocking)
        rebuilt = jax.tree_util.tree_unflatten(treedef, host)
        snapshot.update(rebuilt)
        self.wait()
        self._thread = threading.Thread(target=self.save, args=(step, snapshot))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(
        self,
        step: Optional[int],
        like: Dict,
        shardings: Optional[Dict] = None,
    ) -> Tuple[int, Dict]:
        """Restore into the structure of ``like`` (a pytree template).

        ``shardings``: optional matching pytree of NamedSharding for the
        *current* mesh — arrays are device_put with them (elastic restore).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        arrays = {k: v for k, v in like.items() if k != "meta"}
        leaves, treedef = jax.tree_util.tree_flatten(arrays)
        loaded = [data[str(i)] for i in range(manifest["n_leaves"])]
        assert len(loaded) == len(leaves), "checkpoint/template structure mismatch"
        if shardings is not None:
            sleaves = jax.tree_util.tree_leaves(
                {k: v for k, v in shardings.items() if k != "meta"}
            )
            loaded = [jax.device_put(a, s) for a, s in zip(loaded, sleaves)]
        out = jax.tree_util.tree_unflatten(treedef, loaded)
        out["meta"] = manifest.get("meta", {})
        return step, out
