"""AdamW with warmup+cosine schedule and global-norm clipping.

Written against plain pytrees (no optax dependency in this container).
Moments live in f32; ZeRO-1 sharding of the moments is applied by the caller
through ``repro.sharding.zero1_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig, grads, params, state
) -> Tuple[Dict, Dict, Dict]:
    count = state["count"] + 1
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, state["v"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1**c
    bc2 = 1 - cfg.b2**c
    lr = lr_schedule(cfg, count)

    def upd(p, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, {"grad_norm": gn, "lr": lr}
