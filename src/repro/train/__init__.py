from .optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule  # noqa: F401
from .train_step import make_train_step  # noqa: F401
from .checkpoint import Checkpointer  # noqa: F401
