"""Query execution engine.

Executes a plan tree bottom-up. Every operator protocol runs on static shapes;
the *only* place a public size changes is a ``Resize`` node's reveal-and-trim
(and a public LIMIT) — so dynamic re-dispatch on the revealed size is both
legitimate (it is the disclosed value) and bounded by bucketing.

The engine records a per-node execution report: wall seconds, the ledger's
(rounds, bytes/party), and input/output oblivious sizes — this is what the
benchmarks print and what reproduces the paper's Figures 6-9.

Batched execution (DESIGN.md §11): :meth:`Engine.execute_batch` runs K
structurally identical plans as ONE engine pass. Each operator's protocol is
``jax.vmap``-ed over the K input tables stacked along a new leading batch
axis, so every kernel launch — Kogge-Stone comparison levels, a2b
conversions, bitonic compare-exchange stages — and its PRF folds are shared
across the batch instead of repeated per query. Because the engine's PRF is
fixed per instance and a vmapped body traces with per-slot shapes, every
slot's shares are bit-identical to what a serial :meth:`execute` of that
query would have produced, and the one traced ledger profile IS each slot's
per-query tally (demuxed into per-slot :class:`ExecutionReport`s). Resize
nodes run per slot — each query folds its own noise counter, so noise stays
fresh and i.i.d. per query and CRT observations are never merged — and if
the revealed trim sizes diverge, the batch splits into per-slot execution
for the remainder of the plan.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..config import RuntimeConfig, use_config
from ..core.ledger import CommLedger, active_exchange, batched_tally, log_comm
from ..core import material
from ..core.prf import PRFSetup, setup_prf
from ..obs import redact
from ..obs import trace as obs_trace
from ..ops import SecretTable
from ..plan.nodes import PlanNode
from ..plan.registry import infer_schema, lookup, plan_batchable

__all__ = ["Engine", "ExecutionReport", "NodeStats"]


@dataclasses.dataclass
class NodeStats:
    node: str
    n_in: int  # first input's oblivious size (legacy field; see n_ins)
    n_out: int
    seconds: float
    bytes_per_party: int
    rounds: int
    n_ins: List[int] = dataclasses.field(default_factory=list)  # all inputs
    extra: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ExecutionReport:
    nodes: List[NodeStats] = dataclasses.field(default_factory=list)

    @property
    def total_seconds(self) -> float:
        return sum(s.seconds for s in self.nodes)

    @property
    def total_bytes(self) -> int:
        return sum(s.bytes_per_party for s in self.nodes)

    @property
    def total_rounds(self) -> int:
        return sum(s.rounds for s in self.nodes)

    def to_dict(self) -> Dict:
        """JSON-safe per-node report (machine-readable twin of summary())."""

        def safe(v):
            if isinstance(v, dict):
                return {k: safe(x) for k, x in v.items()}
            if isinstance(v, (list, tuple)):
                return [safe(x) for x in v]
            if hasattr(v, "item"):  # numpy / jax scalars
                return v.item()
            return v

        return {
            "nodes": [
                {
                    "node": s.node,
                    "n_in": int(s.n_in),
                    "n_ins": [int(n) for n in s.n_ins],
                    "n_out": int(s.n_out),
                    "seconds": float(s.seconds),
                    "bytes_per_party": int(s.bytes_per_party),
                    "rounds": int(s.rounds),
                    "extra": safe(s.extra),
                }
                for s in self.nodes
            ],
            "total_seconds": float(self.total_seconds),
            "total_bytes": int(self.total_bytes),
            "total_rounds": int(self.total_rounds),
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict) -> "ExecutionReport":
        """Rebuild a report from :meth:`to_dict` output — the wire form the
        networked runtime's party servers return to the coordinator."""
        return cls(
            nodes=[
                NodeStats(
                    node=n["node"],
                    n_in=int(n["n_in"]),
                    n_ins=[int(x) for x in n.get("n_ins", [])],
                    n_out=int(n["n_out"]),
                    seconds=float(n["seconds"]),
                    bytes_per_party=int(n["bytes_per_party"]),
                    rounds=int(n["rounds"]),
                    extra=dict(n.get("extra", {})),
                )
                for n in d.get("nodes", [])
            ]
        )

    def summary(self) -> str:
        def ins(s: NodeStats) -> str:
            # all inputs, not just the first: a join reads "512x128"
            return "x".join(str(n) for n in s.n_ins) if s.n_ins else "-"

        def note(s: NodeStats) -> str:
            if not s.extra:
                return ""
            pub = redact.public_view(s.extra)
            if pub.get("skipped"):
                return "trim skipped"
            parts = []
            if pub.get("s") is not None:
                parts.append(f"S={pub['s']}")
            sp = pub.get("s_padded")
            if sp is not None and sp != pub.get("s"):
                parts.append(f"pad->{sp}")
            return " ".join(parts)

        lines = [
            f"{'node':<42}{'n_ins':>11}{'n_out':>9}{'sec':>9}"
            f"{'MiB/party':>11}{'rounds':>8}  extra"
        ]
        for s in self.nodes:
            lines.append(
                (
                    f"{s.node:<42}{ins(s):>11}{s.n_out:>9}{s.seconds:>9.3f}"
                    f"{s.bytes_per_party / 2**20:>11.3f}{s.rounds:>8}  {note(s)}"
                ).rstrip()
            )
        lines.append(
            f"{'TOTAL':<42}{'':>11}{'':>9}{self.total_seconds:>9.3f}"
            f"{self.total_bytes / 2**20:>11.3f}{self.total_rounds:>8}"
        )
        return "\n".join(lines)


def _block(table: SecretTable) -> None:
    jax.block_until_ready(table.valid.shares)


# -----------------------------------------------------------------------------
# Batched-execution plumbing
# -----------------------------------------------------------------------------

def _stack_tables(tables: Sequence[SecretTable]) -> SecretTable:
    """K structurally identical tables -> one table whose leaves carry a new
    leading batch axis (shares become ``(K, 3, n)``)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *tables)


def _broadcast_table(table: SecretTable, k: int) -> SecretTable:
    """One shared table viewed as a K-slot batch (zero-copy broadcast)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), table
    )


def _unstack_table(stacked: SecretTable, i: int) -> SecretTable:
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


@dataclasses.dataclass
class _BatchVal:
    """A plan node's output across the batch: either one stacked table (the
    vmapped fast path) or a per-slot list (after the batch split on divergent
    Resize trim sizes, or through a stateful per-slot hook)."""

    k: int
    stacked: Optional[SecretTable] = None
    slots: Optional[List[SecretTable]] = None

    def to_slots(self) -> List[SecretTable]:
        if self.slots is None:
            self.slots = [_unstack_table(self.stacked, i) for i in range(self.k)]
        return self.slots

    def slot_n(self, i: int) -> int:
        if self.slots is not None:
            return self.slots[i].n
        return int(self.stacked.valid.shares.shape[-1])


def _physical_sig(plan: PlanNode) -> tuple:
    """Preorder tuple of operator class names — the *physical* plan shape
    (logical fingerprints collapse physical variants by design)."""
    return (plan.label,) + tuple(
        s for c in plan.children() for s in _physical_sig(c)
    )


def _count_resizes(plan: PlanNode) -> int:
    """Noise-counter consumers per plan (post-order Resize count)."""
    n = sum(_count_resizes(c) for c in plan.children())
    return n + (1 if lookup(type(plan)).provides_resize_info else 0)


@dataclasses.dataclass
class _BatchCtx:
    """Per-``execute_batch`` state threaded through the plan walk."""

    k: int
    reports: List[ExecutionReport]
    ctr_base: int  # engine._resize_ctr before the batch started
    resizes_per_slot: int  # Resize nodes per plan (post-order count)
    resize_idx: int = 0  # next Resize node's post-order index

    def next_resize_index(self) -> int:
        j = self.resize_idx
        self.resize_idx += 1
        return j

    def slot_ctr_before(self, slot: int, resize_index: int) -> int:
        """The counter value engine._resize_ctr must hold *before* this
        slot executes its ``resize_index``-th Resize, so the fold matches a
        serial run of the K queries in submission order exactly: slot i's
        j-th resize consumes ``base + i * R + j + 1``."""
        return self.ctr_base + slot * self.resizes_per_slot + resize_index


class Engine:
    """Executes plans over a set of secret-shared base tables."""

    # process-wide jit cache: operator protocols are pure functions of
    # (static node spec, table shapes) — reusing compiled executables across
    # Engine instances removes both eager-dispatch overhead and recompiles
    # (a beyond-paper optimization; see EXPERIMENTS.md §Perf). LRU-bounded:
    # a long-running serving session sees an unbounded stream of (query,
    # revealed-size) shapes, so the cache would otherwise grow without limit;
    # eviction only costs a recompile on a shape not seen recently.
    _JIT_CACHE: "OrderedDict" = OrderedDict()
    _JIT_CACHE_MAX = 128
    # Logical hit/miss counters. "Logical" because a batched pass that reuses
    # one compiled program for K slots served K queries from the cache: a
    # lookup counts `count` hits on presence, and a batched compile counts one
    # miss plus K-1 hits (the other slots ride the same executable).
    _JIT_STATS: Dict[str, int] = {"hits": 0, "misses": 0}

    @classmethod
    def _jit_cache_get(cls, key, count: int = 1):
        hit = cls._JIT_CACHE.get(key)
        if hit is not None:
            cls._JIT_CACHE.move_to_end(key)
            cls._JIT_STATS["hits"] += count
        else:
            cls._JIT_STATS["misses"] += 1
            if count > 1:
                cls._JIT_STATS["hits"] += count - 1
        return hit

    @classmethod
    def _jit_cache_put(cls, key, value) -> None:
        cls._JIT_CACHE[key] = value
        cls._JIT_CACHE.move_to_end(key)
        while len(cls._JIT_CACHE) > cls._JIT_CACHE_MAX:
            cls._JIT_CACHE.popitem(last=False)

    @classmethod
    def jit_cache_stats(cls) -> Dict[str, float]:
        h, m = cls._JIT_STATS["hits"], cls._JIT_STATS["misses"]
        return {
            "hits": h,
            "misses": m,
            "hit_rate": h / max(h + m, 1),
            "size": len(cls._JIT_CACHE),
        }

    @classmethod
    def reset_jit_stats(cls) -> None:
        cls._JIT_STATS["hits"] = cls._JIT_STATS["misses"] = 0

    def __init__(
        self,
        tables: Dict[str, SecretTable],
        key: jax.Array | None = None,
        prf: PRFSetup | None = None,
        bucket_fn: Optional[Callable[[int], int]] = None,
        jit_ops: bool = False,  # per-op jit pays off for REPEATED same-shape
        # queries (serving); one-shot plans are faster eager (XLA-CPU compile
        # of a 4k-row sort network costs minutes) — see §Perf
        validate: bool = True,  # schema-check plans before any MPC work
        config: Optional[RuntimeConfig] = None,  # execution-strategy knobs;
        # None = the env fallback (repro.config.current_config)
    ):
        self.tables = tables
        key = key if key is not None else jax.random.PRNGKey(0)
        self.key = key
        self.prf = prf if prf is not None else setup_prf(jax.random.fold_in(key, 7))
        self.bucket_fn = bucket_fn
        self.jit_ops = jit_ops
        self.validate = validate
        self.config = config
        self._resize_ctr = 0
        self._last_resize_info: Optional[Dict] = None
        self.last_batch_stats: Dict = {}
        # revealed-size feedback: called as hook(node, info) after every
        # non-skipped Resize reveal-and-trim (serial and per-batch-slot alike).
        # The service wires this to the CalibrationStore so sizes that are
        # ALREADY public refine future planning — zero extra disclosure.
        self.reveal_hook: Optional[Callable[[PlanNode, Dict], None]] = None

    def execute(self, plan: PlanNode) -> tuple[SecretTable, ExecutionReport]:
        if self.validate:
            # registry schema propagation: unknown columns raise SchemaError
            # here, before a single share moves
            from ..sql.catalog import Catalog

            infer_schema(plan, Catalog.from_tables(self.tables))
        report = ExecutionReport()
        self._last_resize_info = None  # never carry info across runs
        with use_config(self.config), obs_trace.span("execute"):
            out = self._run(plan, report)
        return out, report

    # ------------------------------------------------------------------
    def _run_node_slot(
        self, node: PlanNode, children: List[SecretTable]
    ) -> Tuple[SecretTable, NodeStats]:
        """Execute one node for one slot under its own ledger and return the
        output with its filled report entry. The single accounting path for
        serial `_run`, the batch's split tail, and per-slot Resize — so
        batched and serial reports can never desynchronize field by field.

        Consumes the resize info `_apply` may have produced; clearing it
        keeps a later Resize (or a later run) from reporting stale info."""
        led = CommLedger()
        src = material.active_source()
        h0, m0 = (src.hits, src.misses) if src is not None else (0, 0)
        drv = active_exchange()
        if drv is not None:
            x0 = (drv.count, drv.stall_seconds, drv.wire_bytes)
        t0 = time.perf_counter()
        with led:
            out = self._apply(node, children)
        _block(out)
        dt = time.perf_counter() - t0
        tally = led.tally()
        n_ins = [t.n for t in children]
        extra = {}
        if src is not None and (src.hits - h0 or src.misses - m0):
            # hot/cold attribution for EXPLAIN ANALYZE: how much of this
            # node's correlated randomness came from the offline pool
            extra["offline"] = {"hits": src.hits - h0, "misses": src.misses - m0}
        if drv is not None and drv.count > x0[0]:
            # network attribution (networked mode only): this node's share
            # of the ring exchanges, with the time spent blocked on the
            # inbound frame — "net stall" in EXPLAIN ANALYZE. Stall is this
            # party's own clock; wire bytes equal the ledger's by audit.
            extra["wire"] = {
                "exchanges": drv.count - x0[0],
                "stall_seconds": round(drv.stall_seconds - x0[1], 6),
                "wire_bytes": drv.wire_bytes - x0[2],
            }
        if lookup(type(node)).provides_resize_info:
            info = self._last_resize_info or {}
            self._last_resize_info = None
            if self.reveal_hook is not None and info and not info.get("skipped"):
                self.reveal_hook(node, info)
            extra = {**info, **extra}
        stats = NodeStats(
            node=node.describe(),
            n_in=n_ins[0] if n_ins else 0,
            n_ins=n_ins,
            n_out=out.n,
            seconds=dt,
            bytes_per_party=int(tally["bytes_per_party"]),
            rounds=int(tally["rounds"]),
            extra=extra,
        )
        tr = obs_trace.active_tracer()
        if tr is not None:
            # `extra` passes the redaction boundary inside record(): the
            # resizer's t/p/eta never reach the span, S and padding do.
            tr.record(
                f"node[{node.label}]",
                seconds=dt,
                op=node.describe(),
                n_ins=n_ins,
                n_out=stats.n_out,
                bytes_per_party=stats.bytes_per_party,
                rounds=stats.rounds,
                **extra,
            )
        return out, stats

    def _run(self, node: PlanNode, report: ExecutionReport) -> SecretTable:
        children = [self._run(c, report) for c in node.children()]
        out, stats = self._run_node_slot(node, children)
        report.nodes.append(stats)
        return out

    @staticmethod
    def _cache_key(node: PlanNode, children: List[SecretTable]):
        child_sig = tuple(
            (t.n, tuple(sorted((k, type(v).__name__) for k, v in t.cols.items())))
            for t in children
        )
        # node.label disambiguates physical variants that share a describe()
        # string by design (JoinSortMerge inherits Join's — fingerprints must
        # not move when the planner flips algorithms, but compiled programs do)
        return (node.label, node.describe(), child_sig)

    def _apply(self, node: PlanNode, children: List[SecretTable]) -> SecretTable:
        prf = self.prf
        d = lookup(type(node))
        if d.engine_apply is not None:
            # stateful operators (Scan reads the table dict; Resize folds the
            # per-execution noise counter) bypass the jit path
            return d.engine_apply(self, node, children)
        fn = d.protocol(node)
        if not self.jit_ops:
            return fn(prf, *children)
        key = self._cache_key(node, children)
        jitted = Engine._jit_cache_get(key)
        if jitted is None:
            # Capture the ledger profile once at trace time: jit re-executions
            # skip the Python body, so replay the recorded cost on cache hits.
            profile: Dict = {}

            def traced(prf_arg, *tables, _fn=fn, _profile=profile):
                with CommLedger() as led:
                    out = _fn(prf_arg, *tables)
                _profile.setdefault("tally", led.tally())
                return out

            jitted = (jax.jit(traced), profile)
            Engine._jit_cache_put(key, jitted)
        jfn, profile = jitted
        out = jfn(prf, *children)
        if profile.get("tally"):
            t = profile["tally"]
            log_comm(node.label.lower(), int(t["rounds"]), int(t["bytes_per_party"]))
        return out

    # ------------------------------------------------------------------
    # Batched execution: K same-shape queries, one engine pass
    # ------------------------------------------------------------------

    def execute_batch(
        self, plans: Sequence[PlanNode]
    ) -> List[Tuple[SecretTable, ExecutionReport]]:
        """Execute K structurally identical plans as one stacked engine pass.

        Every plan must have the same fingerprint (``plan.pretty()``) — the
        admission scheduler's bucketing guarantees this. Slot i's result and
        per-node ledger tallies are bit-identical to what ``execute(plans[i])``
        would have produced had the K queries run serially in order (the
        noise-counter allocation in :class:`_BatchCtx` preserves per-slot
        Resize freshness exactly). Plans containing non-batchable operators,
        and batches of one, fall back to serial execution.

        ``last_batch_stats`` afterwards holds the physical cost of the pass:
        per-slot bytes all really move (bytes scale with K) but vmapped nodes
        share their synchronous rounds across the batch.
        """
        plans = list(plans)
        if not plans:
            return []
        if len(plans) == 1 or not plan_batchable(plans[0]):
            results = [self.execute(p) for p in plans]
            # same shape as the batched stats: serial execution shares nothing,
            # so the physical pass is just the sum of the per-query tallies
            self.last_batch_stats = {
                "slots": len(plans),
                "stacked_nodes": 0,
                "split_nodes": 0,
                "physical_bytes_per_party": sum(
                    r.total_bytes for _, r in results
                ),
                "physical_rounds": sum(r.total_rounds for _, r in results),
            }
            return results
        fp = plans[0].pretty()
        # pretty() is the *logical* fingerprint and is deliberately identical
        # across physical join variants; the preorder label tuple is the
        # physical signature — stacking a Join slot with a JoinSortMerge slot
        # would vmap one algorithm over the other's inputs
        psig = _physical_sig(plans[0])
        for p in plans[1:]:
            if p.pretty() != fp or _physical_sig(p) != psig:
                raise ValueError(
                    "execute_batch requires structurally identical plans; "
                    "bucket by full plan fingerprint (and physical operator "
                    "signature) before batching"
                )
        if self.validate:
            from ..sql.catalog import Catalog

            infer_schema(plans[0], Catalog.from_tables(self.tables))

        k = len(plans)
        resizes = _count_resizes(plans[0])
        ctx = _BatchCtx(
            k=k,
            reports=[ExecutionReport() for _ in range(k)],
            ctr_base=self._resize_ctr,
            resizes_per_slot=resizes,
        )
        self._last_resize_info = None
        self.last_batch_stats = {
            "slots": k,
            "stacked_nodes": 0,
            "split_nodes": 0,
            "physical_bytes_per_party": 0,
            "physical_rounds": 0,
        }
        try:
            with use_config(self.config), obs_trace.span(
                "execute", slots=k, batched=True
            ):
                out = self._run_batch(plans[0], ctx)
        finally:
            # The batch owns the counter range [base+1, base+k*R]; per-slot
            # execution rewinds within it non-monotonically. Skip past the
            # WHOLE range even on failure — some slots may already have
            # revealed sizes for counters in it, and a later query refolding
            # one would reuse noise the attacker has seen (unused counters
            # are merely skipped, which is safe).
            self._resize_ctr = ctx.ctr_base + k * resizes
        return list(zip(out.to_slots(), ctx.reports))

    def _run_batch(self, node: PlanNode, ctx: _BatchCtx) -> _BatchVal:
        children = [self._run_batch(c, ctx) for c in node.children()]
        d = lookup(type(node))
        if d.batch_apply is not None:
            return d.batch_apply(self, node, children, ctx)
        if all(c.stacked is not None for c in children):
            return self._run_batch_stacked(node, children, ctx)
        return self._run_batch_split(node, children, ctx)

    def _run_batch_stacked(
        self, node: PlanNode, children: List[_BatchVal], ctx: _BatchCtx
    ) -> _BatchVal:
        """One vmapped launch for all K slots. The traced ledger profile is
        the per-slot cost (the body traces with per-slot shapes), so it is
        replayed verbatim into every slot's report — exact parity with a
        serial run — while the physical tally charges bytes K times and the
        shared rounds once."""
        led = CommLedger()
        src = material.active_source()
        h0, m0 = (src.hits, src.misses) if src is not None else (0, 0)
        t0 = time.perf_counter()
        with led:
            out = self._apply_batched(node, [c.stacked for c in children], ctx.k)
        jax.block_until_ready(out.valid.shares)
        dt = time.perf_counter() - t0
        tally = led.tally()
        val = _BatchVal(k=ctx.k, stacked=out)
        n_ins = [c.slot_n(0) for c in children]
        extra = {}
        if src is not None and (src.hits - h0 or src.misses - m0):
            # one vmapped launch serves all K slots: pool traffic is shared,
            # so the whole-pass delta is reported identically into each slot
            extra["offline"] = {"hits": src.hits - h0, "misses": src.misses - m0}
        for report in ctx.reports:
            report.nodes.append(
                NodeStats(
                    node=node.describe(),
                    n_in=n_ins[0] if n_ins else 0,
                    n_ins=list(n_ins),
                    n_out=val.slot_n(0),
                    seconds=dt / ctx.k,  # amortized wall share
                    bytes_per_party=int(tally["bytes_per_party"]),
                    rounds=int(tally["rounds"]),
                    extra=dict(extra),
                )
            )
        tr = obs_trace.active_tracer()
        if tr is not None:
            tr.record(
                f"node[{node.label}]",
                seconds=dt,
                op=node.describe(),
                n_ins=list(n_ins),
                n_out=val.slot_n(0),
                bytes_per_party=int(tally["bytes_per_party"]),
                rounds=int(tally["rounds"]),
                slots=ctx.k,
                stacked=True,
                **extra,
            )
        # physical cost of the pass: bytes x K, synchronous rounds shared
        phys = batched_tally(tally, ctx.k)
        bs = self.last_batch_stats
        bs["stacked_nodes"] += 1
        bs["physical_bytes_per_party"] += int(phys["bytes_per_party"])
        bs["physical_rounds"] += int(phys["rounds"])
        return val

    def _run_batch_split(
        self, node: PlanNode, children: List[_BatchVal], ctx: _BatchCtx
    ) -> _BatchVal:
        """Per-slot execution through the normal `_apply` path — used after a
        Resize split (divergent trim sizes make the slots un-stackable)."""
        slot_children = [c.to_slots() for c in children]
        outs: List[SecretTable] = []
        bs = self.last_batch_stats
        bs["split_nodes"] += 1
        for i in range(ctx.k):
            out, stats = self._run_node_slot(
                node, [sc[i] for sc in slot_children]
            )
            ctx.reports[i].nodes.append(stats)
            bs["physical_bytes_per_party"] += stats.bytes_per_party
            bs["physical_rounds"] += stats.rounds
            outs.append(out)
        return _BatchVal(k=ctx.k, slots=outs)

    def _apply_batched(
        self, node: PlanNode, stacked: List[SecretTable], k: int
    ) -> SecretTable:
        """vmap the node's protocol over the batch axis; under ``jit_ops`` the
        vmapped program is cached like the serial one, and a cache entry that
        serves K slots counts K logical hits (one compile covers them all)."""
        d = lookup(type(node))
        fn = d.protocol(node)

        def batched(prf_arg, *tables, _fn=fn):
            return jax.vmap(lambda *ts: _fn(prf_arg, *ts))(*tables)

        if not self.jit_ops:
            return batched(self.prf, *stacked)
        key = (node.label, node.describe(), self._batch_sig(stacked), ("batch", k))
        jitted = Engine._jit_cache_get(key, count=k)
        if jitted is None:
            profile: Dict = {}

            def traced(prf_arg, *tables, _profile=profile):
                with CommLedger() as led:
                    out = batched(prf_arg, *tables)
                _profile.setdefault("tally", led.tally())
                return out

            jitted = (jax.jit(traced), profile)
            Engine._jit_cache_put(key, jitted)
        jfn, profile = jitted
        out = jfn(self.prf, *stacked)
        if profile.get("tally"):
            t = profile["tally"]
            log_comm(node.label.lower(), int(t["rounds"]), int(t["bytes_per_party"]))
        return out

    @staticmethod
    def _batch_sig(stacked: List[SecretTable]):
        return tuple(
            (
                int(t.valid.shares.shape[-1]),
                tuple(sorted((c, type(v).__name__) for c, v in t.cols.items())),
            )
            for t in stacked
        )

    # -- stateful batch hooks (dispatched via OperatorDef.batch_apply) -------

    def _batch_scan(self, node: PlanNode, ctx: _BatchCtx) -> _BatchVal:
        """All slots read the same secret-shared base table; a zero-copy
        broadcast along the batch axis stands in for K stacked uploads."""
        table = self.tables[node.table]
        for report in ctx.reports:
            report.nodes.append(
                NodeStats(
                    node=node.describe(), n_in=0, n_ins=[], n_out=table.n,
                    seconds=0.0, bytes_per_party=0, rounds=0,
                )
            )
        obs_trace.record(
            f"node[{node.label}]", op=node.describe(), n_ins=[],
            n_out=table.n, bytes_per_party=0, rounds=0,
            slots=ctx.k, stacked=True,
        )
        return _BatchVal(k=ctx.k, stacked=_broadcast_table(table, ctx.k))

    def _batch_resize(
        self, node: PlanNode, children: List[_BatchVal], ctx: _BatchCtx
    ) -> _BatchVal:
        """Per-slot reveal-and-trim: slot i's j-th Resize folds exactly the
        noise counter a serial run would have (fresh i.i.d. noise per query —
        one CRT observation each, never merged across tenants). Slots whose
        revealed sizes agree are re-stacked so the rest of the plan stays
        vmapped; divergent sizes split the batch."""
        j = ctx.next_resize_index()
        slots_in = children[0].to_slots()
        outs: List[SecretTable] = []
        bs = self.last_batch_stats
        for i, tbl in enumerate(slots_in):
            self._resize_ctr = ctx.slot_ctr_before(i, j)
            out, stats = self._run_node_slot(node, [tbl])
            ctx.reports[i].nodes.append(stats)
            bs["physical_bytes_per_party"] += stats.bytes_per_party
            bs["physical_rounds"] += stats.rounds
            outs.append(out)
        if all(o.n == outs[0].n for o in outs):
            return _BatchVal(k=ctx.k, stacked=_stack_tables(outs))
        return _BatchVal(k=ctx.k, slots=outs)
