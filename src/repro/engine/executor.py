"""Query execution engine.

Executes a plan tree bottom-up. Every operator protocol runs on static shapes;
the *only* place a public size changes is a ``Resize`` node's reveal-and-trim
(and a public LIMIT) — so dynamic re-dispatch on the revealed size is both
legitimate (it is the disclosed value) and bounded by bucketing.

The engine records a per-node execution report: wall seconds, the ledger's
(rounds, bytes/party), and input/output oblivious sizes — this is what the
benchmarks print and what reproduces the paper's Figures 6-9.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..core.ledger import CommLedger
from ..core.prf import PRFSetup, setup_prf
from ..ops import SecretTable
from ..plan.nodes import PlanNode
from ..plan.registry import infer_schema, lookup

__all__ = ["Engine", "ExecutionReport", "NodeStats"]


@dataclasses.dataclass
class NodeStats:
    node: str
    n_in: int  # first input's oblivious size (legacy field; see n_ins)
    n_out: int
    seconds: float
    bytes_per_party: int
    rounds: int
    n_ins: List[int] = dataclasses.field(default_factory=list)  # all inputs
    extra: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ExecutionReport:
    nodes: List[NodeStats] = dataclasses.field(default_factory=list)

    @property
    def total_seconds(self) -> float:
        return sum(s.seconds for s in self.nodes)

    @property
    def total_bytes(self) -> int:
        return sum(s.bytes_per_party for s in self.nodes)

    @property
    def total_rounds(self) -> int:
        return sum(s.rounds for s in self.nodes)

    def to_dict(self) -> Dict:
        """JSON-safe per-node report (machine-readable twin of summary())."""

        def safe(v):
            if isinstance(v, dict):
                return {k: safe(x) for k, x in v.items()}
            if isinstance(v, (list, tuple)):
                return [safe(x) for x in v]
            if hasattr(v, "item"):  # numpy / jax scalars
                return v.item()
            return v

        return {
            "nodes": [
                {
                    "node": s.node,
                    "n_in": int(s.n_in),
                    "n_ins": [int(n) for n in s.n_ins],
                    "n_out": int(s.n_out),
                    "seconds": float(s.seconds),
                    "bytes_per_party": int(s.bytes_per_party),
                    "rounds": int(s.rounds),
                    "extra": safe(s.extra),
                }
                for s in self.nodes
            ],
            "total_seconds": float(self.total_seconds),
            "total_bytes": int(self.total_bytes),
            "total_rounds": int(self.total_rounds),
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def summary(self) -> str:
        lines = [
            f"{'node':<42}{'n_in':>9}{'n_out':>9}{'sec':>9}{'MiB/party':>11}{'rounds':>8}"
        ]
        for s in self.nodes:
            lines.append(
                f"{s.node:<42}{s.n_in:>9}{s.n_out:>9}{s.seconds:>9.3f}"
                f"{s.bytes_per_party / 2**20:>11.3f}{s.rounds:>8}"
            )
        lines.append(
            f"{'TOTAL':<42}{'':>9}{'':>9}{self.total_seconds:>9.3f}"
            f"{self.total_bytes / 2**20:>11.3f}{self.total_rounds:>8}"
        )
        return "\n".join(lines)


def _block(table: SecretTable) -> None:
    jax.block_until_ready(table.valid.shares)


class Engine:
    """Executes plans over a set of secret-shared base tables."""

    # process-wide jit cache: operator protocols are pure functions of
    # (static node spec, table shapes) — reusing compiled executables across
    # Engine instances removes both eager-dispatch overhead and recompiles
    # (a beyond-paper optimization; see EXPERIMENTS.md §Perf). LRU-bounded:
    # a long-running serving session sees an unbounded stream of (query,
    # revealed-size) shapes, so the cache would otherwise grow without limit;
    # eviction only costs a recompile on a shape not seen recently.
    _JIT_CACHE: "OrderedDict" = OrderedDict()
    _JIT_CACHE_MAX = 128

    @classmethod
    def _jit_cache_get(cls, key):
        hit = cls._JIT_CACHE.get(key)
        if hit is not None:
            cls._JIT_CACHE.move_to_end(key)
        return hit

    @classmethod
    def _jit_cache_put(cls, key, value) -> None:
        cls._JIT_CACHE[key] = value
        cls._JIT_CACHE.move_to_end(key)
        while len(cls._JIT_CACHE) > cls._JIT_CACHE_MAX:
            cls._JIT_CACHE.popitem(last=False)

    def __init__(
        self,
        tables: Dict[str, SecretTable],
        key: jax.Array | None = None,
        prf: PRFSetup | None = None,
        bucket_fn: Optional[Callable[[int], int]] = None,
        jit_ops: bool = False,  # per-op jit pays off for REPEATED same-shape
        # queries (serving); one-shot plans are faster eager (XLA-CPU compile
        # of a 4k-row sort network costs minutes) — see §Perf
        validate: bool = True,  # schema-check plans before any MPC work
    ):
        self.tables = tables
        key = key if key is not None else jax.random.PRNGKey(0)
        self.key = key
        self.prf = prf if prf is not None else setup_prf(jax.random.fold_in(key, 7))
        self.bucket_fn = bucket_fn
        self.jit_ops = jit_ops
        self.validate = validate
        self._resize_ctr = 0
        self._last_resize_info: Optional[Dict] = None

    def execute(self, plan: PlanNode) -> tuple[SecretTable, ExecutionReport]:
        if self.validate:
            # registry schema propagation: unknown columns raise SchemaError
            # here, before a single share moves
            from ..sql.catalog import Catalog

            infer_schema(plan, Catalog.from_tables(self.tables))
        report = ExecutionReport()
        self._last_resize_info = None  # never carry info across runs
        out = self._run(plan, report)
        return out, report

    # ------------------------------------------------------------------
    def _run(self, node: PlanNode, report: ExecutionReport) -> SecretTable:
        children = [self._run(c, report) for c in node.children()]
        led = CommLedger()
        t0 = time.perf_counter()
        with led:
            out = self._apply(node, children)
        _block(out)
        dt = time.perf_counter() - t0
        tally = led.tally()
        n_ins = [t.n for t in children]
        extra = {}
        if lookup(type(node)).provides_resize_info:
            # consume the info this node's _apply just produced; clearing it
            # keeps a later Resize (or a later run) from reporting stale info
            extra = self._last_resize_info or {}
            self._last_resize_info = None
        report.nodes.append(
            NodeStats(
                node=node.describe(),
                n_in=n_ins[0] if n_ins else 0,
                n_ins=n_ins,
                n_out=out.n,
                seconds=dt,
                bytes_per_party=int(tally["bytes_per_party"]),
                rounds=int(tally["rounds"]),
                extra=extra,
            )
        )
        return out

    @staticmethod
    def _cache_key(node: PlanNode, children: List[SecretTable]):
        child_sig = tuple(
            (t.n, tuple(sorted((k, type(v).__name__) for k, v in t.cols.items())))
            for t in children
        )
        return (node.describe(), child_sig)

    def _apply(self, node: PlanNode, children: List[SecretTable]) -> SecretTable:
        prf = self.prf
        d = lookup(type(node))
        if d.engine_apply is not None:
            # stateful operators (Scan reads the table dict; Resize folds the
            # per-execution noise counter) bypass the jit path
            return d.engine_apply(self, node, children)
        fn = d.protocol(node)
        if not self.jit_ops:
            return fn(prf, *children)
        key = self._cache_key(node, children)
        jitted = Engine._jit_cache_get(key)
        if jitted is None:
            # Capture the ledger profile once at trace time: jit re-executions
            # skip the Python body, so replay the recorded cost on cache hits.
            profile: Dict = {}

            def traced(prf_arg, *tables, _fn=fn, _profile=profile):
                with CommLedger() as led:
                    out = _fn(prf_arg, *tables)
                _profile.setdefault("tally", led.tally())
                return out

            jitted = (jax.jit(traced), profile)
            Engine._jit_cache_put(key, jitted)
        jfn, profile = jitted
        out = jfn(prf, *children)
        if profile.get("tally"):
            from ..core.ledger import log_comm

            t = profile["tally"]
            log_comm(node.label.lower(), int(t["rounds"]), int(t["bytes_per_party"]))
        return out
