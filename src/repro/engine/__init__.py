from .executor import Engine, ExecutionReport  # noqa: F401
