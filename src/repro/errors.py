"""Typed error taxonomy for the Reflex service surface.

Before this module, callers distinguished failure classes by string-matching
``ValueError``/``RuntimeError`` messages raised deep inside the accountant,
planner, and state layers. Every externally meaningful failure now has a
:class:`ReflexError` subclass carrying *structured fields*, so clients (and
tests) branch on types and attributes, never on message text.

Each subclass multiple-inherits the legacy builtin its call sites used to
raise (``RuntimeError`` for refusal/fencing, ``ValueError`` for schema), so
pre-existing ``except`` clauses — including third-party callers of the old
names — keep working. The old names (``QueryRefused``, ``SchemaError``,
``StaleLeaseError``) remain importable from their original modules as
aliases of the new classes.

Hierarchy::

    ReflexError
      BudgetRefused     admission denied: CRT budget exhausted for a signature
      PlanSchemaError   plan references a column/table its input can't produce
      LeaseFenced       a superseded replica tried to write durable state
      TransportError    the multi-party runtime's wire layer failed
"""
from __future__ import annotations

from typing import Optional, Tuple

__all__ = [
    "ReflexError",
    "BudgetRefused",
    "PlanSchemaError",
    "LeaseFenced",
    "TransportError",
]


class ReflexError(Exception):
    """Base class for every typed Reflex failure."""


class BudgetRefused(ReflexError, RuntimeError):
    """Raised under ``policy='refuse'`` when a query would spend an
    observation a signature's CRT budget no longer covers.

    Fields: ``signature`` (the (subplan fingerprint, strategy key) pair),
    ``observed`` (observations already disclosed), ``budget`` (floor of
    ``crt_rounds`` for the signature).
    """

    def __init__(self, signature: Tuple[str, str], observed: int, budget: int):
        self.signature = signature
        self.observed = observed
        self.budget = budget
        super().__init__(
            f"CRT budget exhausted for resize of:\n{signature[0]}\n"
            f"strategy={signature[1]}: "
            f"{observed}/{budget} observations already disclosed"
        )


class PlanSchemaError(ReflexError, ValueError):
    """A plan references a column (or table) its input does not produce.

    Fields: ``node`` (the offending node's describe() string, when known),
    ``column`` / ``table`` (whichever reference failed), ``available``
    (the columns the input actually produces).
    """

    def __init__(
        self,
        message: str,
        *,
        node: Optional[str] = None,
        column: Optional[str] = None,
        table: Optional[str] = None,
        available: Optional[list] = None,
    ):
        self.node = node
        self.column = column
        self.table = table
        self.available = available
        super().__init__(message)


class LeaseFenced(ReflexError, RuntimeError):
    """A writer presented a fencing token older than one already observed —
    its lease was superseded while it was paused; the write must not land.

    Fields: ``token`` (the stale token presented), ``seen`` (the newest
    token the store has observed).
    """

    def __init__(
        self,
        message: str,
        *,
        token: Optional[int] = None,
        seen: Optional[int] = None,
    ):
        self.token = token
        self.seen = seen
        super().__init__(message)


class TransportError(ReflexError, RuntimeError):
    """The multi-party runtime's wire layer failed: a torn or out-of-order
    frame, a connect that exhausted its retries, a recv timeout, or a peer
    that died mid-query.

    Fields: ``party`` (the local party id, when known), ``peer`` (the remote
    party id / endpoint), ``seq`` (the frame sequence number in flight),
    ``op`` (the exchange op at the failure point), ``reason`` (a stable
    machine-readable tag: ``torn-frame`` | ``bad-seq`` | ``connect`` |
    ``timeout`` | ``closed`` | ``divergence`` | ``crashed``).
    """

    def __init__(
        self,
        message: str,
        *,
        party: Optional[int] = None,
        peer=None,
        seq: Optional[int] = None,
        op: Optional[str] = None,
        reason: str = "transport",
    ):
        self.party = party
        self.peer = peer
        self.seq = seq
        self.op = op
        self.reason = reason
        super().__init__(message)
