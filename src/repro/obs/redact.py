"""Disclosure audit boundary for all emitted telemetry (DESIGN.md §14.3).

Shrinkwrap's observation — telemetry about intermediate results is itself a
disclosure channel — applies to our own instruments: a span attribute, metric
label, or EXPLAIN line that carries a *secret-dependent* value (the true
selection cardinality T, the sampled noise parameters p/eta that were derived
from T) would leak exactly what the Resizer's noise exists to hide, without
passing through the CRT accountant at all.

This module is the single policy every emitted value passes through:

* :func:`public_view` — default-deny projection of an attribute mapping onto
  the emittable allow-list. Unknown keys are DROPPED (and counted), never
  forwarded: a new internal field is private until someone argues it into
  ``PUBLIC_KEYS`` here, next to the reason it is public.
* :func:`assert_emittable` — the strict twin used by the redaction test
  suite and by exporters in audit mode: raises :class:`RedactionError` on any
  key outside the allow-list.
* :func:`audit_labels` — metric-registration gate: label names must be
  drawn from the public vocabulary (a secret can't even be *named* as a
  metric dimension).

What is emittable, and why (the full argument lives in DESIGN.md §14.3):

* **Oblivious capacities** (``n``, ``n_in``, ``n_ins``, ``n_out``) — padded
  physical sizes, fixed by the plan and public table sizes; every party sees
  them on the wire.
* **Post-reveal sizes** (``s``, ``s_padded``) — the noisy trimmed size S is
  *the* controlled disclosure: it was opened by the protocol and charged to
  the CRT budget by the accountant before any telemetry could mention it.
* **Protocol-determined costs** (``seconds``, ``bytes_per_party``,
  ``rounds``) — functions of static shapes (the ledger is computed by shape
  tracing alone); wall time is the coordinator's own clock.
* **Plan structure** (``node``, ``op``, fingerprints, strategy/addition
  names) — the coordinator compiled the plan; nothing about the data.
* **Service bookkeeping** (tenants, cache hits, batch slots, flush reasons,
  budget/observed/remaining counts, WAL stats) — coordinator-side state.

What is NOT emittable (``SECRET_KEYS``): ``t`` (the true cardinality — the
exact value CRT prices the attacker's estimate of), ``p`` / ``eta`` (the
sampled noise parameters: eta = S - T, so either one plus the public S
reconstructs T).
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, Tuple

__all__ = [
    "PUBLIC_KEYS",
    "SECRET_KEYS",
    "RedactionError",
    "public_view",
    "assert_emittable",
    "audit_labels",
    "fingerprint_hash",
]


class RedactionError(ValueError):
    """An emitted value failed the disclosure audit."""


#: Keys whose values are secret-dependent and must NEVER be emitted.
SECRET_KEYS = frozenset({
    "t",        # true cardinality of the resized intermediate
    "p",        # parallel-addition coin probability, sampled from (n, t)
    "eta",      # sequential-addition filler count: eta = S - t exactly
    "true_rows",
    "oracle",
})

#: The emittable vocabulary — every key an argument for being public
#: (see module docstring / DESIGN.md §14.3).
PUBLIC_KEYS = frozenset({
    # oblivious capacities and post-reveal sizes
    "n", "n_in", "n_ins", "n_out", "s", "s_padded", "skipped",
    # protocol-determined costs
    "seconds", "bytes_per_party", "rounds", "wait_seconds",
    # plan / strategy structure
    "node", "op", "label", "strategy", "addition", "fingerprint",
    "sig", "template", "placement", "algo", "cols",
    # service bookkeeping
    "tenant", "sql", "query", "cache_hit", "rebind", "batch_slots", "slots",
    "reason", "ticket", "batched", "queue_depth", "bucket", "escalations",
    "budget", "observed", "remaining", "reserved", "open_intents",
    "refused", "recorded", "policy",
    # engine / jit / batch
    "stacked", "split", "jit", "k", "phase", "est_rows", "est_bytes",
    # state layer
    "journal", "wal_bytes", "records", "generation", "compactions",
    "appends", "fsync",
    # misc identity
    "name", "kind", "status", "ok", "count", "version",
    # multi-party runtime (DESIGN.md §16): the party id is execution
    # topology, and wire-byte/exchange counts equal the ledger's
    # protocol-determined costs by construction (audited in CI)
    "party", "wire_bytes", "exchanges", "transport", "peer",
    # offline randomness pool (DESIGN.md §15): hit/miss counts are cache
    # bookkeeping over *template-derived* material — the pool key is the
    # template fingerprint plus pow2 shape buckets, both already public plan
    # structure; depths/refill stats are coordinator-side memory accounting
    "offline", "hits", "misses", "depth", "depth_bytes", "entries",
    "refills", "trigger", "watermark", "evictions", "gc_dropped",
    "static_entries", "counter_entries", "recipes", "bundles",
    # distributed observability (DESIGN.md §17): wire/link accounting is
    # protocol-determined — per-link frame and byte counts equal the ledger's
    # analytic tallies by the coordinator's audit, sequence watermarks are
    # framing metadata every party already sees on the wire, and stall /
    # send / backoff durations are each process's own wall clock (the same
    # argument as "seconds" above). Trace identity (trace_id, clock offsets)
    # is coordinator-chosen plumbing, independent of any secret value.
    "wire", "link", "links", "frames", "bytes", "sent", "recv",
    "stall_seconds", "retries", "backoff_seconds", "rejects", "connects",
    "seq", "queries", "mesh", "up", "clock_offset_s", "trace_id",
    "rtt_seconds", "parties", "spans", "merged",
})


def fingerprint_hash(fp: str) -> str:
    """Short stable id for a (multi-line) plan fingerprint — fingerprints are
    public plan structure, but raw ones are unusable as metric labels."""
    return hashlib.sha1(fp.encode()).hexdigest()[:12]


def _walk(mapping: Dict, path: str = "") -> Iterable[Tuple[str, str, object]]:
    for k, v in mapping.items():
        here = f"{path}.{k}" if path else str(k)
        yield here, str(k), v
        if isinstance(v, dict):
            yield from _walk(v, here)


def public_view(mapping: Dict, dropped: list | None = None) -> Dict:
    """Project ``mapping`` onto the allow-list (recursing into dicts).

    Default-deny: a key neither public nor secret is still dropped — it just
    also lands in ``dropped`` (when given) so callers can count redactions.
    """
    out: Dict = {}
    for k, v in mapping.items():
        if str(k) in SECRET_KEYS or str(k) not in PUBLIC_KEYS:
            if dropped is not None:
                dropped.append(str(k))
            continue
        out[k] = public_view(v, dropped) if isinstance(v, dict) else v
    return out


def assert_emittable(mapping: Dict, where: str = "telemetry") -> None:
    """Strict audit: raise :class:`RedactionError` if ``mapping`` (including
    nested dicts) carries any key outside :data:`PUBLIC_KEYS`."""
    for path, key, _v in _walk(mapping):
        if key in SECRET_KEYS:
            raise RedactionError(
                f"{where}: secret-dependent key {path!r} must never be emitted"
            )
        if key not in PUBLIC_KEYS:
            raise RedactionError(
                f"{where}: key {path!r} is not in the emittable allow-list "
                "(obs/redact.py PUBLIC_KEYS); argue it public there first"
            )


def audit_labels(metric: str, labelnames: Iterable[str]) -> None:
    """Metric-registration gate: every label dimension must be a public
    vocabulary word (checked once, at registry time — fail fast)."""
    for name in labelnames:
        if name in SECRET_KEYS:
            raise RedactionError(
                f"metric {metric!r}: label {name!r} is secret-dependent"
            )
        if name not in PUBLIC_KEYS:
            raise RedactionError(
                f"metric {metric!r}: label {name!r} is not in the emittable "
                "allow-list (obs/redact.py PUBLIC_KEYS)"
            )
