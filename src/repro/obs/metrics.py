"""Metrics registry: typed counters/gauges/histograms with explicit labels.

Replaces the service's untyped ``stats`` dict (DESIGN.md §14.2). Every metric
is declared once with a name, help string, and an explicit label vocabulary;
label *names* are audited against the disclosure policy at registration
(:func:`repro.obs.redact.audit_labels`) — a secret-dependent dimension cannot
even be declared. Two renderers:

* :meth:`MetricsRegistry.render_prometheus` — the text exposition format
  (``# HELP`` / ``# TYPE`` + one sample line per label set, histograms as
  cumulative ``_bucket``/``_sum``/``_count``);
* :meth:`MetricsRegistry.snapshot` — a JSON-safe dict for the service's
  ``status()`` API and the CI telemetry validator.

Metric names follow prometheus conventions (``reflex_`` prefix, ``_total``
for counters, ``_seconds``/``_bytes`` units). The registry is per-service —
process-wide signals (the Engine jit cache) are mirrored into gauges at
snapshot time by the service.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, List, Optional, Tuple

from . import redact

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram"]

DEFAULT_BUCKETS = (
    1e-5, 1e-4, 1e-3, 5e-3, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0,
)


def _label_key(labelnames: Tuple[str, ...], labels: Dict) -> Tuple:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} != declared {sorted(labelnames)}"
        )
    return tuple(str(labels[n]) for n in labelnames)


def _fmt_labels(labelnames: Tuple[str, ...], key: Tuple, extra: str = "") -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(labelnames, key)]
    if extra:
        pairs.append(extra)
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...]):
        redact.audit_labels(name, labelnames)
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._lock = threading.Lock()

    def _key(self, labels: Dict) -> Tuple:
        return _label_key(self.labelnames, labels)


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name, help, labelnames):
        super().__init__(name, help, labelnames)
        self._values: Dict[Tuple, float] = {}

    def labels(self, **labels) -> "_CounterChild":
        return _CounterChild(self, self._key(labels))

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def touch(self, **labels) -> None:
        """Materialize a label set at 0 (so e.g. a tenant appears in the
        per-tenant breakdown the moment its session opens)."""
        key = self._key(labels)
        with self._lock:
            self._values.setdefault(key, 0.0)

    def value(self, **labels) -> float:
        return self._values.get(self._key(labels), 0.0)

    def total(self) -> float:
        return sum(self._values.values())

    def samples(self) -> List[Tuple[Tuple, float]]:
        return sorted(self._values.items())


class _CounterChild:
    def __init__(self, parent: Counter, key: Tuple):
        self._parent, self._key_ = parent, key

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._parent._lock:
            vals = self._parent._values
            vals[self._key_] = vals.get(self._key_, 0.0) + amount


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name, help, labelnames):
        super().__init__(name, help, labelnames)
        self._values: Dict[Tuple, float] = {}

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(self._key(labels), 0.0)

    def samples(self) -> List[Tuple[Tuple, float]]:
        return sorted(self._values.items())


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, labelnames, buckets: Tuple[float, ...]):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        # per label set: (bucket counts, sum, count)
        self._data: Dict[Tuple, List] = {}

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            st = self._data.setdefault(
                key, [[0] * (len(self.buckets) + 1), 0.0, 0]
            )
            st[0][bisect.bisect_left(self.buckets, value)] += 1
            st[1] += float(value)
            st[2] += 1

    def count(self, **labels) -> int:
        st = self._data.get(self._key(labels))
        return 0 if st is None else st[2]

    def sum(self, **labels) -> float:
        st = self._data.get(self._key(labels))
        return 0.0 if st is None else st[1]

    def samples(self) -> List[Tuple[Tuple, List]]:
        return sorted(self._data.items())


class MetricsRegistry:
    """Declare-once, render-anywhere metric store."""

    def __init__(self) -> None:
        self._metrics: "Dict[str, _Metric]" = {}
        self._lock = threading.Lock()

    def _register(self, metric: _Metric) -> _Metric:
        with self._lock:
            existing = self._metrics.get(metric.name)
            if existing is not None:
                if type(existing) is not type(metric) or (
                    existing.labelnames != metric.labelnames
                ):
                    raise ValueError(
                        f"metric {metric.name!r} re-registered with a "
                        "different type or label set"
                    )
                return existing
            self._metrics[metric.name] = metric
            return metric

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._register(Counter(name, help, tuple(labelnames)))

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._register(Gauge(name, help, tuple(labelnames)))

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram(name, help, tuple(labelnames), buckets))

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    # -- renderers ------------------------------------------------------------
    def render_prometheus(self) -> str:
        """Text exposition format. Every line that leaves here carries only
        declared (audited) label names and numeric samples."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, (counts, total, n) in m.samples():
                    cum = 0
                    for b, c in zip(m.buckets, counts):
                        cum += c
                        le = 'le="%s"' % b
                        lines.append(
                            f"{name}_bucket"
                            f"{_fmt_labels(m.labelnames, key, le)} {cum}"
                        )
                    le_inf = 'le="+Inf"'
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(m.labelnames, key, le_inf)} {n}"
                    )
                    lines.append(
                        f"{name}_sum{_fmt_labels(m.labelnames, key)} {total}"
                    )
                    lines.append(
                        f"{name}_count{_fmt_labels(m.labelnames, key)} {n}"
                    )
            else:
                samples = m.samples()
                if not samples:
                    lines.append(f"{name} 0")
                for key, value in samples:
                    lines.append(
                        f"{name}{_fmt_labels(m.labelnames, key)} {value}"
                    )
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict:
        """JSON-safe dump: {metric: {kind, help, samples: [{labels, value}]}}
        (histograms carry sum/count/buckets per label set)."""
        out: Dict = {}
        for name, m in sorted(self._metrics.items()):
            entry: Dict = {"kind": m.kind, "help": m.help,
                           "labelnames": list(m.labelnames)}
            if isinstance(m, Histogram):
                entry["samples"] = [
                    {
                        "labels": dict(zip(m.labelnames, key)),
                        "sum": total,
                        "count": n,
                        "buckets": {str(b): c for b, c in
                                    zip(m.buckets, counts)},
                    }
                    for key, (counts, total, n) in m.samples()
                ]
            else:
                entry["samples"] = [
                    {"labels": dict(zip(m.labelnames, key)), "value": v}
                    for key, v in m.samples()
                ]
            out[name] = entry
        return out
