"""Query lifecycle tracing: hierarchical spans with a thread-local stack.

Mirrors the :class:`~repro.core.ledger.CommLedger` pattern: a
:class:`Tracer` is a context manager that pushes itself onto a thread-local
stack; the module-level helpers (:func:`span`, :func:`record`,
:func:`annotate`) log into the innermost active tracer and are **no-ops when
none is active**, so the engine's hot paths pay one truthiness check per node
when tracing is off.

Span taxonomy (DESIGN.md §14.1)::

    query                      one client submit/ticket, root of the tree
      compile                  SQL -> placed physical plan (cache-aware)
      admit                    accountant admission (+ intent journaling)
      schedule.wait            enqueue -> flush latency of a batched ticket
      batch.flush              one scheduler bucket -> engine pass
        execute                one Engine.execute / execute_batch pass
          node[<Op>]           one plan-node protocol (per slot when split)
      reveal                   result opening + post_reveal derivation
      record                   accountant record + calibration flush

Every attribute dict passes through :func:`repro.obs.redact.public_view`
before it is stored — a span can never hold a secret-dependent value, no
matter what the instrumented call site passed (the redaction test suite
pins this). Dropped keys are counted in ``Tracer.redactions``.

Export is structured JSONL (:meth:`Tracer.to_jsonl` / :meth:`Tracer.write`):
one object per span with ``span_id``/``parent_id`` linkage, wall-clock
``ts``, duration ``seconds``, and the redacted ``attrs`` — validated in CI by
``benchmarks/validate_telemetry.py`` against ``benchmarks/telemetry_span_
schema.json``.

Cross-process propagation (DESIGN.md §17): a tracer optionally carries a
``trace_id`` — an opaque hex string naming the whole distributed trace. The
coordinator mints one per traced query (:meth:`Tracer.ensure_trace_id`),
ships it to the party processes in the ``execute`` control frame, and each
party's per-query tracer is constructed with the same id; when set, every
exported span line carries it, so merged multi-process streams stay
attributable to one query. Span ids remain tracer-local — the merge step
(:mod:`repro.obs.distributed`) renumbers them into the coordinator's id
space and re-parents party roots under the coordinator's ``execute`` span.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time
from typing import Dict, List, Optional

from . import redact

__all__ = ["Span", "Tracer", "active_tracer", "span", "record", "annotate"]

_STATE = threading.local()


def _stack() -> List["Tracer"]:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


@dataclasses.dataclass
class Span:
    name: str
    span_id: int
    parent_id: Optional[int]
    ts: float  # wall-clock start (time.time)
    seconds: float = 0.0
    attrs: Dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "ts": self.ts,
            "seconds": self.seconds,
            "attrs": self.attrs,
        }


class Tracer:
    """Collects a tree of redacted spans for one traced region.

    ``party`` (optional) stamps every span with the RSS party id whose
    process produced it — the multi-party runtime gives each party server
    its own tracer, so exported span streams from a 3-process mesh can be
    merged and still attribute latency per party."""

    def __init__(
        self,
        party: Optional[int] = None,
        trace_id: Optional[str] = None,
    ) -> None:
        self.party = party
        self.trace_id = trace_id
        self.spans: List[Span] = []
        self.redactions: List[str] = []  # dropped attribute keys (audit trail)
        self._open: List[Span] = []
        self._next_id = 0

    def ensure_trace_id(self) -> str:
        """Mint the distributed trace id on first use (coordinator side).

        Party-side tracers never mint — they are constructed with the id the
        coordinator shipped, so all processes agree on one trace identity."""
        if self.trace_id is None:
            import os

            self.trace_id = os.urandom(8).hex()
        return self.trace_id

    # -- context management ---------------------------------------------------
    def __enter__(self) -> "Tracer":
        _stack().append(self)
        return self

    def __exit__(self, *exc) -> None:
        top = _stack().pop()
        assert top is self, "Tracer stack corrupted"

    # -- span lifecycle -------------------------------------------------------
    def _new_span(self, name: str, attrs: Dict) -> Span:
        self._next_id += 1
        if self.party is not None:
            attrs = {**attrs, "party": self.party}
        sp = Span(
            name=name,
            span_id=self._next_id,
            parent_id=self._open[-1].span_id if self._open else None,
            ts=time.time(),
            attrs=redact.public_view(attrs, self.redactions),
        )
        self.spans.append(sp)
        return sp

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        sp = self._new_span(name, attrs)
        self._open.append(sp)
        t0 = time.perf_counter()
        try:
            yield sp
        finally:
            sp.seconds = time.perf_counter() - t0
            popped = self._open.pop()
            assert popped is sp, "span stack corrupted"

    def record(self, name: str, seconds: float = 0.0, **attrs) -> Span:
        """A closed span whose duration was measured elsewhere (e.g. the
        scheduler's enqueue->flush wait, the engine's per-node timer)."""
        sp = self._new_span(name, attrs)
        sp.seconds = float(seconds)
        return sp

    def annotate(self, **attrs) -> None:
        """Merge (redacted) attributes into the innermost open span."""
        if self._open:
            self._open[-1].attrs.update(
                redact.public_view(attrs, self.redactions)
            )

    # -- export ---------------------------------------------------------------
    def to_jsonl(self) -> str:
        def line(s: Span) -> Dict:
            d = s.to_dict()
            if self.trace_id is not None:
                d["trace_id"] = self.trace_id
            return d

        return "\n".join(
            json.dumps(line(s), sort_keys=True, default=float)
            for s in self.spans
        )

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            txt = self.to_jsonl()
            f.write(txt + ("\n" if txt else ""))

    def find(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]


def active_tracer() -> Optional[Tracer]:
    stack = _stack()
    return stack[-1] if stack else None


def span(name: str, **attrs):
    """``active_tracer().span(...)`` or a no-op context when tracing is off."""
    tr = active_tracer()
    if tr is None:
        return contextlib.nullcontext()
    return tr.span(name, **attrs)


def record(name: str, seconds: float = 0.0, **attrs) -> None:
    tr = active_tracer()
    if tr is not None:
        tr.record(name, seconds=seconds, **attrs)


def annotate(**attrs) -> None:
    tr = active_tracer()
    if tr is not None:
        tr.annotate(**attrs)
