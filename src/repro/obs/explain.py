"""EXPLAIN / EXPLAIN ANALYZE: the placed plan as an annotated tree.

``EXPLAIN`` renders the physical plan with the cost model's *estimates*
(rows = the post-trim oblivious size the planner expects, bytes = the
per-node share of the analytic comm cost). ``EXPLAIN ANALYZE`` adds the
*actuals* from an :class:`~repro.engine.executor.ExecutionReport`: per-node
oblivious output rows, wall seconds, MiB/party, synchronous rounds, and —
for Resize nodes — the resizer strategy with its trim outcome.

Every value printed here passes the disclosure audit
(:mod:`repro.obs.redact`): estimated rows come from public catalog sizes and
already-disclosed calibration; actual rows are oblivious capacities; the trim
column shows only the revealed S / padded S the accountant charged for —
never the true cardinality T or the noise draw.

The engine fills reports in post-order (children before parents), which is
exactly a post-order walk of the plan tree — :func:`explain_text` zips the
two and renders pre-order with indentation.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..plan.nodes import PlanNode, Resize
from . import redact

__all__ = ["explain_text"]

_COLS = (
    ("est.rows", 9),
    ("act.rows", 9),
    ("sec", 9),
    ("MiB/party", 11),
    ("rounds", 8),
    ("offline", 9),
    ("net stall", 10),
)


def _post_order(plan: PlanNode) -> List[PlanNode]:
    out: List[PlanNode] = []

    def walk(n: PlanNode) -> None:
        for c in n.children():
            walk(c)
        out.append(n)

    walk(plan)
    return out


def _estimates(plan: PlanNode, cost_model) -> Dict[int, Dict]:
    """One bottom-up pass: id(node) -> {"n","t","cols","bytes","own_bytes"}
    (the registry's "bytes" is cumulative; own_bytes subtracts children)."""
    out: Dict[int, Dict] = {}
    if cost_model is None:
        return out

    def walk(node: PlanNode) -> Dict:
        children = [walk(c) for c in node.children()]
        from ..plan.registry import lookup

        est = lookup(type(node)).estimate(node, children, cost_model)
        if cost_model.calibration is not None:
            est = cost_model.calibration.refine(node, est, cost_model.noise)
        est = dict(est)
        est["own_bytes"] = max(
            est["bytes"] - sum(c["bytes"] for c in children), 0.0
        )
        out[id(node)] = est
        return est

    walk(plan)
    return out


def _offline_note(extra: Optional[Dict]) -> str:
    """Hot-vs-cold correlated-randomness column: how many of this node's
    pool fetches were served precomputed (hits) vs derived on demand
    (misses). Counts are cache bookkeeping over template-keyed material —
    see obs/redact.py for the disclosure argument."""
    if not extra:
        return "-"
    off = redact.public_view(extra).get("offline")
    if not off:
        return "-"
    h, m = int(off.get("hits", 0)), int(off.get("misses", 0))
    if m == 0:
        return f"hot {h}"
    if h == 0:
        return f"cold {m}"
    return f"{h}h/{m}c"


def _stall_note(extra: Optional[Dict]) -> str:
    """Network-attribution column (networked runs only): seconds this node's
    exchanges spent blocked on inbound frames, from the executor's
    per-node ``extra["wire"]`` delta. In-process runs have no wire and
    render "-". Stall is the report party's own view (party 0's in
    networked mode) — wall-clock, never part of the cross-party audit."""
    if not extra:
        return "-"
    wire = redact.public_view(extra).get("wire")
    if not wire:
        return "-"
    return f"{float(wire.get('stall_seconds', 0.0)):.3f}"


def _trim_note(node: PlanNode, extra: Optional[Dict]) -> str:
    """Resize annotation from the report's (redacted) reveal-and-trim info."""
    if not isinstance(node, Resize):
        return ""
    if extra is None:  # plain EXPLAIN: strategy only (it's in the label too)
        return node.cfg.describe()
    pub = redact.public_view(extra)
    if pub.get("skipped"):
        return "trim skipped (NoTrim: nothing disclosed)"
    s, sp = pub.get("s"), pub.get("s_padded")
    note = f"S={s}" if s is not None else "S=?"
    if sp is not None and sp != s:
        note += f" pad->{sp}"
    return note


def explain_text(
    plan: PlanNode,
    cost_model=None,
    report=None,
    title: Optional[str] = None,
    wire_audit: Optional[List[Dict]] = None,
) -> str:
    """Render ``plan`` as an indented tree with estimated vs actual columns.

    ``report`` is an :class:`ExecutionReport` whose ``nodes`` were filled by
    executing this exact plan (post-order); pass None for plain EXPLAIN.
    ``wire_audit`` (networked mode) appends a per-party wire trailer —
    bytes on the wire and total network stall per party — below TOTAL; it
    is omitted entirely when empty, so in-process output is unchanged.
    """
    order = _post_order(plan)
    actual: Dict[int, object] = {}
    if report is not None:
        if len(report.nodes) != len(order):
            raise ValueError(
                f"report has {len(report.nodes)} node entries for a plan "
                f"with {len(order)} nodes — not this plan's report"
            )
        actual = {id(n): s for n, s in zip(order, report.nodes)}
    est = _estimates(plan, cost_model)

    name_w = max(
        [42] + [len("  " * d + n.describe()) + 2 for n, d in _depths(plan)]
    )
    header = f"{'plan':<{name_w}}" + "".join(
        f"{h:>{w}}" for h, w in _COLS
    ) + "  resize"
    lines = [header] if title is None else [title, header]

    for node, depth in _depths(plan):
        label = "  " * depth + node.describe()
        e = est.get(id(node))
        a = actual.get(id(node))
        est_rows = f"{int(e['n'])}" if e else "-"
        act_rows = f"{a.n_out}" if a else "-"
        sec = f"{a.seconds:.3f}" if a else "-"
        mib = f"{a.bytes_per_party / 2**20:.3f}" if a else (
            f"~{e['own_bytes'] / 2**20:.3f}" if e else "-"
        )
        rounds = f"{a.rounds}" if a else "-"
        offline = _offline_note(a.extra if a else None)
        stall = _stall_note(a.extra if a else None)
        note = _trim_note(node, a.extra if a else None)
        lines.append(
            f"{label:<{name_w}}{est_rows:>9}{act_rows:>9}{sec:>9}"
            f"{mib:>11}{rounds:>8}{offline:>9}{stall:>10}  {note}".rstrip()
        )
    if report is not None:
        lines.append(
            f"{'TOTAL':<{name_w}}{'':>9}{'':>9}{report.total_seconds:>9.3f}"
            f"{report.total_bytes / 2**20:>11.3f}{report.total_rounds:>8}"
        )
    if wire_audit:
        parts = "  ".join(
            f"p{a['party']}: {a['wire_bytes']} B wire, "
            f"{a.get('stall_seconds', 0.0):.3f}s stall"
            for a in wire_audit
        )
        lines.append(f"wire: {parts}")
    return "\n".join(lines)


def _depths(plan: PlanNode, depth: int = 0):
    yield plan, depth
    for c in plan.children():
        yield from _depths(c, depth + 1)
