"""Distributed observability for the multi-party mesh (DESIGN.md §17).

Three pieces glue the per-process instruments (:mod:`repro.obs.trace`,
:mod:`repro.obs.metrics`) into one mesh-wide view:

* **Trace propagation + merge** — the coordinator mints a ``trace_id`` per
  traced query and ships a :class:`TraceContext` inside the ``execute``
  control frame; each party runs the query under a fresh per-query
  :class:`~repro.obs.trace.Tracer` carrying that id and ships its (already
  redacted) spans back in the reply. :func:`merge_party_spans` folds the
  three shipments into the coordinator's tracer: span ids are renumbered
  into the coordinator's id space, party root spans are re-parented under
  the coordinator's ``execute`` span, and party timestamps are normalized
  onto the coordinator's clock via :func:`clock_offset` (an NTP-style
  midpoint estimate over the control-frame send/receive timestamps). Every
  shipped attribute dict is re-audited against the disclosure deny-list on
  arrival — a misbehaving (or stale-versioned) party process cannot smuggle
  a secret-keyed attribute into the exported trace.

* **Flame-graph export** — :func:`chrome_trace` /
  :func:`write_chrome_trace` render any span list as Chrome trace-event
  JSON (``chrome://tracing`` / Perfetto ``ui.perfetto.dev``): one complete
  ("ph":"X") event per span, one track per party plus a coordinator track.

* **Wire metrics publication** — :class:`WireMetricsPublisher` maps the
  JSON-safe per-link snapshots that party processes return from the
  ``stats`` control verb (see ``runtime/transport.py:WireStats``) onto
  ``reflex_wire_*`` counters/gauges in a coordinator-side
  :class:`~repro.obs.metrics.MetricsRegistry`, tagged with a ``party``
  label. Counters are advanced by snapshot *delta* (pulled totals are
  monotonic per process), so repeated ``status()`` pulls never double
  count. Label names pass the same ``audit_labels`` deny-list gate as every
  other metric.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Union

from . import redact
from .metrics import MetricsRegistry
from .trace import Span, Tracer

__all__ = [
    "TraceContext",
    "new_trace_id",
    "clock_offset",
    "merge_party_spans",
    "chrome_trace",
    "write_chrome_trace",
    "WireMetricsPublisher",
]


def new_trace_id() -> str:
    """Opaque 16-hex-char trace identity (no secret derivation: pure OS
    entropy, safe to print anywhere)."""
    return os.urandom(8).hex()


@dataclasses.dataclass
class TraceContext:
    """What the ``execute`` control frame carries to each party: the trace
    identity and the coordinator-side span the party's spans hang under."""

    trace_id: str
    parent_span_id: Optional[int] = None

    def to_dict(self) -> Dict:
        return {
            "trace_id": self.trace_id,
            "parent_span_id": self.parent_span_id,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "TraceContext":
        return cls(
            trace_id=str(d["trace_id"]),
            parent_span_id=d.get("parent_span_id"),
        )


def clock_offset(
    t_send: float, t_recv: float, t_reply: float, t_ack: float
) -> float:
    """NTP-style offset of a party's clock relative to the coordinator's.

    ``t_send``/``t_ack`` are coordinator wall clocks around one control round
    trip; ``t_recv``/``t_reply`` are the party's wall clocks for the same
    frames. Returns ``offset`` such that ``party_ts - offset`` lands on the
    coordinator's timeline (accurate to half the round-trip asymmetry —
    microseconds on localhost, and only ever used for display alignment,
    never for protocol decisions)."""
    return ((t_recv - t_send) + (t_reply - t_ack)) / 2.0


def merge_party_spans(
    tracer: Tracer, parent: Span, shipments: Sequence[Dict]
) -> int:
    """Fold party-shipped span lists into the coordinator's tracer.

    Each shipment is one party's execute-reply excerpt::

        {"party": p, "trace_id": ..., "spans": [span dicts],
         "clock": {"t_recv": ..., "t_reply": ...},   # party wall clock
         "t_send": ..., "t_ack": ...}                # coordinator wall clock

    Per shipment: verify the trace identity, re-audit every attribute dict
    against the disclosure deny-list (:func:`repro.obs.redact
    .assert_emittable` — party tracers redact at source, but the coordinator
    does not trust the wire), renumber span ids after the coordinator's
    current counter, re-parent roots under ``parent``, and shift timestamps
    by the estimated clock offset. Returns the number of spans merged."""
    want = tracer.ensure_trace_id()
    merged = 0
    for ship in shipments:
        spans = ship.get("spans")
        if not spans:
            continue
        party = ship.get("party")
        got = ship.get("trace_id")
        if got is not None and got != want:
            raise ValueError(
                f"party {party} shipped spans for trace {got!r}, "
                f"expected {want!r}"
            )
        clk = ship.get("clock") or {}
        off = 0.0
        if {"t_recv", "t_reply"} <= set(clk) and \
                ship.get("t_send") is not None and \
                ship.get("t_ack") is not None:
            off = clock_offset(
                ship["t_send"], clk["t_recv"], clk["t_reply"], ship["t_ack"]
            )
        base = tracer._next_id
        top = 0
        for sd in spans:
            attrs = dict(sd.get("attrs") or {})
            redact.assert_emittable(
                attrs, where=f"party {party} span {sd.get('name')!r}"
            )
            sid = int(sd["span_id"])
            top = max(top, sid)
            pid = sd.get("parent_id")
            if pid is None:
                # party root: hangs under the coordinator's execute span
                new_parent: Optional[int] = parent.span_id
                attrs.setdefault("clock_offset_s", round(off, 6))
            else:
                new_parent = base + int(pid)
            tracer.spans.append(Span(
                name=str(sd["name"]),
                span_id=base + sid,
                parent_id=new_parent,
                ts=float(sd["ts"]) - off,
                seconds=float(sd.get("seconds", 0.0)),
                attrs=attrs,
            ))
            merged += 1
        tracer._next_id = base + top
    return merged


# -----------------------------------------------------------------------------
# Chrome trace-event / Perfetto export
# -----------------------------------------------------------------------------

def _span_dicts(spans: Union[Tracer, Iterable]) -> List[Dict]:
    if isinstance(spans, Tracer):
        spans = spans.spans
    out = []
    for s in spans:
        out.append(s.to_dict() if isinstance(s, Span) else dict(s))
    return out


def chrome_trace(
    spans: Union[Tracer, Iterable], trace_id: Optional[str] = None
) -> Dict:
    """Chrome trace-event JSON for ``chrome://tracing`` / Perfetto.

    One complete ("ph":"X") event per span; the track (``tid``) is the
    party id, with the coordinator's spans on their own track. Timestamps
    are already clock-normalized by :func:`merge_party_spans`, so the
    per-party tracks line up on one timeline."""
    sds = _span_dicts(spans)
    if trace_id is None and isinstance(spans, Tracer):
        trace_id = spans.trace_id
    t0 = min((sd["ts"] for sd in sds), default=0.0)
    events: List[Dict] = []
    tracks = set()
    for sd in sds:
        attrs = sd.get("attrs") or {}
        party = attrs.get("party")
        tid = int(party) + 1 if party is not None else 0
        tracks.add(tid)
        events.append({
            "name": sd["name"],
            "cat": "reflex",
            "ph": "X",
            "pid": 1,
            "tid": tid,
            "ts": (sd["ts"] - t0) * 1e6,           # microseconds
            "dur": max(sd.get("seconds", 0.0), 0.0) * 1e6,
            "args": attrs,
        })
    meta = [{
        "name": "process_name", "ph": "M", "pid": 1,
        "args": {"name": "reflex query"},
    }]
    for tid in sorted(tracks):
        label = "coordinator" if tid == 0 else f"party {tid - 1}"
        meta.append({
            "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
            "args": {"name": label},
        })
    out: Dict = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
    if trace_id is not None:
        out["otherData"] = {"trace_id": trace_id}
    return out


def write_chrome_trace(
    path: str, spans: Union[Tracer, Iterable],
    trace_id: Optional[str] = None,
) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(spans, trace_id=trace_id), f, default=float)


# -----------------------------------------------------------------------------
# Wire metrics: party snapshots -> coordinator registry
# -----------------------------------------------------------------------------

class WireMetricsPublisher:
    """Publish per-party ``WireStats`` snapshots into a MetricsRegistry.

    Snapshots are cumulative per process; counters here advance by delta so
    any number of ``status()`` pulls is safe. Gauges (sequence watermarks,
    link liveness) are set to the latest value."""

    def __init__(self, registry: MetricsRegistry):
        m = registry
        self.frames = m.counter(
            "reflex_wire_frames_total",
            "Frames sent per directed link, by frame kind",
            ("party", "link", "kind"),
        )
        self.bytes = m.counter(
            "reflex_wire_bytes_total",
            "Body bytes sent per directed link, by frame kind "
            "(DATA bytes equal the ledger's analytic tallies by audit)",
            ("party", "link", "kind"),
        )
        self.send_s = m.counter(
            "reflex_wire_send_seconds_total",
            "Local send-path seconds per directed link (enqueue + flush)",
            ("party", "link"),
        )
        self.wait_s = m.counter(
            "reflex_wire_recv_wait_seconds_total",
            "Seconds blocked waiting for inbound frames per directed link",
            ("party", "link"),
        )
        self.rejects = m.counter(
            "reflex_wire_rejects_total",
            "Rejected inbound frames by reason (crc / seq / torn-frame)",
            ("party", "reason"),
        )
        self.retries = m.counter(
            "reflex_wire_connect_retries_total",
            "TCP dial attempts that had to be retried, per peer",
            ("party", "peer"),
        )
        self.backoff_s = m.counter(
            "reflex_wire_connect_backoff_seconds_total",
            "Seconds slept in (jittered) dial backoff, per peer",
            ("party", "peer"),
        )
        self.sent_seq = m.gauge(
            "reflex_wire_sent_seq",
            "Outbound sequence watermark per directed link",
            ("party", "link"),
        )
        self.recv_seq = m.gauge(
            "reflex_wire_recv_seq",
            "Inbound sequence watermark per directed link",
            ("party", "link"),
        )
        self.link_up = m.gauge(
            "reflex_wire_link_up",
            "1 if the directed link is registered and answering",
            ("party", "link"),
        )
        self.rtt = m.histogram(
            "reflex_ctrl_roundtrip_seconds",
            "Coordinator-observed control round-trip time per party",
            ("party",),
        )
        self._last: Dict = {}

    def _delta(self, key, new: float) -> float:
        old = self._last.get(key, 0.0)
        self._last[key] = new
        return max(new - old, 0.0)

    def publish(self, snapshot: Dict) -> None:
        """Fold one process's wire snapshot into the registry."""
        p = str(snapshot.get("party"))
        for e in snapshot.get("sent", ()):
            lk, kd = e["link"], e["kind"]
            self.frames.inc(
                self._delta(("sf", p, lk, kd), e["frames"]),
                party=p, link=lk, kind=kd,
            )
            self.bytes.inc(
                self._delta(("sb", p, lk, kd), e["bytes"]),
                party=p, link=lk, kind=kd,
            )
            self.send_s.inc(
                self._delta(("ss", p, lk, kd), e["seconds"]),
                party=p, link=lk,
            )
        for e in snapshot.get("recv", ()):
            lk = e["link"]
            self.wait_s.inc(
                self._delta(("rw", p, lk, e["kind"]), e["seconds"]),
                party=p, link=lk,
            )
        for e in snapshot.get("rejects", ()):
            self.rejects.inc(
                self._delta(("rj", p, e["reason"]), e["count"]),
                party=p, reason=e["reason"],
            )
        for e in snapshot.get("connects", ()):
            pr = str(e["peer"])
            self.retries.inc(
                self._delta(("cr", p, pr), e["retries"]),
                party=p, peer=pr,
            )
            self.backoff_s.inc(
                self._delta(("cb", p, pr), e["backoff_seconds"]),
                party=p, peer=pr,
            )
        for e in snapshot.get("links", ()):
            lk = e["link"]
            self.sent_seq.set(e["sent"], party=p, link=lk)
            self.recv_seq.set(e["recv"], party=p, link=lk)
            self.link_up.set(1.0, party=p, link=lk)

    def observe_roundtrip(self, party, seconds: float) -> None:
        self.rtt.observe(float(seconds), party=str(party))
