"""End-to-end query observability (DESIGN.md §14).

Three instruments behind one disclosure audit boundary
(:mod:`repro.obs.redact`):

* :mod:`repro.obs.trace` — hierarchical lifecycle spans (query -> compile ->
  admit -> schedule.wait -> batch.flush -> execute -> node[op] -> reveal ->
  record), thread-local like the :class:`~repro.core.ledger.CommLedger`,
  exported as structured JSONL;
* :mod:`repro.obs.metrics` — a typed metrics registry (counters / gauges /
  histograms with audited label sets) rendered as Prometheus text exposition
  or a JSON snapshot;
* :mod:`repro.obs.explain` — EXPLAIN / EXPLAIN ANALYZE plan-tree rendering
  with estimated-vs-actual rows/seconds/bytes/rounds per node.

Telemetry about intermediate results is itself a disclosure channel
(Shrinkwrap's lesson): every emitted value passes ``redact.public_view`` —
only oblivious capacities and accountant-charged post-reveal sizes are
emittable; the true cardinality T and the noise draws p/eta never leave the
process through any span, metric, or EXPLAIN line.
"""
from . import redact
from .distributed import (
    TraceContext,
    WireMetricsPublisher,
    chrome_trace,
    clock_offset,
    merge_party_spans,
    write_chrome_trace,
)
from .explain import explain_text
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Span, Tracer, active_tracer, annotate, record, span

__all__ = [
    "redact",
    "explain_text",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "Span",
    "Tracer",
    "active_tracer",
    "annotate",
    "record",
    "span",
    "TraceContext",
    "WireMetricsPublisher",
    "chrome_trace",
    "clock_offset",
    "merge_party_spans",
    "write_chrome_trace",
]
