"""Append-only JSONL write-ahead log.

One record per line, appended with flush + fsync *before* the caller acts on
the record — the intent->record protocol (DESIGN.md §12) relies on "if the
append returned, the line is durable; if the line is torn, the action never
started".

Torn tails: a crash mid-write can leave a final line without a newline (or
with truncated JSON). Readers stop at the last complete record; the next
appender truncates the torn bytes first (under the state lease), so the log
never accumulates garbage between records.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["WriteAheadLog"]


class WriteAheadLog:
    """JSONL log with offset-based incremental reads.

    ``fsync=False`` trades crash durability for latency (the persistence
    benchmark measures both); correctness under *process* crash still holds
    (the OS page cache survives), only power loss can then lose a tail.

    ``observer(phase, seconds)`` — optional latency callback fired after each
    ``append`` (phase ``"append"`` covers the whole call, ``"fsync"`` just
    the fsync) so the owning store can feed latency histograms without this
    module importing any metrics machinery.
    """

    def __init__(
        self,
        path: str,
        fsync: bool = True,
        observer: Optional[Callable[[str, float], None]] = None,
    ):
        self.path = path
        self.fsync = fsync
        self.observer = observer

    # -- writing ---------------------------------------------------------------
    def append(self, rec: Dict, good_offset: int | None = None) -> int:
        """Durably append one record; returns the end offset. When
        ``good_offset`` is given and the file is longer (a torn tail from a
        crashed writer), the torn bytes are truncated first — callers must
        hold the state lease, so no complete record is ever dropped."""
        line = (json.dumps(rec, sort_keys=True, separators=(",", ":")) + "\n").encode()
        t0 = time.perf_counter()
        with open(self.path, "ab") as f:
            if good_offset is not None and f.tell() > good_offset:
                f.truncate(good_offset)
                f.seek(good_offset)
            f.write(line)
            f.flush()
            if self.fsync:
                ts = time.perf_counter()
                os.fsync(f.fileno())
                if self.observer is not None:
                    self.observer("fsync", time.perf_counter() - ts)
            end = f.tell()
        if self.observer is not None:
            self.observer("append", time.perf_counter() - t0)
        return end

    def truncate(self, offset: int = 0) -> None:
        if os.path.exists(self.path):
            with open(self.path, "r+b") as f:
                f.truncate(offset)
                f.flush()
                if self.fsync:
                    os.fsync(f.fileno())

    # -- reading ---------------------------------------------------------------
    def read_from(self, offset: int) -> Tuple[List[Dict], int]:
        """All complete records at/after ``offset`` plus the offset of the
        first incomplete byte (== EOF when the tail is clean). A torn final
        line — no newline, or unparsable JSON — is excluded and its start
        offset returned, so a later ``append(good_offset=...)`` heals it."""
        if not os.path.exists(self.path):
            return [], 0
        records: List[Dict] = []
        with open(self.path, "rb") as f:
            f.seek(offset)
            good = offset
            for line in f:
                end = good + len(line)
                if not line.endswith(b"\n"):
                    break  # torn tail: mid-line crash
                try:
                    records.append(json.loads(line))
                except ValueError:
                    break  # torn tail: interleaved partial write
                good = end
        return records, good

    def size(self) -> int:
        try:
            return os.path.getsize(self.path)
        except OSError:
            return 0
