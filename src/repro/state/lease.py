"""File-locked leases + fencing tokens over a shared state directory.

Mutual exclusion between replicas is an ``fcntl.flock`` on a lock file —
per open-file-description, so two :class:`FileLease` objects exclude each
other even inside one process (the two-services-one-dir tests), and the lock
is released automatically if the holder dies.

Every acquisition also mints a **fencing token**: a monotonically increasing
counter persisted next to the lock. Writers stamp their token into every WAL
record; the store rejects an append whose token is older than one it has
already seen (:class:`StaleLeaseError`). flock alone cannot be stolen from a
live holder, so fencing is belt-and-braces — it catches the classic paused-
writer bug class (a holder that kept a token across a release/re-acquire by
someone else) instead of silently interleaving its stale writes.
"""
from __future__ import annotations

import contextlib
import fcntl
import os
from typing import Iterator, Optional

from ..errors import LeaseFenced

__all__ = ["FileLease", "StaleLeaseError"]

# The fencing error now lives in the typed taxonomy (repro.errors); the old
# name stays importable here. LeaseFenced subclasses RuntimeError, so
# pre-taxonomy except clauses keep catching it.
StaleLeaseError = LeaseFenced


class FileLease:
    """Exclusive lease on ``<dir>/<name>.lock`` with fencing tokens in
    ``<dir>/<name>.fence``. Re-entrant within one object (compaction runs
    inside a sync transaction)."""

    def __init__(self, directory: str, name: str = "state"):
        self.lock_path = os.path.join(directory, f"{name}.lock")
        self.fence_path = os.path.join(directory, f"{name}.fence")
        self._fh = None
        self._depth = 0
        self._token: Optional[int] = None

    # -- token plumbing --------------------------------------------------------
    def _read_fence(self) -> int:
        try:
            with open(self.fence_path) as f:
                return int(f.read().strip() or 0)
        except (OSError, ValueError):
            return 0

    def _write_fence(self, token: int) -> None:
        tmp = self.fence_path + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(token))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.fence_path)

    # -- acquire / release -----------------------------------------------------
    def acquire(self) -> int:
        """Block until the lease is held; returns this acquisition's fencing
        token (strictly greater than every earlier acquisition's, across all
        replicas of the directory)."""
        if self._depth > 0:
            self._depth += 1
            return self._token  # re-entrant: same token, deeper hold
        self._fh = open(self.lock_path, "a+")
        fcntl.flock(self._fh.fileno(), fcntl.LOCK_EX)
        self._token = self._read_fence() + 1
        self._write_fence(self._token)
        self._depth = 1
        return self._token

    def bump_to(self, token: int) -> int:
        """Advance the fence while holding the lease — used by the store when
        replayed records carry tokens newer than the fence file (a crash
        recovery into a directory whose fence was lost or copied stale).
        Returns the new current token."""
        if self._depth == 0:
            raise RuntimeError("bump_to requires the lease to be held")
        if token > self._token:
            self._token = token
            self._write_fence(token)
        return self._token

    def release(self) -> None:
        if self._depth == 0:
            return
        self._depth -= 1
        if self._depth == 0:
            fcntl.flock(self._fh.fileno(), fcntl.LOCK_UN)
            self._fh.close()
            self._fh = None

    @contextlib.contextmanager
    def hold(self) -> Iterator[int]:
        token = self.acquire()
        try:
            yield token
        finally:
            self.release()

    @property
    def held(self) -> bool:
        return self._depth > 0
