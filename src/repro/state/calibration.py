"""CalibrationStore: feed already-revealed intermediate sizes back to the
planner (DESIGN.md §12.4).

Every non-NoTrim Resize reveal-and-trim discloses a noisy size S for its
child subplan. That disclosure is *already paid for* by the CRT ledger — so
remembering it and using it to plan better is free signal (the SPECIAL
synopsis-reuse observation): the planner's static registry defaults
(``selectivity=0.1``, ``join_selectivity=0.01``) are replaced by the sizes
the engine actually observed, with **zero additional disclosure** — the
store only ever holds values an attacker watching the wire already has.

Observations are keyed by the **literal-masked, Resize-stripped** fingerprint
of the revealed subplan (:func:`calibration_key`): ``WHERE dosage = 325`` and
``WHERE dosage = 81`` share a key (like the prepared-statement cache), and a
physical subtree with inner Resizers maps to the same key as the logical
subtree the join reorderer scores at compile time.

``refine`` is the cost-model hook (:class:`repro.plan.cost.CostModel`): for
a Resizer-candidate node with an observation, the estimated true size T
becomes the EWMA of observed S (an overestimate of T by E[eta] — safely
conservative), and — when the planner knows the noise strategy — the
oblivious size flowing upward becomes the post-trim E[S], because placement
will insert a Resizer there. Under NoTrim, E[S] = N and the refinement
changes nothing: calibration never assumes a trim the mode won't perform.
"""
from __future__ import annotations

from typing import Dict, Optional

from ..plan.nodes import PlanNode, Resize
from ..plan.registry import lookup
from .store import JournalStore, SyncResult

__all__ = ["CalibrationStore", "calibration_key", "strip_resizers"]

EWMA_ALPHA = 0.5  # weight of the newest observation


def strip_resizers(plan: PlanNode) -> PlanNode:
    """The logical twin of a physical subtree: every Resize replaced by its
    child, so execution-time keys match compile-time (pre-placement) keys."""
    children = [strip_resizers(c) for c in plan.children()]
    node = plan.replace_children(children)
    return node.child if isinstance(node, Resize) else node


def calibration_key(plan: PlanNode) -> str:
    """Literal-masked, Resize-stripped fingerprint of a subplan."""
    from ..sql.compile import template_fingerprint

    return template_fingerprint(strip_resizers(plan))


class CalibrationStore:
    """Persisted map calibration_key -> observed revealed-size statistics,
    replicated through a :class:`JournalStore` (same lease/tail-sync/compact
    mechanics as the privacy ledger; merging size observations is conflict-
    free, the journal just makes them durable and shared)."""

    def __init__(self, store: Optional[JournalStore] = None):
        self._store = store
        # key -> {"count", "s_ewma", "n_last", "s_last"}
        self._stats: Dict[str, Dict] = {}
        # observations folded locally but not yet journaled: observe() runs
        # on the engine's execution critical path (the reveal hook), where a
        # per-reveal fsync'd transaction would serialize disk round-trips
        # into every Resize — flush() lands them in one transaction at query
        # finalize / window close instead (calibration is a planning hint,
        # not privacy-critical state, so deferred durability is safe)
        self._pending: list = []
        if store is not None:
            with store.transaction() as sync:
                self._sync(sync)

    # -- journal fold ----------------------------------------------------------
    def _sync(self, sync: SyncResult) -> None:
        if sync.reload:
            self._stats.clear()
            if sync.snapshot:
                self._stats.update(sync.snapshot.get("state", {}))
        for rec in sync.records:
            self._fold(rec)

    def _fold(self, rec: Dict) -> None:
        if rec.get("type") != "obs":
            return
        st = self._stats.setdefault(
            rec["fp"], {"count": 0, "s_ewma": float(rec["s"]),
                        "n_last": 0, "s_last": 0}
        )
        st["count"] += 1
        st["s_ewma"] = (
            EWMA_ALPHA * float(rec["s"])
            + (1.0 - EWMA_ALPHA) * float(st["s_ewma"])
        )
        st["n_last"], st["s_last"] = int(rec["n"]), int(rec["s"])

    # -- recording -------------------------------------------------------------
    def observe(self, key: str, n: int, s: int) -> None:
        """Record one already-revealed (N, S) pair for a subplan key: folded
        into local planning state immediately, journaled (durable + visible
        to every replica) at the next :meth:`flush`."""
        rec = {"type": "obs", "fp": key, "n": int(n), "s": int(s)}
        self._fold(rec)
        if self._store is not None:
            self._pending.append(rec)

    def flush(self) -> None:
        """Journal buffered observations — ONE transaction for all of them,
        off the engine's critical path."""
        if self._store is None or not self._pending:
            return
        pending, self._pending = self._pending, []
        with self._store.transaction() as sync:
            self._sync(sync)
            for rec in pending:
                full = sync.append(rec)
                if sync.reload:
                    # the reload rebuilt _stats from disk, dropping the
                    # buffered local folds — re-fold what we just journaled
                    self._fold(full)

    def observe_plan(self, resize_child: PlanNode, n: int, s: int) -> None:
        self.observe(calibration_key(resize_child), n, s)

    # -- planner hooks ---------------------------------------------------------
    def size_for(self, plan: PlanNode) -> Optional[float]:
        if not self._stats:
            return None  # empty store: skip the fingerprint entirely
        st = self._stats.get(calibration_key(plan))
        return None if st is None else float(st["s_ewma"])

    def refine(self, node: PlanNode, est: Dict, noise) -> Dict:
        """Cost-model refinement: see module docstring. ``est`` is the
        registry estimate ``{"n","t","cols","bytes"}``; returns a (possibly)
        replaced dict — never mutates the input."""
        if not self._stats:
            # computing a subplan fingerprint per node per candidate order is
            # pure waste while nothing has been observed yet — and that is
            # every compile of a freshly-started service
            return est
        if lookup(type(node)).resizer != "internal":
            return est  # only Resizer candidates ever get trimmed
        obs = self.size_for(node)
        if obs is None:
            return est
        out = dict(est)
        t_cal = max(min(obs, est["n"]), 1.0)
        out["t"] = t_cal
        if noise is not None:
            s_eff = min(t_cal + noise.mean(int(est["n"]), int(t_cal)), est["n"])
            out["n"] = max(int(round(s_eff)), 1)
        return out

    # -- persistence / reporting ----------------------------------------------
    def maybe_compact(self, max_wal_bytes: int = 1 << 16) -> bool:
        self.flush()  # buffered observations must reach the WAL first
        if self._store is None or self._store.wal_bytes <= max_wal_bytes:
            return False
        with self._store.transaction() as sync:
            self._sync(sync)
            self._store.compact(dict(self._stats))
        return True

    def __len__(self) -> int:
        return len(self._stats)

    def status(self) -> Dict:
        return {
            "entries": len(self._stats),
            "observations": sum(s["count"] for s in self._stats.values()),
            "pending": len(self._pending),
            "store": None if self._store is None else self._store.status(),
        }
