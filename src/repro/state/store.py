"""JournalStore: snapshot + WAL + lease composed into a replicated journal.

The store is deliberately dumb about record *semantics*: consumers (the
privacy accountant, the calibration store) define record payloads and fold
them into their own in-memory state. The store guarantees the replication
mechanics (DESIGN.md §12):

* every state-changing operation runs inside a :meth:`transaction` — the
  lease is held across *read tail -> decide -> append*, so two replicas can
  never interleave decisions against stale state;
* the transaction first hands back every record appended by other replicas
  since this store last looked (``SyncResult.records``) — consumers apply
  those before deciding anything;
* appends are stamped with ``seq`` / fencing ``tok`` / ``owner`` envelope
  fields and are durable (fsync) before the transaction proceeds;
* :meth:`compact` (called inside a transaction) folds the WAL into an
  atomically-replaced snapshot, truncates the WAL, and bumps a generation
  counter; a replica whose transaction observes a generation bump gets
  ``SyncResult.reload=True`` with the snapshot + full WAL to rebuild from.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import uuid
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional

from .lease import FileLease, StaleLeaseError
from .wal import WriteAheadLog

__all__ = ["JournalStore", "SyncResult"]


@dataclasses.dataclass
class SyncResult:
    """What a transaction learned before yielding control.

    ``reload=False``: ``records`` is the foreign tail to fold onto existing
    in-memory state. ``reload=True``: the journal was compacted (or this is
    the first transaction) — rebuild from ``snapshot`` then fold ``records``.
    """

    store: "JournalStore"
    token: int
    records: List[Dict]
    reload: bool = False
    snapshot: Optional[Dict] = None

    def append(self, rec: Dict) -> Dict:
        return self.store._append(rec, self.token)


class JournalStore:
    def __init__(
        self,
        directory: str,
        name: str,
        session: Optional[str] = None,
        fsync: bool = True,
        metrics=None,  # repro.obs.MetricsRegistry for WAL latency histograms
    ):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.name = name
        # unique per store object: two replicas in one process are two sessions
        self.session = session or uuid.uuid4().hex[:12]
        self.lease = FileLease(directory, name)
        self._m_append = self._m_fsync = self._m_compact = None
        observer = None
        if metrics is not None:
            self._m_append = metrics.histogram(
                "reflex_wal_append_seconds",
                "Durable WAL append latency (write + flush + fsync)",
                ("journal",),
            )
            self._m_fsync = metrics.histogram(
                "reflex_wal_fsync_seconds",
                "fsync share of WAL append latency", ("journal",),
            )
            self._m_compact = metrics.histogram(
                "reflex_journal_compaction_seconds",
                "Snapshot + WAL-truncate compaction latency", ("journal",),
            )
            observer = self._observe_wal
        self.wal = WriteAheadLog(
            os.path.join(directory, f"{name}.wal.jsonl"), fsync=fsync,
            observer=observer,
        )
        self.snapshot_path = os.path.join(directory, f"{name}.snapshot.json")
        self.gen_path = os.path.join(directory, f"{name}.gen")
        self._offset = 0  # first byte of the WAL this store has NOT applied
        self._seq = 0  # last record seq observed (read or written)
        self._max_token = 0  # newest fencing token observed in records
        self._generation: Optional[int] = None  # None => first txn reloads
        self.stats = {"appends": 0, "syncs": 0, "reloads": 0, "compactions": 0}

    def _observe_wal(self, phase: str, seconds: float) -> None:
        m = self._m_append if phase == "append" else self._m_fsync
        if m is not None:
            m.observe(seconds, journal=self.name)

    # -- generation / snapshot -------------------------------------------------
    def _read_generation(self) -> int:
        try:
            with open(self.gen_path) as f:
                return int(f.read().strip() or 0)
        except (OSError, ValueError):
            return 0

    def _read_snapshot(self) -> Optional[Dict]:
        try:
            with open(self.snapshot_path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def _write_atomic(self, path: str, payload: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    # -- transactions ----------------------------------------------------------
    @contextmanager
    def transaction(self) -> Iterator[SyncResult]:
        """Hold the lease across sync + decision + append. The yielded
        :class:`SyncResult` carries the foreign tail (or a full reload after
        someone compacted); use its ``append`` for every record written under
        this transaction."""
        with self.lease.hold() as token:
            gen = self._read_generation()
            if self._generation is None or gen != self._generation:
                self._generation = gen
                snapshot = self._read_snapshot()
                records, self._offset = self.wal.read_from(0)
                if snapshot is not None:
                    # a crash between compact()'s snapshot replace and WAL
                    # truncate leaves both on disk: the snapshot already folds
                    # every record up to its seq, so replaying those again
                    # would double-count — filter by the persisted watermark
                    snap_seq = int(snapshot.get("seq", 0))
                    records = [
                        r for r in records if int(r.get("seq", 0)) > snap_seq
                    ]
                    # seq numbering must continue past the snapshot even when
                    # the WAL is empty, or this store's first append would
                    # land at-or-below the watermark and be filtered later
                    self._seq = max(self._seq, snap_seq)
                sync = SyncResult(self, token, records, reload=True,
                                  snapshot=snapshot)
                self.stats["reloads"] += 1
            else:
                records, self._offset = self.wal.read_from(self._offset)
                sync = SyncResult(self, token, records)
            for rec in records:
                self._seq = max(self._seq, int(rec.get("seq", 0)))
                self._max_token = max(self._max_token, int(rec.get("tok", 0)))
            if self._max_token >= token:
                # replayed records outrun the fence file (crash recovery with
                # a lost/stale fence): advance past them so fencing stays
                # strictly monotonic instead of rejecting the recovered writer
                sync.token = token = self.lease.bump_to(self._max_token + 1)
            self.stats["syncs"] += 1
            yield sync

    def _append(self, rec: Dict, token: int) -> Dict:
        if not self.lease.held:
            raise RuntimeError("append outside a JournalStore.transaction")
        if token < self._max_token:
            raise StaleLeaseError(
                f"fencing token {token} is older than an observed write "
                f"(token {self._max_token}) — this lease was superseded",
                token=token,
                seen=self._max_token,
            )
        self._seq += 1
        self._max_token = token
        full = {"seq": self._seq, "tok": token, "owner": self.session, **rec}
        # good_offset heals any torn tail a crashed writer left behind
        self._offset = self.wal.append(full, good_offset=self._offset)
        self.stats["appends"] += 1
        return full

    # -- compaction ------------------------------------------------------------
    def compact(self, state_blob: Dict) -> None:
        """Fold the journal into a snapshot and truncate the WAL. Must run
        inside a :meth:`transaction` (after the consumer applied the sync),
        so ``state_blob`` reflects every record about to be truncated."""
        if not self.lease.held:
            raise RuntimeError("compact outside a JournalStore.transaction")
        t0 = time.perf_counter()
        gen = self._read_generation() + 1
        snapshot = {
            "generation": gen,
            "seq": self._seq,
            "token": self._max_token,
            "state": state_blob,
        }
        self._write_atomic(self.snapshot_path,
                           json.dumps(snapshot, sort_keys=True))
        self.wal.truncate(0)
        self._write_atomic(self.gen_path, str(gen))
        self._generation = gen
        self._offset = 0
        self.stats["compactions"] += 1
        if self._m_compact is not None:
            self._m_compact.observe(
                time.perf_counter() - t0, journal=self.name
            )

    # -- introspection ---------------------------------------------------------
    @property
    def wal_bytes(self) -> int:
        return self.wal.size()

    def status(self) -> Dict:
        return {
            "directory": self.directory,
            "name": self.name,
            "session": self.session,
            "generation": self._generation,
            "seq": self._seq,
            "wal_bytes": self.wal_bytes,
            **self.stats,
        }
