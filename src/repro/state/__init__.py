"""Durable service state (DESIGN.md §12).

The service tier's ground truth — the CRT privacy ledger and the calibration
observations — used to live in per-process memory: a restart forgot every
observation an attacker had already collected, and two replicas silently
doubled the real disclosure budget. This package makes that state durable and
shareable:

* :mod:`repro.state.wal`    — append-only JSONL write-ahead log (fsync'd,
  torn-tail tolerant).
* :mod:`repro.state.lease`  — file-locked leases + fencing tokens over a
  shared state directory (N replicas, one global budget).
* :mod:`repro.state.store`  — snapshot + WAL + lease composed into a
  replicated journal (`JournalStore`) with tail-sync and compaction.
* :mod:`repro.state.calibration` — persisted already-revealed intermediate
  sizes keyed by literal-masked subplan fingerprint, fed back into the
  planner's cost model (zero additional disclosure).
"""
from .calibration import CalibrationStore, calibration_key  # noqa: F401
from .lease import FileLease, StaleLeaseError  # noqa: F401
from .store import JournalStore, SyncResult  # noqa: F401
from .wal import WriteAheadLog  # noqa: F401

__all__ = [
    "CalibrationStore",
    "calibration_key",
    "FileLease",
    "StaleLeaseError",
    "JournalStore",
    "SyncResult",
    "WriteAheadLog",
]
