"""Query admission batching: one engine pass for many tenants (DESIGN.md §11).

The multi-tenant service used to execute admitted plans strictly serially, so
every query paid the full MPC round latency alone. This scheduler amortizes
it: queries from independent tenants whose *admitted* physical plans are
structurally identical — same normalized-plan fingerprint over the same
pow2-bucketed base-table shapes, i.e. the same identity the prepared-statement
plan cache computes, refined by bound literals and any accountant noise
rewrites — land in one bucket and execute as ONE stacked
:meth:`~repro.engine.executor.Engine.execute_batch` pass. Kogge-Stone levels,
a2b conversions, bitonic stages, and their PRF folds run once for the whole
batch; per-tenant results and :class:`ExecutionReport`s are demuxed with
bit-exact parity against serial execution.

Barrier-free pipeline: there is no global batch barrier. A bucket executes
the moment it fills (``max_batch``), and partially-filled buckets are flushed
once their oldest entry ages past ``max_wait_s`` (checked on every
``submit``/``poll``/``drain``), so a mixed stream of query shapes keeps
flowing instead of waiting for stragglers that will never come.

Privacy: admission happens at ``submit`` time, against the accountant's real
state *plus* a shared ``planned`` group covering every query admitted in the
open window — K queued same-signature queries spend K observations at
admission, exactly as a serial admit/record interleaving would, even though
their ``record`` calls all land after the batched run. Inside the engine,
every slot folds its own noise counter (fresh i.i.d. noise per query), so
batching never merges CRT observations across tenants. Plans containing
non-batchable operators (singleton aggregates, post-reveal hooks) execute
immediately as a serial batch-of-1.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Tuple

from ..obs import trace as obs_trace
from ..plan.registry import plan_batchable
from ..sql.compile import plan_fingerprint

__all__ = ["QueryScheduler", "QueryTicket"]


@dataclasses.dataclass(frozen=True)
class QueryTicket:
    """Handle for an enqueued query; results come back from ``drain`` in
    ticket order (``QueryResult.tenant``/``sql`` identify the query)."""

    id: int
    tenant: str
    sql: str
    batched: bool  # False: executed immediately as a serial batch-of-1


@dataclasses.dataclass
class _Pending:
    ticket: QueryTicket
    aq: object  # service.AdmittedQuery
    enqueued_at: float


class QueryScheduler:
    """Shape-bucketed admission queue over one :class:`AnalyticsService`."""

    def __init__(
        self,
        service,
        max_batch: int = 16,
        max_wait_s: float = 0.05,
        clock=time.monotonic,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.service = service
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.clock = clock
        self._buckets: "OrderedDict[Tuple, List[_Pending]]" = OrderedDict()
        self._done: Dict[int, object] = {}  # ticket id -> QueryResult
        self._next_id = 0
        # accountant admission group for the open batching window: spans every
        # admitted-but-not-yet-recorded query so same-signature queries cannot
        # jointly overdraw a budget (see PrivacyAccountant.admit)
        self._planned: Dict[Tuple[str, str], int] = {}
        # scheduler figures live in the service's metrics registry (the
        # legacy `stats` dict below is a read-only view); a bare service
        # without one gets a private registry so the scheduler is standalone
        from ..obs import MetricsRegistry

        m = getattr(service, "metrics", None) or MetricsRegistry()
        self._m_enqueued = m.counter(
            "reflex_scheduler_enqueued_total",
            "Queries enqueued for batched execution",
        )
        self._m_batches = m.counter(
            "reflex_scheduler_batches_total", "Stacked engine passes executed",
        )
        self._m_batched_queries = m.counter(
            "reflex_scheduler_batched_queries_total",
            "Queries served by stacked passes",
        )
        self._m_serial = m.counter(
            "reflex_scheduler_serial_fallbacks_total",
            "Non-batchable queries executed as a serial batch-of-1",
        )
        self._m_flush = m.counter(
            "reflex_batch_flush_total", "Bucket flushes by trigger",
            ("reason",),
        )
        self._m_occupancy = m.histogram(
            "reflex_batch_occupancy", "Slots per stacked engine pass",
            buckets=(1, 2, 4, 8, 16, 32, 64),
        )
        self._m_wait = m.histogram(
            "reflex_schedule_wait_seconds",
            "Enqueue -> flush latency of batched tickets",
        )
        self._m_queue_depth = m.gauge(
            "reflex_scheduler_queue_depth",
            "Pending queries across open buckets",
        )
        self._m_max_batch = m.gauge(
            "reflex_batch_max_seen", "Largest stacked pass so far",
        )

    @property
    def stats(self) -> Dict[str, int]:
        """Legacy counters dict as a view over the metrics registry."""
        return {
            "enqueued": int(self._m_enqueued.total()),
            "batches": int(self._m_batches.total()),
            "batched_queries": int(self._m_batched_queries.total()),
            "serial_fallbacks": int(self._m_serial.total()),
            "full_flushes": int(self._m_flush.value(reason="full")),
            "deadline_flushes": int(self._m_flush.value(reason="deadline")),
            "forced_flushes": int(self._m_flush.value(reason="forced")),
            "max_batch_seen": int(self._m_max_batch.value()),
        }

    def publish_gauges(self) -> None:
        self._m_queue_depth.set(self.n_pending)

    # -- admission ------------------------------------------------------------
    def _bucket_key(self, aq) -> Tuple:
        # the plan cache's identity (template fingerprint x placement x
        # strategy x pow2-bucketed shapes) groups rebindable queries; stacked
        # execution additionally needs identical literals and noise configs,
        # which the *admitted* plan's full fingerprint pins down
        return (plan_fingerprint(aq.admitted), self.service._shape_key())

    def submit(self, tenant: str, sql: str) -> QueryTicket:
        """Compile, admission-check, and enqueue one query. Full buckets and
        deadline-expired buckets flush immediately (barrier-free)."""
        self.poll()  # deadline check on every submit, whatever path follows
        with obs_trace.span("query", tenant=tenant, sql=sql):
            aq = self.service._admit(tenant, sql, planned=self._planned)
            tid = self._next_id
            self._next_id += 1
            self._m_enqueued.inc()
            if not plan_batchable(aq.admitted):
                ticket = QueryTicket(tid, tenant, sql, batched=False)
                self._m_serial.inc()
                self._done[tid] = self.service._execute_admitted(
                    aq, self._planned
                )
                return ticket
            ticket = QueryTicket(tid, tenant, sql, batched=True)
            key = self._bucket_key(aq)
            bucket = self._buckets.setdefault(key, [])
            bucket.append(_Pending(ticket, aq, self.clock()))
        self._m_queue_depth.set(self.n_pending)
        if len(bucket) >= self.max_batch:
            self._flush(key, "full_flushes")
        return ticket

    # -- execution ------------------------------------------------------------
    def _flush(self, key: Tuple, reason: str) -> None:
        """Execute one bucket. Failure accounting is conservative: a query
        whose execution may have revealed its noisy sizes but could not be
        recorded is charged to the accountant's real state
        (``charge_failed``) — the attacker may hold the sample — and its
        window reservation is then released deterministically, so the shared
        ``planned`` dict never carries state past the flush."""
        entries = self._buckets.pop(key)
        k = len(entries)
        acct = self.service.accountant
        why = reason.replace("_flushes", "")  # full | deadline | forced
        with obs_trace.span("batch.flush", slots=k, reason=why):
            now = self.clock()
            for e in entries:
                wait = max(now - e.enqueued_at, 0.0)
                self._m_wait.observe(wait)
                obs_trace.record(
                    "schedule.wait", seconds=wait,
                    tenant=e.ticket.tenant, ticket=e.ticket.id,
                )
            try:
                # one bucket = one template = one pool bundle: the stacked
                # pass draws its correlated randomness through the same
                # offline scope a serial submit would
                with self.service._offline_scope(
                    getattr(entries[0].aq, "bundle_key", None)
                ):
                    results = self.service.engine.execute_batch(
                        [e.aq.admitted for e in entries]
                    )
            except Exception:
                # the pass may have died after per-slot Resizes already
                # revealed sizes: charge every slot rather than leak a free
                # observation
                for e in entries:
                    acct.charge_failed(e.aq.admitted)
                    acct.release_planned(e.aq.admitted, self._planned)
                raise
            finally:
                self._m_queue_depth.set(self.n_pending)
            self._m_batches.inc()
            self._m_batched_queries.inc(k)
            self._m_flush.inc(reason=why)
            self._m_occupancy.observe(k)
            if k > self._m_max_batch.value():
                self._m_max_batch.set(k)
            first_err: Exception | None = None
            for e, (out, report) in zip(entries, results):
                try:
                    self._done[e.ticket.id] = self.service._finalize(
                        e.aq, out, report, batch_slots=k
                    )
                except Exception as err:  # demux/record failure: slot-local
                    if not e.aq.recorded:  # post-record failures: charged
                        acct.charge_failed(e.aq.admitted)
                    if first_err is None:
                        first_err = err
                finally:
                    acct.release_planned(e.aq.admitted, self._planned)
            if first_err is not None:
                # sibling slots' results were still delivered above
                raise first_err

    def poll(self) -> int:
        """Flush buckets whose oldest entry aged past the deadline; returns
        the number of buckets flushed."""
        now = self.clock()
        due = [
            key
            for key, entries in self._buckets.items()
            if entries and now - entries[0].enqueued_at >= self.max_wait_s
        ]
        for key in due:
            self._flush(key, "deadline_flushes")
        return len(due)

    def drain(self, force: bool = True) -> List:
        """Execute queued buckets (all when ``force``, else only those past
        the deadline) and return completed :class:`QueryResult`s in ticket
        order. Once the queue is empty the admission window closes."""
        if force:
            for key in list(self._buckets):
                self._flush(key, "forced_flushes")
        else:
            self.poll()
        out = [self._done.pop(tid) for tid in sorted(self._done)]
        if not self._buckets:
            self._planned.clear()  # window closed; everything is recorded
            # quiet point: every slot's intent has its record journaled, so
            # folding the durable WALs into snapshots loses nothing
            self.service._maybe_compact()
            # ...and the engine is idle: let the offline provisioner refill
            # the randomness pool for the next window (inline in "on" mode,
            # a thread wake-up in "background" mode)
            prov = getattr(self.service, "provisioner", None)
            if prov is not None:
                prov.hint()
        return out

    # -- introspection --------------------------------------------------------
    @property
    def n_pending(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    @property
    def n_buckets(self) -> int:
        return len(self._buckets)
