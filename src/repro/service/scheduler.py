"""Query admission batching: one engine pass for many tenants (DESIGN.md §11).

The multi-tenant service used to execute admitted plans strictly serially, so
every query paid the full MPC round latency alone. This scheduler amortizes
it: queries from independent tenants whose *admitted* physical plans are
structurally identical — same normalized-plan fingerprint over the same
pow2-bucketed base-table shapes, i.e. the same identity the prepared-statement
plan cache computes, refined by bound literals and any accountant noise
rewrites — land in one bucket and execute as ONE stacked
:meth:`~repro.engine.executor.Engine.execute_batch` pass. Kogge-Stone levels,
a2b conversions, bitonic stages, and their PRF folds run once for the whole
batch; per-tenant results and :class:`ExecutionReport`s are demuxed with
bit-exact parity against serial execution.

Barrier-free pipeline: there is no global batch barrier. A bucket executes
the moment it fills (``max_batch``), and partially-filled buckets are flushed
once their oldest entry ages past ``max_wait_s`` (checked on every
``submit``/``poll``/``drain``), so a mixed stream of query shapes keeps
flowing instead of waiting for stragglers that will never come.

Privacy: admission happens at ``submit`` time, against the accountant's real
state *plus* a shared ``planned`` group covering every query admitted in the
open window — K queued same-signature queries spend K observations at
admission, exactly as a serial admit/record interleaving would, even though
their ``record`` calls all land after the batched run. Inside the engine,
every slot folds its own noise counter (fresh i.i.d. noise per query), so
batching never merges CRT observations across tenants. Plans containing
non-batchable operators (singleton aggregates, post-reveal hooks) execute
immediately as a serial batch-of-1.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Tuple

from ..plan.registry import plan_batchable
from ..sql.compile import plan_fingerprint

__all__ = ["QueryScheduler", "QueryTicket"]


@dataclasses.dataclass(frozen=True)
class QueryTicket:
    """Handle for an enqueued query; results come back from ``drain`` in
    ticket order (``QueryResult.tenant``/``sql`` identify the query)."""

    id: int
    tenant: str
    sql: str
    batched: bool  # False: executed immediately as a serial batch-of-1


@dataclasses.dataclass
class _Pending:
    ticket: QueryTicket
    aq: object  # service.AdmittedQuery
    enqueued_at: float


class QueryScheduler:
    """Shape-bucketed admission queue over one :class:`AnalyticsService`."""

    def __init__(
        self,
        service,
        max_batch: int = 16,
        max_wait_s: float = 0.05,
        clock=time.monotonic,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.service = service
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.clock = clock
        self._buckets: "OrderedDict[Tuple, List[_Pending]]" = OrderedDict()
        self._done: Dict[int, object] = {}  # ticket id -> QueryResult
        self._next_id = 0
        # accountant admission group for the open batching window: spans every
        # admitted-but-not-yet-recorded query so same-signature queries cannot
        # jointly overdraw a budget (see PrivacyAccountant.admit)
        self._planned: Dict[Tuple[str, str], int] = {}
        self.stats = {
            "enqueued": 0,
            "batches": 0,
            "batched_queries": 0,
            "serial_fallbacks": 0,
            "full_flushes": 0,
            "deadline_flushes": 0,
            "forced_flushes": 0,
            "max_batch_seen": 0,
        }

    # -- admission ------------------------------------------------------------
    def _bucket_key(self, aq) -> Tuple:
        # the plan cache's identity (template fingerprint x placement x
        # strategy x pow2-bucketed shapes) groups rebindable queries; stacked
        # execution additionally needs identical literals and noise configs,
        # which the *admitted* plan's full fingerprint pins down
        return (plan_fingerprint(aq.admitted), self.service._shape_key())

    def submit(self, tenant: str, sql: str) -> QueryTicket:
        """Compile, admission-check, and enqueue one query. Full buckets and
        deadline-expired buckets flush immediately (barrier-free)."""
        self.poll()  # deadline check on every submit, whatever path follows
        aq = self.service._admit(tenant, sql, planned=self._planned)
        tid = self._next_id
        self._next_id += 1
        self.stats["enqueued"] += 1
        if not plan_batchable(aq.admitted):
            ticket = QueryTicket(tid, tenant, sql, batched=False)
            self.stats["serial_fallbacks"] += 1
            self._done[tid] = self.service._execute_admitted(aq, self._planned)
            return ticket
        ticket = QueryTicket(tid, tenant, sql, batched=True)
        key = self._bucket_key(aq)
        bucket = self._buckets.setdefault(key, [])
        bucket.append(_Pending(ticket, aq, self.clock()))
        if len(bucket) >= self.max_batch:
            self._flush(key, "full_flushes")
        return ticket

    # -- execution ------------------------------------------------------------
    def _flush(self, key: Tuple, reason: str) -> None:
        """Execute one bucket. Failure accounting is conservative: a query
        whose execution may have revealed its noisy sizes but could not be
        recorded is charged to the accountant's real state
        (``charge_failed``) — the attacker may hold the sample — and its
        window reservation is then released deterministically, so the shared
        ``planned`` dict never carries state past the flush."""
        entries = self._buckets.pop(key)
        k = len(entries)
        acct = self.service.accountant
        try:
            results = self.service.engine.execute_batch(
                [e.aq.admitted for e in entries]
            )
        except Exception:
            # the pass may have died after per-slot Resizes already revealed
            # sizes: charge every slot rather than leak a free observation
            for e in entries:
                acct.charge_failed(e.aq.admitted)
                acct.release_planned(e.aq.admitted, self._planned)
            raise
        self.stats["batches"] += 1
        self.stats["batched_queries"] += k
        self.stats[reason] += 1
        self.stats["max_batch_seen"] = max(self.stats["max_batch_seen"], k)
        first_err: Exception | None = None
        for e, (out, report) in zip(entries, results):
            try:
                self._done[e.ticket.id] = self.service._finalize(
                    e.aq, out, report, batch_slots=k
                )
            except Exception as err:  # demux/record failure for THIS slot only
                if not e.aq.recorded:  # post-record reveal failures: charged
                    acct.charge_failed(e.aq.admitted)
                if first_err is None:
                    first_err = err
            finally:
                acct.release_planned(e.aq.admitted, self._planned)
        if first_err is not None:
            # sibling slots' results were still delivered above
            raise first_err

    def poll(self) -> int:
        """Flush buckets whose oldest entry aged past the deadline; returns
        the number of buckets flushed."""
        now = self.clock()
        due = [
            key
            for key, entries in self._buckets.items()
            if entries and now - entries[0].enqueued_at >= self.max_wait_s
        ]
        for key in due:
            self._flush(key, "deadline_flushes")
        return len(due)

    def drain(self, force: bool = True) -> List:
        """Execute queued buckets (all when ``force``, else only those past
        the deadline) and return completed :class:`QueryResult`s in ticket
        order. Once the queue is empty the admission window closes."""
        if force:
            for key in list(self._buckets):
                self._flush(key, "forced_flushes")
        else:
            self.poll()
        out = [self._done.pop(tid) for tid in sorted(self._done)]
        if not self._buckets:
            self._planned.clear()  # window closed; everything is recorded
            # quiet point: every slot's intent has its record journaled, so
            # folding the durable WALs into snapshots loses nothing
            self.service._maybe_compact()
        return out

    # -- introspection --------------------------------------------------------
    @property
    def n_pending(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    @property
    def n_buckets(self) -> int:
        return len(self._buckets)
