"""AnalyticsService: a multi-tenant SQL front end over the Engine.

Each tenant opens a :class:`TenantSession` and submits SQL strings; the
service compiles them through :mod:`repro.sql` (predicate pushdown, cost-based
join ordering, Resizer placement), runs them on one shared :class:`Engine`
(whose process-wide ``_JIT_CACHE`` already reuses compiled operator
executables across queries), and returns revealed results plus the full
per-node :class:`ExecutionReport`.

Two service-level layers sit on top (DESIGN.md §9):

* **Compiled-plan cache (prepared statements)** — keyed on ``(literal-masked
  plan-template fingerprint, placement, strategy, bucketed base-table
  shapes)``. Differently-written but equivalent SQL (aliases, whitespace,
  predicate spelling) normalizes to the same template, and queries that
  differ *only in predicate constants* (``WHERE age > 40`` vs ``> 50``)
  share one compiled template: the cached physical plan (with its Resizer
  placement) is re-bound with the fresh literals at submit time. Identical
  literals reuse the same *physical plan object*, which keeps the Engine's
  per-op jit cache keys stable too. Shapes are bucketed to the next power of
  two so a growing base table does not thrash the cache.
* **PrivacyAccountant** — every submit is admission-checked against the CRT
  budget before execution and charged after (accountant.py). Budgets are
  global across tenants.

Per-query noise freshness: the Engine folds a monotonically increasing
counter into every Resizer's PRNG key, so repeated executions of the same
plan draw i.i.d. noise — exactly the attacker model CRT prices.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional

import jax
import numpy as np

from ..core.noise import NoiseStrategy, shrinkwrap_default
from ..engine.executor import Engine, ExecutionReport
from ..ops.table import SecretTable
from ..plan.nodes import PlanNode
from ..sql.catalog import Catalog
from ..plan.registry import lookup
from ..sql.compile import (
    bind_params,
    compile_logical,
    default_cost_model,
    plan_params,
    template_fingerprint,
)
from ..plan.policies import insert_resizers
from ..core.resizer import ResizerConfig
from .accountant import PrivacyAccountant, QueryRefused, strategy_key

__all__ = ["AnalyticsService", "TenantSession", "QueryResult"]


def _bucket_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 1).bit_length()


@dataclasses.dataclass
class QueryResult:
    tenant: str
    sql: str
    plan: PlanNode
    table: SecretTable
    rows: Optional[Dict[str, np.ndarray]]
    report: ExecutionReport
    cache_hit: bool
    compile_seconds: float
    accountant_seconds: float
    escalations: List[Dict]


class TenantSession:
    def __init__(self, service: "AnalyticsService", tenant: str):
        self.service = service
        self.tenant = tenant

    def submit(self, sql: str) -> QueryResult:
        return self.service.submit(self.tenant, sql)


class AnalyticsService:
    def __init__(
        self,
        tables: Dict[str, SecretTable],
        *,
        catalog: Optional[Catalog] = None,
        noise: Optional[NoiseStrategy] = None,
        addition: str = "parallel",
        placement: str = "cost_based",
        accountant: Optional[PrivacyAccountant] = None,
        key: Optional[jax.Array] = None,
        jit_ops: bool = False,
        plan_cache_size: int = 256,
        reveal_results: bool = True,
        reorder_joins: bool = True,
    ):
        self.tables = tables
        self.catalog = catalog or Catalog.from_tables(tables)
        self.noise = noise if noise is not None else shrinkwrap_default()
        self.addition = addition
        self.placement = placement
        self.accountant = accountant or PrivacyAccountant()
        self.reveal_results = reveal_results
        self.reorder_joins = reorder_joins
        self.engine = Engine(
            tables, key=key if key is not None else jax.random.PRNGKey(0),
            jit_ops=jit_ops,
        )
        self._plan_cache: "OrderedDict" = OrderedDict()
        self._plan_cache_max = plan_cache_size
        self.stats = {
            "queries": 0,
            "plan_cache_hits": 0,
            "plan_cache_misses": 0,
            "plan_cache_rebinds": 0,  # template hits with fresh literals
            "refusals": 0,
            "per_tenant": {},
        }

    # -- sessions -------------------------------------------------------------
    def session(self, tenant: str) -> TenantSession:
        self.stats["per_tenant"].setdefault(tenant, 0)
        return TenantSession(self, tenant)

    # -- compile + cache ------------------------------------------------------
    def _shape_key(self) -> tuple:
        return tuple(
            (name, _bucket_pow2(t.n)) for name, t in sorted(self.tables.items())
        )

    def compile(self, sql: str) -> tuple[PlanNode, bool, float]:
        """SQL -> physical plan via the prepared-statement cache; returns
        (plan, hit, seconds). The cache is keyed on the literal-masked
        template fingerprint: a hit with different predicate constants
        re-binds the cached physical plan (Resizer placement included)
        instead of recompiling."""
        t0 = time.perf_counter()
        cm = default_cost_model(self.catalog, noise=self.noise)
        logical = compile_logical(
            sql, self.catalog, cost_model=cm, reorder_joins=self.reorder_joins
        )
        params = plan_params(logical)
        cache_key = (
            template_fingerprint(logical),
            self.placement,
            strategy_key(self.noise, self.addition),
            self._shape_key(),
        )
        entry = self._plan_cache.get(cache_key)
        hit = entry is not None
        if hit:
            self._plan_cache.move_to_end(cache_key)
            self.stats["plan_cache_hits"] += 1
            cached_params, cached_plan = entry
            if params == cached_params:
                plan = cached_plan  # identical query: shared plan object
            else:
                self.stats["plan_cache_rebinds"] += 1
                plan = bind_params(cached_plan, params)
        else:
            self.stats["plan_cache_misses"] += 1
            if self.placement == "none":
                plan = logical
            else:
                cfg = ResizerConfig(noise=self.noise, addition=self.addition)
                plan = insert_resizers(
                    logical, lambda _n: cfg, placement=self.placement,
                    cost_model=cm,
                )
            self._plan_cache[cache_key] = (params, plan)
            while len(self._plan_cache) > self._plan_cache_max:
                self._plan_cache.popitem(last=False)
        return plan, hit, time.perf_counter() - t0

    # -- the query path -------------------------------------------------------
    def submit(self, tenant: str, sql: str) -> QueryResult:
        plan, hit, compile_s = self.compile(sql)
        ta = time.perf_counter()
        try:
            admitted, escalations = self.accountant.admit(plan)
        except QueryRefused:
            self.stats["refusals"] += 1
            raise
        acct_s = time.perf_counter() - ta

        out, report = self.engine.execute(admitted)

        ta = time.perf_counter()
        self.accountant.record(admitted, report)
        acct_s += time.perf_counter() - ta

        self.stats["queries"] += 1
        self.stats["per_tenant"][tenant] = self.stats["per_tenant"].get(tenant, 0) + 1
        rows = out.reveal_true_rows() if self.reveal_results else None
        post = lookup(type(admitted)).post_reveal
        if rows is not None and post is not None:
            # operator-defined client-side derivation (e.g. AVG = sum // cnt)
            rows = post(admitted, rows)
        return QueryResult(
            tenant=tenant,
            sql=sql,
            plan=admitted,
            table=out,
            rows=rows,
            report=report,
            cache_hit=hit,
            compile_seconds=compile_s,
            accountant_seconds=acct_s,
            escalations=escalations,
        )

    # -- reporting ------------------------------------------------------------
    def cache_stats(self) -> Dict[str, float]:
        h, m = self.stats["plan_cache_hits"], self.stats["plan_cache_misses"]
        return {
            "hits": h,
            "misses": m,
            "hit_rate": h / max(h + m, 1),
            "size": len(self._plan_cache),
        }

    def status(self) -> Dict:
        return {
            **self.stats,
            "plan_cache": self.cache_stats(),
            "accountant": self.accountant.status(),
        }
