"""AnalyticsService: a multi-tenant SQL front end over the Engine.

Each tenant opens a :class:`TenantSession` and submits SQL strings; the
service compiles them through :mod:`repro.sql` (predicate pushdown, cost-based
join ordering, Resizer placement), runs them on one shared :class:`Engine`
(whose process-wide ``_JIT_CACHE`` already reuses compiled operator
executables across queries), and returns revealed results plus the full
per-node :class:`ExecutionReport`.

Two service-level layers sit on top (DESIGN.md §9):

* **Compiled-plan cache (prepared statements)** — keyed on ``(literal-masked
  plan-template fingerprint, placement, strategy, bucketed base-table
  shapes)``. Differently-written but equivalent SQL (aliases, whitespace,
  predicate spelling) normalizes to the same template, and queries that
  differ *only in predicate constants* (``WHERE age > 40`` vs ``> 50``)
  share one compiled template: the cached physical plan (with its Resizer
  placement) is re-bound with the fresh literals at submit time. Identical
  literals reuse the same *physical plan object*, which keeps the Engine's
  per-op jit cache keys stable too. Shapes are bucketed to the next power of
  two so a growing base table does not thrash the cache.
* **PrivacyAccountant** — every submit is admission-checked against the CRT
  budget before execution and charged after (accountant.py). Budgets are
  global across tenants.

A third layer batches admissions (DESIGN.md §11): ``enqueue()``/``drain()``
route through :class:`~repro.service.scheduler.QueryScheduler`, which groups
same-fingerprint queries from independent tenants into shape-bucketed batches
and executes each as ONE stacked engine pass (``Engine.execute_batch``); the
synchronous ``submit()`` is the batch-of-1 special case of the same
admit -> execute -> finalize pipeline.

A fourth layer makes the service's ground truth durable (DESIGN.md §12):
``state_dir=`` puts the accountant's CRT ledger behind a WAL-backed
:class:`repro.state.JournalStore` (intent -> record journaling, so budgets
survive restarts and N replicas sharing the directory enforce ONE global
budget) and adds a :class:`repro.state.CalibrationStore` fed by the engine's
revealed-size hook: every already-disclosed intermediate size S refines the
planner's cost model — join reordering improves across restarts with zero
additional disclosure.

Per-query noise freshness: the Engine folds a monotonically increasing
counter into every Resizer's PRNG key, so repeated executions of the same
plan draw i.i.d. noise — exactly the attacker model CRT prices.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional

import jax
import numpy as np

from ..core.noise import NoiseStrategy, shrinkwrap_default
from ..engine.executor import Engine, ExecutionReport
from ..ops.table import SecretTable
from ..plan.nodes import PlanNode
from ..sql.catalog import Catalog
from ..plan.registry import lookup
from ..sql.compile import (
    bind_params,
    compile_logical,
    default_cost_model,
    plan_params,
    template_fingerprint,
)
from ..plan.policies import insert_resizers
from ..core.resizer import ResizerConfig
from .accountant import PrivacyAccountant, QueryRefused, strategy_key

__all__ = ["AnalyticsService", "TenantSession", "QueryResult", "AdmittedQuery"]


def _bucket_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 1).bit_length()


@dataclasses.dataclass
class QueryResult:
    tenant: str
    sql: str
    plan: PlanNode
    table: SecretTable
    rows: Optional[Dict[str, np.ndarray]]
    report: ExecutionReport
    cache_hit: bool
    compile_seconds: float
    accountant_seconds: float
    escalations: List[Dict]
    batch_slots: int = 1  # size of the engine pass this query rode in


@dataclasses.dataclass
class AdmittedQuery:
    """A compiled + admission-checked query awaiting execution (the unit the
    scheduler buckets). ``admitted`` is the accountant-rewritten plan."""

    tenant: str
    sql: str
    plan: PlanNode
    admitted: PlanNode
    cache_hit: bool
    compile_seconds: float
    accountant_seconds: float
    escalations: List[Dict]
    recorded: bool = False  # set once accountant.record committed


class TenantSession:
    def __init__(self, service: "AnalyticsService", tenant: str):
        self.service = service
        self.tenant = tenant

    def submit(self, sql: str) -> QueryResult:
        return self.service.submit(self.tenant, sql)

    def enqueue(self, sql: str):
        """Queue for batched execution; results arrive via ``service.drain``."""
        return self.service.enqueue(self.tenant, sql)


class AnalyticsService:
    def __init__(
        self,
        tables: Dict[str, SecretTable],
        *,
        catalog: Optional[Catalog] = None,
        noise: Optional[NoiseStrategy] = None,
        addition: str = "parallel",
        placement: str = "cost_based",
        accountant: Optional[PrivacyAccountant] = None,
        key: Optional[jax.Array] = None,
        jit_ops: bool = False,
        plan_cache_size: int = 256,
        reveal_results: bool = True,
        reorder_joins: bool = True,
        batch_max: int = 16,
        batch_wait_s: float = 0.05,
        state_dir: Optional[str] = None,  # durable shared state (DESIGN §12)
        wal_fsync: bool = True,
        compact_wal_bytes: int = 1 << 16,  # auto-compaction threshold
    ):
        self.tables = tables
        self.catalog = catalog or Catalog.from_tables(tables)
        self.noise = noise if noise is not None else shrinkwrap_default()
        self.addition = addition
        self.placement = placement
        self.accountant = accountant or PrivacyAccountant()
        self.reveal_results = reveal_results
        self.reorder_joins = reorder_joins
        self.engine = Engine(
            tables, key=key if key is not None else jax.random.PRNGKey(0),
            jit_ops=jit_ops,
        )
        self.state_dir = state_dir
        self.compact_wal_bytes = compact_wal_bytes
        self.calibration = None
        if state_dir is not None:
            from ..state import CalibrationStore, JournalStore

            if not self.accountant.durable:
                self.accountant.attach_store(
                    JournalStore(state_dir, "ledger", fsync=wal_fsync)
                )
            self.calibration = CalibrationStore(
                JournalStore(state_dir, "calibration", fsync=wal_fsync)
            )
            self.engine.reveal_hook = self._observe_reveal
        self._plan_cache: "OrderedDict" = OrderedDict()
        self._plan_cache_max = plan_cache_size
        from .scheduler import QueryScheduler

        self.scheduler = QueryScheduler(
            self, max_batch=batch_max, max_wait_s=batch_wait_s
        )
        self.stats = {
            "queries": 0,
            "plan_cache_hits": 0,
            "plan_cache_misses": 0,
            "plan_cache_rebinds": 0,  # template hits with fresh literals
            "refusals": 0,
            "per_tenant": {},
        }

    # -- sessions -------------------------------------------------------------
    def session(self, tenant: str) -> TenantSession:
        self.stats["per_tenant"].setdefault(tenant, 0)
        return TenantSession(self, tenant)

    # -- compile + cache ------------------------------------------------------
    def _shape_key(self) -> tuple:
        return tuple(
            (name, _bucket_pow2(t.n)) for name, t in sorted(self.tables.items())
        )

    def compile(self, sql: str) -> tuple[PlanNode, bool, float]:
        """SQL -> physical plan via the prepared-statement cache; returns
        (plan, hit, seconds). The cache is keyed on the literal-masked
        template fingerprint: a hit with different predicate constants
        re-binds the cached physical plan (Resizer placement included)
        instead of recompiling."""
        t0 = time.perf_counter()
        cm = default_cost_model(
            self.catalog, noise=self.noise, calibration=self.calibration
        )
        logical = compile_logical(
            sql, self.catalog, cost_model=cm, reorder_joins=self.reorder_joins
        )
        params = plan_params(logical)
        cache_key = (
            template_fingerprint(logical),
            self.placement,
            strategy_key(self.noise, self.addition),
            self._shape_key(),
        )
        entry = self._plan_cache.get(cache_key)
        hit = entry is not None
        if hit:
            self._plan_cache.move_to_end(cache_key)
            self.stats["plan_cache_hits"] += 1
            cached_params, cached_plan = entry
            if params == cached_params:
                plan = cached_plan  # identical query: shared plan object
            else:
                self.stats["plan_cache_rebinds"] += 1
                plan = bind_params(cached_plan, params)
        else:
            self.stats["plan_cache_misses"] += 1
            if self.placement == "none":
                plan = logical
            else:
                cfg = ResizerConfig(noise=self.noise, addition=self.addition)
                plan = insert_resizers(
                    logical, lambda _n: cfg, placement=self.placement,
                    cost_model=cm,
                )
            self._plan_cache[cache_key] = (params, plan)
            while len(self._plan_cache) > self._plan_cache_max:
                self._plan_cache.popitem(last=False)
        return plan, hit, time.perf_counter() - t0

    # -- the query path -------------------------------------------------------
    def _admit(self, tenant: str, sql: str, planned=None) -> AdmittedQuery:
        """Compile + admission-check one query (shared by the synchronous
        path and the scheduler). ``planned`` threads the accountant's
        cross-query admission group through a batching window."""
        plan, hit, compile_s = self.compile(sql)
        ta = time.perf_counter()
        try:
            admitted, escalations = self.accountant.admit(plan, planned)
        except QueryRefused:
            self.stats["refusals"] += 1
            raise
        return AdmittedQuery(
            tenant=tenant,
            sql=sql,
            plan=plan,
            admitted=admitted,
            cache_hit=hit,
            compile_seconds=compile_s,
            accountant_seconds=time.perf_counter() - ta,
            escalations=escalations,
        )

    def _finalize(
        self,
        aq: AdmittedQuery,
        out: SecretTable,
        report: ExecutionReport,
        batch_slots: int = 1,
    ) -> QueryResult:
        """Record the executed query's observations, update counters, and
        reveal — identical for serial and batched (demuxed) executions."""
        ta = time.perf_counter()
        self.accountant.record(aq.admitted, report)
        aq.recorded = True  # failure past this point must not charge_failed
        if self.calibration is not None:
            # one journal transaction for all of this query's revealed sizes
            # (buffered during execution, off the engine's critical path)
            self.calibration.flush()
        acct_s = aq.accountant_seconds + (time.perf_counter() - ta)

        self.stats["queries"] += 1
        self.stats["per_tenant"][aq.tenant] = (
            self.stats["per_tenant"].get(aq.tenant, 0) + 1
        )
        rows = out.reveal_true_rows() if self.reveal_results else None
        post = lookup(type(aq.admitted)).post_reveal
        if rows is not None and post is not None:
            # operator-defined client-side derivation (e.g. AVG = sum // cnt)
            rows = post(aq.admitted, rows)
        return QueryResult(
            tenant=aq.tenant,
            sql=aq.sql,
            plan=aq.admitted,
            table=out,
            rows=rows,
            report=report,
            cache_hit=aq.cache_hit,
            compile_seconds=aq.compile_seconds,
            accountant_seconds=acct_s,
            escalations=aq.escalations,
            batch_slots=batch_slots,
        )

    def _execute_admitted(self, aq: AdmittedQuery, planned) -> QueryResult:
        """Serial batch-of-1: execute + finalize with the failure-accounting
        protocol (the one shared code path for sync submits and the
        scheduler's non-batchable fallback — privacy-critical, keep single)."""
        try:
            out, report = self.engine.execute(aq.admitted)
            return self._finalize(aq, out, report)
        except Exception:
            # execution may have revealed noisy sizes that record() never
            # charged — price them conservatively (see charge_failed); a
            # post-record failure (reveal/post_reveal) is already charged
            if not aq.recorded:
                self.accountant.charge_failed(aq.admitted)
            raise
        finally:
            # recorded (or charged above): the window reservation must not
            # double-count it
            self.accountant.release_planned(aq.admitted, planned)

    def submit(self, tenant: str, sql: str) -> QueryResult:
        """Synchronous execution — admission + a batch-of-1 engine pass.

        Shares the scheduler's admission group, so a sync submit landing in
        the middle of an open batching window is charged against the queued
        (admitted-but-unrecorded) observations too."""
        self.scheduler.poll()  # sync traffic must not starve queued buckets
        planned = self.scheduler._planned
        aq = self._admit(tenant, sql, planned=planned)
        return self._execute_admitted(aq, planned)

    # -- batched admission (DESIGN.md §11) ------------------------------------
    def enqueue(self, tenant: str, sql: str):
        """Admit ``sql`` into the batching queue; same-bucket queries execute
        as one stacked engine pass. Returns a :class:`~repro.service.scheduler.
        QueryTicket`; fetch results with :meth:`drain`."""
        return self.scheduler.submit(tenant, sql)

    def drain(self, force: bool = True) -> List[QueryResult]:
        """Flush the batching queue (all buckets when ``force``, else only
        full/deadline-expired ones) and return completed results in
        submission order."""
        return self.scheduler.drain(force=force)

    # -- durable state (DESIGN.md §12) ----------------------------------------
    def _observe_reveal(self, node: PlanNode, info: Dict) -> None:
        """Engine revealed-size feedback hook: persist the already-public
        (N, S) pair for the resized subplan so future planning uses observed
        selectivities instead of static defaults. S is on the wire either
        way — recording it discloses nothing new."""
        if self.calibration is not None:
            self.calibration.observe_plan(
                node.child, n=int(info["n"]), s=int(info["s"])
            )

    def _maybe_compact(self) -> None:
        """Opportunistic snapshot+truncate of both journals once their WALs
        outgrow the threshold (called by the scheduler at window close and
        safe to call any time — compaction preserves open intents)."""
        if self.state_dir is None:
            return
        self.accountant.maybe_compact(self.compact_wal_bytes)
        self.calibration.maybe_compact(self.compact_wal_bytes)

    def compact_state(self) -> None:
        """Force-compact the durable journals now (restart-fast snapshots)."""
        if self.state_dir is None:
            return
        self.accountant.maybe_compact(-1)
        self.calibration.maybe_compact(-1)

    # -- reporting ------------------------------------------------------------
    def cache_stats(self) -> Dict[str, float]:
        h, m = self.stats["plan_cache_hits"], self.stats["plan_cache_misses"]
        return {
            "hits": h,
            "misses": m,
            "hit_rate": h / max(h + m, 1),
            "size": len(self._plan_cache),
        }

    def status(self) -> Dict:
        return {
            **self.stats,
            "plan_cache": self.cache_stats(),
            # process-wide: Engine._JIT_CACHE is shared by every Engine, so
            # these counters span all services in the process
            "jit_cache": {**Engine.jit_cache_stats(), "scope": "process"},
            "scheduler": self.scheduler.stats,
            "accountant": self.accountant.status(),
            "state": None if self.state_dir is None else {
                "dir": self.state_dir,
                "ledger": self.accountant.store.status(),
                "calibration": self.calibration.status(),
            },
        }
