"""AnalyticsService: a multi-tenant SQL front end over the Engine.

Each tenant opens a :class:`TenantSession` and submits SQL strings; the
service compiles them through :mod:`repro.sql` (predicate pushdown, cost-based
join ordering, Resizer placement), runs them on one shared :class:`Engine`
(whose process-wide ``_JIT_CACHE`` already reuses compiled operator
executables across queries), and returns revealed results plus the full
per-node :class:`ExecutionReport`.

Two service-level layers sit on top (DESIGN.md §9):

* **Compiled-plan cache (prepared statements)** — keyed on ``(literal-masked
  plan-template fingerprint, placement, strategy, bucketed base-table
  shapes)``. Differently-written but equivalent SQL (aliases, whitespace,
  predicate spelling) normalizes to the same template, and queries that
  differ *only in predicate constants* (``WHERE age > 40`` vs ``> 50``)
  share one compiled template: the cached physical plan (with its Resizer
  placement) is re-bound with the fresh literals at submit time. Identical
  literals reuse the same *physical plan object*, which keeps the Engine's
  per-op jit cache keys stable too. Shapes are bucketed to the next power of
  two so a growing base table does not thrash the cache.
* **PrivacyAccountant** — every submit is admission-checked against the CRT
  budget before execution and charged after (accountant.py). Budgets are
  global across tenants.

A third layer batches admissions (DESIGN.md §11): ``enqueue()``/``drain()``
route through :class:`~repro.service.scheduler.QueryScheduler`, which groups
same-fingerprint queries from independent tenants into shape-bucketed batches
and executes each as ONE stacked engine pass (``Engine.execute_batch``); the
synchronous ``submit()`` is the batch-of-1 special case of the same
admit -> execute -> finalize pipeline.

A fourth layer makes the service's ground truth durable (DESIGN.md §12):
``state_dir=`` puts the accountant's CRT ledger behind a WAL-backed
:class:`repro.state.JournalStore` (intent -> record journaling, so budgets
survive restarts and N replicas sharing the directory enforce ONE global
budget) and adds a :class:`repro.state.CalibrationStore` fed by the engine's
revealed-size hook: every already-disclosed intermediate size S refines the
planner's cost model — join reordering improves across restarts with zero
additional disclosure.

Per-query noise freshness: the Engine folds a monotonically increasing
counter into every Resizer's PRNG key, so repeated executions of the same
plan draw i.i.d. noise — exactly the attacker model CRT prices.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional

import jax
import numpy as np

from ..config import RuntimeConfig
from ..core.material import material_scope
from ..core.noise import NoiseStrategy, shrinkwrap_default
from ..engine.executor import Engine, ExecutionReport
from ..obs import MetricsRegistry, explain_text, redact
from ..obs import trace as obs_trace
from ..offline import Provisioner, RandomnessPool
from ..ops.table import SecretTable
from ..plan.nodes import PlanNode
from ..sql.catalog import Catalog
from ..plan.registry import lookup
from ..sql.compile import (
    bind_params,
    compile_logical,
    default_cost_model,
    plan_params,
    template_fingerprint,
)
from ..plan.policies import insert_resizers, select_join_algorithms
from ..core.resizer import ResizerConfig
from .accountant import PrivacyAccountant, QueryRefused, strategy_key

__all__ = ["AnalyticsService", "TenantSession", "QueryResult", "AdmittedQuery"]


def _bucket_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 1).bit_length()


@dataclasses.dataclass
class QueryResult:
    tenant: str
    sql: str
    plan: PlanNode
    table: SecretTable
    rows: Optional[Dict[str, np.ndarray]]
    report: ExecutionReport
    cache_hit: bool
    compile_seconds: float
    accountant_seconds: float
    escalations: List[Dict]
    batch_slots: int = 1  # size of the engine pass this query rode in


@dataclasses.dataclass
class AdmittedQuery:
    """A compiled + admission-checked query awaiting execution (the unit the
    scheduler buckets). ``admitted`` is the accountant-rewritten plan."""

    tenant: str
    sql: str
    plan: PlanNode
    admitted: PlanNode
    cache_hit: bool
    compile_seconds: float
    accountant_seconds: float
    escalations: List[Dict]
    recorded: bool = False  # set once accountant.record committed
    # offline-pool identity: (template fingerprint hash, pow2 shape key) —
    # the same public identity the plan cache uses, never a data-dependent
    # value (see DESIGN.md §15)
    bundle_key: Optional[tuple] = None


class TenantSession:
    def __init__(self, service: "AnalyticsService", tenant: str):
        self.service = service
        self.tenant = tenant

    def submit(self, sql: str) -> QueryResult:
        return self.service.submit(self.tenant, sql)

    def enqueue(self, sql: str):
        """Queue for batched execution; results arrive via ``service.drain``."""
        return self.service.enqueue(self.tenant, sql)


class AnalyticsService:
    def __init__(
        self,
        tables: Dict[str, SecretTable],
        *,
        catalog: Optional[Catalog] = None,
        noise: Optional[NoiseStrategy] = None,
        addition: str = "parallel",
        placement: str = "cost_based",
        accountant: Optional[PrivacyAccountant] = None,
        key: Optional[jax.Array] = None,
        jit_ops: bool = False,
        plan_cache_size: int = 256,
        reveal_results: bool = True,
        reorder_joins: bool = True,
        batch_max: int = 16,
        batch_wait_s: float = 0.05,
        state_dir: Optional[str] = None,  # durable shared state (DESIGN §12)
        wal_fsync: bool = True,
        compact_wal_bytes: int = 1 << 16,  # auto-compaction threshold
        offline: str = "on",  # correlated-randomness pool (DESIGN §15):
        # "off" = derive everything on demand; "on" = pool + inline refills
        # at idle windows; "background" = pool + provisioner daemon thread
        offline_pool_bytes: int = 64 << 20,
        offline_window: int = 8,  # upcoming counters provisioned per template
        config: Optional[RuntimeConfig] = None,  # execution-strategy knobs;
        # None = env fallback. Threaded into the Engine (kernels/fusion/tile)
        # and the planner's physical join selection.
        engine_factory=None,  # Engine-compatible constructor — the networked
        # runtime passes one that builds a coordinator-backed RemoteEngine
    ):
        if offline not in ("off", "on", "background"):
            raise ValueError(
                f"offline={offline!r} (expected off|on|background)"
            )
        self.tables = tables
        self.config = config
        self.catalog = catalog or Catalog.from_tables(tables)
        self.noise = noise if noise is not None else shrinkwrap_default()
        self.addition = addition
        self.placement = placement
        self.accountant = accountant or PrivacyAccountant()
        self.reveal_results = reveal_results
        self.reorder_joins = reorder_joins
        # metrics registry: the single source of truth for service counters —
        # the legacy `stats` dict is a read-only view over it (DESIGN.md §14.2)
        self.metrics = MetricsRegistry()
        m = self.metrics
        self._m_queries = m.counter(
            "reflex_queries_total",
            "Completed queries (recorded and revealed)", ("tenant",),
        )
        self._m_refusals = m.counter(
            "reflex_refusals_total",
            "Queries refused at admission (CRT budget exhausted)",
        )
        self._m_plan_cache = m.counter(
            "reflex_plan_cache_lookups_total",
            "Prepared-statement cache lookups by outcome "
            "(a rebind also counts as a hit)", ("status",),
        )
        self._m_jit = m.gauge(
            "reflex_jit_cache_logical",
            "Process-wide Engine jit cache counters (logical hits: a K-slot "
            "batched pass counts K)", ("status",),
        )
        self._m_budget_total = m.gauge(
            "reflex_privacy_budget_total",
            "floor(crt_rounds) per observation signature", ("sig", "strategy"),
        )
        self._m_budget_remaining = m.gauge(
            "reflex_privacy_budget_remaining",
            "CRT observations still spendable per signature "
            "(budget - observed - foreign reserved)", ("sig", "strategy"),
        )
        self._m_budget_observed = m.gauge(
            "reflex_privacy_budget_observed",
            "Noisy-size observations already disclosed per signature",
            ("sig", "strategy"),
        )
        # offline pool traffic, labeled by template fingerprint hash — the
        # pool key IS the plan-cache identity, never a true size (§15)
        self._m_off_hits = m.counter(
            "reflex_offline_hits_total",
            "Correlated-randomness fetches served from the offline pool",
            ("template",),
        )
        self._m_off_misses = m.counter(
            "reflex_offline_misses_total",
            "Correlated-randomness fetches derived on demand (cold)",
            ("template",),
        )
        self._m_off_demand = m.counter(
            "reflex_offline_demand_total",
            "Engine passes executed under each template's pool bundle "
            "(feeds provisioner target sizing)",
            ("template",),
        )
        self._m_off_depth = m.gauge(
            "reflex_offline_pool_depth_bytes",
            "Bytes of precomputed randomness currently pooled",
        )
        self._m_off_entries = m.gauge(
            "reflex_offline_pool_entries",
            "Pooled entries by material class", ("kind",),
        )
        make_engine = engine_factory if engine_factory is not None else Engine
        self.engine = make_engine(
            tables, key=key if key is not None else jax.random.PRNGKey(0),
            jit_ops=jit_ops, config=config,
        )
        self.offline_mode = offline
        self.pool: Optional[RandomnessPool] = None
        self.provisioner: Optional[Provisioner] = None
        self._offline_demand_counts: Dict[tuple, float] = {}
        if offline != "off":
            self.pool = RandomnessPool(max_bytes=offline_pool_bytes)
            self.provisioner = Provisioner(
                self.pool,
                self.engine.prf,
                ctr_fn=lambda: self.engine._resize_ctr,
                demand_fn=lambda: dict(self._offline_demand_counts),
                window=offline_window,
                metrics=self.metrics,
            )
            if offline == "background":
                self.provisioner.start()
        self.state_dir = state_dir
        self.compact_wal_bytes = compact_wal_bytes
        self.calibration = None
        if state_dir is not None:
            from ..state import CalibrationStore, JournalStore

            if not self.accountant.durable:
                self.accountant.attach_store(
                    JournalStore(
                        state_dir, "ledger", fsync=wal_fsync,
                        metrics=self.metrics,
                    )
                )
            self.calibration = CalibrationStore(
                JournalStore(
                    state_dir, "calibration", fsync=wal_fsync,
                    metrics=self.metrics,
                )
            )
            self.engine.reveal_hook = self._observe_reveal
        self._plan_cache: "OrderedDict" = OrderedDict()
        self._plan_cache_max = plan_cache_size
        self._last_bundle_key: Optional[tuple] = None
        from .scheduler import QueryScheduler

        self.scheduler = QueryScheduler(
            self, max_batch=batch_max, max_wait_s=batch_wait_s
        )

    @property
    def stats(self) -> Dict:
        """Legacy counters dict, assembled as a read-only view over the
        metrics registry — the dict and the registry cannot drift because
        there is only one underlying counter per figure (e.g. `per_tenant`
        IS `reflex_queries_total` broken out by its tenant label)."""
        return {
            "queries": int(self._m_queries.total()),
            "plan_cache_hits": int(self._m_plan_cache.value(status="hit")),
            "plan_cache_misses": int(self._m_plan_cache.value(status="miss")),
            "plan_cache_rebinds": int(
                self._m_plan_cache.value(status="rebind")
            ),
            "refusals": int(self._m_refusals.total()),
            "per_tenant": {
                key[0]: int(v) for key, v in self._m_queries.samples()
            },
        }

    # -- sessions -------------------------------------------------------------
    def session(self, tenant: str) -> TenantSession:
        self._m_queries.touch(tenant=tenant)
        return TenantSession(self, tenant)

    # -- compile + cache ------------------------------------------------------
    def _shape_key(self) -> tuple:
        return tuple(
            (name, _bucket_pow2(t.n)) for name, t in sorted(self.tables.items())
        )

    def compile(self, sql: str) -> tuple[PlanNode, bool, float]:
        """SQL -> physical plan via the prepared-statement cache; returns
        (plan, hit, seconds). The cache is keyed on the literal-masked
        template fingerprint: a hit with different predicate constants
        re-binds the cached physical plan (Resizer placement included)
        instead of recompiling."""
        t0 = time.perf_counter()
        cm = default_cost_model(
            self.catalog, noise=self.noise, calibration=self.calibration
        )
        logical = compile_logical(
            sql, self.catalog, cost_model=cm, reorder_joins=self.reorder_joins
        )
        params = plan_params(logical)
        cache_key = (
            template_fingerprint(logical),
            self.placement,
            strategy_key(self.noise, self.addition),
            self._shape_key(),
        )
        entry = self._plan_cache.get(cache_key)
        hit = entry is not None
        rebind = False
        # the offline pool's bundle identity: same public template identity
        # as the plan cache, hashed so it can double as a metric label
        self._last_bundle_key = (
            redact.fingerprint_hash(cache_key[0]), cache_key[3],
        )
        if hit:
            self._plan_cache.move_to_end(cache_key)
            self._m_plan_cache.inc(status="hit")
            cached_params, cached_plan = entry
            if params == cached_params:
                plan = cached_plan  # identical query: shared plan object
            else:
                rebind = True
                self._m_plan_cache.inc(status="rebind")
                plan = bind_params(cached_plan, params)
        else:
            self._m_plan_cache.inc(status="miss")
            # physical join selection BEFORE resizer placement, against the
            # calibration-refined cost model: observed (already-disclosed)
            # intermediate sizes steer the product-vs-sortmerge choice with
            # zero extra disclosure. Catalogs without declared multiplicity
            # bounds never rewrite (sort-merge inapplicable).
            physical = select_join_algorithms(
                logical, cost_model=cm, catalog=self.catalog,
                mode=self.config.join_algo if self.config is not None else None,
            )
            if self.placement == "none":
                plan = physical
            else:
                cfg = ResizerConfig(noise=self.noise, addition=self.addition)
                plan = insert_resizers(
                    physical, lambda _n: cfg, placement=self.placement,
                    cost_model=cm,
                )
            self._plan_cache[cache_key] = (params, plan)
            while len(self._plan_cache) > self._plan_cache_max:
                self._plan_cache.popitem(last=False)
        dt = time.perf_counter() - t0
        obs_trace.record("compile", seconds=dt, cache_hit=hit, rebind=rebind)
        return plan, hit, dt

    # -- the query path -------------------------------------------------------
    def _admit(self, tenant: str, sql: str, planned=None) -> AdmittedQuery:
        """Compile + admission-check one query (shared by the synchronous
        path and the scheduler). ``planned`` threads the accountant's
        cross-query admission group through a batching window."""
        plan, hit, compile_s = self.compile(sql)
        bundle_key = self._last_bundle_key
        ta = time.perf_counter()
        try:
            admitted, escalations = self.accountant.admit(plan, planned)
        except QueryRefused:
            self._m_refusals.inc()
            obs_trace.record(
                "admit", seconds=time.perf_counter() - ta,
                tenant=tenant, refused=True,
            )
            raise
        obs_trace.record(
            "admit", seconds=time.perf_counter() - ta,
            tenant=tenant, refused=False, escalations=len(escalations),
        )
        return AdmittedQuery(
            tenant=tenant,
            sql=sql,
            plan=plan,
            admitted=admitted,
            cache_hit=hit,
            compile_seconds=compile_s,
            accountant_seconds=time.perf_counter() - ta,
            escalations=escalations,
            bundle_key=bundle_key,
        )

    def _finalize(
        self,
        aq: AdmittedQuery,
        out: SecretTable,
        report: ExecutionReport,
        batch_slots: int = 1,
    ) -> QueryResult:
        """Record the executed query's observations, update counters, and
        reveal — identical for serial and batched (demuxed) executions."""
        ta = time.perf_counter()
        with obs_trace.span("record", tenant=aq.tenant):
            self.accountant.record(aq.admitted, report)
            aq.recorded = True  # failure past this point must not charge_failed
            if self.calibration is not None:
                # one journal transaction for all of this query's revealed
                # sizes (buffered during execution, off the engine's critical
                # path)
                self.calibration.flush()
        acct_s = aq.accountant_seconds + (time.perf_counter() - ta)

        self._m_queries.inc(tenant=aq.tenant)
        self._publish_budget_gauges()
        with obs_trace.span("reveal", tenant=aq.tenant):
            rows = out.reveal_true_rows() if self.reveal_results else None
            post = lookup(type(aq.admitted)).post_reveal
            if rows is not None and post is not None:
                # operator-defined client-side derivation (AVG = sum // cnt)
                rows = post(aq.admitted, rows)
        return QueryResult(
            tenant=aq.tenant,
            sql=aq.sql,
            plan=aq.admitted,
            table=out,
            rows=rows,
            report=report,
            cache_hit=aq.cache_hit,
            compile_seconds=aq.compile_seconds,
            accountant_seconds=acct_s,
            escalations=aq.escalations,
            batch_slots=batch_slots,
        )

    @contextlib.contextmanager
    def _offline_scope(self, bundle_key: Optional[tuple]):
        """Install the offline randomness pool around one engine pass.

        A no-op when the pool is off. Otherwise every eager correlated-
        randomness derivation inside consults the pool first (hot) and falls
        back to on-demand derivation (cold) — bit-identical either way, the
        pool is a content-addressed cache in front of the same pure
        functions. The first pass per bundle records the derivation recipe
        the provisioner replays offline."""
        if self.pool is None or bundle_key is None:
            yield None
            return
        template = bundle_key[0]
        self._offline_demand_counts[bundle_key] = (
            self._offline_demand_counts.get(bundle_key, 0.0) + 1.0
        )
        self._m_off_demand.inc(template=template)
        src = self.pool.source(bundle_key, self.engine.prf.pair_keys)
        try:
            with obs_trace.span("offline", template=template):
                with material_scope(src):
                    yield src
        finally:
            src.finish()
            if src.hits:
                self._m_off_hits.inc(src.hits, template=template)
            if src.misses:
                self._m_off_misses.inc(src.misses, template=template)
            obs_trace.record(
                "offline.pass", template=template,
                hits=src.hits, misses=src.misses,
            )

    def _execute_admitted(self, aq: AdmittedQuery, planned) -> QueryResult:
        """Serial batch-of-1: execute + finalize with the failure-accounting
        protocol (the one shared code path for sync submits and the
        scheduler's non-batchable fallback — privacy-critical, keep single)."""
        try:
            with self._offline_scope(aq.bundle_key):
                out, report = self.engine.execute(aq.admitted)
            return self._finalize(aq, out, report)
        except Exception:
            # execution may have revealed noisy sizes that record() never
            # charged — price them conservatively (see charge_failed); a
            # post-record failure (reveal/post_reveal) is already charged
            if not aq.recorded:
                self.accountant.charge_failed(aq.admitted)
            raise
        finally:
            # recorded (or charged above): the window reservation must not
            # double-count it
            self.accountant.release_planned(aq.admitted, planned)

    def submit(self, tenant: str, sql: str) -> QueryResult:
        """Synchronous execution — admission + a batch-of-1 engine pass.

        Shares the scheduler's admission group, so a sync submit landing in
        the middle of an open batching window is charged against the queued
        (admitted-but-unrecorded) observations too."""
        self.scheduler.poll()  # sync traffic must not starve queued buckets
        with obs_trace.span("query", tenant=tenant, sql=sql):
            planned = self.scheduler._planned
            aq = self._admit(tenant, sql, planned=planned)
            return self._execute_admitted(aq, planned)

    # -- batched admission (DESIGN.md §11) ------------------------------------
    def enqueue(self, tenant: str, sql: str):
        """Admit ``sql`` into the batching queue; same-bucket queries execute
        as one stacked engine pass. Returns a :class:`~repro.service.scheduler.
        QueryTicket`; fetch results with :meth:`drain`."""
        return self.scheduler.submit(tenant, sql)

    def drain(self, force: bool = True) -> List[QueryResult]:
        """Flush the batching queue (all buckets when ``force``, else only
        full/deadline-expired ones) and return completed results in
        submission order."""
        return self.scheduler.drain(force=force)

    # -- durable state (DESIGN.md §12) ----------------------------------------
    def _observe_reveal(self, node: PlanNode, info: Dict) -> None:
        """Engine revealed-size feedback hook: persist the already-public
        (N, S) pair for the resized subplan so future planning uses observed
        selectivities instead of static defaults. S is on the wire either
        way — recording it discloses nothing new."""
        if self.calibration is not None:
            self.calibration.observe_plan(
                node.child, n=int(info["n"]), s=int(info["s"])
            )

    def _maybe_compact(self) -> None:
        """Opportunistic snapshot+truncate of both journals once their WALs
        outgrow the threshold (called by the scheduler at window close and
        safe to call any time — compaction preserves open intents)."""
        if self.state_dir is None:
            return
        self.accountant.maybe_compact(self.compact_wal_bytes)
        self.calibration.maybe_compact(self.compact_wal_bytes)

    def compact_state(self) -> None:
        """Force-compact the durable journals now (restart-fast snapshots)."""
        if self.state_dir is None:
            return
        self.accountant.maybe_compact(-1)
        self.calibration.maybe_compact(-1)

    def close(self) -> None:
        """Stop background work (the offline provisioner thread, if any)."""
        if self.provisioner is not None:
            self.provisioner.stop()

    # -- reporting ------------------------------------------------------------
    def _publish_budget_gauges(self) -> None:
        """Mirror the accountant's per-signature burn-down into gauges.
        Labels carry the fingerprint *hash* and the strategy key — both
        public (the signature identifies the subplan, not its data)."""
        for e in self.accountant.budget_metrics():
            labels = {
                "sig": redact.fingerprint_hash(e["fp"]),
                "strategy": e["strategy"],
            }
            self._m_budget_observed.set(e["observed"], **labels)
            if e["budget"] is not None:
                self._m_budget_total.set(e["budget"], **labels)
                self._m_budget_remaining.set(e["remaining"], **labels)

    def _refresh_gauges(self) -> None:
        """Bring point-in-time gauges current before any export."""
        js = Engine.jit_cache_stats()
        for k in ("hits", "misses", "size"):
            self._m_jit.set(js[k], status=k)
        if self.pool is not None:
            ps = self.pool.stats()
            self._m_off_depth.set(ps["depth_bytes"])
            self._m_off_entries.set(ps["static_entries"], kind="static")
            self._m_off_entries.set(ps["counter_entries"], kind="counter")
        self.scheduler.publish_gauges()
        self._publish_budget_gauges()

    def render_metrics(self) -> str:
        """Prometheus text exposition of every service metric."""
        self._refresh_gauges()
        return self.metrics.render_prometheus()

    def metrics_snapshot(self) -> Dict:
        """JSON-safe dump of the registry (the machine-readable twin of
        :meth:`render_metrics`; validated in CI against a checked-in schema)."""
        self._refresh_gauges()
        return self.metrics.snapshot()

    # -- EXPLAIN / EXPLAIN ANALYZE (DESIGN.md §14.4) --------------------------
    def explain(self, sql: str) -> str:
        """Compile (through the plan cache) and render the placed physical
        plan with the cost model's estimates — no execution, no admission,
        nothing disclosed."""
        plan, _hit, _s = self.compile(sql)
        cm = default_cost_model(
            self.catalog, noise=self.noise, calibration=self.calibration
        )
        return explain_text(plan, cost_model=cm, title=f"EXPLAIN {sql}")

    def explain_analyze(self, tenant: str, sql: str):
        """Execute ``sql`` through the full admission pipeline and render the
        plan with estimated-vs-actual columns. Costs one real query (the
        accountant charges it like any other). Returns ``(text, result)``."""
        res = self.submit(tenant, sql)
        cm = default_cost_model(
            self.catalog, noise=self.noise, calibration=self.calibration
        )
        text = explain_text(
            res.plan, cost_model=cm, report=res.report,
            title=f"EXPLAIN ANALYZE {sql}",
            wire_audit=getattr(self.engine, "last_wire_audit", None),
        )
        return text, res

    def cache_stats(self) -> Dict[str, float]:
        h, m = self.stats["plan_cache_hits"], self.stats["plan_cache_misses"]
        return {
            "hits": h,
            "misses": m,
            "hit_rate": h / max(h + m, 1),
            "size": len(self._plan_cache),
        }

    def status(self) -> Dict:
        return {
            **self.stats,
            "plan_cache": self.cache_stats(),
            # process-wide: Engine._JIT_CACHE is shared by every Engine, so
            # these counters span all services in the process
            "jit_cache": {**Engine.jit_cache_stats(), "scope": "process"},
            "scheduler": self.scheduler.stats,
            "offline": None if self.pool is None else {
                "mode": self.offline_mode,
                **self.pool.stats(),
                "provisioner": self.provisioner.stats(),
            },
            "accountant": self.accountant.status(),
            "state": None if self.state_dir is None else {
                "dir": self.state_dir,
                "ledger": self.accountant.store.status(),
                "calibration": self.calibration.status(),
            },
        }
