"""Multi-tenant analytics service: SQL sessions, plan cache, CRT budget."""
from .accountant import (  # noqa: F401
    PrivacyAccountant,
    QueryRefused,
    escalate_strategy,
    strategy_key,
)
from .service import AnalyticsService, QueryResult, TenantSession  # noqa: F401

__all__ = [
    "AnalyticsService",
    "PrivacyAccountant",
    "QueryRefused",
    "QueryResult",
    "TenantSession",
    "escalate_strategy",
    "strategy_key",
]
