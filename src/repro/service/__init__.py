"""Multi-tenant analytics service: SQL sessions, plan cache, CRT budget."""
from ..errors import BudgetRefused, ReflexError  # noqa: F401
from .accountant import (  # noqa: F401
    PrivacyAccountant,
    QueryRefused,
    escalate_strategy,
    strategy_key,
)
from .scheduler import QueryScheduler, QueryTicket  # noqa: F401
from .service import (  # noqa: F401
    AdmittedQuery,
    AnalyticsService,
    QueryResult,
    TenantSession,
)

__all__ = [
    "AdmittedQuery",
    "AnalyticsService",
    "BudgetRefused",
    "PrivacyAccountant",
    "QueryRefused",
    "ReflexError",
    "QueryResult",
    "QueryScheduler",
    "QueryTicket",
    "TenantSession",
    "escalate_strategy",
    "strategy_key",
]
