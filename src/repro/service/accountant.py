"""PrivacyAccountant: the CRT metric enforced as a runtime budget.

The paper's Cardinality Recovery Threshold (core/crt.py, §3.3) says how many
*equivalent observations* r of a noisy intermediate size S = T + eta an
attacker needs to pin the true size T within ±err at confidence alpha. The
offline metric guards nothing: an engine that happily serves observation
r + 1 hands the attacker exactly the sample mean it needs. This module turns
the metric into an admission-control budget (DESIGN.md §9).

**What counts as one observation.** Every non-NoTrim ``Resize`` node reveals
one noisy size S when it trims. Two reveals are *equivalent* — i.i.d. draws
of the same S distribution — iff they resize the same logical intermediate
(structurally identical subplan over the same base tables, hence the same T)
using the same noise strategy and addition design. The observation signature
is therefore ``(fingerprint(child subplan), strategy key, addition)``; the
budget for a signature is ``floor(crt_rounds(noise, addition, N, T, err,
confidence))``, initialized on first observation (when N and T are known) and
decremented on every subsequent one. Budgets are *global* across tenants —
colluding tenants submitting the same query are one attacker.

**Depletion.** When a signature's budget is exhausted the accountant either
refuses the query (``policy="refuse"``) or escalates the noise strategy
(``policy="escalate"``): TLap eps is halved (4x the variance, so ~4x the
fresh budget) until ``min_eps``, then the Resizer degenerates to NoTrim —
no trim, no disclosure, no budget to spend. Observations under the escalated
strategy form a *new* signature: mixing draws from different distributions
does not refund the attacker's spent observations (Eq. 1 assumes i.i.d.
noise), so per-strategy accounting is conservative and correct.

Simulation note: T is read from the Resizer's oracle info — the coordinator-
side trusted state a real deployment would hold as each party's share of the
accounting, or bound via a DP estimate.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from ..core.crt import crt_rounds
from ..core.noise import BetaNoise, NoiseStrategy, NoTrim, TruncatedLaplace
from ..engine.executor import ExecutionReport
from ..plan.nodes import PlanNode, Resize
from ..sql.compile import plan_fingerprint

__all__ = ["PrivacyAccountant", "QueryRefused", "strategy_key", "escalate_strategy"]


class QueryRefused(RuntimeError):
    """Raised under ``policy='refuse'`` when a query would spend an
    observation a signature's CRT budget no longer covers."""

    def __init__(self, signature: Tuple[str, str], observed: int, budget: int):
        self.signature = signature
        self.observed = observed
        self.budget = budget
        super().__init__(
            f"CRT budget exhausted for resize of:\n{signature[0]}\n"
            f"strategy={signature[1]}: "
            f"{observed}/{budget} observations already disclosed"
        )


def strategy_key(noise: NoiseStrategy, addition: str) -> str:
    """Stable identity of a (noise strategy, addition design) pair — dataclass
    repr carries every calibration parameter."""
    return f"{noise!r}|{addition}"


def escalate_strategy(
    noise: NoiseStrategy, min_eps: float = 0.0625
) -> Optional[NoiseStrategy]:
    """Next rung of the noise ladder, or None if there is none (NoTrim).

    TLap: halve eps (b doubles, Var(eta) ~ 4x, so Eq. 1 gives ~4x budget)
    until min_eps, then NoTrim. Beta: halve (alpha, beta) — same mean
    fraction, fatter spread — until alpha < 0.5, then NoTrim. Everything
    else jumps straight to NoTrim (fully oblivious: nothing disclosed).
    """
    if isinstance(noise, NoTrim):
        return None
    if isinstance(noise, TruncatedLaplace) and noise.eps / 2.0 >= min_eps:
        return TruncatedLaplace(
            eps=noise.eps / 2.0, delta=noise.delta, sensitivity=noise.sensitivity
        )
    if isinstance(noise, BetaNoise) and noise.alpha / 2.0 >= 0.5:
        return BetaNoise(alpha=noise.alpha / 2.0, beta=noise.beta / 2.0)
    return NoTrim()


def _iter_resizes(plan: PlanNode, include_notrim: bool = False):
    """Post-order (== execution-order) Resize nodes of a plan — the one
    traversal shared by reservation, release, charge, and record, so their
    eligibility rules cannot drift apart."""
    for c in plan.children():
        yield from _iter_resizes(c, include_notrim)
    if isinstance(plan, Resize) and (
        include_notrim or not isinstance(plan.cfg.noise, NoTrim)
    ):
        yield plan


def _drop_reservations(
    planned: Dict[Tuple[str, str], int], sig: Tuple[str, str], count: int = 1
) -> None:
    left = planned.get(sig, 0) - count
    if left > 0:
        planned[sig] = left
    else:
        planned.pop(sig, None)


@dataclasses.dataclass
class _SigState:
    observed: int = 0
    budget: Optional[int] = None  # set at first observation (needs N, T)
    n: int = 0
    t: int = 0


class PrivacyAccountant:
    """Tracks per-signature observation counts against ``crt_rounds`` and
    rewrites (or refuses) plans whose next reveal would exceed the budget."""

    def __init__(
        self,
        err: float = 1.0,
        confidence: float = 0.999,
        policy: str = "escalate",  # "escalate" | "refuse"
        min_eps: float = 0.0625,
    ):
        if policy not in ("escalate", "refuse"):
            raise ValueError(f"unknown policy {policy!r}")
        self.err = err
        self.confidence = confidence
        self.policy = policy
        self.min_eps = min_eps
        self._state: Dict[Tuple[str, str], _SigState] = {}
        self.escalation_count = 0
        self.refusal_count = 0

    # -- signatures -----------------------------------------------------------
    def signature(self, node: Resize) -> Tuple[str, str]:
        # strategy_key already embeds the addition design
        return (
            plan_fingerprint(node.child),
            strategy_key(node.cfg.noise, node.cfg.addition),
        )

    def budget_for(self, noise: NoiseStrategy, addition: str, n: int, t: int) -> int:
        """floor(crt_rounds): the number of equivalent observations that may
        be disclosed before the attacker's Eq. 1 estimator reaches ±err at
        the configured confidence."""
        return int(
            math.floor(
                crt_rounds(noise, addition, n, t, err=self.err,
                           confidence=self.confidence)
            )
        )

    def remaining(self, sig: Tuple[str, str]) -> Optional[int]:
        st = self._state.get(sig)
        if st is None or st.budget is None:
            return None  # not yet observed: first observation is always free
        return st.budget - st.observed

    # -- admission ------------------------------------------------------------
    def admit(
        self, plan: PlanNode, planned: Optional[Dict[Tuple[str, str], int]] = None
    ) -> Tuple[PlanNode, List[Dict]]:
        """Check every Resize in the plan against its budget. Returns a
        (possibly rewritten) plan plus the escalation records. Raises
        :class:`QueryRefused` under ``policy='refuse'``. The input plan is
        never mutated (it may be cache-shared).

        A plan may contain several Resizes with the *same* signature
        (duplicated subtrees, e.g. a self-join); ``planned`` charges them
        against the remaining budget as a group so a single admit cannot
        overdraw a known budget. (A signature's very first budget is only
        learned at execution, so duplicates inside the first-ever plan for a
        signature may still spend up to that plan's multiplicity.)

        Pass an explicit ``planned`` dict to extend that group across
        *several* admits: the admission scheduler threads one dict through
        every query queued in the same drain window, so K queued queries with
        the same signature spend K observations against the remaining budget
        at admit time — exactly what a serial admit/record interleaving would
        have charged — even though their ``record`` calls all land after the
        batched execution. The dict is mutated in place; drop it once the
        window's records are committed."""
        escalations: List[Dict] = []
        if planned is None:
            planned = {}
        added: Dict[Tuple[str, str], int] = {}  # this admit's reservations

        def reserve(sig: Tuple[str, str]) -> None:
            planned[sig] = planned.get(sig, 0) + 1
            added[sig] = added.get(sig, 0) + 1

        def rewrite(node: PlanNode) -> PlanNode:
            old_children = node.children()
            new_children = [rewrite(c) for c in old_children]
            if any(n is not o for n, o in zip(new_children, old_children)):
                node = node.replace_children(new_children)  # preserve identity
                # when nothing changed: cache hits stay shared objects
            if not isinstance(node, Resize) or isinstance(node.cfg.noise, NoTrim):
                return node
            while True:
                sig = self.signature(node)
                rem = self.remaining(sig)
                if rem is None or rem - planned.get(sig, 0) > 0:
                    reserve(sig)
                    return node
                st = self._state[sig]
                if self.policy == "refuse":
                    self.refusal_count += 1
                    raise QueryRefused(sig, st.observed, st.budget)
                nxt = escalate_strategy(node.cfg.noise, self.min_eps)
                if nxt is None:
                    return node  # already NoTrim: nothing disclosed
                self.escalation_count += 1
                escalations.append(
                    {
                        "from": strategy_key(node.cfg.noise, node.cfg.addition),
                        "to": strategy_key(nxt, node.cfg.addition),
                        "observed": st.observed,
                        "budget": st.budget,
                    }
                )
                node = Resize(
                    node.child, dataclasses.replace(node.cfg, noise=nxt)
                )
                if isinstance(nxt, NoTrim):
                    return node

        try:
            return rewrite(plan), escalations
        except QueryRefused:
            # a refused query executes nothing: roll this admit's reservations
            # back out of the (possibly caller-shared) admission group, or
            # they would shrink other queries' effective budgets forever
            for sig, count in added.items():
                _drop_reservations(planned, sig, count)
            raise

    def release_planned(
        self, plan: PlanNode, planned: Dict[Tuple[str, str], int]
    ) -> None:
        """Drop a now-recorded plan's contributions from an admission group:
        once :meth:`record` has charged the plan's observations to the real
        per-signature state, keeping them in ``planned`` too would double-
        count them against queries admitted later in the same window."""
        for node in _iter_resizes(plan):
            _drop_reservations(planned, self.signature(node))

    def charge_failed(self, plan: PlanNode) -> None:
        """Conservatively charge one observation per non-NoTrim Resize of a
        plan whose execution may have disclosed its noisy sizes but could not
        be recorded (engine failure mid-plan, demux/record failure): the
        attacker may already hold the sample, so the budget must count it —
        over-charging a plan that in fact died before its reveal only errs
        toward refusing/escalating earlier, never toward extra disclosure.
        A never-seen signature keeps ``budget=None``; a later successful
        record initializes it with these observations already spent."""
        for node in _iter_resizes(plan):
            self._state.setdefault(self.signature(node), _SigState()).observed += 1

    # -- recording ------------------------------------------------------------
    def record(self, plan: PlanNode, report: ExecutionReport) -> None:
        """Charge one observation per executed non-NoTrim Resize, matching
        plan Resize nodes (post-order == execution order) to the report's
        per-node resize info to learn (N, T) for budget initialization."""
        resizes = list(_iter_resizes(plan, include_notrim=True))
        infos = [s.extra for s in report.nodes if s.node.startswith("Resize")]
        if len(infos) != len(resizes):
            raise RuntimeError(
                f"report has {len(infos)} resize entries for "
                f"{len(resizes)} Resize nodes — cannot attribute observations"
            )
        for node, info in zip(resizes, infos):
            if isinstance(node.cfg.noise, NoTrim) or info.get("skipped"):
                continue
            sig = self.signature(node)
            st = self._state.setdefault(sig, _SigState())
            if st.budget is None:
                st.n, st.t = int(info["n"]), int(info["t"])
                st.budget = max(
                    self.budget_for(
                        node.cfg.noise, node.cfg.addition, st.n, st.t
                    ),
                    1,
                )
            st.observed += 1

    # -- reporting ------------------------------------------------------------
    def status(self) -> List[Dict]:
        return [
            {
                "subplan": sig[0].splitlines()[0],
                "strategy": sig[1],
                "observed": st.observed,
                "budget": st.budget,
                "remaining": None if st.budget is None else st.budget - st.observed,
                "n": st.n,
                "t": st.t,
            }
            for sig, st in self._state.items()
        ]
