"""PrivacyAccountant: the CRT metric enforced as a runtime budget.

The paper's Cardinality Recovery Threshold (core/crt.py, §3.3) says how many
*equivalent observations* r of a noisy intermediate size S = T + eta an
attacker needs to pin the true size T within ±err at confidence alpha. The
offline metric guards nothing: an engine that happily serves observation
r + 1 hands the attacker exactly the sample mean it needs. This module turns
the metric into an admission-control budget (DESIGN.md §9).

**What counts as one observation.** Every non-NoTrim ``Resize`` node reveals
one noisy size S when it trims. Two reveals are *equivalent* — i.i.d. draws
of the same S distribution — iff they resize the same logical intermediate
(structurally identical subplan over the same base tables, hence the same T)
using the same noise strategy and addition design. The observation signature
is therefore ``(fingerprint(child subplan), strategy key, addition)``; the
budget for a signature is ``floor(crt_rounds(noise, addition, N, T, err,
confidence))``, initialized on first observation (when N and T are known) and
decremented on every subsequent one. Budgets are *global* across tenants —
colluding tenants submitting the same query are one attacker.

**Depletion.** When a signature's budget is exhausted the accountant either
refuses the query (``policy="refuse"``) or escalates the noise strategy
(``policy="escalate"``): TLap eps is halved (4x the variance, so ~4x the
fresh budget) until ``min_eps``, then the Resizer degenerates to NoTrim —
no trim, no disclosure, no budget to spend. Observations under the escalated
strategy form a *new* signature: mixing draws from different distributions
does not refund the attacker's spent observations (Eq. 1 assumes i.i.d.
noise), so per-strategy accounting is conservative and correct.

**Durability (DESIGN.md §12).** With a :class:`repro.state.JournalStore`
attached, the ledger survives restarts and is shared across replicas via a
two-phase **intent -> record** protocol: ``admit`` journals one *intent* per
observation it is about to allow — durably, *before* the engine reveals
anything — and ``record``/``charge_failed`` later journal the matching
*record*/*charge*. An intent without a matching record (a crash between
reveal and record, or a torn record line) stays open forever and counts
against the budget exactly like a spent observation — the attacker may
already hold the sample — so crash-replay refuses at-or-before where an
uninterrupted run would, never after. A torn *intent* line means the append
never returned, so the engine never ran: dropping it discloses nothing.
Open intents owned by *this* session are excluded from ``remaining`` (they
are already counted by the in-memory admission group ``planned``); foreign
open intents — other live replicas' in-flight queries, or a dead session's
conservative charges — are subtracted like observations.

Simulation note: T is read from the Resizer's oracle info — the coordinator-
side trusted state a real deployment would hold as each party's share of the
accounting, or bound via a DP estimate.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Tuple

from ..core.crt import crt_rounds
from ..errors import BudgetRefused
from ..core.noise import BetaNoise, NoiseStrategy, NoTrim, TruncatedLaplace
from ..engine.executor import ExecutionReport
from ..plan.nodes import PlanNode, Resize
from ..sql.compile import plan_fingerprint

__all__ = ["PrivacyAccountant", "QueryRefused", "strategy_key", "escalate_strategy"]

# The refusal error now lives in the typed taxonomy (repro.errors); the old
# name stays importable here. BudgetRefused subclasses RuntimeError, so
# pre-taxonomy except clauses keep catching it.
QueryRefused = BudgetRefused


def strategy_key(noise: NoiseStrategy, addition: str) -> str:
    """Stable identity of a (noise strategy, addition design) pair — dataclass
    repr carries every calibration parameter."""
    return f"{noise!r}|{addition}"


def escalate_strategy(
    noise: NoiseStrategy, min_eps: float = 0.0625
) -> Optional[NoiseStrategy]:
    """Next rung of the noise ladder, or None if there is none (NoTrim).

    TLap: halve eps (b doubles, Var(eta) ~ 4x, so Eq. 1 gives ~4x budget)
    until min_eps, then NoTrim. Beta: halve (alpha, beta) — same mean
    fraction, fatter spread — until alpha < 0.5, then NoTrim. Everything
    else jumps straight to NoTrim (fully oblivious: nothing disclosed).
    """
    if isinstance(noise, NoTrim):
        return None
    if isinstance(noise, TruncatedLaplace) and noise.eps / 2.0 >= min_eps:
        return TruncatedLaplace(
            eps=noise.eps / 2.0, delta=noise.delta, sensitivity=noise.sensitivity
        )
    if isinstance(noise, BetaNoise) and noise.alpha / 2.0 >= 0.5:
        return BetaNoise(alpha=noise.alpha / 2.0, beta=noise.beta / 2.0)
    return NoTrim()


def _iter_resizes(plan: PlanNode, include_notrim: bool = False):
    """Post-order (== execution-order) Resize nodes of a plan — the one
    traversal shared by reservation, release, charge, and record, so their
    eligibility rules cannot drift apart."""
    for c in plan.children():
        yield from _iter_resizes(c, include_notrim)
    if isinstance(plan, Resize) and (
        include_notrim or not isinstance(plan.cfg.noise, NoTrim)
    ):
        yield plan


def _drop_reservations(
    planned: Dict[Tuple[str, str], int], sig: Tuple[str, str], count: int = 1
) -> None:
    left = planned.get(sig, 0) - count
    if left > 0:
        planned[sig] = left
    else:
        planned.pop(sig, None)


@dataclasses.dataclass
class _SigState:
    observed: int = 0
    budget: Optional[int] = None  # set at first observation (needs N, T)
    n: int = 0
    t: int = 0
    # open intents: journaled "about to reveal" charges not yet matched by a
    # record (intent id -> owner session). Foreign entries count against the
    # budget like observations (conservative: the sample may be out there).
    intents: Dict[str, str] = dataclasses.field(default_factory=dict)


class PrivacyAccountant:
    """Tracks per-signature observation counts against ``crt_rounds`` and
    rewrites (or refuses) plans whose next reveal would exceed the budget."""

    def __init__(
        self,
        err: float = 1.0,
        confidence: float = 0.999,
        policy: str = "escalate",  # "escalate" | "refuse"
        min_eps: float = 0.0625,
        store=None,  # repro.state.JournalStore for a durable, shared ledger
    ):
        if policy not in ("escalate", "refuse"):
            raise ValueError(f"unknown policy {policy!r}")
        self.err = err
        self.confidence = confidence
        self.policy = policy
        self.min_eps = min_eps
        self._state: Dict[Tuple[str, str], _SigState] = {}
        self.escalation_count = 0
        self.refusal_count = 0
        self._store = None
        self._intent_ids = itertools.count(1)
        if store is not None:
            self.attach_store(store)

    # -- durable journal (intent -> record; see module docstring) -------------
    @property
    def durable(self) -> bool:
        return self._store is not None

    @property
    def store(self):
        return self._store

    def attach_store(self, store) -> None:
        """Bind a :class:`repro.state.JournalStore` and fold its snapshot +
        WAL into this accountant's state. Every open intent found on disk
        belongs to some *other* (possibly dead) session and is conservatively
        counted against its signature's budget from here on.

        Observations charged while this accountant ran non-durably are NOT
        discarded: they merge on top of the journal's state (summed observed,
        the tighter budget). They stay local-only — the journal has no record
        of them, so other replicas cannot see them — which errs toward
        refusing earlier here, never toward extra disclosure anywhere."""
        if self._store is not None:
            raise ValueError("accountant already has a journal store")
        pre = self._state
        self._state = {}
        self._store = store
        with store.transaction() as sync:
            self._sync(sync)
        for sig, st_mem in pre.items():
            st = self._state.get(sig)
            if st is None:
                self._state[sig] = st_mem
                continue
            st.observed += st_mem.observed
            st.intents.update(st_mem.intents)
            if st_mem.budget is not None and (
                st.budget is None or st_mem.budget < st.budget
            ):
                st.budget, st.n, st.t = st_mem.budget, st_mem.n, st_mem.t

    def _sync(self, sync) -> None:
        if sync.reload:
            self._state.clear()
            if sync.snapshot:
                self._load_snapshot(sync.snapshot.get("state", {}))
        for rec in sync.records:
            self._apply(rec)

    def _apply(self, rec: Dict) -> None:
        """Fold one journal record into in-memory state — the single place
        WAL semantics are defined (startup replay, tail-sync, and this
        session's own appends all route through here)."""
        typ = rec.get("type")
        if typ not in ("intent", "record", "charge"):
            return
        sig = (rec["fp"], rec["strat"])
        st = self._state.setdefault(sig, _SigState())
        if typ == "intent":
            st.intents[rec["intent"]] = rec.get("owner", "?")
            return
        iid = rec.get("intent")
        if iid is not None:
            st.intents.pop(iid, None)
        st.observed += 1
        if typ == "record" and st.budget is None:
            st.n, st.t = int(rec["n"]), int(rec["t"])
            st.budget = int(rec["budget"])

    def _load_snapshot(self, blob: Dict) -> None:
        for entry in blob.get("sigs", []):
            self._state[(entry["fp"], entry["strat"])] = _SigState(
                observed=int(entry["observed"]),
                budget=entry["budget"],
                n=int(entry["n"]),
                t=int(entry["t"]),
                intents=dict(entry.get("intents", {})),
            )

    def _snapshot_blob(self) -> Dict:
        return {
            "sigs": [
                {
                    "fp": sig[0],
                    "strat": sig[1],
                    "observed": st.observed,
                    "budget": st.budget,
                    "n": st.n,
                    "t": st.t,
                    "intents": dict(st.intents),
                }
                for sig, st in self._state.items()
            ]
        }

    def maybe_compact(self, max_wal_bytes: int = 1 << 16) -> bool:
        """Fold the WAL into a snapshot once it outgrows ``max_wal_bytes``
        (open intents are preserved in the snapshot — compaction never
        forgets a conservative charge)."""
        if self._store is None or self._store.wal_bytes <= max_wal_bytes:
            return False
        with self._store.transaction() as sync:
            self._sync(sync)
            self._store.compact(self._snapshot_blob())
        return True

    def _oldest_own_intent(self, sig: Tuple[str, str]) -> Optional[str]:
        st = self._state.get(sig)
        if st is None or self._store is None:
            return None
        own = self._store.session
        for iid, owner in st.intents.items():  # dict preserves append order
            if owner == own:
                return iid
        return None

    def _reserved(self, sig: Tuple[str, str]) -> int:
        """Foreign open intents: other replicas' in-flight observations and
        dead sessions' conservative charges. This session's own open intents
        are excluded — ``planned`` already counts them at admission."""
        st = self._state.get(sig)
        if st is None or not st.intents:
            return 0
        own = self._store.session if self._store is not None else None
        return sum(1 for owner in st.intents.values() if owner != own)

    # -- signatures -----------------------------------------------------------
    def signature(self, node: Resize) -> Tuple[str, str]:
        # strategy_key already embeds the addition design
        return (
            plan_fingerprint(node.child),
            strategy_key(node.cfg.noise, node.cfg.addition),
        )

    def budget_for(self, noise: NoiseStrategy, addition: str, n: int, t: int) -> int:
        """floor(crt_rounds): the number of equivalent observations that may
        be disclosed before the attacker's Eq. 1 estimator reaches ±err at
        the configured confidence."""
        return int(
            math.floor(
                crt_rounds(noise, addition, n, t, err=self.err,
                           confidence=self.confidence)
            )
        )

    def remaining(self, sig: Tuple[str, str]) -> Optional[int]:
        st = self._state.get(sig)
        if st is None or st.budget is None:
            return None  # not yet observed: first observation is always free
        return st.budget - st.observed - self._reserved(sig)

    def spent(self, sig: Tuple[str, str]) -> int:
        """Observations charged against ``sig`` including open (foreign)
        intents — the conservative count crash-recovery tests assert on."""
        st = self._state.get(sig)
        if st is None:
            return 0
        return st.observed + len(st.intents)

    # -- admission ------------------------------------------------------------
    def admit(
        self, plan: PlanNode, planned: Optional[Dict[Tuple[str, str], int]] = None
    ) -> Tuple[PlanNode, List[Dict]]:
        """Durable path: sync foreign journal records, decide, then journal
        one *intent* per reserved observation — all under the state lease, so
        two replicas can never jointly overdraw — before any engine work.
        Non-durable path: the in-memory decision alone (see below)."""
        if self._store is None:
            return self._admit_locked(plan, planned)[:2]
        with self._store.transaction() as sync:
            self._sync(sync)
            admitted, escalations, added = self._admit_locked(plan, planned)
            for sig, count in added.items():
                for _ in range(count):
                    iid = f"{self._store.session}-{next(self._intent_ids)}"
                    self._apply(sync.append({
                        "type": "intent", "fp": sig[0], "strat": sig[1],
                        "intent": iid,
                    }))
            return admitted, escalations

    def _admit_locked(
        self, plan: PlanNode, planned: Optional[Dict[Tuple[str, str], int]] = None
    ) -> Tuple[PlanNode, List[Dict], Dict[Tuple[str, str], int]]:
        """Check every Resize in the plan against its budget. Returns a
        (possibly rewritten) plan plus the escalation records. Raises
        :class:`QueryRefused` under ``policy='refuse'``. The input plan is
        never mutated (it may be cache-shared).

        A plan may contain several Resizes with the *same* signature
        (duplicated subtrees, e.g. a self-join); ``planned`` charges them
        against the remaining budget as a group so a single admit cannot
        overdraw a known budget. (A signature's very first budget is only
        learned at execution, so duplicates inside the first-ever plan for a
        signature may still spend up to that plan's multiplicity.)

        Pass an explicit ``planned`` dict to extend that group across
        *several* admits: the admission scheduler threads one dict through
        every query queued in the same drain window, so K queued queries with
        the same signature spend K observations against the remaining budget
        at admit time — exactly what a serial admit/record interleaving would
        have charged — even though their ``record`` calls all land after the
        batched execution. The dict is mutated in place; drop it once the
        window's records are committed."""
        escalations: List[Dict] = []
        if planned is None:
            planned = {}
        added: Dict[Tuple[str, str], int] = {}  # this admit's reservations

        def reserve(sig: Tuple[str, str]) -> None:
            planned[sig] = planned.get(sig, 0) + 1
            added[sig] = added.get(sig, 0) + 1

        def rewrite(node: PlanNode) -> PlanNode:
            old_children = node.children()
            new_children = [rewrite(c) for c in old_children]
            if any(n is not o for n, o in zip(new_children, old_children)):
                node = node.replace_children(new_children)  # preserve identity
                # when nothing changed: cache hits stay shared objects
            if not isinstance(node, Resize) or isinstance(node.cfg.noise, NoTrim):
                return node
            while True:
                sig = self.signature(node)
                rem = self.remaining(sig)
                if rem is None or rem - planned.get(sig, 0) > 0:
                    reserve(sig)
                    return node
                st = self._state[sig]
                if self.policy == "refuse":
                    self.refusal_count += 1
                    raise QueryRefused(sig, st.observed, st.budget)
                nxt = escalate_strategy(node.cfg.noise, self.min_eps)
                if nxt is None:
                    return node  # already NoTrim: nothing disclosed
                self.escalation_count += 1
                escalations.append(
                    {
                        "from": strategy_key(node.cfg.noise, node.cfg.addition),
                        "to": strategy_key(nxt, node.cfg.addition),
                        "observed": st.observed,
                        "budget": st.budget,
                    }
                )
                node = Resize(
                    node.child, dataclasses.replace(node.cfg, noise=nxt)
                )
                if isinstance(nxt, NoTrim):
                    return node

        try:
            return rewrite(plan), escalations, added
        except QueryRefused:
            # a refused query executes nothing: roll this admit's reservations
            # back out of the (possibly caller-shared) admission group, or
            # they would shrink other queries' effective budgets forever
            # (no intents were journaled yet — they are appended only after
            # the whole rewrite succeeds)
            for sig, count in added.items():
                _drop_reservations(planned, sig, count)
            raise

    def release_planned(
        self, plan: PlanNode, planned: Dict[Tuple[str, str], int]
    ) -> None:
        """Drop a now-recorded plan's contributions from an admission group:
        once :meth:`record` has charged the plan's observations to the real
        per-signature state, keeping them in ``planned`` too would double-
        count them against queries admitted later in the same window."""
        for node in _iter_resizes(plan):
            _drop_reservations(planned, self.signature(node))

    def charge_failed(self, plan: PlanNode) -> None:
        """Conservatively charge one observation per non-NoTrim Resize of a
        plan whose execution may have disclosed its noisy sizes but could not
        be recorded (engine failure mid-plan, demux/record failure): the
        attacker may already hold the sample, so the budget must count it —
        over-charging a plan that in fact died before its reveal only errs
        toward refusing/escalating earlier, never toward extra disclosure.
        A never-seen signature keeps ``budget=None``; a later successful
        record initializes it with these observations already spent.

        Durable path: journals a *charge* record closing this plan's open
        intent (the same net state a crash-replay would reach)."""
        if self._store is None:
            for node in _iter_resizes(plan):
                self._state.setdefault(
                    self.signature(node), _SigState()
                ).observed += 1
            return
        with self._store.transaction() as sync:
            self._sync(sync)
            for node in _iter_resizes(plan):
                sig = self.signature(node)
                self._apply(sync.append({
                    "type": "charge", "fp": sig[0], "strat": sig[1],
                    "intent": self._oldest_own_intent(sig),
                }))

    # -- recording ------------------------------------------------------------
    def record(self, plan: PlanNode, report: ExecutionReport) -> None:
        """Charge one observation per executed non-NoTrim Resize, matching
        plan Resize nodes (post-order == execution order) to the report's
        per-node resize info to learn (N, T) for budget initialization.

        Durable path: each charge is journaled as a *record* closing the
        oldest open intent this session holds for the signature (equivalent
        observations are i.i.d. draws, so oldest-first matching is exact)."""
        resizes = list(_iter_resizes(plan, include_notrim=True))
        infos = [s.extra for s in report.nodes if s.node.startswith("Resize")]
        if len(infos) != len(resizes):
            raise RuntimeError(
                f"report has {len(infos)} resize entries for "
                f"{len(resizes)} Resize nodes — cannot attribute observations"
            )
        charges = [
            (node, info)
            for node, info in zip(resizes, infos)
            if not (isinstance(node.cfg.noise, NoTrim) or info.get("skipped"))
        ]
        if self._store is None:
            for node, info in charges:
                self._charge_observation(self.signature(node), node, info)
            return
        with self._store.transaction() as sync:
            self._sync(sync)
            for node, info in charges:
                sig = self.signature(node)
                n, t = int(info["n"]), int(info["t"])
                budget = max(
                    self.budget_for(node.cfg.noise, node.cfg.addition, n, t), 1
                )
                self._apply(sync.append({
                    "type": "record", "fp": sig[0], "strat": sig[1],
                    "intent": self._oldest_own_intent(sig),
                    "n": n, "t": t, "budget": budget,
                }))

    def _charge_observation(self, sig, node, info) -> None:
        st = self._state.setdefault(sig, _SigState())
        if st.budget is None:
            st.n, st.t = int(info["n"]), int(info["t"])
            st.budget = max(
                self.budget_for(node.cfg.noise, node.cfg.addition, st.n, st.t),
                1,
            )
        st.observed += 1

    # -- reporting ------------------------------------------------------------
    def budget_metrics(self) -> List[Dict]:
        """Per-signature budget burn-down for the metrics registry. Unlike
        :meth:`status` (the coordinator-side trusted API) this view is
        export-safe: full fingerprints for the caller to hash into labels,
        observed/budget/remaining counts — and no true cardinality T."""
        return [
            {
                "fp": sig[0],
                "strategy": sig[1],
                "observed": st.observed,
                "budget": st.budget,
                "remaining": None if st.budget is None
                else st.budget - st.observed - self._reserved(sig),
            }
            for sig, st in self._state.items()
        ]

    def status(self) -> List[Dict]:
        return [
            {
                "subplan": sig[0].splitlines()[0],
                "strategy": sig[1],
                "observed": st.observed,
                "budget": st.budget,
                "remaining": None if st.budget is None
                else st.budget - st.observed - self._reserved(sig),
                "reserved": self._reserved(sig),
                "open_intents": len(st.intents),
                "n": st.n,
                "t": st.t,
            }
            for sig, st in self._state.items()
        ]
