"""Resizer placement policies (§5.3 "Resizer placement").

The paper inserts a Resizer after every internal operator by hand and
sketches the cost functions a future optimizer would use (Fig. 9). We provide
those policies plus a simple analytic cost-based one built on
:mod:`repro.plan.cost`.

Which operators are Resizer candidates is not hard-coded here: every
operator's :class:`~repro.plan.registry.OperatorDef` carries a ``resizer``
hint (``internal`` = wrap candidate — the operator balloons or carries dead
tuples; ``skip`` = never wrapped: leaves, terminals, free projections, and
Resize itself).
"""
from __future__ import annotations

from typing import Callable, Optional

from ..config import current_config
from ..core.resizer import ResizerConfig
from .nodes import Filter, Join, JoinSortMerge, PlanNode, Project, Resize, Scan
from .registry import lookup

__all__ = ["insert_resizers", "select_join_algorithms"]


def insert_resizers(
    plan: PlanNode,
    cfg_factory: Callable[[PlanNode], Optional[ResizerConfig]],
    placement: str = "all_internal",
    cost_model=None,
) -> PlanNode:
    """Rewrite the plan, wrapping operators with Resize nodes.

    placement:
      * ``none``          — fully oblivious (no resizers)
      * ``all_internal``  — after every non-terminal operator whose registry
                            hint is ``internal`` (Filter/Join/GroupBy — the
                            paper's evaluation setup)
      * ``after_joins``   — only after Join nodes (where ballooning happens)
      * ``cost_based``    — insert only where the cost model predicts a win
                            (requires ``cost_model`` from repro.plan.cost)
    """
    if placement == "none":
        return plan

    def rewrite(node: PlanNode, is_root: bool) -> PlanNode:
        node = node.replace_children(
            [rewrite(c, False) for c in node.children()]
        )
        d = lookup(type(node))
        if is_root or d.resizer != "internal":
            return node
        wrap = False
        if placement == "all_internal":
            wrap = True
        elif placement == "after_joins":
            wrap = d.balloons
        elif placement == "cost_based":
            wrap = cost_model is None or cost_model.resizer_profitable(node)
        if wrap:
            cfg = cfg_factory(node)
            if cfg is not None:
                return Resize(node, cfg)
        return node

    return rewrite(plan, True)


# -----------------------------------------------------------------------------
# Join algorithm selection (physical Join -> JoinSortMerge rewrite)
# -----------------------------------------------------------------------------

def _key_multiplicity(node: PlanNode, col: str, catalog) -> Optional[int]:
    """Public upper bound on duplicates of ``col`` at this subplan's output,
    derived from the catalog's declared per-table bounds. Only rewrites that
    cannot *increase* multiplicity propagate the bound; anything else (joins,
    aggregates, unknown shapes) returns None = unbounded."""
    if catalog is None:
        return None
    if isinstance(node, Scan):
        return catalog.key_multiplicity(node.table, col)
    if isinstance(node, (Filter, Resize)):
        return _key_multiplicity(node.children()[0], col, catalog)
    if isinstance(node, Project) and col in node.cols:
        return _key_multiplicity(node.children()[0], col, catalog)
    return None


def select_join_algorithms(
    plan: PlanNode,
    cost_model=None,
    catalog=None,
    mode: Optional[str] = None,
) -> PlanNode:
    """Rewrite logical :class:`Join` nodes to :class:`JoinSortMerge` where the
    sort-merge algorithm is applicable (a finite catalog multiplicity bound on
    at least one input's join key) and — in ``auto`` mode — cheaper per the
    cost model.

    mode (default: ``RuntimeConfig.join_algo`` — ``auto`` unless the
    ``REPRO_JOIN_ALGO`` env fallback says otherwise):
      * ``product``   — never rewrite (the lazy Cartesian join everywhere)
      * ``sortmerge`` — rewrite every applicable join (force the new path)
      * ``auto``      — rewrite when applicable AND the analytic byte cost of
                        the sort-merge variant beats the product variant

    The rewrite is physical-only: ``JoinSortMerge.describe()`` is inherited
    from Join, so plan fingerprints, accountant signatures, and rendered SQL
    are identical across the flip (DESIGN.md §13).
    """
    if mode is None:
        mode = current_config().join_algo
    if mode not in ("auto", "product", "sortmerge"):
        raise ValueError(
            f"join algo mode {mode!r} (expected auto|product|sortmerge)"
        )
    if mode == "product":
        return plan

    def rewrite(node: PlanNode) -> PlanNode:
        node = node.replace_children([rewrite(c) for c in node.children()])
        if type(node) is not Join:
            return node
        lb = _key_multiplicity(node.left, node.on[0], catalog)
        rb = _key_multiplicity(node.right, node.on[1], catalog)
        if lb is None and rb is None:
            return node  # no public fanout bound -> sort-merge inapplicable
        # build on the side with the smaller finite bound (fewer match slots)
        if rb is None or (lb is not None and lb <= rb):
            fanout, build = lb, "left"
        else:
            fanout, build = rb, "right"
        sm = JoinSortMerge(
            node.left, node.right, node.on, node.theta,
            fanout=max(int(fanout), 1), build=build,
        )
        if mode == "sortmerge":
            return sm
        if cost_model is None:
            return node
        own = lambda est, kids: est["bytes"] - sum(k["bytes"] for k in kids)
        # child estimates arrive calibration-refined: CostModel.estimate
        # applies the CalibrationStore's observed (already-disclosed)
        # post-trim sizes, so the product-vs-sortmerge byte comparison below
        # tracks learned cardinalities instead of static selectivity
        # defaults — the product join's cost falls quadratically with
        # observed input sizes, the sort-merge cost only log-linearly, so
        # observations genuinely flip this choice (see
        # tests/test_service.py::test_calibration_steers_join_algorithm)
        kids = [cost_model.estimate(c) for c in node.children()]
        d_prod = lookup(Join).estimate(node, kids, cost_model)
        d_sm = lookup(JoinSortMerge).estimate(sm, kids, cost_model)
        if getattr(cost_model, "calibration", None) is not None:
            # refine the candidates' own output estimates too, so an
            # observed join output size reaches the decision record
            d_prod = cost_model.calibration.refine(
                node, d_prod, cost_model.noise
            )
            d_sm = cost_model.calibration.refine(sm, d_sm, cost_model.noise)
        return sm if own(d_sm, kids) < own(d_prod, kids) else node

    return rewrite(plan)
