"""Resizer placement policies (§5.3 "Resizer placement").

The paper inserts a Resizer after every internal operator by hand and
sketches the cost functions a future optimizer would use (Fig. 9). We provide
those policies plus a simple analytic cost-based one built on
:mod:`repro.plan.cost`.

Which operators are Resizer candidates is not hard-coded here: every
operator's :class:`~repro.plan.registry.OperatorDef` carries a ``resizer``
hint (``internal`` = wrap candidate — the operator balloons or carries dead
tuples; ``skip`` = never wrapped: leaves, terminals, free projections, and
Resize itself).
"""
from __future__ import annotations

from typing import Callable, Optional

from ..core.resizer import ResizerConfig
from .nodes import PlanNode, Resize
from .registry import lookup

__all__ = ["insert_resizers"]


def insert_resizers(
    plan: PlanNode,
    cfg_factory: Callable[[PlanNode], Optional[ResizerConfig]],
    placement: str = "all_internal",
    cost_model=None,
) -> PlanNode:
    """Rewrite the plan, wrapping operators with Resize nodes.

    placement:
      * ``none``          — fully oblivious (no resizers)
      * ``all_internal``  — after every non-terminal operator whose registry
                            hint is ``internal`` (Filter/Join/GroupBy — the
                            paper's evaluation setup)
      * ``after_joins``   — only after Join nodes (where ballooning happens)
      * ``cost_based``    — insert only where the cost model predicts a win
                            (requires ``cost_model`` from repro.plan.cost)
    """
    if placement == "none":
        return plan

    def rewrite(node: PlanNode, is_root: bool) -> PlanNode:
        node = node.replace_children(
            [rewrite(c, False) for c in node.children()]
        )
        d = lookup(type(node))
        if is_root or d.resizer != "internal":
            return node
        wrap = False
        if placement == "all_internal":
            wrap = True
        elif placement == "after_joins":
            wrap = d.balloons
        elif placement == "cost_based":
            wrap = cost_model is None or cost_model.resizer_profitable(node)
        if wrap:
            cfg = cfg_factory(node)
            if cfg is not None:
                return Resize(node, cfg)
        return node

    return rewrite(plan, True)
