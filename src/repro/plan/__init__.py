from .nodes import (  # noqa: F401
    Scan,
    Filter,
    Project,
    Join,
    GroupByCount,
    OrderBy,
    Distinct,
    CountValid,
    CountDistinct,
    Sum,
    Avg,
    Min,
    Max,
    Resize,
    PlanNode,
)
from .registry import (  # noqa: F401
    OperatorDef,
    PlanSchema,
    SchemaError,
    infer_schema,
    lookup,
    register,
    registered_ops,
)
from .policies import insert_resizers  # noqa: F401
