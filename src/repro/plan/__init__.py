from .nodes import (  # noqa: F401
    Scan,
    Filter,
    Join,
    GroupByCount,
    OrderBy,
    Distinct,
    CountValid,
    CountDistinct,
    Resize,
    PlanNode,
)
from .policies import insert_resizers  # noqa: F401
