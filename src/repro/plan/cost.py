"""Analytic cost model for oblivious plans (the Fig. 9 "cost functions").

Costs are expressed in *communication bytes per party* — the resource that
dominates MPC runtime (§4.5) — derived from the same per-circuit constants the
ledger records:

  mul/AND on E lanes    : 4E bytes, 1 round
  eq  on E lanes        : 20E bytes (5 AND-words), 5 rounds
  lt  on E lanes        : 44E bytes, 6 rounds
  bitonic sort on N     : stages(N) * (44 + 4*ncols) * N/1 bytes
  shuffle on N x M      : 3 * N * M bytes, 3 rounds
  resizer on N          : noise-add ~ (a2b 88 + lt 40 + OR 4) * N + shuffle

Per-operator formulas live on each operator's :class:`OperatorDef`
(:mod:`repro.plan.registry`); :class:`CostModel` is the thin driver that
walks a plan and dispatches. The model powers the ``cost_based`` Resizer
placement: inserting a Resizer after an operator is profitable iff its own
cost is smaller than the downstream savings from the reduced intermediate
size (using the strategy's E[S] = T_est + E[eta]).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from ..core.noise import NoiseStrategy
from .nodes import PlanNode
from .registry import (  # noqa: F401  (re-exported: historical import site)
    BYTES,
    lookup,
    resizer_bytes,
    shuffle_bytes,
    sort_bytes,
)

__all__ = ["CostModel", "BYTES", "sort_bytes", "shuffle_bytes", "resizer_bytes"]


@dataclasses.dataclass
class CostModel:
    """Walks a plan, propagating (oblivious size N, estimated true size T,
    ncols) and summing comm bytes — dispatching per-operator formulas
    through the registry.

    ``calibration`` (a :class:`repro.state.calibration.CalibrationStore`, or
    any object with the same ``refine(node, est, noise)`` hook) replaces the
    static selectivity defaults with sizes the engine has *already revealed*
    for matching subplans: T becomes the observed E[S], and — when ``noise``
    says placement will trim there — the oblivious size flowing upward
    becomes the post-trim size. Join reordering then improves across
    restarts with zero additional disclosure (DESIGN.md §12.4).
    """

    table_sizes: Dict[str, int]
    table_cols: Dict[str, int]
    selectivity: float = 0.1  # planner's default per-predicate selectivity
    join_selectivity: float = 0.01
    noise: NoiseStrategy | None = None
    calibration: object | None = None  # duck-typed: refine(node, est, noise)

    def estimate(self, node: PlanNode) -> Dict[str, float]:
        children = [self.estimate(c) for c in node.children()]
        est = lookup(type(node)).estimate(node, children, self)
        if self.calibration is not None:
            est = self.calibration.refine(node, est, self.noise)
        return est

    def _estimate_untrimmed(self, node: PlanNode) -> Dict[str, float]:
        """Like :meth:`estimate` but the node's OWN output size is not
        reduced to the post-trim E[S] (children still are). The Resizer
        profitability decision must see the full pre-trim N at the candidate
        node — otherwise calibration's own trim model makes every observed
        node look already-small and placement stops inserting the very
        Resizer that produced the observation."""
        children = [self.estimate(c) for c in node.children()]
        est = lookup(type(node)).estimate(node, children, self)
        if self.calibration is not None:
            # noise=None: calibrate T only, never the oblivious size
            est = self.calibration.refine(node, est, None)
        return est

    def plan_bytes(self, node: PlanNode) -> float:
        return self.estimate(node)["bytes"]

    def resizer_profitable(self, node: PlanNode) -> bool:
        """Fig. 9 decision: a Resizer pays off iff the bytes it saves
        downstream exceed its own cost. Approximated locally: compare the
        resizer cost at this node's output against the per-row downstream
        cost times the expected row reduction."""
        if self.noise is None:
            return True
        est = self._estimate_untrimmed(node)
        n, t, cols = int(est["n"]), int(est["t"]), int(est["cols"])
        s = min(t + self.noise.mean(n, t), n)
        saved_rows = n - s
        # downstream per-row cost approximation: one sort-ish operator
        downstream_per_row = BYTES["lt"] + BYTES["and"] * cols
        saving = saved_rows * downstream_per_row * max(
            math.log2(max(n, 2)), 1.0
        )
        return saving > resizer_bytes(n, cols)
