"""Analytic cost model for oblivious plans (the Fig. 9 "cost functions").

Costs are expressed in *communication bytes per party* — the resource that
dominates MPC runtime (§4.5) — derived from the same per-circuit constants the
ledger records:

  mul/AND on E lanes    : 4E bytes, 1 round
  eq  on E lanes        : 20E bytes (5 AND-words), 5 rounds
  lt  on E lanes        : 44E bytes, 6 rounds
  bitonic sort on N     : stages(N) * (44 + 4*ncols) * N/1 bytes
  shuffle on N x M      : 3 * N * M bytes, 3 rounds
  resizer on N          : noise-add ~ (a2b 88 + lt 40 + OR 4) * N + shuffle

The model powers the ``cost_based`` Resizer placement: inserting a Resizer
after an operator is profitable iff its own cost is smaller than the
downstream savings from the reduced intermediate size (using the strategy's
E[S] = T_est + E[eta]).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from ..core.noise import NoiseStrategy
from .nodes import (
    CountDistinct,
    CountValid,
    Distinct,
    Filter,
    GroupByCount,
    Join,
    OrderBy,
    PlanNode,
    Resize,
    Scan,
)

__all__ = ["CostModel", "BYTES"]

BYTES = {
    "and": 4,
    "eq": 20,
    "lt": 44,
    "bit2a": 8,
    "a2b": 88,
    "b2a": 256,
}


def _stages(n: int) -> int:
    m = max(int(math.ceil(math.log2(max(n, 2)))), 1)
    return m * (m + 1) // 2


def sort_bytes(n: int, ncols: int) -> float:
    return _stages(n) * n * (BYTES["lt"] + BYTES["and"] * (ncols + 2))


def shuffle_bytes(n: int, ncols: int) -> float:
    return 3 * n * 4 * (ncols + 2)


def resizer_bytes(n: int, ncols: int) -> float:
    noise_add = n * (BYTES["a2b"] + BYTES["lt"] + BYTES["and"])
    return noise_add + shuffle_bytes(n, ncols) + 4 * n  # + reveal k


@dataclasses.dataclass
class CostModel:
    """Walks a plan, propagating (oblivious size N, estimated true size T,
    ncols) and summing comm bytes."""

    table_sizes: Dict[str, int]
    table_cols: Dict[str, int]
    selectivity: float = 0.1  # planner's default per-predicate selectivity
    join_selectivity: float = 0.01
    noise: NoiseStrategy | None = None

    def estimate(self, node: PlanNode) -> Dict[str, float]:
        if isinstance(node, Scan):
            n = self.table_sizes[node.table]
            return {"n": n, "t": n, "cols": self.table_cols[node.table], "bytes": 0.0}
        if isinstance(node, Filter):
            c = self.estimate(node.child)
            k = len(node.predicates)
            cost = c["n"] * (BYTES["eq"] * k + BYTES["and"] * k)
            return {
                "n": c["n"],
                "t": max(c["t"] * self.selectivity**k, 1),
                "cols": c["cols"],
                "bytes": c["bytes"] + cost,
            }
        if isinstance(node, Join):
            l, r = self.estimate(node.left), self.estimate(node.right)
            n = l["n"] * r["n"]
            cost = n * (BYTES["eq"] + 2 * BYTES["and"])
            if node.theta:
                cost += n * (BYTES["lt"] + BYTES["and"])
            return {
                "n": n,
                "t": max(l["t"] * r["t"] * self.join_selectivity, 1),
                "cols": l["cols"] + r["cols"],
                "bytes": l["bytes"] + r["bytes"] + cost,
            }
        if isinstance(node, (GroupByCount, Distinct, OrderBy)):
            c = self.estimate(node.child)
            n = 1 << max(int(math.ceil(math.log2(max(c["n"], 2)))), 0)
            cost = sort_bytes(n, c["cols"]) + n * (BYTES["eq"] + 4 * BYTES["and"])
            if isinstance(node, GroupByCount):
                cost += n * 2 * BYTES["bit2a"] + math.log2(max(n, 2)) * n * 8
            out_n = node.limit if isinstance(node, OrderBy) and node.limit else n
            return {
                "n": out_n,
                "t": min(c["t"], out_n),
                "cols": c["cols"] + 1,
                "bytes": c["bytes"] + cost,
            }
        if isinstance(node, (CountValid, CountDistinct)):
            c = self.estimate(node.child)
            cost = c["n"] * BYTES["bit2a"]
            if isinstance(node, CountDistinct):
                cost += sort_bytes(c["n"], c["cols"]) + c["n"] * BYTES["eq"]
            return {"n": 1, "t": 1, "cols": 1, "bytes": c["bytes"] + cost}
        if isinstance(node, Resize):
            c = self.estimate(node.child)
            noise = node.cfg.noise
            s = min(c["t"] + noise.mean(int(c["n"]), int(c["t"])), c["n"])
            cost = resizer_bytes(c["n"], c["cols"])
            return {"n": s, "t": c["t"], "cols": c["cols"], "bytes": c["bytes"] + cost}
        raise TypeError(f"unknown node {node}")

    def plan_bytes(self, node: PlanNode) -> float:
        return self.estimate(node)["bytes"]

    def resizer_profitable(self, node: PlanNode) -> bool:
        """Fig. 9 decision: a Resizer pays off iff the bytes it saves
        downstream exceed its own cost. Approximated locally: compare the
        resizer cost at this node's output against the per-row downstream
        cost times the expected row reduction."""
        if self.noise is None:
            return True
        est = self.estimate(node)
        n, t, cols = int(est["n"]), int(est["t"]), int(est["cols"])
        s = min(t + self.noise.mean(n, t), n)
        saved_rows = n - s
        # downstream per-row cost approximation: one sort-ish operator
        downstream_per_row = BYTES["lt"] + BYTES["and"] * cols
        saving = saved_rows * downstream_per_row * max(
            math.log2(max(n, 2)), 1.0
        )
        return saving > resizer_bytes(n, cols)
