"""OperatorDef registry: the single extension point for plan operators.

Adding a plan node used to mean editing five separate ``isinstance`` chains
(engine dispatch, cost model, SQL renderer, resizer placement, compiler
terminal handling). Now each operator registers *one* :class:`OperatorDef`
holding everything the drivers need:

* ``protocol``       — physical protocol factory: ``node -> (prf, *tables)
                       -> SecretTable`` (pure, jit-able). ``None`` for nodes
                       the engine applies statefully (``engine_apply``).
* ``engine_apply``   — stateful execution hook (Scan reads the engine's
                       table dict; Resize folds the engine's noise counter).
* ``estimate``       — cost/selectivity model: ``(node, child_estimates,
                       cost_model) -> {"n","t","cols","bytes"}``.
* ``schema``         — compile-time output schema: ``(node, child_schemas,
                       catalog) -> PlanSchema``; raises :class:`SchemaError`
                       on unknown columns, so column errors surface before
                       any MPC work.
* ``render_rel`` / ``render_head`` / ``render_order``
                     — SQL rendering hooks (see repro.sql.render for the
                       driver contract).
* ``sql_shape``      — where the node may appear in rendered SQL:
                       ``leaf`` (Scan), ``relational`` (FROM/WHERE subtree),
                       ``head`` (SELECT-list terminal), ``order``, ``none``.
* ``resizer``        — placement hint: ``internal`` operators are Resizer
                       candidates (they balloon or preserve dead tuples);
                       ``skip`` operators are never wrapped.
* ``singleton``      — produces a 1-row output (ORDER BY over it is
                       rejected at compile time).
* ``provides_resize_info`` — the engine attaches reveal-and-trim info to
                       this node's report entry.
* ``post_reveal``    — optional revealed-rows post-processing hook
                       (AVG derives ``sum // count`` client-side).
* ``batchable``      — the operator may run inside the engine's stacked
                       (vmapped) multi-query pass (DESIGN.md §11). Singleton
                       aggregates and ``post_reveal`` ops opt out: their
                       1-row outputs amortize nothing and their client-side
                       derivation hooks run per tenant outside the engine.
* ``batch_apply``    — stateful batched-execution hook for operators that
                       cannot simply be vmapped: ``(engine, node, children,
                       ctx) -> batch value``. Scan stacks the engine's base
                       table across the batch axis; Resize runs per slot so
                       every query draws fresh noise from its own counter
                       stream (CRT observations are never merged).

DESIGN.md §10 documents the contract; tests/test_registry.py enforces it
(every registered operator must instantiate, execute, cost, schema-check,
and — when renderable — round-trip plan -> SQL -> plan).
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Type

import jax

from ..core.resizer import Resizer
from ..errors import PlanSchemaError
from ..ops import (
    avg_column,
    count_distinct,
    count_valid,
    max_column,
    min_column,
    oblivious_distinct,
    oblivious_filter,
    oblivious_groupby_avg,
    oblivious_groupby_count,
    oblivious_groupby_sum,
    oblivious_join,
    oblivious_join_sortmerge,
    oblivious_orderby,
    sum_column,
)
from ..ops.filter import pred_leaves
from ..ops.join import _disambiguate
from .nodes import (
    Avg,
    CountDistinct,
    CountValid,
    Distinct,
    Filter,
    GroupByAvg,
    GroupByCount,
    GroupBySum,
    Having,
    Join,
    JoinSortMerge,
    Max,
    Min,
    OrderBy,
    PlanNode,
    Project,
    Resize,
    Scan,
    Sum,
)

__all__ = [
    "OperatorDef",
    "PlanSchema",
    "SchemaError",
    "register",
    "lookup",
    "registered_ops",
    "infer_schema",
    "plan_batchable",
]


# -----------------------------------------------------------------------------
# Schema propagation
# -----------------------------------------------------------------------------

# The schema error now lives in the typed taxonomy (repro.errors); the old
# name stays importable here. PlanSchemaError subclasses ValueError, so
# pre-taxonomy except clauses keep catching it.
SchemaError = PlanSchemaError


@dataclasses.dataclass
class PlanSchema:
    """Ordered column name -> share kind ("b" = boolean/XOR word, "a" =
    arithmetic) for one plan node's output. Mirrors exactly what the
    executed operator's SecretTable will carry."""

    cols: "OrderedDict[str, str]"

    @classmethod
    def of(cls, names, kind: str = "b") -> "PlanSchema":
        return cls(OrderedDict((n, kind) for n in names))

    @property
    def names(self) -> List[str]:
        return list(self.cols)

    def kind(self, name: str) -> str:
        return self.cols[name]

    def require(self, col: str, node: PlanNode) -> None:
        if col not in self.cols:
            raise PlanSchemaError(
                f"{node.describe()} references column {col!r}, but its input "
                f"produces only {self.names}",
                node=node.describe(),
                column=col,
                available=self.names,
            )

    def require_pred(self, pred, node: PlanNode) -> None:
        for leaf in pred_leaves(pred):
            self.require(leaf.column, node)
            if isinstance(leaf.value, str) and leaf.value.startswith("col:"):
                self.require(leaf.value[4:], node)


def infer_schema(plan: PlanNode, catalog) -> PlanSchema:
    """Propagate the typed column set bottom-up through ``plan`` against a
    :class:`repro.sql.catalog.Catalog`, raising :class:`SchemaError` at the
    first unresolvable column — the compile-time guard that runs before any
    MPC work (Engine.execute calls this on every plan)."""
    d = lookup(type(plan))
    children = [infer_schema(c, catalog) for c in plan.children()]
    return d.schema(plan, children, catalog)


# -----------------------------------------------------------------------------
# OperatorDef + registry
# -----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OperatorDef:
    node_type: Type[PlanNode]
    schema: Callable[[PlanNode, List[PlanSchema], object], PlanSchema]
    estimate: Callable[[PlanNode, List[Dict], object], Dict]
    protocol: Optional[Callable[[PlanNode], Callable]] = None
    engine_apply: Optional[Callable] = None
    render_rel: Optional[Callable] = None
    render_head: Optional[Callable] = None
    render_order: Optional[Callable] = None
    render_having: Optional[Callable] = None
    post_reveal: Optional[Callable] = None
    sql_shape: str = "none"  # leaf | relational | head | order | having | none
    resizer: str = "skip"  # internal | skip
    balloons: bool = False  # output is larger than inputs (join product)
    singleton: bool = False
    provides_resize_info: bool = False
    batchable: bool = True  # may run in the stacked multi-query engine pass
    batch_apply: Optional[Callable] = None  # stateful batched-execution hook

    def __post_init__(self):
        if self.protocol is None and self.engine_apply is None:
            raise ValueError(
                f"OperatorDef({self.node_type.__name__}) needs a protocol "
                "factory or an engine_apply hook"
            )


_REGISTRY: Dict[Type[PlanNode], OperatorDef] = {}


def register(d: OperatorDef) -> OperatorDef:
    if d.node_type in _REGISTRY:
        raise ValueError(f"duplicate OperatorDef for {d.node_type.__name__}")
    _REGISTRY[d.node_type] = d
    return d


def lookup(node_type: Type[PlanNode]) -> OperatorDef:
    try:
        return _REGISTRY[node_type]
    except KeyError:
        raise TypeError(
            f"unregistered plan node {node_type.__name__} — add an "
            "OperatorDef in repro.plan.registry"
        ) from None


def registered_ops() -> Dict[Type[PlanNode], OperatorDef]:
    return dict(_REGISTRY)


def plan_batchable(plan: PlanNode) -> bool:
    """True iff every operator in ``plan`` may run inside the engine's
    stacked multi-query pass — the admission scheduler's eligibility check
    (non-batchable plans fall back to serial batch-of-1 execution).

    An operator needs either a vmappable ``protocol`` or an explicit
    ``batch_apply`` hook; a stateful ``engine_apply``-only operator cannot
    run stacked regardless of its ``batchable`` default."""
    d = lookup(type(plan))
    if not d.batchable or (d.protocol is None and d.batch_apply is None):
        return False
    return all(plan_batchable(c) for c in plan.children())


# -----------------------------------------------------------------------------
# Cost model pieces (constants shared with plan.cost; kept here so a new
# operator's whole definition lives in one file)
# -----------------------------------------------------------------------------

BYTES = {
    "and": 4,
    "eq": 20,
    "lt": 44,
    "bit2a": 8,
    "a2b": 88,
    "b2a": 256,
}


def _stages(n: int) -> int:
    m = max(int(math.ceil(math.log2(max(n, 2)))), 1)
    return m * (m + 1) // 2


def sort_bytes(n: int, ncols: int) -> float:
    return _stages(n) * n * (BYTES["lt"] + BYTES["and"] * (ncols + 2))


def shuffle_bytes(n: int, ncols: int) -> float:
    return 3 * n * 4 * (ncols + 2)


def resizer_bytes(n: int, ncols: int) -> float:
    noise_add = n * (BYTES["a2b"] + BYTES["lt"] + BYTES["and"])
    return noise_add + shuffle_bytes(n, ncols) + 4 * n  # + reveal k


def _leaf_bytes(leaf) -> int:
    return BYTES["eq"] if leaf.op == "eq" else BYTES["lt"]


# -----------------------------------------------------------------------------
# Rendering helpers (driver-side Schema objects come in via the renderer)
# -----------------------------------------------------------------------------

_OP_SYM = {"eq": "=", "lt": "<", "le": "<=", "gt": ">"}


def _sql_leaf(p, qual) -> str:
    if isinstance(p.value, str) and p.value.startswith("col:"):
        return f"{qual(p.column)} {_OP_SYM[p.op]} {qual(p.value[4:])}"
    return f"{qual(p.column)} {_OP_SYM[p.op]} {int(p.value)}"


def sql_conjuncts(pred, qual) -> List[str]:
    """WHERE-clause conjunct strings for a predicate tree: top-level AND
    terms become separate conjuncts; an OR term is one parenthesized
    conjunct. Tree rendering (SQL precedence, parens) is
    :func:`repro.ops.filter.render_pred` with a qualified-SQL leaf format."""
    from ..ops.filter import And, Or, render_pred

    fmt = lambda p: _sql_leaf(p, qual)
    terms = pred.terms if isinstance(pred, And) else (pred,)
    return [
        f"({render_pred(t, fmt)})" if isinstance(t, Or) else render_pred(t, fmt)
        for t in terms
    ]


# -----------------------------------------------------------------------------
# Operator definitions
# -----------------------------------------------------------------------------

def _scan_schema(node: Scan, children, catalog) -> PlanSchema:
    if node.table not in catalog.tables:
        raise PlanSchemaError(
            f"Scan references unknown table {node.table!r}",
            node=node.describe(),
            table=node.table,
            available=sorted(catalog.tables),
        )
    return PlanSchema.of(catalog.columns(node.table))


def _scan_estimate(node: Scan, children, cm) -> Dict:
    n = cm.table_sizes[node.table]
    return {"n": n, "t": n, "cols": cm.table_cols[node.table], "bytes": 0.0}


def _render_scan(r, node: Scan):
    alias = f"t{len(r.aliases)}"
    r.aliases.append((alias, node.table))
    if node.table not in r.catalog.tables:
        raise ValueError(f"table {node.table!r} not in catalog")
    return r.schema_for_table(alias, r.catalog.columns(node.table))


register(OperatorDef(
    node_type=Scan,
    schema=_scan_schema,
    estimate=_scan_estimate,
    engine_apply=lambda eng, node, children: eng.tables[node.table],
    # batched pass: broadcast the (shared) base table across the batch axis
    batch_apply=lambda eng, node, children, ctx: eng._batch_scan(node, ctx),
    render_rel=_render_scan,
    sql_shape="leaf",
))


def _filter_schema(node: Filter, children, catalog) -> PlanSchema:
    children[0].require_pred(node.pred, node)
    return children[0]


def _filter_estimate(node: Filter, children, cm) -> Dict:
    c = children[0]
    leaves = pred_leaves(node.pred)
    k = len(leaves)
    cost = c["n"] * (sum(_leaf_bytes(p) for p in leaves) + BYTES["and"] * k)
    return {
        "n": c["n"],
        "t": max(c["t"] * cm.selectivity ** k, 1),
        "cols": c["cols"],
        "bytes": c["bytes"] + cost,
    }


def _render_filter(r, node: Filter):
    schema = r.walk(node.child)
    r.filters.extend(
        sql_conjuncts(node.pred, lambda col: r.qual(schema, col))
    )
    return schema


register(OperatorDef(
    node_type=Filter,
    schema=_filter_schema,
    estimate=_filter_estimate,
    protocol=lambda node: lambda prf, t: oblivious_filter(t, node.pred, prf),
    render_rel=_render_filter,
    sql_shape="relational",
    resizer="internal",
))


def _project_schema(node: Project, children, catalog) -> PlanSchema:
    c = children[0]
    for col in node.cols:
        c.require(col, node)
    return PlanSchema(OrderedDict((n, c.kind(n)) for n in node.cols))


def _project_estimate(node: Project, children, cm) -> Dict:
    c = children[0]
    # free: projection is local (no communication) and keeps the row count
    return {
        "n": c["n"],
        "t": c["t"],
        "cols": len(node.cols),
        "bytes": c["bytes"],
    }


def _render_project_head(r, node: Project, schema):
    return ", ".join(r.qual(schema, c) for c in node.cols), None


register(OperatorDef(
    node_type=Project,
    schema=_project_schema,
    estimate=_project_estimate,
    protocol=lambda node: lambda prf, t: t.select_columns(node.cols),
    render_head=_render_project_head,
    sql_shape="head",
))


def _join_schema(node: Join, children, catalog) -> PlanSchema:
    l, r = children
    l.require(node.on[0], node)
    r.require(node.on[1], node)
    if node.theta is not None:
        l.require(node.theta[0], node)
        r.require(node.theta[2], node)
    merged = OrderedDict(l.cols)
    for name, kind in r.cols.items():
        merged[_disambiguate(merged, name)] = kind
    return PlanSchema(merged)


def _join_estimate(node: Join, children, cm) -> Dict:
    l, r = children
    n = l["n"] * r["n"]
    cost = n * (BYTES["eq"] + 2 * BYTES["and"])
    if node.theta:
        cost += n * (BYTES["lt"] + BYTES["and"])
    return {
        "n": n,
        "t": max(l["t"] * r["t"] * cm.join_selectivity, 1),
        "cols": l["cols"] + r["cols"],
        "bytes": l["bytes"] + r["bytes"] + cost,
    }


def _render_join(r, node: Join):
    left = r.walk(node.left)
    right = r.walk(node.right)
    right_alias, right_table = r.aliases[-1]
    conds = [f"{r.qual(left, node.on[0])} = {r.qual(right, node.on[1])}"]
    if node.theta is not None:
        lcol, op, rcol = node.theta
        conds.append(f"{r.qual(left, lcol)} {_OP_SYM[op]} {r.qual(right, rcol)}")
    r.joins.append(f"JOIN {right_table} {right_alias} ON " + " AND ".join(conds))
    return left.merge(right)


register(OperatorDef(
    node_type=Join,
    schema=_join_schema,
    estimate=_join_estimate,
    protocol=lambda node: lambda prf, l, r: oblivious_join(
        l, r, node.on, prf, theta=node.theta
    ),
    render_rel=_render_join,
    sql_shape="relational",
    resizer="internal",
    balloons=True,
))


def sortmerge_join_bytes(
    n1: int,
    n2: int,
    build_cols: int,
    probe_cols: int,
    fanout: int = 1,
    theta: bool = False,
) -> float:
    """Analytic comm cost of the sort-merge join (ops/join_sortmerge.py):
    union sort on pow2(n1+n2) rows + O(n) payload gather + segmented
    propagation — vs. the product join's O(n1*n2) equality sweep."""
    n = 1 << max(int(math.ceil(math.log2(max(n1 + n2, 2)))), 1)
    levels = max(int(math.log2(n)), 1)
    # union sort: 3 network columns (key, origin, index), 2-key lexicographic
    cost = _stages(n) * n * (BYTES["lt"] + 3 * BYTES["and"])
    cost += _stages(n) * n * (BYTES["eq"] + BYTES["lt"] + 2 * BYTES["and"])
    # payload gather via shuffle-and-reveal: 1-col shuffle + n-word reveal +
    # (build + probe + valid)-column inverse shuffle
    w = build_cols + probe_cols + 1
    cost += 3 * n * 4 + 4 * n + 3 * n * 4 * w
    # segment boundary equality + build-row marker AND
    cost += n * (BYTES["eq"] + BYTES["and"])
    if fanout > 1:
        # rank scan (2 bit2a + 2 ring mults/level), one a2b, batched rank eq
        cost += n * 2 * BYTES["bit2a"] + levels * n * 8 + n * BYTES["a2b"]
        cost += fanout * n * (BYTES["eq"] + BYTES["and"])
    # segmented copy-last scan: 3 control ANDs + build-width select per level
    cost += levels * fanout * n * (3 + max(build_cols, 1)) * BYTES["and"]
    # output validity
    cost += 2 * fanout * n * BYTES["and"]
    if theta:
        cost += fanout * n * (BYTES["lt"] + BYTES["and"])
    return cost


def _sortmerge_estimate(node: JoinSortMerge, children, cm) -> Dict:
    l, r = children
    bc, pc = (
        (l["cols"], r["cols"]) if node.build == "left" else (r["cols"], l["cols"])
    )
    n_union = 1 << max(int(math.ceil(math.log2(max(l["n"] + r["n"], 2)))), 1)
    cost = sortmerge_join_bytes(
        int(l["n"]), int(r["n"]), int(bc), int(pc), node.fanout, node.theta is not None
    )
    return {
        "n": node.fanout * n_union,
        "t": max(l["t"] * r["t"] * cm.join_selectivity, 1),
        "cols": l["cols"] + r["cols"],
        "bytes": l["bytes"] + r["bytes"] + cost,
    }


register(OperatorDef(
    node_type=JoinSortMerge,
    schema=_join_schema,
    estimate=_sortmerge_estimate,
    protocol=lambda node: lambda prf, l, r: oblivious_join_sortmerge(
        l, r, node.on, prf, theta=node.theta, fanout=node.fanout, build=node.build
    ),
    # physical-only node: the planner's algorithm-selection pass introduces it
    # after compilation; SQL text always renders from the logical Join plan
    sql_shape="none",
    resizer="internal",
    balloons=True,
))


def _sortish_estimate(c: Dict, extra_key_cols: int = 0) -> (int, float):
    """Shared sort-based cost core for GroupBy/Distinct/OrderBy."""
    n = 1 << max(int(math.ceil(math.log2(max(c["n"], 2)))), 0)
    cost = sort_bytes(n, c["cols"]) + n * (BYTES["eq"] + 4 * BYTES["and"])
    cost += extra_key_cols * _stages(n) * n * (
        BYTES["eq"] + BYTES["lt"] + 2 * BYTES["and"]
    )
    return n, cost


def _groupby_schema(node: GroupByCount, children, catalog) -> PlanSchema:
    c = children[0]
    for k in node.keys:
        c.require(k, node)
    out = OrderedDict((k, c.kind(k)) for k in node.keys)
    out[node.count_name] = "a"
    return PlanSchema(out)


def _groupby_estimate(node: GroupByCount, children, cm) -> Dict:
    c = children[0]
    n, cost = _sortish_estimate(c, extra_key_cols=len(node.keys) - 1)
    cost += n * 2 * BYTES["bit2a"] + math.log2(max(n, 2)) * n * 8
    return {
        "n": n,
        "t": min(c["t"], n),
        "cols": len(node.keys) + 1,
        "bytes": c["bytes"] + cost,
    }


def _render_groupby_head(r, node: GroupByCount, schema):
    keys = [r.qual(schema, k) for k in node.keys]
    head = ", ".join(keys) + f", COUNT(*) AS {node.count_name}"
    return head, "GROUP BY " + ", ".join(keys)


register(OperatorDef(
    node_type=GroupByCount,
    schema=_groupby_schema,
    estimate=_groupby_estimate,
    protocol=lambda node: lambda prf, t: oblivious_groupby_count(
        t, node.keys, prf, node.count_name
    ),
    render_head=_render_groupby_head,
    sql_shape="head",
    resizer="internal",
))


def _groupby_agg_schema(out_names):
    def schema(node, children, catalog) -> PlanSchema:
        c = children[0]
        for k in node.keys:
            c.require(k, node)
        c.require(node.col, node)
        out = OrderedDict((k, c.kind(k)) for k in node.keys)
        for n in out_names(node):
            out[n] = "a"
        return PlanSchema(out)

    return schema


def _groupby_agg_estimate(node, children, cm) -> Dict:
    c = children[0]
    n, cost = _sortish_estimate(c, extra_key_cols=len(node.keys) - 1)
    # value b2a + valid bit2a + mask mult + segmented scan over the pair
    cost += n * (BYTES["b2a"] + 2 * BYTES["bit2a"] + BYTES["and"])
    cost += math.log2(max(n, 2)) * n * 16
    return {
        "n": n,
        "t": min(c["t"], n),
        "cols": len(node.keys) + 2,
        "bytes": c["bytes"] + cost,
    }


def _render_groupby_agg_head(kw: str, default_name: str):
    # the default name is a dialect keyword — render the alias only when set
    def render(r, node, schema):
        keys = [r.qual(schema, k) for k in node.keys]
        alias = f" AS {node.name}" if node.name != default_name else ""
        head = ", ".join(keys) + f", {kw}({r.qual(schema, node.col)}){alias}"
        return head, "GROUP BY " + ", ".join(keys)

    return render


def _groupby_avg_post_reveal(node: GroupByAvg, rows):
    import numpy as np

    s, c = rows.get(f"{node.name}_sum"), rows.get(f"{node.name}_cnt")
    if s is None or c is None:
        return rows
    out = {k: v for k, v in rows.items() if k not in (f"{node.name}_sum", f"{node.name}_cnt")}
    out[node.name] = s // np.maximum(c, 1)
    return out


register(OperatorDef(
    node_type=GroupBySum,
    schema=_groupby_agg_schema(lambda node: [node.name]),
    estimate=_groupby_agg_estimate,
    protocol=lambda node: lambda prf, t: oblivious_groupby_sum(
        t, node.keys, node.col, prf, node.name
    ),
    render_head=_render_groupby_agg_head("SUM", "sum"),
    sql_shape="head",
    resizer="internal",
))


register(OperatorDef(
    node_type=GroupByAvg,
    schema=_groupby_agg_schema(lambda node: [f"{node.name}_sum", f"{node.name}_cnt"]),
    estimate=_groupby_agg_estimate,
    protocol=lambda node: lambda prf, t: oblivious_groupby_avg(
        t, node.keys, node.col, prf, node.name
    ),
    render_head=_render_groupby_agg_head("AVG", "avg"),
    post_reveal=_groupby_avg_post_reveal,
    sql_shape="head",
    resizer="internal",
    batchable=False,
))


def _having_schema(node: Having, children, catalog) -> PlanSchema:
    children[0].require_pred(node.pred, node)
    return children[0]


def _render_having(r, node: Having, head_node, schema) -> str:
    """HAVING clause text. The predicate names the aggregate *output* schema
    (group keys + the aggregate column), so the aggregate column renders back
    to its SQL expression and group keys re-qualify against the input."""
    agg = {}
    if isinstance(head_node, GroupByCount):
        agg[head_node.count_name] = "COUNT(*)"
    elif isinstance(head_node, GroupBySum):
        agg[head_node.name] = f"SUM({r.qual(schema, head_node.col)})"
    else:
        raise ValueError(
            "HAVING renders only over GROUP BY COUNT(*)/SUM heads"
        )
    qual = lambda col: agg.get(col) or r.qual(schema, col)
    return "HAVING " + " AND ".join(sql_conjuncts(node.pred, qual))


# the protocol is exactly the WHERE filter: comparisons over the aggregate
# column go through bshare_col's a->b conversion, validity bits flip, the
# (oblivious) size is unchanged — HAVING discloses nothing WHERE doesn't
register(OperatorDef(
    node_type=Having,
    schema=_having_schema,
    estimate=_filter_estimate,
    protocol=lambda node: lambda prf, t: oblivious_filter(t, node.pred, prf),
    render_having=_render_having,
    sql_shape="having",
    resizer="internal",
))


def _orderby_schema(node: OrderBy, children, catalog) -> PlanSchema:
    children[0].require(node.col, node)
    return children[0]


def _orderby_estimate(node: OrderBy, children, cm) -> Dict:
    c = children[0]
    n, cost = _sortish_estimate(c)
    out_n = node.limit if node.limit else n
    return {
        "n": out_n,
        "t": min(c["t"], out_n),
        "cols": c["cols"] + 1,
        "bytes": c["bytes"] + cost,
    }


def _render_order(r, node: OrderBy, head_node, schema) -> str:
    count_name = getattr(head_node, "count_name", None)
    if count_name is not None and node.col == count_name:
        return "COUNT(*)"
    return r.qual(schema, node.col)


register(OperatorDef(
    node_type=OrderBy,
    schema=_orderby_schema,
    estimate=_orderby_estimate,
    protocol=lambda node: lambda prf, t: oblivious_orderby(
        t, node.col, prf, descending=node.descending, limit=node.limit
    ),
    render_order=_render_order,
    sql_shape="order",
))


def _distinct_schema(node: Distinct, children, catalog) -> PlanSchema:
    children[0].require(node.col, node)
    return children[0]


def _distinct_estimate(node: Distinct, children, cm) -> Dict:
    c = children[0]
    n, cost = _sortish_estimate(c)
    return {
        "n": n,
        "t": min(c["t"], n),
        "cols": c["cols"] + 1,
        "bytes": c["bytes"] + cost,
    }


register(OperatorDef(
    node_type=Distinct,
    schema=_distinct_schema,
    estimate=_distinct_estimate,
    protocol=lambda node: lambda prf, t: oblivious_distinct(t, node.col, prf),
    render_head=lambda r, node, schema: (
        f"DISTINCT {r.qual(schema, node.col)}", None
    ),
    sql_shape="head",
))


def _count_schema(node: CountValid, children, catalog) -> PlanSchema:
    return PlanSchema(OrderedDict(cnt="a"))


def _count_estimate(node, children, cm) -> Dict:
    c = children[0]
    return {"n": 1, "t": 1, "cols": 1, "bytes": c["bytes"] + c["n"] * BYTES["bit2a"]}


register(OperatorDef(
    node_type=CountValid,
    schema=_count_schema,
    estimate=_count_estimate,
    protocol=lambda node: lambda prf, t: count_valid(t, prf),
    render_head=lambda r, node, schema: ("COUNT(*)", None),
    sql_shape="head",
    singleton=True,
    batchable=False,
))


def _count_distinct_schema(node: CountDistinct, children, catalog) -> PlanSchema:
    children[0].require(node.col, node)
    return PlanSchema(OrderedDict(cnt="a"))


def _count_distinct_estimate(node: CountDistinct, children, cm) -> Dict:
    c = children[0]
    cost = c["n"] * BYTES["bit2a"] + sort_bytes(c["n"], c["cols"]) + c["n"] * BYTES["eq"]
    return {"n": 1, "t": 1, "cols": 1, "bytes": c["bytes"] + cost}


register(OperatorDef(
    node_type=CountDistinct,
    schema=_count_distinct_schema,
    estimate=_count_distinct_estimate,
    protocol=lambda node: lambda prf, t: count_distinct(t, node.col, prf),
    render_head=lambda r, node, schema: (
        f"COUNT(DISTINCT {r.qual(schema, node.col)})", None
    ),
    sql_shape="head",
    singleton=True,
    batchable=False,
))


def _sum_schema(node: Sum, children, catalog) -> PlanSchema:
    children[0].require(node.col, node)
    return PlanSchema(OrderedDict({node.name: "a"}))


def _sum_estimate(node: Sum, children, cm) -> Dict:
    c = children[0]
    cost = c["n"] * (BYTES["b2a"] + BYTES["bit2a"] + BYTES["and"])
    return {"n": 1, "t": 1, "cols": 1, "bytes": c["bytes"] + cost}


register(OperatorDef(
    node_type=Sum,
    schema=_sum_schema,
    estimate=_sum_estimate,
    protocol=lambda node: lambda prf, t: sum_column(t, node.col, prf, node.name),
    # the default name is a dialect keyword — render the alias only when set
    render_head=lambda r, node, schema: (
        f"SUM({r.qual(schema, node.col)})"
        + (f" AS {node.name}" if node.name != "sum" else ""),
        None,
    ),
    sql_shape="head",
    singleton=True,
    batchable=False,
))


def _avg_schema(node: Avg, children, catalog) -> PlanSchema:
    children[0].require(node.col, node)
    return PlanSchema(
        OrderedDict({f"{node.name}_sum": "a", f"{node.name}_cnt": "a"})
    )


def _avg_estimate(node: Avg, children, cm) -> Dict:
    c = children[0]
    cost = c["n"] * (BYTES["b2a"] + 2 * BYTES["bit2a"] + BYTES["and"])
    return {"n": 1, "t": 1, "cols": 2, "bytes": c["bytes"] + cost}


def _avg_post_reveal(node: Avg, rows):
    import numpy as np

    s, c = rows.get(f"{node.name}_sum"), rows.get(f"{node.name}_cnt")
    if s is None or c is None:
        return rows
    out = dict(rows)
    out[node.name] = s // np.maximum(c, 1)
    return out


register(OperatorDef(
    node_type=Avg,
    schema=_avg_schema,
    estimate=_avg_estimate,
    protocol=lambda node: lambda prf, t: avg_column(t, node.col, prf, node.name),
    # the default name is a dialect keyword — render the alias only when set
    render_head=lambda r, node, schema: (
        f"AVG({r.qual(schema, node.col)})"
        + (f" AS {node.name}" if node.name != "avg" else ""),
        None,
    ),
    post_reveal=_avg_post_reveal,
    sql_shape="head",
    singleton=True,
    batchable=False,
))


def _minmax_schema(node, children, catalog) -> PlanSchema:
    children[0].require(node.col, node)
    return PlanSchema(OrderedDict({node.name: "b"}))


def _minmax_estimate(node, children, cm) -> Dict:
    # sort-head over the bitonic machinery: only the aggregated column rides
    # the sort (ops.aggregate._extreme_column slims the table first), then a
    # free public 1-row head slice
    c = children[0]
    n, cost = _sortish_estimate({**c, "cols": 1})
    return {"n": 1, "t": 1, "cols": 1, "bytes": c["bytes"] + cost}


def _minmax_render_head(kw: str, default_name: str):
    # the default name is a dialect keyword — render the alias only when set
    def render(r, node, schema):
        alias = f" AS {node.name}" if node.name != default_name else ""
        return f"{kw}({r.qual(schema, node.col)}){alias}", None

    return render


register(OperatorDef(
    node_type=Min,
    schema=_minmax_schema,
    estimate=_minmax_estimate,
    protocol=lambda node: lambda prf, t: min_column(t, node.col, prf, node.name),
    render_head=_minmax_render_head("MIN", "min"),
    sql_shape="head",
    singleton=True,
    batchable=False,
))


register(OperatorDef(
    node_type=Max,
    schema=_minmax_schema,
    estimate=_minmax_estimate,
    protocol=lambda node: lambda prf, t: max_column(t, node.col, prf, node.name),
    render_head=_minmax_render_head("MAX", "max"),
    sql_shape="head",
    singleton=True,
    batchable=False,
))


def _resize_schema(node: Resize, children, catalog) -> PlanSchema:
    return children[0]


def _resize_estimate(node: Resize, children, cm) -> Dict:
    c = children[0]
    noise = node.cfg.noise
    s = min(c["t"] + noise.mean(int(c["n"]), int(c["t"])), c["n"])
    cost = resizer_bytes(c["n"], c["cols"])
    return {"n": s, "t": c["t"], "cols": c["cols"], "bytes": c["bytes"] + cost}


def _apply_resize(eng, node: Resize, children):
    eng._resize_ctr += 1
    rkey = jax.random.fold_in(eng.key, 1000 + eng._resize_ctr)
    out, info = Resizer(node.cfg)(
        children[0],
        eng.prf.fold(900 + eng._resize_ctr),
        rkey,
        bucket_fn=eng.bucket_fn,
    )
    eng._last_resize_info = info
    return out


register(OperatorDef(
    node_type=Resize,
    schema=_resize_schema,
    estimate=_resize_estimate,
    engine_apply=_apply_resize,
    # batched pass: executed per slot — every query folds its own noise
    # counter (fresh i.i.d. noise, one CRT observation each) and the revealed
    # trim sizes may diverge, splitting the batch downstream
    batch_apply=lambda eng, node, children, ctx: eng._batch_resize(
        node, children, ctx
    ),
    sql_shape="none",
    provides_resize_info=True,
))
