"""Logical/physical plan nodes.

A plan is a tree of dataclass nodes; leaves are ``Scan``s over named base
tables. Plans are "hand-compiled" exactly as in the paper (§4.5: no automatic
SQL translation yet); ``Resize`` nodes are inserted either by hand or by a
placement policy (:mod:`repro.plan.policies`).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from ..core.resizer import ResizerConfig
from ..ops.filter import Predicate

__all__ = [
    "PlanNode",
    "Scan",
    "Filter",
    "Join",
    "GroupByCount",
    "OrderBy",
    "Distinct",
    "CountValid",
    "CountDistinct",
    "Resize",
]


@dataclasses.dataclass
class PlanNode:
    def children(self) -> List["PlanNode"]:
        return [
            getattr(self, f.name)
            for f in dataclasses.fields(self)
            if isinstance(getattr(self, f.name), PlanNode)
        ]

    def replace_children(self, new_children: List["PlanNode"]) -> "PlanNode":
        kwargs, i = {}, 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, PlanNode):
                kwargs[f.name] = new_children[i]
                i += 1
            else:
                kwargs[f.name] = v
        return type(self)(**kwargs)

    @property
    def label(self) -> str:
        return type(self).__name__

    def pretty(self, indent: int = 0) -> str:
        lines = ["  " * indent + self.describe()]
        for c in self.children():
            lines.append(c.pretty(indent + 1))
        return "\n".join(lines)

    def describe(self) -> str:
        return self.label


@dataclasses.dataclass
class Scan(PlanNode):
    table: str

    def describe(self) -> str:
        return f"Scan({self.table})"


@dataclasses.dataclass
class Filter(PlanNode):
    child: PlanNode
    predicates: Sequence[Predicate]

    def describe(self) -> str:
        ps = " AND ".join(f"{p.column} {p.op} {p.value}" for p in self.predicates)
        return f"Filter({ps})"


@dataclasses.dataclass
class Join(PlanNode):
    left: PlanNode
    right: PlanNode
    on: Tuple[str, str]
    theta: Optional[Tuple[str, str, str]] = None

    def describe(self) -> str:
        t = f" theta={self.theta}" if self.theta else ""
        return f"Join({self.on[0]}=={self.on[1]}{t})"


@dataclasses.dataclass
class GroupByCount(PlanNode):
    child: PlanNode
    key: str
    count_name: str = "cnt"

    def describe(self) -> str:
        # count_name is part of the node's identity: describe() feeds plan
        # fingerprints (sql/compile.py) and jit-cache keys, and two plans
        # differing only in the count column name are different plans
        return f"GroupByCount({self.key}->{self.count_name})"


@dataclasses.dataclass
class OrderBy(PlanNode):
    child: PlanNode
    col: str
    descending: bool = False
    limit: Optional[int] = None

    def describe(self) -> str:
        return f"OrderBy({self.col}{' DESC' if self.descending else ''}, limit={self.limit})"


@dataclasses.dataclass
class Distinct(PlanNode):
    child: PlanNode
    col: str

    def describe(self) -> str:
        return f"Distinct({self.col})"


@dataclasses.dataclass
class CountValid(PlanNode):
    child: PlanNode

    def describe(self) -> str:
        return "Count(*)"


@dataclasses.dataclass
class CountDistinct(PlanNode):
    child: PlanNode
    col: str

    def describe(self) -> str:
        return f"CountDistinct({self.col})"


@dataclasses.dataclass
class Resize(PlanNode):
    child: PlanNode
    cfg: ResizerConfig

    def describe(self) -> str:
        return f"Resize[{self.cfg.describe()}]"
