"""Logical/physical plan nodes.

A plan is a tree of dataclass nodes; leaves are ``Scan``s over named base
tables. Every node type is declared here and *registered* in
:mod:`repro.plan.registry` — the engine, cost model, SQL renderer, and
Resizer-placement policy all dispatch through that registry, so adding an
operator never touches their drivers.

``describe()`` strings are load-bearing: they feed plan fingerprints
(sql/compile.py), the service plan cache, the privacy accountant's
observation signatures, and the engine's jit-cache keys. Changing a node's
describe() output invalidates every one of those — treat the format as a
stable wire format.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

from ..core.resizer import ResizerConfig
from ..ops.filter import And, Or, Pred, Predicate, normalize_pred, pred_leaves, render_pred

__all__ = [
    "PlanNode",
    "Scan",
    "Filter",
    "Having",
    "Project",
    "Join",
    "JoinSortMerge",
    "GroupByCount",
    "GroupBySum",
    "GroupByAvg",
    "OrderBy",
    "Distinct",
    "CountValid",
    "CountDistinct",
    "Sum",
    "Avg",
    "Min",
    "Max",
    "Resize",
]


@dataclasses.dataclass
class PlanNode:
    def children(self) -> List["PlanNode"]:
        return [
            getattr(self, f.name)
            for f in dataclasses.fields(self)
            if isinstance(getattr(self, f.name), PlanNode)
        ]

    def replace_children(self, new_children: List["PlanNode"]) -> "PlanNode":
        kwargs, i = {}, 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, PlanNode):
                kwargs[f.name] = new_children[i]
                i += 1
            else:
                kwargs[f.name] = v
        return type(self)(**kwargs)

    @property
    def label(self) -> str:
        return type(self).__name__

    def pretty(self, indent: int = 0) -> str:
        lines = ["  " * indent + self.describe()]
        for c in self.children():
            lines.append(c.pretty(indent + 1))
        return "\n".join(lines)

    def describe(self) -> str:
        return self.label


@dataclasses.dataclass
class Scan(PlanNode):
    table: str

    def describe(self) -> str:
        return f"Scan({self.table})"


@dataclasses.dataclass
class Filter(PlanNode):
    """Filter by a predicate *tree* (AND/OR/leaf; see repro.ops.filter).

    A plain sequence of :class:`Predicate` is accepted and normalized to a
    conjunction, preserving the historical ``Filter(child, [p1, p2])`` call
    shape — and, for flat conjunctions, the historical describe() string.
    """

    child: PlanNode
    pred: Pred

    def __post_init__(self):
        self.pred = normalize_pred(self.pred)

    @property
    def predicates(self) -> Tuple[Predicate, ...]:
        """Flat conjunction view (legacy accessor). Raises for trees with OR
        — callers that predate the predicate tree only build conjunctions."""
        if isinstance(self.pred, Or) or (
            isinstance(self.pred, And)
            and any(not isinstance(t, Predicate) for t in self.pred.terms)
        ):
            raise ValueError(
                "Filter holds a non-conjunctive predicate tree; use .pred"
            )
        return pred_leaves(self.pred)

    def describe(self) -> str:
        return f"Filter({render_pred(self.pred)})"


@dataclasses.dataclass
class Having(PlanNode):
    """Post-aggregation filter (SQL HAVING): the same oblivious-filter
    protocol as WHERE, applied to a GROUP BY output. Predicate columns name
    the aggregate output schema (group keys plus the aggregate column, e.g.
    the COUNT(*) name), so ``HAVING COUNT(*) >= 2`` compiles to a predicate
    over the count column — the aggregate values stay secret; only validity
    bits flip, sizes never change."""

    child: PlanNode
    pred: Pred

    def __post_init__(self):
        self.pred = normalize_pred(self.pred)

    def describe(self) -> str:
        return f"Having({render_pred(self.pred)})"


@dataclasses.dataclass
class Project(PlanNode):
    """Keep only the named columns (plus the validity column). Free: an
    oblivious projection is local — no communication, no size change — but
    it shrinks every downstream operator's payload width and the final
    reveal."""

    child: PlanNode
    cols: Tuple[str, ...]

    def __post_init__(self):
        self.cols = tuple(self.cols)

    def describe(self) -> str:
        return f"Project({','.join(self.cols)})"


@dataclasses.dataclass
class Join(PlanNode):
    left: PlanNode
    right: PlanNode
    on: Tuple[str, str]
    theta: Optional[Tuple[str, str, str]] = None

    def describe(self) -> str:
        t = f" theta={self.theta}" if self.theta else ""
        return f"Join({self.on[0]}=={self.on[1]}{t})"


@dataclasses.dataclass
class JoinSortMerge(Join):
    """Physical sort-merge variant of :class:`Join` (same logical contract).

    Produced only by the planner's algorithm-selection pass
    (:func:`repro.plan.policies.select_join_algorithms`) — the SQL compiler
    always emits the logical :class:`Join`. ``describe()`` is deliberately
    *inherited*: plan fingerprints, the privacy accountant's observation
    signatures, and the service plan cache must not change when the planner
    flips the physical algorithm (the disclosed sizes are identical).

    ``fanout`` is a public catalog-derived upper bound on the build side's
    valid rows per key; ``build`` names that side ("left"/"right").
    """

    fanout: int = 1
    build: str = "left"


@dataclasses.dataclass
class GroupByCount(PlanNode):
    """GROUP BY one or more key columns with a COUNT(*) aggregate.

    ``key`` is a single column name (the historical shape — kept so existing
    fingerprints stay byte-stable) or a tuple of names for composite keys.
    """

    child: PlanNode
    key: Union[str, Tuple[str, ...]]
    count_name: str = "cnt"

    def __post_init__(self):
        # canonical: 1-column keys are plain strings (fingerprint stability)
        if not isinstance(self.key, str):
            key = tuple(self.key)
            self.key = key[0] if len(key) == 1 else key

    @property
    def keys(self) -> Tuple[str, ...]:
        return (self.key,) if isinstance(self.key, str) else self.key

    def describe(self) -> str:
        # key/count_name are part of the node's identity: describe() feeds
        # plan fingerprints (sql/compile.py) and jit-cache keys, and two plans
        # differing only in the count column name are different plans
        return f"GroupByCount({','.join(self.keys)}->{self.count_name})"


@dataclasses.dataclass
class GroupBySum(PlanNode):
    """GROUP BY key column(s) with a SUM(col) aggregate (segmented
    arithmetic scan; see repro.ops.groupby)."""

    child: PlanNode
    key: Union[str, Tuple[str, ...]]
    col: str = ""
    name: str = "sum"

    def __post_init__(self):
        if not isinstance(self.key, str):
            key = tuple(self.key)
            self.key = key[0] if len(key) == 1 else key

    @property
    def keys(self) -> Tuple[str, ...]:
        return (self.key,) if isinstance(self.key, str) else self.key

    def describe(self) -> str:
        return f"GroupBySum({','.join(self.keys)}:{self.col}->{self.name})"


@dataclasses.dataclass
class GroupByAvg(PlanNode):
    """GROUP BY key column(s) with an AVG(col) aggregate: per-group (sum,
    count) pair; the division happens post-reveal like :class:`Avg`."""

    child: PlanNode
    key: Union[str, Tuple[str, ...]]
    col: str = ""
    name: str = "avg"

    def __post_init__(self):
        if not isinstance(self.key, str):
            key = tuple(self.key)
            self.key = key[0] if len(key) == 1 else key

    @property
    def keys(self) -> Tuple[str, ...]:
        return (self.key,) if isinstance(self.key, str) else self.key

    def describe(self) -> str:
        return f"GroupByAvg({','.join(self.keys)}:{self.col}->{self.name})"


@dataclasses.dataclass
class OrderBy(PlanNode):
    child: PlanNode
    col: str
    descending: bool = False
    limit: Optional[int] = None

    def describe(self) -> str:
        return f"OrderBy({self.col}{' DESC' if self.descending else ''}, limit={self.limit})"


@dataclasses.dataclass
class Distinct(PlanNode):
    child: PlanNode
    col: str

    def describe(self) -> str:
        return f"Distinct({self.col})"


@dataclasses.dataclass
class CountValid(PlanNode):
    child: PlanNode

    def describe(self) -> str:
        return "Count(*)"


@dataclasses.dataclass
class CountDistinct(PlanNode):
    child: PlanNode
    col: str

    def describe(self) -> str:
        return f"CountDistinct({self.col})"


@dataclasses.dataclass
class Sum(PlanNode):
    """SUM(col) over true rows -> 1-row table with an arithmetic share."""

    child: PlanNode
    col: str
    name: str = "sum"

    def describe(self) -> str:
        return f"Sum({self.col}->{self.name})"


@dataclasses.dataclass
class Avg(PlanNode):
    """AVG(col) -> 1-row (sum, count) pair; division happens post-reveal
    (see repro.ops.aggregate)."""

    child: PlanNode
    col: str
    name: str = "avg"

    def describe(self) -> str:
        return f"Avg({self.col}->{self.name})"


@dataclasses.dataclass
class Min(PlanNode):
    """MIN(col) over true rows -> 1-row table (sort-head, see
    repro.ops.aggregate). An empty selection yields zero revealed rows."""

    child: PlanNode
    col: str
    name: str = "min"

    def describe(self) -> str:
        return f"Min({self.col}->{self.name})"


@dataclasses.dataclass
class Max(PlanNode):
    """MAX(col) over true rows -> 1-row table (sort-head)."""

    child: PlanNode
    col: str
    name: str = "max"

    def describe(self) -> str:
        return f"Max({self.col}->{self.name})"


@dataclasses.dataclass
class Resize(PlanNode):
    child: PlanNode
    cfg: ResizerConfig

    def describe(self) -> str:
        return f"Resize[{self.cfg.describe()}]"
