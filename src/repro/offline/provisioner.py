"""Background provisioner: size pool targets and refill during idle windows.

The provisioner owns the *when* of offline work; the pool owns the *what*.
Refill passes run:

* inline, when the service signals an idle window (``hint()`` after the
  scheduler drains its last bucket) — bounded work on the caller thread,
  deterministic for tests;
* on a daemon thread (``start()``), woken by hints and a periodic
  interval, for deployments where idle windows are scarce.

Sizing: the per-template demand callback (the service feeds it from the
``reflex_offline_demand_total`` counter in the metrics registry, i.e. the
observed admission rate per template fingerprint) sets how many upcoming
engine counters each template's Resizer material is provisioned for:
``clamp(window, demand_since_last_refill, max_window)``. Static material
is re-derived whenever its bundle was evicted. All refill work is
traced (``offline.refill`` spans) and exported through the
``reflex_offline_refill*`` metrics.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional

from ..obs import trace as obs_trace
from .pool import RandomnessPool

__all__ = ["Provisioner"]


class Provisioner:
    """Sizes and refills a :class:`RandomnessPool` off the critical path."""

    def __init__(
        self,
        pool: RandomnessPool,
        base_prf,
        ctr_fn: Callable[[], int],
        demand_fn: Optional[Callable[[], Dict[tuple, float]]] = None,
        window: int = 8,
        max_window: int = 64,
        interval_s: float = 1.0,
        metrics=None,
    ):
        self.pool = pool
        self.base_pair_keys = base_prf.pair_keys
        self.ctr_fn = ctr_fn
        self.demand_fn = demand_fn
        self.window = int(window)
        self.max_window = int(max_window)
        self.interval_s = float(interval_s)
        self.refills = 0
        self.last_refill_s = 0.0
        self.last_error: Optional[BaseException] = None
        self._demand_seen: Dict[tuple, float] = {}
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._refill_lock = threading.Lock()
        self._m_refills = self._m_refill_s = None
        if metrics is not None:
            self._m_refills = metrics.counter(
                "reflex_offline_refills_total",
                "Offline pool refill passes by trigger",
                ("trigger",),
            )
            self._m_refill_s = metrics.histogram(
                "reflex_offline_refill_seconds",
                "Wall time of one offline refill pass",
            )

    # -- sizing --------------------------------------------------------------

    def _target_window(self, bundle_key: tuple) -> int:
        """Upcoming-counter coverage for one template, from observed demand."""
        if self.demand_fn is None:
            return self.window
        demand = self.demand_fn() or {}
        total = float(demand.get(bundle_key, 0.0))
        delta = total - self._demand_seen.get(bundle_key, 0.0)
        self._demand_seen[bundle_key] = total
        return max(self.window, min(self.max_window, int(delta)))

    # -- refill --------------------------------------------------------------

    def refill(self, trigger: str = "manual") -> dict:
        """One synchronous refill pass: GC consumed counters, restore evicted
        static bundles, provision upcoming counter windows. Thread-safe and
        reentrant-serialized; returns a summary dict."""
        with self._refill_lock:
            t0 = time.perf_counter()
            watermark = int(self.ctr_fn())
            dropped = self.pool.gc(watermark)
            static_made = counter_made = 0
            with obs_trace.span("offline.refill", reason=trigger):
                for bundle_key in self.pool.recipes():
                    static_made += self.pool.ensure_static(
                        bundle_key, self.base_pair_keys
                    )
                    target = self._target_window(bundle_key)
                    counter_made += self.pool.provision(
                        bundle_key,
                        self.base_pair_keys,
                        range(watermark + 1, watermark + 1 + target),
                    )
            dt = time.perf_counter() - t0
            self.refills += 1
            self.last_refill_s = dt
            if self._m_refills is not None:
                self._m_refills.inc(trigger=trigger)
                self._m_refill_s.observe(dt)
            return {
                "trigger": trigger,
                "seconds": dt,
                "gc_dropped": dropped,
                "static_entries": static_made,
                "counter_entries": counter_made,
                "watermark": watermark,
            }

    def hint(self) -> Optional[dict]:
        """Idle-window signal (e.g. scheduler drained its last bucket). Wakes
        the background thread if running, else refills inline."""
        if self._thread is not None and self._thread.is_alive():
            self._wake.set()
            return None
        return self.refill(trigger="idle")

    # -- background thread ---------------------------------------------------

    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="reflex-offline-provisioner", daemon=True
        )
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            self._wake.wait(timeout=self.interval_s)
            self._wake.clear()
            if self._stop.is_set():
                return
            try:
                self.refill(trigger="background")
            except Exception as e:  # keep the daemon alive; surface via stats
                self.last_error = e

    def stats(self) -> dict:
        return {
            "refills": self.refills,
            "last_refill_seconds": self.last_refill_s,
            "running": self._thread is not None and self._thread.is_alive(),
            "error": repr(self.last_error) if self.last_error else None,
        }
