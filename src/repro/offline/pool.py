"""The randomness pool: content-addressed precomputed correlated randomness.

Storage model (DESIGN.md §15.2)
-------------------------------

Material falls into two classes with different lifetimes:

* **Template-static** material — every derivation whose PRF-fold path does
  not pass through a Resizer counter root (filter/gate/conversion folds,
  sort and shuffle controls of stateless operators). The fold tags are
  static per plan template, so the same entries serve every execution of
  the template: a pure memo, stored per (template fingerprint, shape-key)
  bundle and evicted LRU under the byte budget.
* **Counter-dependent** material — everything derived under a Resizer's
  per-execution root fold ``prf.fold(900 + ctr)``. Counters never repeat,
  so these entries are single-use: stored in a global content-addressed
  map tagged with their counter and garbage-collected once the engine's
  counter watermark passes them.

Counter-range ownership: the engine's ``_resize_ctr`` is the *only*
allocator of counters; the pool never advances it. The pool merely owns
**material** for a declared range of upcoming counters (``owned_counters``)
— a pooled counter the engine never reaches is garbage-collected, and an
engine counter the pool never provisioned is an ordinary miss that falls
back to on-demand derivation *from the same counter*, so the counter
stream never splits between hot and cold executions.

Recording and replay
--------------------

The first (cold) execution of a template runs under a recording
:class:`PoolSource`: every derivation event is captured as
``(op, parent-ref, args)`` where the parent-ref points at the event that
produced the parent pair-keys (or at the engine's base PRF). Static events
are inserted into the pool as they are computed (record-and-fill); events
under a counter root form a per-root *recipe subtree* that the
:class:`~repro.offline.provisioner.Provisioner` replays later with future
counter tags to provision material the engine has not drawn yet. Replay
calls the same jitted derivation primitives (``_fold_keys`` /
``_draw_bits`` / ``_zero_share`` / ``jax.random.permutation``) the online
path uses, which is what makes hits bit-identical to misses.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import material
from ..core.prf import _draw_bits, _draw_uniform, _fold_keys, _zero_share

__all__ = ["RandomnessPool", "PoolSource", "Recipe", "RESIZE_TAG_LO", "RESIZE_TAG_HI"]

# The engine derives each Resizer's per-execution randomness from
# eng.prf.fold(900 + ctr) (plan/registry.py _apply_resize). Tags in this
# window folded directly from the engine's base PRF are counter roots;
# everything else folded from the base is template-static.
RESIZE_TAG_LO = 900
RESIZE_TAG_HI = 1000


def _derive(op: str, parent: jax.Array, args: tuple) -> jax.Array:
    """The on-demand derivation for one recorded event — identical to the
    compute() closures at the call sites in core/prf.py and core/shuffle.py."""
    if op == "fold":
        return _fold_keys(parent, args[0])
    if op == "draw":
        return _draw_bits(parent, tuple(args[0]), jnp.dtype(args[1]))
    if op == "uniform":
        return _draw_uniform(parent, tuple(args[0]))
    if op == "zero_add":
        return _zero_share(parent, tuple(args[0]), jnp.dtype(args[1]), xor=False)
    if op == "zero_xor":
        return _zero_share(parent, tuple(args[0]), jnp.dtype(args[1]), xor=True)
    if op == "perm":
        hop, n = args
        key = jax.random.wrap_key_data(parent[hop])
        return jax.random.permutation(key, n)
    raise ValueError(f"unknown derivation op {op!r}")


@dataclasses.dataclass(frozen=True)
class _Event:
    op: str
    parent: tuple  # ("base",) | ("ev", producing event index) | ("lit", bytes)
    args: tuple
    root: Optional[int]  # counter-root ordinal, None for template-static
    is_root: bool  # the fold event that opens a counter subtree


@dataclasses.dataclass(frozen=True)
class Recipe:
    """The recorded derivation DAG of one template execution."""

    events: Tuple[_Event, ...]
    n_roots: int  # number of Resizer counter roots (== resizes per execution)

    def static_events(self) -> List[Tuple[int, _Event]]:
        return [(i, e) for i, e in enumerate(self.events) if e.root is None]


class RandomnessPool:
    """Bounded store of precomputed correlated randomness.

    Thread-safe: consumption (engine thread) and refill (provisioner
    thread) interleave under one lock; values themselves are immutable
    jax arrays, so a served reference never changes under the reader.
    """

    def __init__(self, max_bytes: int = 64 << 20):
        self.max_bytes = int(max_bytes)
        self._lock = threading.RLock()
        # bundle_key -> {content_key -> value}; OrderedDict for bundle LRU
        self._static: "OrderedDict[tuple, Dict[tuple, jax.Array]]" = OrderedDict()
        self._static_bytes: Dict[tuple, int] = {}
        # content_key -> (value, counter); single-use, GC'd by watermark
        self._counter: Dict[tuple, Tuple[jax.Array, int]] = {}
        self._counter_bytes = 0
        self._recipes: Dict[tuple, Recipe] = {}
        self._provisioned: Dict[tuple, Set[int]] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.gc_dropped = 0

    # -- consumption ---------------------------------------------------------

    def take(self, bundle_key: tuple, key: tuple) -> Optional[jax.Array]:
        """Serve a precomputed value, or None (caller derives on demand).
        Entries are NOT removed on take: static entries are memos, and
        counter entries can legitimately be re-fetched within one execution
        (e.g. the lazy-payload path re-deriving the shuffle's hop perms)."""
        with self._lock:
            bundle = self._static.get(bundle_key)
            if bundle is not None:
                val = bundle.get(key)
                if val is not None:
                    self._static.move_to_end(bundle_key)
                    self.hits += 1
                    return val
            ent = self._counter.get(key)
            if ent is not None:
                self.hits += 1
                return ent[0]
            self.misses += 1
            return None

    # -- filling -------------------------------------------------------------

    def put(self, bundle_key: tuple, key: tuple, val: jax.Array) -> None:
        """Insert template-static material (memo class)."""
        nbytes = int(np.asarray(val).nbytes)
        with self._lock:
            bundle = self._static.setdefault(bundle_key, {})
            if key in bundle:
                return
            bundle[key] = val
            self._static_bytes[bundle_key] = (
                self._static_bytes.get(bundle_key, 0) + nbytes
            )
            self._static.move_to_end(bundle_key)
            self._enforce_budget(protect=bundle_key)

    def put_counter(self, key: tuple, val: jax.Array, ctr: int) -> None:
        """Insert counter-dependent material for a future counter."""
        nbytes = int(np.asarray(val).nbytes)
        with self._lock:
            if key in self._counter:
                return
            self._counter[key] = (val, int(ctr))
            self._counter_bytes += nbytes
            self._enforce_budget()

    def _enforce_budget(self, protect: Optional[tuple] = None) -> None:
        # evict least-recently-used static bundles first (they can always be
        # re-derived); counter entries expire via gc() instead
        while self.total_bytes() > self.max_bytes and len(self._static) > (
            1 if protect in self._static else 0
        ):
            for bk in self._static:
                if bk != protect:
                    self._drop_bundle(bk)
                    self.evictions += 1
                    break
            else:
                break

    def _drop_bundle(self, bundle_key: tuple) -> None:
        self._static.pop(bundle_key, None)
        self._static_bytes.pop(bundle_key, None)

    def gc(self, counter_watermark: int) -> int:
        """Drop counter entries at or below the engine's counter watermark:
        those counters have been allocated (or skipped) and never recur."""
        with self._lock:
            dead = [k for k, (_, c) in self._counter.items() if c <= counter_watermark]
            for k in dead:
                val, _ = self._counter.pop(k)
                self._counter_bytes -= int(np.asarray(val).nbytes)
            for owned in self._provisioned.values():
                owned.difference_update(
                    {c for c in owned if c <= counter_watermark}
                )
            self.gc_dropped += len(dead)
            return len(dead)

    # -- recipes + provisioning ---------------------------------------------

    def register_recipe(self, bundle_key: tuple, recipe: Recipe) -> None:
        with self._lock:
            self._recipes.setdefault(bundle_key, recipe)

    def has_recipe(self, bundle_key: tuple) -> bool:
        with self._lock:
            return bundle_key in self._recipes

    def recipes(self) -> List[tuple]:
        with self._lock:
            return list(self._recipes)

    def ensure_static(self, bundle_key: tuple, base_pair_keys: jax.Array) -> int:
        """Re-derive a bundle's template-static entries (after eviction or a
        restart with a persisted recipe). Returns the number of entries made."""
        with self._lock:
            recipe = self._recipes.get(bundle_key)
            if recipe is None:
                return 0
            todo = recipe.static_events()
        env: Dict[int, jax.Array] = {}
        made = 0
        for i, ev in todo:
            parent = self._resolve_parent(ev, env, base_pair_keys)
            if parent is None:
                continue
            key = (ev.op, np.asarray(parent).tobytes(), ev.args)
            with self._lock:
                val = self._static.get(bundle_key, {}).get(key)
            if val is None:
                val = _derive(ev.op, parent, ev.args)
                self.put(bundle_key, key, val)
                made += 1
            if ev.op == "fold":
                env[i] = val
        return made

    def provision(
        self,
        bundle_key: tuple,
        base_pair_keys: jax.Array,
        counters: Iterable[int],
    ) -> int:
        """Precompute the counter-dependent material of ``bundle_key`` for
        each future counter in ``counters`` (every root subtree is replayed
        per counter, since which Resizer lands on which counter depends on
        future admission order). Returns the number of entries made."""
        with self._lock:
            recipe = self._recipes.get(bundle_key)
            if recipe is None or recipe.n_roots == 0:
                return 0
            owned = self._provisioned.setdefault(bundle_key, set())
            todo = [c for c in counters if c not in owned]
        made = 0
        for ctr in todo:
            if self.total_bytes() >= self.max_bytes:
                break
            for root in range(recipe.n_roots):
                made += self._replay_root(recipe, base_pair_keys, root, ctr)
            with self._lock:
                self._provisioned[bundle_key].add(ctr)
        return made

    def _replay_root(
        self, recipe: Recipe, base_pair_keys: jax.Array, root: int, ctr: int
    ) -> int:
        env: Dict[int, jax.Array] = {}
        made = 0
        for i, ev in enumerate(recipe.events):
            if ev.root != root:
                continue
            parent = self._resolve_parent(ev, env, base_pair_keys)
            if parent is None:
                return made  # unresolvable chain: leave the rest on-demand
            args = (RESIZE_TAG_LO + ctr,) if ev.is_root else ev.args
            val = _derive(ev.op, parent, args)
            key = (ev.op, np.asarray(parent).tobytes(), args)
            self.put_counter(key, val, ctr)
            made += 1
            if ev.op == "fold":
                env[i] = val
        return made

    @staticmethod
    def _resolve_parent(
        ev: _Event, env: Dict[int, jax.Array], base_pair_keys: jax.Array
    ) -> Optional[jax.Array]:
        kind = ev.parent[0]
        if kind == "base":
            return base_pair_keys
        if kind == "ev":
            return env.get(ev.parent[1])
        # literal parent: pair keys produced outside the recorded stream
        # (should not occur under counter roots; static replay uses verbatim)
        raw = np.frombuffer(ev.parent[1], dtype=np.uint32)
        return jnp.asarray(raw.reshape(3, 2))

    # -- introspection -------------------------------------------------------

    def total_bytes(self) -> int:
        with self._lock:
            return sum(self._static_bytes.values()) + self._counter_bytes

    def owned_counters(self, bundle_key: tuple) -> Tuple[int, int, int]:
        """(lo, hi, count) of counters provisioned for this bundle."""
        with self._lock:
            owned = self._provisioned.get(bundle_key) or set()
            if not owned:
                return (0, 0, 0)
            return (min(owned), max(owned), len(owned))

    def stats(self) -> dict:
        with self._lock:
            return {
                "bundles": len(self._static),
                "static_entries": sum(len(b) for b in self._static.values()),
                "counter_entries": len(self._counter),
                "depth_bytes": self.total_bytes(),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "gc_dropped": self.gc_dropped,
                "recipes": len(self._recipes),
            }

    def source(
        self,
        bundle_key: tuple,
        base_pair_keys: jax.Array,
        record: Optional[bool] = None,
    ) -> "PoolSource":
        """A per-execution consumption handle. ``record`` defaults to True
        exactly when this bundle has no recipe yet (first cold run)."""
        if record is None:
            record = not self.has_recipe(bundle_key)
        return PoolSource(self, bundle_key, base_pair_keys, record=record)


class PoolSource(material.MaterialSource):
    """One execution's window onto the pool: serves hits, derives misses,
    and (on the first cold run of a template) records the derivation DAG."""

    def __init__(
        self,
        pool: RandomnessPool,
        bundle_key: tuple,
        base_pair_keys: jax.Array,
        record: bool = False,
    ):
        self.pool = pool
        self.bundle_key = bundle_key
        self.base_bytes = np.asarray(base_pair_keys).tobytes()
        self.record = record
        self.hits = 0
        self.misses = 0
        self._events: List[_Event] = []
        self._produced: Dict[bytes, int] = {}  # fold output bytes -> event idx
        self._root_of: Dict[bytes, int] = {}  # pair-key bytes -> root ordinal
        self._seen: Set[tuple] = set()
        self._n_roots = 0

    def fetch(self, op, pair_keys, args, compute):
        pk_bytes = np.asarray(pair_keys).tobytes()
        key = (op, pk_bytes, args)
        val = self.pool.take(self.bundle_key, key)
        if val is None:
            self.misses += 1
            val = compute()
            fresh = True
        else:
            self.hits += 1
            fresh = False
        self._note(op, pk_bytes, args, key, val, fresh)
        return val

    def _note(self, op, pk_bytes, args, key, val, fresh):
        if key in self._seen:
            return  # one event per unique derivation
        self._seen.add(key)
        root = self._root_of.get(pk_bytes)
        is_root = False
        if (
            op == "fold"
            and pk_bytes == self.base_bytes
            and RESIZE_TAG_LO <= args[0] < RESIZE_TAG_HI
        ):
            root, is_root = self._n_roots, True
            self._n_roots += 1
        if self.record:
            if pk_bytes == self.base_bytes:
                parent: tuple = ("base",)
            elif pk_bytes in self._produced:
                parent = ("ev", self._produced[pk_bytes])
            else:
                parent = ("lit", pk_bytes)
            self._events.append(_Event(op, parent, args, root, is_root))
        if op == "fold":
            out_b = np.asarray(val).tobytes()
            if self.record:
                self._produced.setdefault(out_b, len(self._events) - 1)
            if root is not None:
                self._root_of.setdefault(out_b, root)
        if root is None and fresh:
            # backfill: static material fills the pool on every cold fetch,
            # whether or not this run is the recording one (self-healing
            # after eviction or shape drift)
            self.pool.put(self.bundle_key, key, val)

    def finish(self) -> None:
        """Register the recorded recipe (call after the execution completes)."""
        if self.record and self._events:
            self.pool.register_recipe(
                self.bundle_key, Recipe(tuple(self._events), self._n_roots)
            )

    def event_counts(self) -> Dict[str, int]:
        """Recorded unique derivation events by op (test/manifest cross-check)."""
        out: Dict[str, int] = {}
        for e in self._events:
            out[e.op] = out.get(e.op, 0) + 1
        return out
