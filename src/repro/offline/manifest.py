"""RandomnessPlanner: derive a plan template's randomness manifest.

The manifest answers "how much correlated randomness will one execution of
this template draw, per node?" — counted at the **eager call-site
granularity** the ambient :mod:`repro.core.material` hook intercepts:
``PRFSetup.fold`` / ``draw`` / ``draw_uniform``, ``zero_share_add/xor``,
and shuffle-hop permutations. (Gate-internal zero-sharings that live
inside jitted whole-level payloads compile into the program and are
neither intercepted nor counted — see DESIGN.md §15.1.)

Counts are a pure function of the template and its pow2-bucketed shapes.
For the operators whose derivation stream is simple enough to enumerate
statically (Scan/Project/Filter/Having/Count/Sum/Avg/Resize) the counts
are **exact** and cross-checked against recorded event streams in
``tests/test_offline.py``; for the sort- and join-based operators they
are sizing estimates, flagged ``exact=False``.

The provisioner uses manifest totals to prioritize refill work and the
service exports them per template through EXPLAIN and the
``reflex_offline_*`` metrics.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from ..core.noise import NoTrim
from ..ops.filter import And, Or, Pred, Predicate, pred_leaves

__all__ = ["NodeManifest", "RandomnessManifest", "RandomnessPlanner"]

# Eager fold counts of the conversion circuits (core/circuits.py): a2b does
# fold(31), fold(32) plus one fold(11) inside each of its two ks_add calls;
# bit2a does fold(21), fold(22).
A2B_FOLDS = 4
BIT2A_FOLDS = 2
SHUFFLE_HOPS = 3


def _bucket_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 1).bit_length()


@dataclasses.dataclass(frozen=True)
class NodeManifest:
    """Per-node randomness demand for one execution of the template."""

    label: str
    op: str
    bucket: int  # pow2-bucketed estimated row count
    folds: int  # PRF fold invocations
    draws: int  # replicated draws (prf.draw / draw_uniform)
    zero_shares: int  # eager zero-sharing derivations
    perms: int  # shuffle-hop control permutations
    conversions: int  # a2b / bit2a conversion call sites
    resize_counters: int  # Resizer noise-counter reservations
    exact: bool  # counts are exact (vs sizing estimate)

    def total_events(self) -> int:
        return self.folds + self.draws + self.zero_shares + self.perms


@dataclasses.dataclass(frozen=True)
class RandomnessManifest:
    """The full manifest of one plan template at one shape bucket."""

    template: str  # fingerprint hash of the literal-masked plan
    nodes: Tuple[NodeManifest, ...]

    def totals(self) -> Dict[str, int]:
        out = {
            "folds": 0,
            "draws": 0,
            "zero_shares": 0,
            "perms": 0,
            "conversions": 0,
            "resize_counters": 0,
            "events": 0,
        }
        for nm in self.nodes:
            out["folds"] += nm.folds
            out["draws"] += nm.draws
            out["zero_shares"] += nm.zero_shares
            out["perms"] += nm.perms
            out["conversions"] += nm.conversions
            out["resize_counters"] += nm.resize_counters
            out["events"] += nm.total_events()
        return out

    @property
    def exact(self) -> bool:
        return all(nm.exact for nm in self.nodes)

    def resizes(self) -> int:
        return sum(nm.resize_counters for nm in self.nodes)


class RandomnessPlanner:
    """Walk a compiled plan template and derive its randomness manifest."""

    def __init__(self, catalog=None, cost_model=None):
        self.catalog = catalog
        self.cost_model = cost_model

    def manifest(self, plan) -> "RandomnessManifest":
        from ..sql.compile import template_fingerprint
        from ..obs.redact import fingerprint_hash

        nodes = []
        self._walk(plan, nodes)
        return RandomnessManifest(
            template=fingerprint_hash(template_fingerprint(plan)),
            nodes=tuple(nodes),
        )

    # -- internals -----------------------------------------------------------

    def _walk(self, node, out: list) -> None:
        for c in node.children():
            self._walk(c, out)
        out.append(self._node_manifest(node))

    def _rows(self, node) -> int:
        if self.cost_model is not None:
            try:
                return int(self.cost_model.estimate(node).get("n", 1))
            except Exception:
                return 1
        return 1

    def _schema(self, node):
        if self.catalog is None:
            return None
        try:
            from ..plan.registry import infer_schema

            return infer_schema(node, self.catalog)
        except Exception:
            return None

    def _node_manifest(self, node) -> NodeManifest:
        name = type(node).__name__
        bucket = _bucket_pow2(self._rows(node))
        zero = dict(
            label=node.label,
            op=name,
            bucket=bucket,
            folds=0,
            draws=0,
            zero_shares=0,
            perms=0,
            conversions=0,
            resize_counters=0,
            exact=True,
        )
        handler = getattr(self, f"_count_{name}", None)
        if handler is not None:
            zero.update(handler(node))
        elif name not in ("Scan", "Project", "Limit"):
            # unmodeled operator: unknown demand, flagged inexact
            zero.update(dict(exact=False))
        return NodeManifest(**zero)

    # predicate evaluation: one fold per leaf tag, two per combining gate
    # (430/470 then the gate ordinal), one for the valid-AND (449); leaves
    # over arithmetic-share columns a2b-convert first (4 folds each), and
    # secret-secret lt/le leaves fold once more for the generate AND.
    def _pred_counts(self, pred: Pred, child) -> Dict[str, int]:
        leaves = pred_leaves(pred)
        gates = self._gate_count(pred)
        schema = self._schema(child)
        folds = len(leaves) + 2 * gates + 1
        conversions = 0
        exact = True
        converted = set()

        def col_kind(name: str) -> Optional[str]:
            if schema is None:
                return None
            return schema.cols.get(name)

        for leaf in leaves:
            cols = [leaf.column]
            secret_pair = isinstance(leaf.value, str) and str(leaf.value).startswith(
                "col:"
            )
            if secret_pair:
                cols.append(str(leaf.value)[4:])
                if leaf.op in ("lt", "le"):
                    folds += 1  # the eager generate-AND fold(7) in lt()
            for col in cols:
                kind = col_kind(col)
                if kind is None:
                    exact = schema is not None and exact
                    if schema is None:
                        exact = False
                elif kind == "a" and col not in converted:
                    converted.add(col)
                    folds += A2B_FOLDS
                    conversions += 1
        return dict(folds=folds, conversions=conversions, exact=exact)

    @staticmethod
    def _gate_count(pred: Pred) -> int:
        if isinstance(pred, Predicate):
            return 0
        count = len(pred.terms) - 1
        for t in pred.terms:
            count += RandomnessPlanner._gate_count(t)
        return count

    def _count_Filter(self, node) -> Dict[str, int]:
        return self._pred_counts(node.pred, node.child)

    def _count_Having(self, node) -> Dict[str, int]:
        return self._pred_counts(node.pred, node.child)

    def _count_GroupByCount(self, node) -> Dict[str, int]:
        # sort-based: keys ride the bitonic network (stage folds), payload
        # gathered once via shuffle-apply (6 hop perms). Sizing estimate.
        k = max(1, int(math.log2(max(2, _bucket_pow2(self._rows(node))))))
        stages = k * (k + 1) // 2
        return dict(
            folds=2 * stages + 12,
            perms=2 * SHUFFLE_HOPS,
            conversions=2,
            exact=False,
        )

    _count_GroupBySum = _count_GroupByCount
    _count_GroupByAvg = _count_GroupByCount
    _count_OrderBy = _count_GroupByCount
    _count_Distinct = _count_GroupByCount
    _count_Min = _count_GroupByCount
    _count_Max = _count_GroupByCount

    def _count_Count(self, node) -> Dict[str, int]:
        # aggregate.py: bit2a(valid, fold(701)) -> 1 + BIT2A_FOLDS
        return dict(folds=1 + BIT2A_FOLDS, conversions=1, exact=True)

    def _count_Sum(self, node) -> Dict[str, int]:
        # b2a(col, fold(711)) -> 1 + BIT2A_FOLDS; bit2a(valid, fold(712)) ->
        # 1 + BIT2A_FOLDS; mul(fold(713)) -> 1
        return dict(folds=2 * (1 + BIT2A_FOLDS) + 1, conversions=2, exact=True)

    _count_Avg = _count_Sum

    def _count_Join(self, node) -> Dict[str, int]:
        return dict(folds=8, exact=False)

    def _count_JoinSortMerge(self, node) -> Dict[str, int]:
        k = max(1, int(math.log2(max(2, _bucket_pow2(self._rows(node))))))
        stages = k * (k + 1) // 2
        return dict(
            folds=2 * stages + 24,
            perms=2 * SHUFFLE_HOPS,
            conversions=2,
            exact=False,
        )

    def _count_Resize(self, node) -> Dict[str, int]:
        cfg = node.cfg
        counts = dict(resize_counters=1, folds=1)  # the counter-root fold
        if isinstance(cfg.noise, NoTrim):
            return counts  # Resizer returns before any further derivation
        schema = self._schema(node.child)
        if schema is None or getattr(cfg, "use_sort", False):
            counts["exact"] = False
        cols = dict(schema.cols) if schema is not None else {}
        ncols = len(cols)
        a_cols = sum(1 for kind in cols.values() if kind == "a")
        folds, zero, perms, conv = counts["folds"], 0, 0, 0
        if cfg.addition == "parallel":
            # fold(801) + a2b + fold(802) + lt_public(eager folds: 0) +
            # or_bit(fold(803))
            folds += 1 + A2B_FOLDS + 1 + 1
            conv += 1
        else:  # sequential
            # bit2a(fold(811)) + a2b(fold(812)) + lt_public(fold(813)) +
            # or_bit(fold(814))
            folds += (1 + BIT2A_FOLDS) + (1 + A2B_FOLDS) + 1 + 1
            conv += 2
        folds += a_cols * A2B_FOLDS  # bshare_col of arithmetic payload cols
        conv += a_cols
        # secure_shuffle under fold(821): hop folds + hop perms + one
        # re-randomize (fold + zero-share) per column per hop; the shuffled
        # set is the payload plus the __k / __valid control columns
        shuffled_cols = ncols + 2
        folds += 1 + SHUFFLE_HOPS * (1 + shuffled_cols)
        perms += SHUFFLE_HOPS
        zero += SHUFFLE_HOPS * shuffled_cols
        counts.update(
            folds=folds, zero_shares=zero, perms=perms, conversions=conv
        )
        # a join below can carry lazy payload views through the deferred
        # gather path, which re-derives hop perms and re-randomizes per
        # lazy column — demand we cannot see from the template alone
        if self._has_join_below(node):
            counts["exact"] = False
        return counts

    @staticmethod
    def _has_join_below(node) -> bool:
        for c in node.children():
            if type(c).__name__ in ("Join", "JoinSortMerge"):
                return True
            if RandomnessPlanner._has_join_below(c):
                return True
        return False
