"""Offline/online phase split: correlated-randomness provisioning (DESIGN.md §15).

The online phase of every query pays for its correlated randomness — PRF
folds, zero-sharings, shuffle-hop permutations, conversion material — on
the critical path. This package moves that work into a background offline
phase, keyed by the plan cache's template fingerprints:

* :class:`~repro.offline.manifest.RandomnessPlanner` walks a compiled plan
  template and derives its randomness **manifest** (per node: PRF folds,
  shuffle control sets, a2b/bit2a conversion material, Resizer
  noise-counter reservations, as a function of pow2-bucketed shapes).
* :class:`~repro.offline.pool.RandomnessPool` stores precomputed material
  keyed by (template fingerprint, shape bucket) with bounded memory and
  explicit counter-range ownership; its :class:`~repro.offline.pool.PoolSource`
  plugs into the ambient hook in :mod:`repro.core.material`.
* :class:`~repro.offline.provisioner.Provisioner` sizes pool targets from
  observed admission rates and refills during idle windows (scheduler
  drain) or from a background thread.

Pooled and on-demand draws are bit-identical by construction: the pool is
a content-addressed cache in front of the same pure derivation functions
the online path calls on a miss.
"""
from .manifest import NodeManifest, RandomnessManifest, RandomnessPlanner
from .pool import PoolSource, RandomnessPool, Recipe
from .provisioner import Provisioner

__all__ = [
    "NodeManifest",
    "RandomnessManifest",
    "RandomnessPlanner",
    "PoolSource",
    "RandomnessPool",
    "Recipe",
    "Provisioner",
]
