"""RuntimeConfig: one frozen dataclass for every engine execution knob.

The flags that select execution strategy — Pallas kernels on/off, circuit
fusion, the join valid-computation tile, the physical join algorithm — used
to be scattered across module-level ``os.environ`` reads in
``repro.kernels``, ``repro.ops.join``, and ``repro.plan.policies``, plus
assorted constructor kwargs. This module is now the **only** place the
``REPRO_*`` environment variables are parsed; everything else consumes a
:class:`RuntimeConfig`.

Resolution order, from strongest to weakest:

1. block-scoped thread-local overrides (``repro.kernels.override_kernels`` /
   ``override_fusion`` — kept for tests and benchmarks that flip one switch
   around one call);
2. an explicit ``RuntimeConfig`` passed to :class:`~repro.engine.Engine`,
   :func:`~repro.sql.compile.compile_query`, or
   :class:`~repro.service.AnalyticsService`, applied via :func:`use_config`
   for the duration of an execution (and shipped to party processes by the
   networked runtime, so the whole mesh executes under one config);
3. the environment fallback: :func:`current_config` parses the ``REPRO_*``
   variables (cached; re-parsed only when the raw values change, so
   ``monkeypatch.setenv`` in tests keeps working).

Env fallbacks (all optional):

* ``REPRO_USE_PALLAS=1``     -> ``use_pallas=True``
* ``REPRO_FUSE_CIRCUITS=0``  -> ``fuse_circuits=False``
* ``REPRO_JOIN_TILE=<int>``  -> ``join_tile`` (product-grid rows per tile)
* ``REPRO_JOIN_ALGO=<mode>`` -> ``join_algo`` (``auto|product|sortmerge``)
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Iterator, Mapping, Optional, Tuple

__all__ = ["RuntimeConfig", "current_config", "use_config", "DEFAULT_JOIN_TILE"]

DEFAULT_JOIN_TILE = 1 << 16

_ENV_VARS = (
    "REPRO_USE_PALLAS",
    "REPRO_FUSE_CIRCUITS",
    "REPRO_JOIN_TILE",
    "REPRO_JOIN_ALGO",
)


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Execution-strategy knobs for one engine (or one whole party mesh).

    Frozen: a config is an identity (it participates in jit-cache keys via
    the flags it toggles), so it must never mutate under a running engine.
    Use :func:`dataclasses.replace` to derive variants.
    """

    use_pallas: bool = False  # route gates/circuits through Pallas kernels
    fuse_circuits: bool = True  # single-launch fused circuit kernels
    join_tile: int = DEFAULT_JOIN_TILE  # product-grid rows per valid tile
    join_algo: str = "auto"  # physical join selection: auto|product|sortmerge

    def __post_init__(self):
        if self.join_algo not in ("auto", "product", "sortmerge"):
            raise ValueError(
                f"join algo mode {self.join_algo!r} "
                "(expected auto|product|sortmerge)"
            )
        if self.join_tile < 1:
            raise ValueError(
                f"REPRO_JOIN_TILE must be >= 1, got {self.join_tile}"
            )

    @classmethod
    def from_env(cls, env: Optional[Mapping[str, str]] = None) -> "RuntimeConfig":
        """Parse the ``REPRO_*`` fallback variables — the single env parse
        site for the whole codebase."""
        env = os.environ if env is None else env
        raw_tile = env.get("REPRO_JOIN_TILE", "")
        if raw_tile:
            try:
                tile = int(raw_tile)
            except ValueError as e:
                raise ValueError(
                    f"REPRO_JOIN_TILE must be an integer, got {raw_tile!r}"
                ) from e
        else:
            tile = DEFAULT_JOIN_TILE
        return cls(
            use_pallas=env.get("REPRO_USE_PALLAS", "0") == "1",
            fuse_circuits=env.get("REPRO_FUSE_CIRCUITS", "1") == "1",
            join_tile=tile,
            join_algo=env.get("REPRO_JOIN_ALGO") or "auto",
        )

    # -- wire form (the coordinator ships its config to every party) ----------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "RuntimeConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


_cache: Tuple[Optional[Tuple], Optional[RuntimeConfig]] = (None, None)
_STATE = threading.local()


def current_config() -> RuntimeConfig:
    """The config in effect on this thread: an explicit :func:`use_config`
    override when one is active (the Engine installs its own config for the
    duration of an execution; a party server installs the mesh-wide config
    the coordinator shipped), else the env fallback. The fallback parse is
    cached and redone only when one of the ``REPRO_*`` raw values changes
    (cheap enough for per-gate callers, and test monkeypatching is picked up
    immediately)."""
    global _cache
    stack = getattr(_STATE, "stack", None)
    if stack:
        return stack[-1]
    raw = tuple(os.environ.get(v) for v in _ENV_VARS)
    cached_raw, cached_cfg = _cache
    if raw != cached_raw or cached_cfg is None:
        cached_cfg = RuntimeConfig.from_env()
        _cache = (raw, cached_cfg)
    return cached_cfg


@contextlib.contextmanager
def use_config(cfg: Optional[RuntimeConfig]) -> Iterator[None]:
    """Thread-locally pin :func:`current_config` to ``cfg`` for the duration
    of the block. ``None`` is a no-op (callers without an explicit config
    stay on the env fallback without branching)."""
    if cfg is None:
        yield
        return
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(cfg)
    try:
        yield
    finally:
        stack.pop()
