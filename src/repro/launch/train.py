"""End-to-end training driver (example application + fault-tolerance demo).

Trains any registered architecture on the synthetic resumable pipeline:

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

* checkpoints (atomic, async, keep-k) every ``--ckpt-every`` steps,
* auto-resumes from the latest checkpoint in --ckpt-dir (bitwise-identical
  continuation: the pipeline is a pure function of (seed, step)),
* ``--simulate-failure N`` aborts the process at step N to exercise the
  restart path (the fault-tolerance test uses this).

On CPU use --reduced (a ~1-3M-param same-family config). On a real pod the
full config + mesh shardings from repro.sharding apply unchanged.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

from ..configs import get_config
from ..data.pipeline import TokenPipeline
from ..models import init_params
from ..train import AdamWConfig, Checkpointer, adamw_init, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-async", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # keep the smoke seq length inside the windowed archs' horizon
    cfg = dataclasses.replace(cfg, remat=False)

    pipe = TokenPipeline(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq,
        global_batch=args.batch,
        seed=args.seed,
        d_model=cfg.d_model,
        mode=cfg.input_mode,
        n_prefix=cfg.n_prefix,
    )
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, args.grad_accum))

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    start_step = 0

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        start_step, state = ckpt.restore(
            None, {"params": params, "opt": opt_state, "meta": {}}
        )
        params, opt_state = state["params"], state["opt"]
        print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"lr {float(metrics['lr']):.2e} gn {float(metrics['grad_norm']):.2f} "
                f"({(time.time()-t0):.1f}s)",
                flush=True,
            )
        next_step = step + 1
        if ckpt is not None and (
            next_step % args.ckpt_every == 0 or next_step == args.steps
        ):
            state = {"params": params, "opt": opt_state, "meta": {"arch": args.arch}}
            if args.ckpt_async:
                ckpt.save_async(next_step, state)
            else:
                ckpt.save(next_step, state)
        if args.simulate_failure is not None and next_step >= args.simulate_failure:
            print(f"[failure-sim] aborting at step {next_step}", flush=True)
            return 17
    if ckpt is not None:
        ckpt.wait()
    print(
        f"final: loss[first 5]={np.mean(losses[:5]):.4f} "
        f"loss[last 5]={np.mean(losses[-5:]):.4f} steps={args.steps}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
