"""Perf hillclimb harness: hypothesis -> change -> re-lower -> validate.

Runs a named set of config-level variants against a (arch x shape x mesh)
cell, re-deriving the roofline terms per variant, and writes
artifacts/perf_<arch>_<shape>.json for the EXPERIMENTS.md §Perf log.

Variants are expressed as ArchConfig field overrides (the dry-run path
rebuilds sharding rules from the config, so e.g. MoE capacity policies and
remat changes flow through to the compiled collectives).

  PYTHONPATH=src python -m repro.launch.hillclimb --arch mixtral_8x7b \
      --shape train_4k --variants baseline,remat_off,cap_full,cap_reflex
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

VARIANTS = {
    # name -> (overrides dict, hypothesis string)
    "baseline": ({}, "paper-faithful baseline (remat on, const capacity 1.25)"),
    "remat_off": (
        {"remat": False},
        "remat recomputes the fwd pass: dropping it cuts HLO FLOPs ~25% "
        "(t_compute) at the cost of activation memory",
    ),
    "cap_full": (
        {"capacity_policy": "full"},
        "fully-'oblivious' MoE capacity (C=tokens): upper-bounds the EP "
        "dispatch volume — expect collective/memory terms to balloon ~E/topk x",
    ),
    "cap_const_1_0": (
        {"capacity_factor": 1.0},
        "trim capacity to the balanced load exactly (eta=0, 'revealed' "
        "analogue): dispatch volume down 20% vs cf=1.25",
    ),
    "cap_reflex_tlap": (
        {"capacity_policy": "reflex_tlap"},
        "Reflex TLap slack: near-balanced capacity + DP-style headroom — "
        "dispatch volume within a few % of cf=1.0 with drop protection",
    ),
    "cap_reflex_beta": (
        {"capacity_policy": "reflex_beta"},
        "Reflex Beta(2,6) slack (25% of free space): between const and full",
    ),
    "ce_einsum": (
        {"ce_impl": "einsum"},
        "cross-entropy via one-hot einsum keeps vocab-sharded logits local "
        "(reduce over vocab shards) instead of all-gathering (B,S,V) logits",
    ),
    "no_zero1": (
        {"zero1": False},
        "ZeRO-1 moment sharding off: fewer spec constraints, more HBM/device",
    ),
    "moe_gather": (
        {"moe_impl": "gather"},
        "one-hot dispatch matmuls cost 2*T*E*C*D flops (>> expert FFNs); "
        "gather/scatter dispatch keeps only FFN flops — expect t_compute to "
        "collapse to ~active-param matmuls",
    ),
    "moe_gather_reflex": (
        {"moe_impl": "gather", "capacity_policy": "reflex_tlap"},
        "gather dispatch + Reflex TLap capacity: compound the flop fix with "
        "a ~20% dispatch-buffer trim (collective + memory terms)",
    ),
    "mla_rank_shard": (
        {"mla_shard": "rank"},
        "MLA up-projections sharded on latent rank (contraction) instead of "
        "per-head features: one psum per projection replaces the per-head "
        "feature reshards that SPMD resolves by full rematerialization",
    ),
    "constrain_acts": (
        {"constrain_acts": True},
        "pin the residual stream to (dp, None, None): stops attention-internal "
        "shardings from leaking and forcing involuntary full replication",
    ),
    "acts_and_rank": (
        {"constrain_acts": True, "mla_shard": "rank"},
        "combine the two sharding fixes",
    ),
    "acts_and_gather": (
        {"constrain_acts": True, "moe_impl": "gather"},
        "combine residual pinning with gather dispatch",
    ),
    "gather_ce_einsum": (
        {"moe_impl": "gather", "ce_impl": "einsum"},
        "after the dispatch fix the cell is collective-bound: the vocab-"
        "sharded logits gather in CE is the next suspect — einsum CE keeps "
        "the (B,S,V) logits local",
    ),
    "gather_no_remat": (
        {"moe_impl": "gather", "remat": False},
        "with dispatch fixed, remat's fwd recompute is a real fraction of "
        "t_compute/t_memory again",
    ),
    "rank_no_remat": (
        {"mla_shard": "rank", "remat": False},
        "memory-bound after the collective fix: drop remat's recompute reads",
    ),
    "rank_ce_einsum": (
        {"mla_shard": "rank", "ce_impl": "einsum"},
        "prefill logits over 73k vocab: einsum CE avoids gathering them",
    ),
    "decode_bf16_scores": (
        {"decode_score_dtype": "bf16"},
        "decode is memory-bound on the (B,H,32k) f32 score intermediates: "
        "bf16 scores + additive mask halve the dominant traffic",
    ),
    "rank_chunked": (
        {"mla_shard": "rank", "attn_impl": "chunked"},
        "dense 32k x 32k scores need ~700 GB/device of temps (memory_analysis "
        "— does NOT fit HBM): flash-style online-softmax chunking keeps only "
        "(S, chunk) tiles live; MLA K/V built per-chunk from the latent",
    ),
    "chunked_only": (
        {"attn_impl": "chunked"},
        "chunked attention alone (without the MLA rank-sharding fix)",
    ),
    "gather_chunked": (
        {"moe_impl": "gather", "attn_impl": "chunked", "remat": False},
        "compose all confirmed wins for the MoE train cell",
    ),
    "sp_only": (
        {"attn_sp": True},
        "40 heads % 16 != 0 leaves (B,H,S,S) scores REPLICATED (651 GiB/dev "
        "temps): shard query rows over 'model' (S always divides) — expect "
        "temp ~ /16",
    ),
    "sp_chunked_rank": (
        {"attn_sp": True, "attn_impl": "chunked", "mla_shard": "rank"},
        "compose: SP query sharding + flash-chunked tiles + latent-rank TP — "
        "target: fits 16 GB HBM",
    ),
    "sp_chunked": (
        {"attn_sp": True, "attn_impl": "chunked"},
        "SP + chunked without the MLA rank fix (ablation)",
    ),
    "kv_int8": (
        {"kv_quant": True},
        "decode reads the whole KV cache per token: int8 cache (+per-pos/head "
        "bf16 scales) halves that dominant traffic; logit err < 0.03, argmax "
        "agreement 100% in tests",
    ),
    "kv_int8_bf16": (
        {"kv_quant": True, "decode_score_dtype": "bf16"},
        "compose int8 cache with bf16 score tensors",
    ),
}


def main() -> None:
    from .dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out-dir", default="artifacts")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    out_path = os.path.join(args.out_dir, f"perf_{args.arch}_{args.shape}.json")
    results = []
    if os.path.exists(out_path):
        results = json.load(open(out_path))

    for name in args.variants.split(","):
        if any(r["variant"] == name for r in results):
            continue
        overrides, hypothesis = VARIANTS[name]
        t0 = time.time()
        row = run_cell(args.arch, args.shape, args.multi_pod, opt_overrides=overrides or None)
        row["variant"] = name
        row["hypothesis"] = hypothesis
        row["wall_s"] = time.time() - t0
        results.append(row)
        if row["status"] == "ok":
            temp = ""
            ma = row.get("memory_analysis") or ""
            import re as _re

            m = _re.search(r"temp_size_in_bytes=(\d+)", ma)
            if m:
                temp = f" temp={int(m.group(1))/2**30:.1f}GiB"
            print(
                f"[{name:>16}] tc={row['t_compute_s']:.3e} tm={row['t_memory_s']:.3e} "
                f"tx={row['t_collective_s']:.3e} bottleneck={row['bottleneck']} "
                f"frac={row['roofline_fraction']:.4f}{temp}",
                flush=True,
            )
        else:
            print(f"[{name:>16}] {row['status']}: {row.get('error','')[:200]}", flush=True)
        json.dump(results, open(out_path, "w"), indent=1)


if __name__ == "__main__":
    main()
