"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch x shape x mesh), TPU v5e constants:

    t_compute    = HLO_FLOPs       / (chips * 197e12)      [bf16 peak]
    t_memory     = HLO_bytes       / (chips * 819e9)       [HBM BW]
    t_collective = collective_bytes / (chips * 50e9)       [per-link ICI]

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. collective_bytes is
NOT in cost_analysis: we parse the optimized HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async ``-start`` counted, ``-done`` skipped).

MODEL_FLOPS (the "useful" compute) = 6*N*D for training (N = active params,
D = tokens) and 2*N*B for one decode token; the ratio MODEL_FLOPS/HLO_FLOPs
exposes remat recompute and dispatch/padding waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?([%\w.\-]+)\s*=\s*(.+)$")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string (handles tuples by summing)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in the optimized module.

    Works line-wise: build name->shape from definitions, then resolve each
    collective's operand names.
    """
    shapes: Dict[str, str] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        # rhs starts with the result shape
        sp = rhs.find(" ")
        shapes[name.lstrip("%")] = rhs[: sp if sp > 0 else len(rhs)]

    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    op_re = re.compile(
        r"\s(" + "|".join(_COLLECTIVES) + r")(-start)?\(([^)]*)\)"
    )
    for ln in lines:
        if "-done(" in ln:
            continue  # async completion: counted at -start
        m = op_re.search(ln)
        if not m:
            continue
        kind, _, operands = m.groups()
        total = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            # Two operand spellings across jaxlib versions: a bare name
            # ("%foo") resolved via the definition table, or an inline-typed
            # operand ("f32[4,32]{1,0} %foo") whose shape is right there.
            head = op.split(" ")[0]
            if _SHAPE_RE.search(head):
                total += shape_bytes(head)
            elif head in shapes:
                total += shape_bytes(shapes[head])
        count_by[kind] += 1
        bytes_by[kind] += total
    return CollectiveStats(bytes_by, count_by)


def cost_analysis_of(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def memory_analysis_of(compiled) -> Optional[str]:
    try:
        ma = compiled.memory_analysis()
        return str(ma)
    except Exception:
        return None


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: Dict[str, int]
    collective_counts: Dict[str, int]
    model_flops: float
    bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term-bound step time that is useful
        compute: (model_flops / (chips*peak)) / max(t_compute, t_mem, t_coll)."""
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound > 0 else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_breakdown": self.collectives,
            "collective_counts": self.collective_counts,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_device": self.bytes_per_device,
        }


def analytic_bytes_for(cfg, shape_name: str) -> float:
    """First-principles HBM-traffic lower-bound model (sanity column next to
    cost_analysis's 'bytes accessed', which on the CPU backend over-counts
    unfused temporaries):

    train:   params fwd+bwd reads (2x2B) + grad write/read (2x4B) +
             AdamW moments read+write (4x4B) + param write (2B)
             + activations ~ 2 passes x ~12 intermediate tensors x B*S*d x 2B
    prefill: params read (2B) + activations 1 pass
    decode:  params read (2B) + full KV/state cache read (2B)
    """
    from ..configs.shapes import SHAPE_DEFS

    n = cfg.param_count()
    d = SHAPE_DEFS[shape_name]
    if d["step"] == "train":
        tok = d["seq"] * d["batch"]
        act = 2 * 12 * tok * cfg.d_model * 2.0 * cfg.n_layers
        return n * (2 * 2 + 2 * 4 + 4 * 4 + 2) + act
    if d["step"] == "prefill":
        tok = d["seq"] * d["batch"]
        return n * 2 + 12 * tok * cfg.d_model * 2.0 * cfg.n_layers
    # decode: weights + cache traffic dominate
    import jax

    from ..models import init_caches

    caches = jax.eval_shape(lambda: init_caches(cfg, d["batch"], d["seq"]))
    cache_bytes = sum(
        int(np_prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(caches)
    )
    n_active = cfg.active_param_count()
    return n_active * 2 + cache_bytes


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def model_flops_for(cfg, shape_name: str) -> float:
    """6*N_active*D (train) / 2*N_active*D (prefill) / 2*N_active*B (decode)."""
    from ..configs.shapes import SHAPE_DEFS

    n_active = cfg.active_param_count()
    d = SHAPE_DEFS[shape_name]
    if d["step"] == "train":
        return 6.0 * n_active * d["seq"] * d["batch"]
    if d["step"] == "prefill":
        return 2.0 * n_active * d["seq"] * d["batch"]
    return 2.0 * n_active * d["batch"]  # one decode token
