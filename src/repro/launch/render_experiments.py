"""Renders the §Roofline table (and per-arch bottleneck sentences) from
artifacts/dryrun.json into EXPERIMENTS.md (replacing the ROOFLINE_TABLE
marker), and the §Perf log from artifacts/perf_*.json (PERF_SECTION marker).

  PYTHONPATH=src python -m repro.launch.render_experiments
"""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
ART = os.path.join(ROOT, "artifacts", "dryrun.json")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")

MOVE_SENTENCES = {
    "compute": "drop remat / raise per-chip batch to amortize — t_compute bound",
    "memory": "fuse/steer XLA to cut HBM round-trips; bigger microbatch raises intensity",
    "collective": "reshard (smaller TP extent / EP capacity trim) to cut moved bytes",
}


def fmt(x: float) -> str:
    return f"{x:.2e}"


def roofline_table(rows) -> str:
    header = (
        "| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
        "MODEL/HLO flops | roofline frac |\n|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | N/A | — | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} | {r['bottleneck']} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.4f} |"
        )
    return header + "\n".join(lines)


def per_arch_summary(rows) -> str:
    """One sentence per (arch, single-pod train/decode): dominant term + what
    would move it."""
    out = ["\n**Per-cell bottleneck notes (single-pod):**\n"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16" or r["status"] != "ok":
            continue
        b = r["bottleneck"]
        out.append(
            f"- `{r['arch']}/{r['shape']}`: {b}-bound "
            f"(tc={fmt(r['t_compute_s'])}, tm={fmt(r['t_memory_s'])}, "
            f"tx={fmt(r['t_collective_s'])}); MODEL_FLOPS/HLO={r['useful_flops_ratio']:.2f} — "
            f"{MOVE_SENTENCES[b]}."
        )
    return "\n".join(out)


def perf_section() -> str:
    files = sorted(glob.glob(os.path.join(ROOT, "artifacts", "perf_*.json")))
    if not files:
        return "_(hillclimb artifacts not yet generated)_"
    parts = []
    for f in files:
        rows = json.load(open(f))
        cell = os.path.basename(f)[len("perf_"):-len(".json")]
        parts.append(f"\n### {cell}\n")
        base = next((r for r in rows if r["variant"] == "baseline" and r["status"] == "ok"), None)
        parts.append(
            "| variant | hypothesis | t_comp | t_mem | t_coll | bound | frac | verdict |\n"
            "|---|---|---|---|---|---|---|---|"
        )
        for r in rows:
            if r["status"] != "ok":
                parts.append(f"| {r['variant']} | {r.get('hypothesis','')[:60]} | ERROR | | | | | |")
                continue
            verdict = ""
            if base and r is not base:
                d = (r["roofline_fraction"] - base["roofline_fraction"]) / max(
                    base["roofline_fraction"], 1e-12
                )
                verdict = f"{'+' if d >= 0 else ''}{d*100:.1f}% frac"
            parts.append(
                f"| {r['variant']} | {r.get('hypothesis','')[:60]} | "
                f"{fmt(r['t_compute_s'])} | {fmt(r['t_memory_s'])} | "
                f"{fmt(r['t_collective_s'])} | {r['bottleneck']} | "
                f"{r['roofline_fraction']:.4f} | {verdict} |"
            )
    return "\n".join(parts)


def main() -> None:
    rows = json.load(open(ART))
    table = roofline_table(rows) + "\n" + per_arch_summary(rows)
    text = open(EXP).read()
    if "<!-- ROOFLINE_TABLE -->" in text:
        text = text.replace("<!-- ROOFLINE_TABLE -->", table, 1)
    else:
        import re

        text = re.sub(
            r"(## §Roofline.*?\n)(\|.*?\n\n|.*?)(## §Perf)",
            lambda m: m.group(1) + table + "\n\n" + m.group(3),
            text,
            flags=re.S,
        )
    if "<!-- PERF_SECTION -->" in text:
        text = text.replace("<!-- PERF_SECTION -->", perf_section(), 1)
    open(EXP, "w").write(text)
    print(f"rendered {sum(r['status']=='ok' for r in rows)} ok / "
          f"{sum(r['status']=='skipped' for r in rows)} skipped cells into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
