"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first import side-effect: the XLA_FLAGS above forces 512 host
placeholder devices before jax locks the device count, so
``make_production_mesh`` can build the single-pod 16x16 (256-chip) and
multi-pod 2x16x16 (512-chip) meshes on CPU.

For every cell:
  * build abstract params / optimizer state / caches (ShapeDtypeStruct only),
  * resolve shardings from repro.sharding.rules,
  * jit(step, in_shardings, out_shardings).lower(...).compile(),
  * record memory_analysis / cost_analysis / parsed collective bytes
    -> roofline terms (launch/roofline.py),
  * append the row to a JSON artifact consumed by EXPERIMENTS.md and
    benchmarks/bench_lm_roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                      # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only
"""
# The VERY FIRST executable lines — before ANY other import (jax locks the
# device count on first init):
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get_config
from ..configs.shapes import SHAPE_NAMES, input_specs, shape_applicable
from ..models import abstract_params
from ..models.lm import loss_fn
from ..serve.serve_step import make_prefill_step, make_serve_step
from ..sharding import batch_specs, cache_specs, make_param_specs, zero1_specs
from ..train.optimizer import AdamWConfig, adamw_init, adamw_update
from .mesh import make_production_mesh, mesh_chips
from .roofline import (
    Roofline,
    analytic_bytes_for,
    cost_analysis_of,
    memory_analysis_of,
    model_flops_for,
    parse_collectives,
)

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts")


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(arch: str, shape_name: str, mesh, opt_overrides: Optional[Dict] = None):
    """Returns (jitted_fn, example_args) for one cell — all abstract."""
    cfg = get_config(arch)
    if opt_overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **opt_overrides)
    spec = input_specs(cfg, shape_name)
    params_sds = abstract_params(cfg)
    p_specs = make_param_specs(cfg, params_sds, mesh)
    p_shard = _named(mesh, p_specs)
    b_shard = _named(mesh, batch_specs(cfg, spec["batch"], mesh))

    if spec["step"] == "train":
        opt_sds = jax.eval_shape(lambda p: adamw_init(p), params_sds)
        moment_specs = (
            zero1_specs(p_specs, params_sds, mesh) if cfg.zero1 else p_specs
        )
        o_specs = {
            "m": moment_specs,
            "v": moment_specs,
            "count": P(),
        }
        o_shard = _named(mesh, o_specs)
        opt_cfg = AdamWConfig()

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True
            )(params)
            new_params, new_state, om = adamw_update(opt_cfg, grads, params, opt_state)
            return new_params, new_state, {"loss": loss, **om}

        fn = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (params_sds, opt_sds, spec["batch"])
    elif spec["step"] == "prefill":
        step = make_prefill_step(cfg)
        fn = jax.jit(step, in_shardings=(p_shard, b_shard), out_shardings=None)
        args = (params_sds, spec["batch"])
    else:  # decode
        step = make_serve_step(cfg)
        c_shard = _named(mesh, cache_specs(cfg, spec["caches"], mesh))
        fn = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        args = (params_sds, spec["caches"], spec["batch"])
    return cfg, fn, args


def _measure(arch, shape_name, mesh, n_layers, opt_overrides) -> Dict:
    """Compile an unrolled reduced-depth variant and return raw costs.

    ``jax.lax.scan`` hides per-iteration costs from cost_analysis (the body is
    counted once), so the roofline numbers are obtained by compiling unrolled
    1-group and 2-group models and extrapolating linearly:
        total = (cost_2g - cost_1g) * n_groups + (2*cost_1g - cost_2g).
    This is exact for the depth-homogeneous stacks used here and keeps the
    per-cell compile cost tiny; the *full* scanned compile still runs as the
    mesh-coherence proof.
    """
    ov = dict(opt_overrides or {})
    ov.update({"n_layers": n_layers, "scan_layers": False})
    _, fn, args = build_cell(arch, shape_name, mesh, ov)
    with mesh:
        compiled = fn.lower(*args).compile()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    ca = cost_analysis_of(compiled)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll.total_bytes),
        "coll_by_kind": coll.bytes_by_kind,
        "coll_counts": coll.count_by_kind,
    }


def extrapolated_costs(arch, shape_name, mesh, opt_overrides=None) -> Dict:
    cfg = get_config(arch)
    period = cfg.pattern_period
    c1 = _measure(arch, shape_name, mesh, period, opt_overrides)
    c2 = _measure(arch, shape_name, mesh, 2 * period, opt_overrides)
    g = cfg.n_layers // period

    def lin(k):
        body = c2[k] - c1[k]
        fixed = 2 * c1[k] - c2[k]
        return max(body, 0.0) * g + max(fixed, 0.0)

    by_kind = {
        k: max(c2["coll_by_kind"][k] - c1["coll_by_kind"][k], 0) * g
        + max(2 * c1["coll_by_kind"][k] - c2["coll_by_kind"][k], 0)
        for k in c1["coll_by_kind"]
    }
    counts = {
        k: max(c2["coll_counts"][k] - c1["coll_counts"][k], 0) * g
        + max(2 * c1["coll_counts"][k] - c2["coll_counts"][k], 0)
        for k in c1["coll_counts"]
    }
    return {
        "flops": lin("flops"),
        "bytes": lin("bytes"),
        "coll_bytes": lin("coll_bytes"),
        "coll_by_kind": by_kind,
        "coll_counts": counts,
    }


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, opt_overrides: Optional[Dict] = None
) -> Dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": why,
        }
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # 1) the dry-run proof: full-depth scanned compile on the target mesh
        cfg2, fn, args = build_cell(arch, shape_name, mesh, opt_overrides)
        with mesh:
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        t_compile = time.time() - t0
        ma = memory_analysis_of(compiled)
        hlo_lines = compiled.as_text().count("\n")
        # 2) roofline costs via 1g/2g unrolled extrapolation.
        # cost_analysis() on an SPMD-partitioned module reports the
        # PER-DEVICE program; scale by chips to express global costs (the
        # Roofline formulas then divide by chips per the spec).
        costs = extrapolated_costs(arch, shape_name, mesh, opt_overrides)
        chips = mesh_chips(mesh)
        r = Roofline(
            arch=arch,
            shape=shape_name,
            mesh=mesh_name,
            chips=chips,
            hlo_flops=costs["flops"] * chips,
            hlo_bytes=costs["bytes"] * chips,
            collective_bytes=costs["coll_bytes"] * chips,
            collectives={k: v * chips for k, v in costs["coll_by_kind"].items()},
            collective_counts=costs["coll_counts"],
            model_flops=model_flops_for(cfg2, shape_name),
        )
        row = r.row()
        row.update(
            {
                "status": "ok",
                "compile_s": t_compile,
                "total_s": time.time() - t0,
                "memory_analysis": ma,
                "hlo_lines": hlo_lines,
                "analytic_bytes": analytic_bytes_for(cfg2, shape_name),
            }
        )
        return row
    except Exception as e:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
            "compile_s": time.time() - t0,
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else SHAPE_NAMES
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    rows = []
    if args.append and os.path.exists(args.out):
        rows = json.load(open(args.out))

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if any((r["arch"], r["shape"], r["mesh"]) == key for r in rows):
                    continue
                row = run_cell(arch, shape, mp)
                rows.append(row)
                status = row["status"]
                extra = ""
                if status == "ok":
                    extra = (
                        f"compile={row['compile_s']:.1f}s flops={row['hlo_flops']:.3g} "
                        f"coll={row['collective_bytes']:.3g}B bottleneck={row['bottleneck']}"
                    )
                elif status == "error":
                    extra = row["error"][:160]
                else:
                    extra = row["reason"][:80]
                print(f"[{status:>7}] {arch:<20} {shape:<12} {key[2]:<8} {extra}", flush=True)
                json.dump(rows, open(args.out, "w"), indent=1)

    n_ok = sum(r["status"] == "ok" for r in rows)
    n_err = sum(r["status"] == "error" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
