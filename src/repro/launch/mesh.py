"""Production meshes.

Defined as a FUNCTION so importing this module never touches jax device
state; only launch/dryrun.py (which sets XLA_FLAGS first) materializes the
512-device placeholder topology.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_production_mesh", "mesh_chips"]


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present — "
            "run under launch/dryrun.py (it forces 512 host devices)"
        )
    return jax.make_mesh(shape, axes, devices=devices)


def mesh_chips(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
