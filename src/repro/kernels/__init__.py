"""Pallas TPU kernels for the MPC engine's compute hot spots.

Five kernels cover the protocol-local inner loops that dominate the engine's
arithmetic (the *communication* between parties is JAX-level and cannot live
inside a kernel — on a real 3-TPU deployment each kernel body runs per-party
between round boundaries; in this simulation the 3-share axis is local, so the
fused body is exactly the simulation hot loop):

* ``rss_gate``      — cross-term + re-randomization of the 1-round AND / mul
                      gate (every comparison circuit bottoms out here)
* ``ks_prefix``     — an entire Kogge-Stone borrow/carry prefix (all log2 k
                      levels, both independent AND pairs per level) plus the
                      equality AND-fold tree, in ONE launch instead of one
                      ``rss_gate`` launch per level
* ``a2b_fused``     — the full arithmetic->boolean conversion (two chained
                      Kogge-Stone adders, 12 gate rounds) and the fused
                      ``bit2a`` double-multiply, each in ONE launch
* ``shuffle_gather``— permutation row-gather (the secure shuffle's data move)
* ``bitonic_stage`` — fused conditional-swap of one sort stage across all
                      payload columns

Each kernel directory has ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd wrapper with padding + interpret-mode switch), and
``ref.py`` (pure-jnp oracle). CPU validation uses ``interpret=True``; the
BlockSpecs are sized for TPU v5e VMEM (~16 MiB/core).

Switches
--------
The defaults come from :func:`repro.config.current_config` (``use_pallas`` /
``fuse_circuits``, with ``REPRO_USE_PALLAS`` / ``REPRO_FUSE_CIRCUITS`` as the
env fallback parsed in :mod:`repro.config`). ``REPRO_FUSE_CIRCUITS=0`` keeps
kernels on but forces the gate-by-gate circuit path (used by parity tests and
the fused-vs-unfused benchmark). Both can be overridden per-thread with
:func:`override_kernels` / :func:`override_fusion` so tests and benches work
without mutating the environment — the Engine uses exactly these overrides to
apply an explicit ``RuntimeConfig`` for the duration of an execution.

Launch accounting
-----------------
Every ``ops.py`` wrapper records the kernel dispatches it issues from Python
(trace-time accounting: a jit-cached re-execution of an enclosing function is
not re-counted — the engine's protocol layer runs eagerly by default, where
the count equals real dispatches). ``launch_counts()`` is what
``benchmarks/bench_kernels.py`` uses to demonstrate the fused-kernel launch
reduction.
"""
from __future__ import annotations

import contextlib
import threading
from collections import Counter
from typing import Dict, Iterator, Optional

from repro.config import current_config

_STATE = threading.local()


def kernels_enabled() -> bool:
    ov = getattr(_STATE, "kernels", None)
    return current_config().use_pallas if ov is None else ov


def fusion_enabled() -> bool:
    """True when circuits should route through the single-launch fused
    kernels (requires the kernel layer itself to be enabled)."""
    if not kernels_enabled():
        return False
    ov = getattr(_STATE, "fusion", None)
    return current_config().fuse_circuits if ov is None else ov


@contextlib.contextmanager
def override_kernels(enabled: Optional[bool]) -> Iterator[None]:
    """Thread-locally force the kernel layer on/off (None = env default)."""
    prev = getattr(_STATE, "kernels", None)
    _STATE.kernels = enabled
    try:
        yield
    finally:
        _STATE.kernels = prev


@contextlib.contextmanager
def override_fusion(enabled: Optional[bool]) -> Iterator[None]:
    """Thread-locally force circuit fusion on/off (None = env default)."""
    prev = getattr(_STATE, "fusion", None)
    _STATE.fusion = enabled
    try:
        yield
    finally:
        _STATE.fusion = prev


# -----------------------------------------------------------------------------
# Launch accounting
# -----------------------------------------------------------------------------

def _counter() -> Counter:
    if not hasattr(_STATE, "launches"):
        _STATE.launches = Counter()
    return _STATE.launches


def record_launch(kind: str, n: int = 1) -> None:
    _counter()[kind] += n


def launch_counts() -> Dict[str, int]:
    return dict(_counter())


def total_launches() -> int:
    return sum(_counter().values())


def reset_launch_counts() -> None:
    _counter().clear()
