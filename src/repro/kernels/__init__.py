"""Pallas TPU kernels for the MPC engine's compute hot spots.

Three kernels cover the protocol-local inner loops that dominate the engine's
arithmetic (the *communication* between parties is JAX-level and cannot live
inside a kernel — on a real 3-TPU deployment each kernel body runs per-party
between round boundaries; in this simulation the 3-share axis is local, so the
fused body is exactly the simulation hot loop):

* ``rss_gate``      — cross-term + re-randomization of the 1-round AND / mul
                      gate (every comparison circuit bottoms out here)
* ``shuffle_gather``— permutation row-gather (the secure shuffle's data move)
* ``bitonic_stage`` — fused conditional-swap of one sort stage across all
                      payload columns

Each kernel directory has ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd wrapper with padding + interpret-mode switch), and
``ref.py`` (pure-jnp oracle). CPU validation uses ``interpret=True``; the
BlockSpecs are sized for TPU v5e VMEM (~16 MiB/core).
"""
from __future__ import annotations

import os

_USE_KERNELS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def kernels_enabled() -> bool:
    return _USE_KERNELS
