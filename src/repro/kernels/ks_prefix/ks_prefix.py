"""Pallas kernels: fused Kogge-Stone prefix + equality AND-fold.

The comparison circuits (`lt`, `lt_public`, `ks_add`, and through them `a2b`)
spend all their interactive gates inside one of two loops over XOR-replicated
shares:

* the Kogge-Stone borrow/carry prefix — per level ``d``::

      pg = (p AND (g << d)) ^ alpha_pg      # two independent ANDs,
      pp = (p AND (p << d)) ^ alpha_pp      # batched into one comm round
      g, p = g ^ pg, pp

* the equality AND-fold tree — per level ``d``::

      v = (v AND (v >> d)) ^ alpha

Gate-by-gate execution dispatches one ``rss_gate`` launch per level (5 for a
32-bit word), each doing an HBM round-trip of the full (3, N) share triple.
These kernels run *all* levels in one launch: shares stay resident in VMEM,
the per-level cross-terms + re-randomization are register-level ops, and only
the final ``g`` (resp. folded ``v``) is written back.

The per-level zero-sharings ``alpha`` are PRF-derived *outside* the kernel
(they must match the unfused path bit-for-bit, and communication/randomness
derivation is protocol-level, not launch-level) and streamed in as one stacked
(3, W, N) operand, where W = alpha words across all levels.

Tiling matches ``rss_gate``: lanes blocked at ``BLOCK`` (multiple of 128 for
VPU lane alignment), the 3-share axis whole inside the block. Worst case
(width 64: W = 12) is 3 x 14 x BLOCK x 8 B ~ 2.6 MiB of VMEM at BLOCK=2048 —
inside v5e's ~16 MiB.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048


def _cross_xor(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Party-local AND cross terms: z'_i = (x_i&y_i) ^ (x_i&y_{i+1}) ^
    (x_{i+1}&y_i); static 3-way roll inside VMEM. (Kernel-layer counterpart
    of ``core.sharing._cross_terms_xor``; also used by ``a2b_fused``.)"""
    xn = jnp.roll(x, -1, axis=0)
    yn = jnp.roll(y, -1, axis=0)
    return (x & y) ^ (x & yn) ^ (xn & y)


def _cross_add(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Arithmetic (mul-gate) cross terms: z'_i = x_i*y_i + x_i*y_{i+1} +
    x_{i+1}*y_i."""
    xn = jnp.roll(x, -1, axis=0)
    yn = jnp.roll(y, -1, axis=0)
    return x * y + x * yn + xn * y


def _ks_prefix_kernel(g_ref, p_ref, a_ref, o_ref, *, shifts: Tuple[int, ...]):
    g = g_ref[...]  # (3, BLOCK)
    p = p_ref[...]
    a = a_ref[...]  # (3, 2*len(shifts), BLOCK)
    for lvl, d in enumerate(shifts):
        pg = _cross_xor(p, g << d) ^ a[:, 2 * lvl]
        pp = _cross_xor(p, p << d) ^ a[:, 2 * lvl + 1]
        g = g ^ pg
        p = pp
    o_ref[...] = g


def _and_fold_kernel(v_ref, a_ref, o_ref, *, shifts: Tuple[int, ...]):
    v = v_ref[...]  # (3, BLOCK)
    a = a_ref[...]  # (3, len(shifts), BLOCK)
    for lvl, d in enumerate(shifts):
        v = _cross_xor(v, v >> d) ^ a[:, lvl]
    o_ref[...] = v


@functools.partial(jax.jit, static_argnames=("shifts", "interpret", "block"))
def ks_prefix(
    g: jax.Array,
    p: jax.Array,
    alphas: jax.Array,
    shifts: Tuple[int, ...],
    interpret: bool = True,
    block: int = BLOCK,
) -> jax.Array:
    """All Kogge-Stone levels in one launch.

    g, p: (3, N); alphas: (3, 2*len(shifts), N); N % block == 0 (wrapper
    pads). Returns the final prefix ``g``.
    """
    n = g.shape[1]
    grid = (n // block,)
    spec2 = pl.BlockSpec((3, block), lambda i: (0, i))
    spec3 = pl.BlockSpec((3, alphas.shape[1], block), lambda i: (0, 0, i))
    return pl.pallas_call(
        functools.partial(_ks_prefix_kernel, shifts=shifts),
        grid=grid,
        in_specs=[spec2, spec2, spec3],
        out_specs=spec2,
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=interpret,
    )(g, p, alphas)


@functools.partial(jax.jit, static_argnames=("shifts", "interpret", "block"))
def and_fold(
    v: jax.Array,
    alphas: jax.Array,
    shifts: Tuple[int, ...],
    interpret: bool = True,
    block: int = BLOCK,
) -> jax.Array:
    """The equality circuit's AND-reduce tree in one launch.

    v: (3, N); alphas: (3, len(shifts), N). Returns the folded word (the
    conjunction of all ``width`` bits lands in the LSB; caller masks).
    """
    n = v.shape[1]
    grid = (n // block,)
    spec2 = pl.BlockSpec((3, block), lambda i: (0, i))
    spec3 = pl.BlockSpec((3, alphas.shape[1], block), lambda i: (0, 0, i))
    return pl.pallas_call(
        functools.partial(_and_fold_kernel, shifts=shifts),
        grid=grid,
        in_specs=[spec2, spec3],
        out_specs=spec2,
        out_shape=jax.ShapeDtypeStruct(v.shape, v.dtype),
        interpret=interpret,
    )(v, alphas)
