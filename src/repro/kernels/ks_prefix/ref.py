"""Pure-jnp oracles for the fused Kogge-Stone prefix / AND-fold kernels."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def _cross_xor(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    xn = jnp.roll(x, -1, axis=0)
    yn = jnp.roll(y, -1, axis=0)
    return (x & y) ^ (x & yn) ^ (xn & y)


def _cross_add(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    xn = jnp.roll(x, -1, axis=0)
    yn = jnp.roll(y, -1, axis=0)
    return x * y + x * yn + xn * y


def ks_prefix_ref(
    g: jnp.ndarray, p: jnp.ndarray, alphas: jnp.ndarray, shifts: Tuple[int, ...]
) -> jnp.ndarray:
    """g, p: (3, N); alphas: (3, 2*len(shifts), N)."""
    for lvl, d in enumerate(shifts):
        pg = _cross_xor(p, g << d) ^ alphas[:, 2 * lvl]
        pp = _cross_xor(p, p << d) ^ alphas[:, 2 * lvl + 1]
        g = g ^ pg
        p = pp
    return g


def and_fold_ref(
    v: jnp.ndarray, alphas: jnp.ndarray, shifts: Tuple[int, ...]
) -> jnp.ndarray:
    """v: (3, N); alphas: (3, len(shifts), N)."""
    for lvl, d in enumerate(shifts):
        v = _cross_xor(v, v >> d) ^ alphas[:, lvl]
    return v


def ks_shifts(width: int) -> Tuple[int, ...]:
    """Doubling shift schedule of the Kogge-Stone loop (d = 1, 2, ... < width),
    matching ``circuits._ks_levels`` exactly (including non-power-of-2
    widths)."""
    shifts = []
    d = 1
    while d < width:
        shifts.append(d)
        d *= 2
    return tuple(shifts)


def fold_shifts(width: int) -> Tuple[int, ...]:
    """Halving shift schedule of the equality AND-fold tree (d = width//2,
    ..., 1), matching ``circuits._and_reduce_bits`` exactly."""
    shifts = []
    d = width // 2
    while d >= 1:
        shifts.append(d)
        d //= 2
    return tuple(shifts)
