from .ops import and_fold_fused, ks_levels_fused  # noqa: F401
