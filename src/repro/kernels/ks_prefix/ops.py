"""Protocol-level wrappers for the fused Kogge-Stone / AND-fold kernels.

These are the entry points ``core/circuits.py`` routes through when
``fusion_enabled()``. They own three responsibilities the raw kernels do not:

* **randomness parity** — the per-level zero-sharings are derived with the
  *same* PRF folds as the gate-by-gate path (``prf.fold(base + d)`` per level,
  ``(2,) + lane_shape`` draws for the batched AND pairs), so fused and unfused
  outputs are bit-identical, not merely semantically equal;
* **ledger parity** — each level logs the same ``("and", 1 round, bytes)``
  entry the unfused ``and_`` calls would have logged: communication cost is
  protocol-determined, not launch-determined;
* **shape plumbing** — arbitrary lane shapes are flattened and padded to the
  block size, mirroring ``rss_gate.ops.gate``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .. import record_launch
from ...core.ledger import log_comm
from ...core.prf import PRFSetup, zero_share_xor
from ...core.sharing import BShare
from .ks_prefix import BLOCK, and_fold, ks_prefix
from .ref import fold_shifts, ks_shifts


def _pick_block(n: int, block: int) -> int:
    return min(block, max(128, 1 << (n - 1).bit_length()))


def _flat_pad(arrs, n: int, block: int):
    pad = (-n) % block
    if not pad:
        return arrs
    return [jnp.pad(a, ((0, 0),) * (a.ndim - 1) + ((0, pad),)) for a in arrs]


def ks_levels_fused(
    g: BShare, p: BShare, prf: PRFSetup, width: int, fold_base: int
) -> BShare:
    """All Kogge-Stone levels of ``circuits._ks_levels`` in one kernel launch."""
    ring = g.ring
    shape = g.shape
    shifts: Tuple[int, ...] = ks_shifts(width)
    lanes = g.size

    # Same draws as the unfused _and_pair path: one (2, *lane_shape) XOR
    # zero-sharing per level, alpha[:, 0] for the pg gate, alpha[:, 1] for pp.
    alphas = [
        zero_share_xor(prf.fold(fold_base + d), (2,) + shape, ring) for d in shifts
    ]
    al = jnp.concatenate([a.reshape(3, 2, -1) for a in alphas], axis=1)

    gs = g.shares.reshape(3, -1)
    ps = p.shares.reshape(3, -1)
    n = gs.shape[1]
    if n == 0:  # pallas_call cannot slice 0-lane operands
        from .ref import ks_prefix_ref

        out = ks_prefix_ref(gs, ps, al, shifts)
    else:
        block = _pick_block(n, BLOCK)
        gs, ps, al = _flat_pad([gs, ps, al], n, block)
        record_launch("ks_prefix")
        out = ks_prefix(
            gs, ps, al, shifts,
            interpret=jax.default_backend() != "tpu", block=block,
        )
    for _ in shifts:
        log_comm("and", 1, 2 * lanes * ring.bytes)
    return BShare(out[:, :n].reshape((3,) + shape))


def and_fold_fused(v: BShare, prf: PRFSetup, width: int) -> BShare:
    """The equality AND-reduce tree of ``circuits._and_reduce_bits`` in one
    kernel launch (caller still masks the LSB)."""
    ring = v.ring
    shape = v.shape
    shifts: Tuple[int, ...] = fold_shifts(width)
    lanes = v.size

    alphas = [zero_share_xor(prf.fold(d), shape, ring) for d in shifts]
    al = jnp.stack([a.reshape(3, -1) for a in alphas], axis=1)

    vs = v.shares.reshape(3, -1)
    n = vs.shape[1]
    if n == 0:
        from .ref import and_fold_ref

        out = and_fold_ref(vs, al, shifts)
    else:
        block = _pick_block(n, BLOCK)
        vs, al = _flat_pad([vs, al], n, block)
        record_launch("and_fold")
        out = and_fold(
            vs, al, shifts, interpret=jax.default_backend() != "tpu", block=block
        )
    for _ in shifts:
        log_comm("and", 1, lanes * ring.bytes)
    return BShare(out[:, :n].reshape((3,) + shape))
