"""Pallas kernel: permutation row-gather (secure-shuffle apply).

out[r, :] = table[perm[r], :] for a (N, C) share plane. Each secure-shuffle
hop applies one permutation to every column of the table, three hops per
shuffle — the Resizer's dominant data movement (Table 1: O(N*M) bytes).

TPU adaptation (vs. the CPU pointer-chase in MP-SPDZ): the permutation vector
rides in scalar-prefetch SMEM (``PrefetchScalarGridSpec``), output rows are
blocked at ``BLOCK_ROWS``; the source table is staged whole into VMEM while it
fits (N*C*4B <= ~8 MiB — always true for the Resizer's post-trim tables), so
each block is a vectorized VMEM take rather than N scattered HBM touches.
Larger tables fall back to the XLA gather path in ops.py (documented).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 256


def _gather_kernel(perm_ref, x_ref, o_ref, *, block_rows: int):
    i = pl.program_id(0)
    idx = perm_ref[pl.dslice(i * block_rows, block_rows)]  # SMEM scalars
    o_ref[...] = jnp.take(x_ref[...], idx, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def shuffle_gather(
    table: jax.Array,  # (N, C) one share plane
    perm: jax.Array,  # (N,) int32
    interpret: bool = True,
    block_rows: int = BLOCK_ROWS,
) -> jax.Array:
    n, c = table.shape
    grid = (n // block_rows,)
    return pl.pallas_call(
        functools.partial(_gather_kernel, block_rows=block_rows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((n, c), lambda i, *_: (0, 0))],  # whole table
            out_specs=pl.BlockSpec((block_rows, c), lambda i, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, c), table.dtype),
        interpret=interpret,
    )(perm, table)
