"""jit'd wrapper: pads rows to the block size (identity-mapping pad indices so
padded rows gather from themselves), falls back to XLA gather for tables too
large for a whole-table VMEM stage."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import record_launch
from .ref import shuffle_gather_ref
from .shuffle_gather import BLOCK_ROWS, shuffle_gather

VMEM_LIMIT_BYTES = 8 * 2**20


def gather_rows(table, perm, use_kernel: bool = True, block_rows: int = BLOCK_ROWS):
    """table: (N, C); perm: (N,) int32. Returns table[perm]."""
    n, c = table.shape
    if not use_kernel or table.size == 0 or table.size * table.dtype.itemsize > VMEM_LIMIT_BYTES:
        return shuffle_gather_ref(table, perm)
    record_launch("shuffle_gather")
    block_rows = min(block_rows, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % block_rows
    if pad:
        table_p = jnp.pad(table, ((0, pad), (0, 0)))
        perm_p = jnp.concatenate(
            [perm.astype(jnp.int32), jnp.arange(n, n + pad, dtype=jnp.int32)]
        )
    else:
        table_p, perm_p = table, perm.astype(jnp.int32)
    out = shuffle_gather(
        table_p, perm_p, interpret=jax.default_backend() != "tpu", block_rows=block_rows
    )
    return out[:n]
