"""Pure-jnp oracle for shuffle_gather."""
from __future__ import annotations

import jax.numpy as jnp


def shuffle_gather_ref(table, perm):
    return jnp.take(table, perm, axis=0)
