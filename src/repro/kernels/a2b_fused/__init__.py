from .ops import a2b_fused, bit2a_fused  # noqa: F401
