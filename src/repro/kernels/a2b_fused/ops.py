"""Protocol-level wrappers for the fused share-conversion kernels.

Entry points for ``core/circuits.py`` when ``fusion_enabled()``. Randomness
and ledger parity with the gate-by-gate path are exact (same PRF folds, same
per-gate log entries); see ``ks_prefix/ops.py`` for the rationale.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .. import record_launch
from ...core.ledger import fused_scope, log_comm
from ...core.prf import PRFSetup, zero_share_add, zero_share_xor
from ...core.sharing import AShare, BShare
from ..ks_prefix.ops import _flat_pad, _pick_block
from ..ks_prefix.ref import ks_shifts
from .a2b_fused import BLOCK, a2b_kernel, bit2a_kernel


def _ks_add_alphas(prf: PRFSetup, shape, ring, shifts: Tuple[int, ...]):
    """Alpha words of one fused Kogge-Stone adder, in kernel packing order
    [init, lvl0_pg, lvl0_pp, lvl1_pg, ...] — same PRF folds as the unfused
    ``ks_add`` (init gate: fold(11); level d: fold(200 + d))."""
    words = [zero_share_xor(prf.fold(11), shape, ring).reshape(3, 1, -1)]
    for d in shifts:
        a = zero_share_xor(prf.fold(200 + d), (2,) + shape, ring)
        words.append(a.reshape(3, 2, -1))
    return jnp.concatenate(words, axis=1)


def a2b_fused(x: AShare, prf: PRFSetup, width: int) -> BShare:
    """Full arithmetic -> boolean conversion in ONE kernel launch (vs
    2 * (1 + log2 k) gate launches): trivial leg sharing + two chained
    Kogge-Stone adders, all VMEM-resident."""
    ring = x.ring
    shape = x.shape
    shifts = ks_shifts(width)
    levels = width.bit_length() - 1  # ledger round count (matches ks_add)
    lanes = x.size

    al = jnp.concatenate(
        [
            _ks_add_alphas(prf.fold(31), shape, ring, shifts),
            _ks_add_alphas(prf.fold(32), shape, ring, shifts),
        ],
        axis=1,
    )

    xs = x.shares.reshape(3, -1)
    n = xs.shape[1]
    if n == 0:  # pallas_call cannot slice 0-lane operands
        from .ref import a2b_ref

        out = a2b_ref(xs, al, shifts)
    else:
        block = _pick_block(n, BLOCK)
        xs, al = _flat_pad([xs, al], n, block)
        record_launch("a2b_fused")
        out = a2b_kernel(
            xs, al, shifts, interpret=jax.default_backend() != "tpu", block=block
        )
    # Ledger: identical to the two unfused ks_add invocations.
    for _ in range(2):
        with fused_scope("ks_add", rounds=1 + levels):
            log_comm("and", 1, lanes * ring.bytes)
            for _d in shifts:
                log_comm("and", 1, 2 * lanes * ring.bytes)
    return BShare(out[:, :n].reshape((3,) + shape))


def bit2a_fused(b: BShare, prf: PRFSetup) -> AShare:
    """Both dependent ring multiplications of the bit injection in ONE
    launch (vs 2 ``rss_gate`` dispatches)."""
    ring = b.ring
    shape = b.shape
    lanes = b.size

    al = jnp.stack(
        [
            zero_share_add(prf.fold(21), shape, ring).reshape(3, -1),
            zero_share_add(prf.fold(22), shape, ring).reshape(3, -1),
        ],
        axis=1,
    )

    bs = b.shares.reshape(3, -1)
    n = bs.shape[1]
    if n == 0:
        from .ref import bit2a_ref

        out = bit2a_ref(bs, al)
    else:
        block = _pick_block(n, BLOCK)
        bs, al = _flat_pad([bs, al], n, block)
        record_launch("bit2a_fused")
        out = bit2a_kernel(bs, al, interpret=jax.default_backend() != "tpu", block=block)
    for _ in range(2):
        log_comm("mul", 1, lanes * ring.bytes)
    return AShare(out[:, :n].reshape((3,) + shape))
