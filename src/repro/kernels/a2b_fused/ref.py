"""Pure-jnp oracles for the fused share-conversion kernels."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from ..ks_prefix.ref import _cross_add, _cross_xor


def _trivial_legs(xs: jnp.ndarray):
    z = jnp.zeros_like(xs[0:1])
    l0 = jnp.concatenate([xs[0:1], z, z], axis=0)
    l1 = jnp.concatenate([z, xs[1:2], z], axis=0)
    l2 = jnp.concatenate([z, z, xs[2:3]], axis=0)
    return l0, l1, l2


def ks_add_ref(
    x: jnp.ndarray, y: jnp.ndarray, a: jnp.ndarray, shifts: Tuple[int, ...]
) -> jnp.ndarray:
    g = _cross_xor(x, y) ^ a[:, 0]
    p = x ^ y
    for lvl, d in enumerate(shifts):
        pg = _cross_xor(p, g << d) ^ a[:, 1 + 2 * lvl]
        pp = _cross_xor(p, p << d) ^ a[:, 2 + 2 * lvl]
        g = g ^ pg
        p = pp
    return x ^ y ^ (g << 1)


def a2b_ref(
    xs: jnp.ndarray, alphas: jnp.ndarray, shifts: Tuple[int, ...]
) -> jnp.ndarray:
    """xs: (3, N) arithmetic shares; alphas: (3, 2*(1+2L), N)."""
    l0, l1, l2 = _trivial_legs(xs)
    words = 1 + 2 * len(shifts)
    s = ks_add_ref(l0, l1, alphas[:, :words], shifts)
    return ks_add_ref(s, l2, alphas[:, words:], shifts)


def bit2a_ref(bs: jnp.ndarray, alphas: jnp.ndarray) -> jnp.ndarray:
    """bs: (3, N) boolean shares (LSB used); alphas: (3, 2, N) additive."""
    b = bs & bs.dtype.type(1)
    a0, a1, a2 = _trivial_legs(b)
    two = bs.dtype.type(2)
    t = a0 + a1 - two * (_cross_add(a0, a1) + alphas[:, 0])
    return t + a2 - two * (_cross_add(t, a2) + alphas[:, 1])
