"""Pallas kernels: single-launch share conversions.

``a2b`` (arithmetic -> boolean) is the most launch-hungry circuit in the
engine: boolean-share each arithmetic leg trivially, then run TWO chained
Kogge-Stone adders — gate-by-gate that is 2 x (1 + log2 k) = 12 ``rss_gate``
dispatches for 32-bit words, and the Resizer's parallel noise addition runs
one per tuple batch. The ``a2b_fused`` kernel executes the whole conversion
(leg construction, both adders, all prefix levels) in one launch: the share
triple is read from HBM once and written once.

``bit2a_fused`` fuses the two dependent ring multiplications of the bit
injection b = b0 ^ b1 ^ b2 emulated arithmetically (u ^ v = u + v - 2uv),
halving the launches of ``bit2a`` / ``b2a``.

As everywhere in this kernel layer, the PRF-derived re-randomization words
are computed *outside* and streamed in (randomness/communication is protocol
state, not launch state): ``alphas`` packs, per Kogge-Stone adder, [1 init
gate word, 2 words per level], i.e. 2*(1 + 2*L) words total for a2b.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..ks_prefix.ks_prefix import _cross_add, _cross_xor

BLOCK = 2048


def _ks_add_body(
    x: jnp.ndarray, y: jnp.ndarray, a: jnp.ndarray, shifts: Tuple[int, ...]
) -> jnp.ndarray:
    """One full boolean Kogge-Stone addition; a: (3, 1 + 2*len(shifts), B)."""
    g = _cross_xor(x, y) ^ a[:, 0]
    p = x ^ y
    for lvl, d in enumerate(shifts):
        pg = _cross_xor(p, g << d) ^ a[:, 1 + 2 * lvl]
        pp = _cross_xor(p, p << d) ^ a[:, 2 + 2 * lvl]
        g = g ^ pg
        p = pp
    return x ^ y ^ (g << 1)


def _trivial_legs(xs: jnp.ndarray):
    """Boolean share (x_i, 0, 0)/(0, x_i, 0)/(0, 0, x_i) of each arithmetic
    leg — locally constructible, no communication."""
    z = jnp.zeros_like(xs[0:1])
    l0 = jnp.concatenate([xs[0:1], z, z], axis=0)
    l1 = jnp.concatenate([z, xs[1:2], z], axis=0)
    l2 = jnp.concatenate([z, z, xs[2:3]], axis=0)
    return l0, l1, l2


def _a2b_kernel(x_ref, a_ref, o_ref, *, shifts: Tuple[int, ...]):
    xs = x_ref[...]  # (3, BLOCK) arithmetic share triple
    a = a_ref[...]  # (3, 2*(1+2L), BLOCK)
    l0, l1, l2 = _trivial_legs(xs)
    words = 1 + 2 * len(shifts)
    s = _ks_add_body(l0, l1, a[:, :words], shifts)
    o_ref[...] = _ks_add_body(s, l2, a[:, words:], shifts)


def _bit2a_kernel(b_ref, a_ref, o_ref):
    b = b_ref[...]
    bs = b & b.dtype.type(1)  # LSB of each boolean leg
    a = a_ref[...]  # (3, 2, BLOCK) additive zero-sharings
    a0, a1, a2 = _trivial_legs(bs)
    two = b.dtype.type(2)
    t = a0 + a1 - two * (_cross_add(a0, a1) + a[:, 0])
    o_ref[...] = t + a2 - two * (_cross_add(t, a2) + a[:, 1])


@functools.partial(jax.jit, static_argnames=("shifts", "interpret", "block"))
def a2b_kernel(
    xs: jax.Array,
    alphas: jax.Array,
    shifts: Tuple[int, ...],
    interpret: bool = True,
    block: int = BLOCK,
) -> jax.Array:
    """xs: (3, N) arithmetic shares; alphas: (3, 2*(1+2L), N)."""
    n = xs.shape[1]
    grid = (n // block,)
    spec2 = pl.BlockSpec((3, block), lambda i: (0, i))
    spec3 = pl.BlockSpec((3, alphas.shape[1], block), lambda i: (0, 0, i))
    return pl.pallas_call(
        functools.partial(_a2b_kernel, shifts=shifts),
        grid=grid,
        in_specs=[spec2, spec3],
        out_specs=spec2,
        out_shape=jax.ShapeDtypeStruct(xs.shape, xs.dtype),
        interpret=interpret,
    )(xs, alphas)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def bit2a_kernel(
    bs: jax.Array,
    alphas: jax.Array,
    interpret: bool = True,
    block: int = BLOCK,
) -> jax.Array:
    """bs: (3, N) boolean shares (LSB used); alphas: (3, 2, N) additive."""
    n = bs.shape[1]
    grid = (n // block,)
    spec2 = pl.BlockSpec((3, block), lambda i: (0, i))
    spec3 = pl.BlockSpec((3, 2, block), lambda i: (0, 0, i))
    return pl.pallas_call(
        _bit2a_kernel,
        grid=grid,
        in_specs=[spec2, spec3],
        out_specs=spec2,
        out_shape=jax.ShapeDtypeStruct(bs.shape, bs.dtype),
        interpret=interpret,
    )(bs, alphas)
