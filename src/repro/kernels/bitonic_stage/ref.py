"""Pure-jnp oracle for the fused bitonic conditional swap."""
from __future__ import annotations

import jax.numpy as jnp


def bitonic_swap_ref(mask, own, other, alpha):
    m = mask[:, None, :]
    d = own ^ other
    mn = jnp.roll(m, -1, axis=0)
    dn = jnp.roll(d, -1, axis=0)
    z = (m & d) ^ (m & dn) ^ (mn & d) ^ alpha
    return own ^ z
