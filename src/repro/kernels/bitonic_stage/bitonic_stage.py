"""Pallas kernel: fused conditional-swap for one bitonic compare-exchange
stage, across all payload columns at once.

After the (interactive) swap-decision bit is known in shared form, every
column c of the table must be updated as

    out_i = own_i ^ cross_terms(mask, own ^ other)_i ^ alpha_i

(the local body of the oblivious select). Unfused, this is 4 elementwise ops x
C columns x 3 shares of HBM traffic per stage — and a sort runs
O(log^2 N) stages. The kernel fuses the whole per-stage update into one VMEM
pass over a (3, C, BLOCK) tile.

Partner values ("other") are pre-gathered by the caller (the partner index
i ^ j is a static XOR shuffle that XLA folds into the surrounding program).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _swap_kernel(mask_ref, own_ref, other_ref, alpha_ref, o_ref):
    mask = mask_ref[...]  # (3, 1, BLOCK) swap-decision full-width mask
    own = own_ref[...]  # (3, C, BLOCK)
    other = other_ref[...]
    alpha = alpha_ref[...]
    d = own ^ other
    mn = jnp.roll(mask, -1, axis=0)
    dn = jnp.roll(d, -1, axis=0)
    z = (mask & d) ^ (mask & dn) ^ (mn & d) ^ alpha  # AND-gate cross terms
    o_ref[...] = own ^ z


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def bitonic_swap(
    mask: jax.Array,  # (3, N)
    own: jax.Array,  # (3, C, N)
    other: jax.Array,  # (3, C, N)
    alpha: jax.Array,  # (3, C, N)
    interpret: bool = True,
    block: int = BLOCK,
) -> jax.Array:
    _, c, n = own.shape
    grid = (n // block,)
    col_spec = pl.BlockSpec((3, c, block), lambda i: (0, 0, i))
    mask_spec = pl.BlockSpec((3, 1, block), lambda i: (0, 0, i))
    return pl.pallas_call(
        _swap_kernel,
        grid=grid,
        in_specs=[mask_spec, col_spec, col_spec, col_spec],
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct(own.shape, own.dtype),
        interpret=interpret,
    )(mask[:, None, :], own, other, alpha)
