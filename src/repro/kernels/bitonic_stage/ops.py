"""jit'd wrapper for the fused stage swap (pads lanes to the block size)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import record_launch
from .bitonic_stage import BLOCK, bitonic_swap
from .ref import bitonic_swap_ref


def stage_swap(mask, own, other, alpha, use_kernel: bool = True, block: int = BLOCK):
    """mask: (3, N); own/other/alpha: (3, C, N). Returns own ^ select-diff."""
    if not use_kernel or own.size == 0:
        return bitonic_swap_ref(mask, own, other, alpha)
    record_launch("bitonic_stage")
    n = own.shape[2]
    block = min(block, max(128, 1 << (n - 1).bit_length()))
    pad = (-n) % block
    if pad:
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        padc = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        own_p, other_p, alpha_p = padc(own), padc(other), padc(alpha)
    else:
        own_p, other_p, alpha_p = own, other, alpha
    out = bitonic_swap(
        mask, own_p, other_p, alpha_p,
        interpret=jax.default_backend() != "tpu", block=block,
    )
    return out[:, :, :n]
