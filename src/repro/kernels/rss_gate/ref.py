"""Pure-jnp oracle for the rss_gate kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rss_gate_ref(xs, ys, alpha, boolean: bool = True):
    xn = jnp.roll(xs, -1, axis=0)
    yn = jnp.roll(ys, -1, axis=0)
    if boolean:
        return (xs & ys) ^ (xs & yn) ^ (xn & ys) ^ alpha
    return xs * ys + xs * yn + xn * ys + alpha
