"""Pallas kernel: replicated-secret-sharing gate cross-terms.

Computes, for every lane j, the party-local value of the 1-round RSS
multiplication / AND gate

    arith:  z'_i = x_i*y_i + x_i*y_{i+1} + x_{i+1}*y_i + alpha_i
    bool :  z'_i = (x_i&y_i) ^ (x_i&y_{i+1}) ^ (x_{i+1}&y_i) ^ alpha_i

over the canonical share triple (axis 0 of size 3). This is the innermost
loop of every comparison circuit in the engine: eq = 5 gate calls, lt = 11,
the Resizer's noise addition ~ 25 per tuple. Fusing the 5 elementwise ops +
the roll into one VMEM pass removes 6 HBM round-trips per gate.

Tiling: lanes are blocked at ``BLOCK`` (multiple of 128 for VPU lane
alignment); the 3-share axis stays whole inside the block (3 x BLOCK x 4B x 4
arrays ~ 100 KiB of VMEM at BLOCK=2048 — comfortably inside v5e's ~16 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048


def _gate_kernel(x_ref, y_ref, a_ref, o_ref, *, boolean: bool):
    x = x_ref[...]  # (3, BLOCK)
    y = y_ref[...]
    alpha = a_ref[...]
    xn = jnp.roll(x, -1, axis=0)  # x_{i+1}: static 3-way roll inside VMEM
    yn = jnp.roll(y, -1, axis=0)
    if boolean:
        z = (x & y) ^ (x & yn) ^ (xn & y) ^ alpha
    else:
        z = x * y + x * yn + xn * y + alpha
    o_ref[...] = z


@functools.partial(jax.jit, static_argnames=("boolean", "interpret", "block"))
def rss_gate(
    xs: jax.Array,
    ys: jax.Array,
    alpha: jax.Array,
    boolean: bool = True,
    interpret: bool = True,
    block: int = BLOCK,
) -> jax.Array:
    """xs, ys, alpha: (3, N) uint32 with N % block == 0 (wrapper pads)."""
    n = xs.shape[1]
    grid = (n // block,)
    spec = pl.BlockSpec((3, block), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_gate_kernel, boolean=boolean),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(xs.shape, xs.dtype),
        interpret=interpret,
    )(xs, ys, alpha)
