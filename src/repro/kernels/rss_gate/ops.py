"""jit'd public wrapper for rss_gate: pads lanes to the block size, flattens
arbitrary trailing shapes, and dispatches to the kernel (interpret=True on
CPU) or the jnp reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import record_launch
from .ref import rss_gate_ref
from .rss_gate import BLOCK, rss_gate


def gate(xs, ys, alpha, boolean: bool = True, use_kernel: bool = True, block: int = BLOCK):
    # lanes are flattened below, so broadcast-compatible operands (e.g. a
    # (3,n,2) x against a (3,n,1) y) must be materialized to a common shape
    # first or their flat lane indices misalign
    xs, ys, alpha = jnp.broadcast_arrays(xs, ys, alpha)
    if not use_kernel or xs.size == 0:  # pallas_call cannot slice 0-lane operands
        return rss_gate_ref(xs, ys, alpha, boolean)
    record_launch("rss_gate")
    shape = xs.shape
    flat = lambda a: a.reshape(3, -1)
    x, y, al = flat(xs), flat(ys), flat(alpha)
    n = x.shape[1]
    block = min(block, max(128, 1 << (n - 1).bit_length()))
    pad = (-n) % block
    if pad:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, pad)))
        x, y, al = padf(x), padf(y), padf(al)
    out = rss_gate(x, y, al, boolean=boolean, interpret=jax.default_backend() != "tpu", block=block)
    return out[:, :n].reshape(shape)
