"""Synthetic HealthLnK-like clinical data (the paper's §5.3 workload tables).

The real HealthLnK extract is not public; we generate schema-compatible
synthetic relations with dictionary-encoded categorical columns (which is how
strings enter MPC engines anyway) and tunable selectivities so the paper's
queries produce non-trivial intermediate sizes.

Tables (column -> meaning):
  diagnoses     pid, icd9, major_icd9, diag, time
  medications   pid, med, dosage, time
  demographics  pid, zip

Encodings used by the queries:
  ICD9_CIRCULATORY (icd9 == 'circulatory disorder'), ICD9_HEART_414
  MED_ASPIRIN, DOSAGE_325MG, DIAG_HEART_DISEASE
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import numpy as np

from ..ops.table import SecretTable

__all__ = ["generate_healthlnk", "plaintext_oracle"]

ICD9_CIRCULATORY = 390
ICD9_HEART_414 = 414
MED_ASPIRIN = 1
DOSAGE_325MG = 325
DIAG_HEART_DISEASE = 7


def generate_healthlnk(
    n: int = 128,
    key: jax.Array | None = None,
    seed: int = 0,
    n_patients: int | None = None,
    aspirin_frac: float = 0.2,
    icd_heart_frac: float = 0.15,
) -> Tuple[Dict[str, SecretTable], Dict[str, Dict[str, np.ndarray]]]:
    """Returns ({table -> SecretTable}, {table -> plaintext columns})."""
    key = key if key is not None else jax.random.PRNGKey(11)
    rng = np.random.default_rng(seed)
    n_patients = n_patients or max(n // 4, 4)

    diag = {
        "pid": rng.integers(0, n_patients, n).astype(np.uint32),
        "icd9": np.where(
            rng.random(n) < icd_heart_frac,
            ICD9_HEART_414,
            rng.choice([ICD9_CIRCULATORY, 401, 250, 486], n),
        ).astype(np.uint32),
        "diag": np.where(
            rng.random(n) < icd_heart_frac, DIAG_HEART_DISEASE, rng.integers(0, 6, n)
        ).astype(np.uint32),
        "time": rng.integers(0, 1000, n).astype(np.uint32),
    }
    diag["major_icd9"] = (diag["icd9"] // 100).astype(np.uint32)

    meds = {
        "pid": rng.integers(0, n_patients, n).astype(np.uint32),
        "med": np.where(
            rng.random(n) < aspirin_frac, MED_ASPIRIN, rng.integers(2, 12, n)
        ).astype(np.uint32),
        "dosage": rng.choice([81, 100, DOSAGE_325MG, 500], n).astype(np.uint32),
        "time": rng.integers(0, 1000, n).astype(np.uint32),
    }

    demo = {
        "pid": np.arange(n_patients, dtype=np.uint32),
        "zip": rng.integers(10000, 99999, n_patients).astype(np.uint32),
    }

    plain = {"diagnoses": diag, "medications": meds, "demographics": demo}
    keys = jax.random.split(key, 3)
    shared = {
        name: SecretTable.from_plaintext(cols, k)
        for (name, cols), k in zip(plain.items(), keys)
    }
    return shared, plain


# -----------------------------------------------------------------------------
# Plaintext oracles for the four paper queries (Table 2)
# -----------------------------------------------------------------------------

def plaintext_oracle(query: str, plain: Dict[str, Dict[str, np.ndarray]]):
    d, m, demo = plain["diagnoses"], plain["medications"], plain["demographics"]
    if query == "comorbidity":
        vals, counts = np.unique(d["major_icd9"], return_counts=True)
        order = np.argsort(-counts, kind="stable")
        top = sorted(
            zip(counts.tolist(), vals.tolist()), key=lambda t: (-t[0], t[1])
        )[:10]
        return {int(v): int(c) for c, v in top}
    if query == "dosage_study":
        pids = set()
        for i in range(len(d["pid"])):
            if d["icd9"][i] != ICD9_CIRCULATORY:
                continue
            for j in range(len(m["pid"])):
                if (
                    m["pid"][j] == d["pid"][i]
                    and m["med"][j] == MED_ASPIRIN
                    and m["dosage"][j] == DOSAGE_325MG
                ):
                    pids.add(int(d["pid"][i]))
        return sorted(pids)
    if query == "aspirin_count":
        pids = set()
        for i in range(len(d["pid"])):
            if d["icd9"][i] != ICD9_HEART_414:
                continue
            for j in range(len(m["pid"])):
                if (
                    m["pid"][j] == d["pid"][i]
                    and m["med"][j] == MED_ASPIRIN
                    and d["time"][i] <= m["time"][j]
                ):
                    pids.add(int(d["pid"][i]))
        return len(pids)
    if query == "three_join":
        demo_pids = set(demo["pid"].tolist())
        pids = set()
        for i in range(len(d["pid"])):
            if d["diag"][i] != DIAG_HEART_DISEASE:
                continue
            for j in range(len(m["pid"])):
                if (
                    m["pid"][j] == d["pid"][i]
                    and m["med"][j] == MED_ASPIRIN
                    and d["time"][i] <= m["time"][j]
                    and int(d["pid"][i]) in demo_pids
                ):
                    pids.add(int(d["pid"][i]))
        return len(pids)
    # -- dialect-growth goldens (projection / SUM / AVG / OR / 2-col GROUP BY)
    if query == "projection_join":
        pairs = set()
        for i in range(len(d["pid"])):
            for j in range(len(m["pid"])):
                if m["pid"][j] == d["pid"][i] and m["med"][j] == MED_ASPIRIN:
                    pairs.add((int(d["pid"][i]), int(m["dosage"][j])))
        return sorted(pairs)
    if query == "dosage_sum":
        mask = m["med"] == MED_ASPIRIN
        return int(m["dosage"][mask].sum())
    if query == "dosage_avg":
        mask = m["med"] == MED_ASPIRIN
        total, cnt = int(m["dosage"][mask].sum()), int(mask.sum())
        return {"sum": total, "cnt": cnt, "avg": total // max(cnt, 1)}
    if query in ("dosage_min", "dosage_max"):
        vals = m["dosage"][m["med"] == MED_ASPIRIN]
        if len(vals) == 0:
            return None  # empty selection: the engine reveals zero rows
        return int(vals.min() if query == "dosage_min" else vals.max())
    if query == "heart_or_circulatory":
        return int(
            ((d["icd9"] == ICD9_HEART_414) | (d["icd9"] == ICD9_CIRCULATORY)).sum()
        )
    if query == "diag_breakdown":
        counts: Dict[Tuple[int, int], int] = {}
        for mi, di in zip(d["major_icd9"].tolist(), d["diag"].tolist()):
            counts[(int(mi), int(di))] = counts.get((int(mi), int(di)), 0) + 1
        return counts
    if query in ("med_dosage_sum", "med_dosage_avg"):
        sums: Dict[int, int] = {}
        cnts: Dict[int, int] = {}
        for mv, dv in zip(m["med"].tolist(), m["dosage"].tolist()):
            sums[int(mv)] = sums.get(int(mv), 0) + int(dv)
            cnts[int(mv)] = cnts.get(int(mv), 0) + 1
        if query == "med_dosage_sum":
            return sums
        return {k: {"sum": sums[k], "cnt": cnts[k], "avg": sums[k] // cnts[k]}
                for k in sums}
    if query == "repeat_diagnoses":
        vals, counts = np.unique(d["major_icd9"], return_counts=True)
        return {int(v): int(c) for v, c in zip(vals, counts) if c >= 2}
    raise ValueError(query)
