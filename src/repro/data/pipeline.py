"""Deterministic, resumable, shard-aware synthetic token pipeline.

Every batch is a pure function of (seed, step, dp_rank) — so a restarted run
resumes bit-identically from the checkpointed step with no persisted reader
state, and each data-parallel shard generates exactly its slice (no broadcast
of global batches through host 0 — the 1000-node-friendly layout)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dp_rank: int = 0
    dp_size: int = 1
    d_model: Optional[int] = None  # for embedding-mode archs
    mode: str = "tokens"  # tokens | embeddings
    n_prefix: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.dp_size == 0
        return self.global_batch // self.dp_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Markov-ish synthetic tokens: learnable structure (next token
        depends on current), so training loss visibly decreases."""
        rng = np.random.default_rng(
            np.uint64(self.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(9_176)
            + np.uint64(self.dp_rank)
        )
        b, s, v = self.local_batch, self.seq_len, self.vocab_size
        base = rng.integers(0, v, (b, 1))
        steps = rng.integers(1, 7, (b, s))
        toks = (base + np.cumsum(steps, axis=1)) % v  # drifting sequences
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1  # no target for the last position
        if self.mode == "embeddings":
            emb = rng.standard_normal((b, self.n_prefix or s, self.d_model)).astype(
                np.float32
            ) * 0.02
            if self.n_prefix:
                return {
                    "embeds": emb,
                    "tokens": tokens[:, : s - self.n_prefix],
                    "labels": labels[:, : s - self.n_prefix],
                }
            return {"embeds": emb, "labels": labels}
        return {"tokens": tokens, "labels": labels}
