"""Hand-compiled plans for the four HealthLnK queries (paper Table 2).

Filters are pushed below joins (as in the paper's Fig. 2/4 example plans);
Resizer placement is applied separately via
:func:`repro.plan.policies.insert_resizers` so every benchmark can compare
fully-oblivious / sort&cut / Reflex / revealed executions of the *same*
logical plan.
"""
from __future__ import annotations

from ..ops.filter import Or, Predicate
from ..plan.nodes import (
    Avg,
    CountDistinct,
    CountValid,
    Distinct,
    Filter,
    GroupByAvg,
    GroupByCount,
    GroupBySum,
    Having,
    Join,
    Max,
    Min,
    OrderBy,
    PlanNode,
    Project,
    Scan,
    Sum,
)
from .healthlnk import (
    DIAG_HEART_DISEASE,
    DOSAGE_325MG,
    ICD9_CIRCULATORY,
    ICD9_HEART_414,
    MED_ASPIRIN,
)

__all__ = [
    "comorbidity_plan",
    "dosage_study_plan",
    "aspirin_count_plan",
    "three_join_plan",
    "projection_join_plan",
    "dosage_sum_plan",
    "dosage_avg_plan",
    "dosage_min_plan",
    "dosage_max_plan",
    "heart_or_circulatory_plan",
    "diag_breakdown_plan",
    "med_dosage_sum_plan",
    "med_dosage_avg_plan",
    "repeat_diagnoses_plan",
    "all_query_plans",
    "all_query_sql",
    "QUERY_SQL",
    "DIALECT_QUERIES",
]


def comorbidity_plan() -> PlanNode:
    """SELECT major_icd9, COUNT(*) FROM diagnoses GROUP BY major_icd9
    ORDER BY COUNT(*) DESC LIMIT 10 — no join: little ballooning (the paper's
    explanation for its modest speedups)."""
    return OrderBy(
        GroupByCount(Scan("diagnoses"), "major_icd9"),
        col="cnt",
        descending=True,
        limit=10,
    )


def dosage_study_plan() -> PlanNode:
    """SELECT DISTINCT d.pid FROM diagnoses d, medications m WHERE
    d.pid = m.pid AND med='aspirin' AND icd9='circulatory' AND dosage='325mg'."""
    d = Filter(Scan("diagnoses"), [Predicate("icd9", "eq", ICD9_CIRCULATORY)])
    m = Filter(
        Scan("medications"),
        [Predicate("med", "eq", MED_ASPIRIN), Predicate("dosage", "eq", DOSAGE_325MG)],
    )
    return Distinct(Join(d, m, ("pid", "pid")), "pid")


def aspirin_count_plan() -> PlanNode:
    """SELECT COUNT(DISTINCT d.pid) FROM diagnoses d JOIN medications m ON
    d.pid = m.pid WHERE med='aspirin' AND icd9='414' AND d.time <= m.time."""
    d = Filter(Scan("diagnoses"), [Predicate("icd9", "eq", ICD9_HEART_414)])
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    return CountDistinct(
        Join(d, m, ("pid", "pid"), theta=("time", "le", "time")), "pid"
    )


def three_join_plan() -> PlanNode:
    """SELECT COUNT(DISTINCT pid) FROM diagnosis d JOIN medication m ON pid
    JOIN demographics demo ON pid JOIN demographics demo2 ON pid WHERE
    d.diag='heart disease' AND m.med='aspirin' AND d.time <= m.time."""
    d = Filter(Scan("diagnoses"), [Predicate("diag", "eq", DIAG_HEART_DISEASE)])
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    j1 = Join(d, m, ("pid", "pid"), theta=("time", "le", "time"))
    demo = Scan("demographics")
    j2 = Join(j1, demo, ("pid", "pid"))
    demo2 = Scan("demographics")
    j3 = Join(j2, demo2, ("pid", "pid"))
    return CountDistinct(j3, "pid")


# -----------------------------------------------------------------------------
# Dialect-growth goldens (PR 3): one per operator the registry unlocked —
# projection, SUM, AVG, OR-predicates, multi-column GROUP BY.
# -----------------------------------------------------------------------------

def projection_join_plan() -> PlanNode:
    """SELECT d.pid, m.dosage FROM diagnoses d JOIN medications m ON
    d.pid = m.pid WHERE m.med='aspirin' — the free Project narrows the
    9-column join payload to 2 columns before reveal."""
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    return Project(Join(Scan("diagnoses"), m, ("pid", "pid")), ("pid", "dosage"))


def dosage_sum_plan() -> PlanNode:
    """SELECT SUM(dosage) AS total FROM medications WHERE med='aspirin'."""
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    return Sum(m, "dosage", name="total")


def dosage_avg_plan() -> PlanNode:
    """SELECT AVG(dosage) AS avg_dosage FROM medications WHERE
    med='aspirin' — revealed as (sum, cnt); the service derives sum // cnt."""
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    return Avg(m, "dosage", name="avg_dosage")


def dosage_min_plan() -> PlanNode:
    """SELECT MIN(dosage) AS lo FROM medications WHERE med='aspirin' —
    sort-head terminal aggregate over the bitonic machinery."""
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    return Min(m, "dosage", name="lo")


def dosage_max_plan() -> PlanNode:
    """SELECT MAX(dosage) AS hi FROM medications WHERE med='aspirin'."""
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    return Max(m, "dosage", name="hi")


def heart_or_circulatory_plan() -> PlanNode:
    """SELECT COUNT(*) FROM diagnoses WHERE icd9='414' OR
    icd9='circulatory' — the first disjunctive predicate tree."""
    f = Filter(
        Scan("diagnoses"),
        Or((
            Predicate("icd9", "eq", ICD9_HEART_414),
            Predicate("icd9", "eq", ICD9_CIRCULATORY),
        )),
    )
    return CountValid(f)


def diag_breakdown_plan() -> PlanNode:
    """SELECT major_icd9, diag, COUNT(*) FROM diagnoses GROUP BY
    major_icd9, diag — composite-key oblivious GroupBy."""
    return GroupByCount(Scan("diagnoses"), ("major_icd9", "diag"))


def med_dosage_sum_plan() -> PlanNode:
    """SELECT med, SUM(dosage) AS total FROM medications GROUP BY med —
    per-group SUM via the segmented-scan GroupBy core."""
    return GroupBySum(Scan("medications"), "med", "dosage", name="total")


def med_dosage_avg_plan() -> PlanNode:
    """SELECT med, AVG(dosage) AS mean FROM medications GROUP BY med —
    revealed as per-group (sum, cnt); the client derives sum // cnt."""
    return GroupByAvg(Scan("medications"), "med", "dosage", name="mean")


def repeat_diagnoses_plan() -> PlanNode:
    """SELECT major_icd9, COUNT(*) AS cnt FROM diagnoses GROUP BY major_icd9
    HAVING COUNT(*) >= 2 — the post-aggregation oblivious filter (HAVING):
    the count column stays secret, only validity bits flip, and the integer
    domain turns >= 2 into cnt > 1 at compile time."""
    return Having(
        GroupByCount(Scan("diagnoses"), "major_icd9"),
        [Predicate("cnt", "gt", 1)],
    )


def all_query_plans():
    return {
        "comorbidity": comorbidity_plan(),
        "dosage_study": dosage_study_plan(),
        "aspirin_count": aspirin_count_plan(),
        "three_join": three_join_plan(),
        "projection_join": projection_join_plan(),
        "dosage_sum": dosage_sum_plan(),
        "dosage_avg": dosage_avg_plan(),
        "dosage_min": dosage_min_plan(),
        "dosage_max": dosage_max_plan(),
        "heart_or_circulatory": heart_or_circulatory_plan(),
        "diag_breakdown": diag_breakdown_plan(),
        "med_dosage_sum": med_dosage_sum_plan(),
        "med_dosage_avg": med_dosage_avg_plan(),
        "repeat_diagnoses": repeat_diagnoses_plan(),
    }


# -----------------------------------------------------------------------------
# SQL forms — goldens for the SQL frontend (repro.sql): each string must
# compile to a plan structurally equal to its hand-compiled twin above
# (tests/test_sql.py; `python -m repro.sql --check`). Comma-FROM pools go
# through cost-based join reordering; explicit JOIN chains are honored as
# written, which is how the three-join golden pins the paper's join order.
# -----------------------------------------------------------------------------

QUERY_SQL = {
    "comorbidity": (
        "SELECT major_icd9, COUNT(*) AS cnt FROM diagnoses "
        "GROUP BY major_icd9 ORDER BY COUNT(*) DESC LIMIT 10"
    ),
    "dosage_study": (
        "SELECT DISTINCT d.pid FROM diagnoses d, medications m "
        f"WHERE d.pid = m.pid AND d.icd9 = {ICD9_CIRCULATORY} "
        f"AND m.med = {MED_ASPIRIN} AND m.dosage = {DOSAGE_325MG}"
    ),
    "aspirin_count": (
        "SELECT COUNT(DISTINCT d.pid) FROM diagnoses d "
        "JOIN medications m ON d.pid = m.pid AND d.time <= m.time "
        f"WHERE d.icd9 = {ICD9_HEART_414} AND m.med = {MED_ASPIRIN}"
    ),
    "three_join": (
        "SELECT COUNT(DISTINCT d.pid) FROM diagnoses d "
        "JOIN medications m ON d.pid = m.pid AND d.time <= m.time "
        "JOIN demographics demo ON d.pid = demo.pid "
        "JOIN demographics demo2 ON d.pid = demo2.pid "
        f"WHERE d.diag = {DIAG_HEART_DISEASE} AND m.med = {MED_ASPIRIN}"
    ),
    "projection_join": (
        "SELECT d.pid, m.dosage FROM diagnoses d "
        "JOIN medications m ON d.pid = m.pid "
        f"WHERE m.med = {MED_ASPIRIN}"
    ),
    "dosage_sum": (
        f"SELECT SUM(dosage) AS total FROM medications WHERE med = {MED_ASPIRIN}"
    ),
    "dosage_avg": (
        "SELECT AVG(dosage) AS avg_dosage FROM medications "
        f"WHERE med = {MED_ASPIRIN}"
    ),
    "dosage_min": (
        f"SELECT MIN(dosage) AS lo FROM medications WHERE med = {MED_ASPIRIN}"
    ),
    "dosage_max": (
        f"SELECT MAX(dosage) AS hi FROM medications WHERE med = {MED_ASPIRIN}"
    ),
    "heart_or_circulatory": (
        "SELECT COUNT(*) FROM diagnoses "
        f"WHERE icd9 = {ICD9_HEART_414} OR icd9 = {ICD9_CIRCULATORY}"
    ),
    "diag_breakdown": (
        "SELECT major_icd9, diag, COUNT(*) AS cnt FROM diagnoses "
        "GROUP BY major_icd9, diag"
    ),
    "med_dosage_sum": (
        "SELECT med, SUM(dosage) AS total FROM medications GROUP BY med"
    ),
    "med_dosage_avg": (
        "SELECT med, AVG(dosage) AS mean FROM medications GROUP BY med"
    ),
    "repeat_diagnoses": (
        "SELECT major_icd9, COUNT(*) AS cnt FROM diagnoses "
        "GROUP BY major_icd9 HAVING COUNT(*) >= 2"
    ),
}

# The dialect-feature subset (used by the `python -m repro.sql --check`
# execution smoke and the service benchmarks).
DIALECT_QUERIES = (
    "projection_join",
    "dosage_sum",
    "dosage_avg",
    "dosage_min",
    "dosage_max",
    "heart_or_circulatory",
    "diag_breakdown",
    "med_dosage_sum",
    "med_dosage_avg",
    "repeat_diagnoses",
)


def all_query_sql():
    return dict(QUERY_SQL)
