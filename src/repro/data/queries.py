"""Hand-compiled plans for the four HealthLnK queries (paper Table 2).

Filters are pushed below joins (as in the paper's Fig. 2/4 example plans);
Resizer placement is applied separately via
:func:`repro.plan.policies.insert_resizers` so every benchmark can compare
fully-oblivious / sort&cut / Reflex / revealed executions of the *same*
logical plan.
"""
from __future__ import annotations

from ..ops.filter import Predicate
from ..plan.nodes import (
    CountDistinct,
    Distinct,
    Filter,
    GroupByCount,
    Join,
    OrderBy,
    PlanNode,
    Scan,
)
from .healthlnk import (
    DIAG_HEART_DISEASE,
    DOSAGE_325MG,
    ICD9_CIRCULATORY,
    ICD9_HEART_414,
    MED_ASPIRIN,
)

__all__ = [
    "comorbidity_plan",
    "dosage_study_plan",
    "aspirin_count_plan",
    "three_join_plan",
    "all_query_plans",
    "all_query_sql",
    "QUERY_SQL",
]


def comorbidity_plan() -> PlanNode:
    """SELECT major_icd9, COUNT(*) FROM diagnoses GROUP BY major_icd9
    ORDER BY COUNT(*) DESC LIMIT 10 — no join: little ballooning (the paper's
    explanation for its modest speedups)."""
    return OrderBy(
        GroupByCount(Scan("diagnoses"), "major_icd9"),
        col="cnt",
        descending=True,
        limit=10,
    )


def dosage_study_plan() -> PlanNode:
    """SELECT DISTINCT d.pid FROM diagnoses d, medications m WHERE
    d.pid = m.pid AND med='aspirin' AND icd9='circulatory' AND dosage='325mg'."""
    d = Filter(Scan("diagnoses"), [Predicate("icd9", "eq", ICD9_CIRCULATORY)])
    m = Filter(
        Scan("medications"),
        [Predicate("med", "eq", MED_ASPIRIN), Predicate("dosage", "eq", DOSAGE_325MG)],
    )
    return Distinct(Join(d, m, ("pid", "pid")), "pid")


def aspirin_count_plan() -> PlanNode:
    """SELECT COUNT(DISTINCT d.pid) FROM diagnoses d JOIN medications m ON
    d.pid = m.pid WHERE med='aspirin' AND icd9='414' AND d.time <= m.time."""
    d = Filter(Scan("diagnoses"), [Predicate("icd9", "eq", ICD9_HEART_414)])
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    return CountDistinct(
        Join(d, m, ("pid", "pid"), theta=("time", "le", "time")), "pid"
    )


def three_join_plan() -> PlanNode:
    """SELECT COUNT(DISTINCT pid) FROM diagnosis d JOIN medication m ON pid
    JOIN demographics demo ON pid JOIN demographics demo2 ON pid WHERE
    d.diag='heart disease' AND m.med='aspirin' AND d.time <= m.time."""
    d = Filter(Scan("diagnoses"), [Predicate("diag", "eq", DIAG_HEART_DISEASE)])
    m = Filter(Scan("medications"), [Predicate("med", "eq", MED_ASPIRIN)])
    j1 = Join(d, m, ("pid", "pid"), theta=("time", "le", "time"))
    demo = Scan("demographics")
    j2 = Join(j1, demo, ("pid", "pid"))
    demo2 = Scan("demographics")
    j3 = Join(j2, demo2, ("pid", "pid"))
    return CountDistinct(j3, "pid")


def all_query_plans():
    return {
        "comorbidity": comorbidity_plan(),
        "dosage_study": dosage_study_plan(),
        "aspirin_count": aspirin_count_plan(),
        "three_join": three_join_plan(),
    }


# -----------------------------------------------------------------------------
# SQL forms — goldens for the SQL frontend (repro.sql): each string must
# compile to a plan structurally equal to its hand-compiled twin above
# (tests/test_sql.py; `python -m repro.sql --check`). Comma-FROM pools go
# through cost-based join reordering; explicit JOIN chains are honored as
# written, which is how the three-join golden pins the paper's join order.
# -----------------------------------------------------------------------------

QUERY_SQL = {
    "comorbidity": (
        "SELECT major_icd9, COUNT(*) AS cnt FROM diagnoses "
        "GROUP BY major_icd9 ORDER BY COUNT(*) DESC LIMIT 10"
    ),
    "dosage_study": (
        "SELECT DISTINCT d.pid FROM diagnoses d, medications m "
        f"WHERE d.pid = m.pid AND d.icd9 = {ICD9_CIRCULATORY} "
        f"AND m.med = {MED_ASPIRIN} AND m.dosage = {DOSAGE_325MG}"
    ),
    "aspirin_count": (
        "SELECT COUNT(DISTINCT d.pid) FROM diagnoses d "
        "JOIN medications m ON d.pid = m.pid AND d.time <= m.time "
        f"WHERE d.icd9 = {ICD9_HEART_414} AND m.med = {MED_ASPIRIN}"
    ),
    "three_join": (
        "SELECT COUNT(DISTINCT d.pid) FROM diagnoses d "
        "JOIN medications m ON d.pid = m.pid AND d.time <= m.time "
        "JOIN demographics demo ON d.pid = demo.pid "
        "JOIN demographics demo2 ON d.pid = demo2.pid "
        f"WHERE d.diag = {DIAG_HEART_DISEASE} AND m.med = {MED_ASPIRIN}"
    ),
}


def all_query_sql():
    return dict(QUERY_SQL)
