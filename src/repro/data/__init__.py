from .healthlnk import (  # noqa: F401
    generate_healthlnk,
    plaintext_oracle,
    ICD9_CIRCULATORY,
    ICD9_HEART_414,
    MED_ASPIRIN,
    DOSAGE_325MG,
    DIAG_HEART_DISEASE,
)
from .queries import (  # noqa: F401
    comorbidity_plan,
    dosage_study_plan,
    aspirin_count_plan,
    three_join_plan,
    all_query_plans,
)
