"""SQL frontend: dialect tokenizer/parser, optimizing compiler, renderer.

``sql.compile(q)`` turns a SQL string into a Resizer-placed physical
:class:`~repro.plan.nodes.PlanNode` tree ready for the Engine — see
DESIGN.md §9 and ``python -m repro.sql --help``.
"""
from ..plan.registry import SchemaError, infer_schema  # noqa: F401
from .catalog import Catalog, HEALTHLNK_CATALOG  # noqa: F401
from .compile import (  # noqa: F401
    bind_params,
    compile_logical,
    compile_query,
    default_cost_model,
    plan_fingerprint,
    plan_params,
    plan_template,
    template_fingerprint,
)
from .lexer import SqlError, tokenize  # noqa: F401
from .parser import parse  # noqa: F401
from .render import render_sql  # noqa: F401

compile = compile_query  # the ISSUE-facing name: sql.compile(q)

__all__ = [
    "Catalog",
    "HEALTHLNK_CATALOG",
    "SchemaError",
    "SqlError",
    "bind_params",
    "compile",
    "compile_query",
    "compile_logical",
    "default_cost_model",
    "infer_schema",
    "parse",
    "plan_fingerprint",
    "plan_params",
    "plan_template",
    "render_sql",
    "template_fingerprint",
    "tokenize",
]
