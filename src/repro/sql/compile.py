"""SQL -> PlanNode compiler with a rule-based logical optimizer (DESIGN.md §9).

Pipeline::

    parse(sql)                    # AST (parser.py)
      -> resolve                  # aliases, columns, ambiguity checks
      -> classify conditions      # per-table (pushdown) / equi-join / theta
                                  # / OR-trees (pushdown or post-join Filter)
      -> join order               # explicit JOINs honored as written;
                                  # comma-FROM pools reordered cost-based
                                  # (left-deep enumeration over plan/cost.py)
      -> terminal ops             # GROUP BY / DISTINCT / COUNT / SUM / AVG /
                                  # ORDER BY / SELECT-list projection
      -> schema propagation       # registry infer_schema: typed column-set
                                  # check before any MPC work
      -> insert_resizers(...)     # Resizer placement policy (plan/policies.py)

Schema tracking mirrors :func:`repro.ops.join.oblivious_join`'s column
disambiguation exactly (right-side collisions get ``r<k>.`` prefixes), so a
qualified reference like ``d.pid`` resolves to the physical column name the
executed join output will actually carry.

A ``SELECT col, ...`` list (no aggregate, no DISTINCT) compiles to a
:class:`~repro.plan.nodes.Project` node — free (an oblivious projection is
local) but it narrows every downstream payload and the final reveal.

Prepared statements: :func:`plan_template` masks predicate literals with
``?`` placeholders, :func:`plan_params` extracts them, and
:func:`bind_params` re-binds a (possibly Resizer-placed) cached plan with
fresh constants — the service keys its plan cache on the template
fingerprint, so ``WHERE age > 40`` and ``WHERE age > 50`` share one
compiled template.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..config import RuntimeConfig
from ..core.resizer import ResizerConfig
from ..ops.filter import And, Or, Pred, Predicate, normalize_pred
# the executed join's own collision-renaming IS the compiler's schema rule:
# importing it makes drift between compiled names and runtime names impossible
from ..ops.join import _disambiguate
from ..plan.cost import CostModel
from ..plan.nodes import (
    Avg,
    CountDistinct,
    CountValid,
    Distinct,
    Filter,
    GroupByAvg,
    GroupByCount,
    GroupBySum,
    Having,
    Join,
    Max,
    Min,
    OrderBy,
    PlanNode,
    Project,
    Scan,
    Sum,
)
from ..plan.policies import insert_resizers, select_join_algorithms
from ..plan.registry import SchemaError, infer_schema, lookup
from .catalog import Catalog, HEALTHLNK_CATALOG
from .lexer import SqlError
from .parser import (
    AndExpr,
    AvgItem,
    BoolExpr,
    ColumnRef,
    Condition,
    CountDistinctItem,
    CountStar,
    MaxItem,
    MinItem,
    OrExpr,
    SelectStmt,
    SumItem,
    parse,
)

__all__ = [
    "compile_query",
    "compile_logical",
    "default_cost_model",
    "plan_fingerprint",
    "plan_template",
    "plan_params",
    "bind_params",
    "template_fingerprint",
    "Schema",
]

MAX_REORDER_TABLES = 7  # left-deep enumeration is k! — plenty for analytics


# -----------------------------------------------------------------------------
# Schema tracking
# -----------------------------------------------------------------------------



@dataclasses.dataclass
class Schema:
    """Ordered physical-name -> (alias, source column) map for a subtree."""

    entries: Dict[str, Tuple[str, str]]  # insertion-ordered

    @classmethod
    def for_table(cls, alias: str, columns: Sequence[str]) -> "Schema":
        return cls({c: (alias, c) for c in columns})

    @property
    def aliases(self) -> frozenset:
        return frozenset(a for a, _ in self.entries.values())

    def physical(self, alias: str, col: str) -> str:
        for phys, (a, c) in self.entries.items():
            if a == alias and c == col:
                return phys
        raise KeyError((alias, col))

    def merge(self, right: "Schema") -> "Schema":
        merged = dict(self.entries)
        for phys_r, origin in right.entries.items():
            merged[_disambiguate(merged, phys_r)] = origin
        return Schema(merged)


@dataclasses.dataclass
class _SubPlan:
    node: PlanNode
    schema: Schema


# -----------------------------------------------------------------------------
# Resolution
# -----------------------------------------------------------------------------

class _Resolver:
    def __init__(self, stmt: SelectStmt, catalog: Catalog, sql: str):
        self.stmt = stmt
        self.catalog = catalog
        self.sql = sql
        refs = list(stmt.tables) + [j.table for j in stmt.joins]
        self.alias_to_table: Dict[str, str] = {}
        self.from_order: List[str] = []  # aliases in FROM appearance order
        for ref in refs:
            if ref.table not in catalog.tables:
                raise SqlError(f"unknown table {ref.table!r}", sql, ref.pos)
            if ref.alias in self.alias_to_table:
                raise SqlError(f"duplicate table alias {ref.alias!r}", sql, ref.pos)
            self.alias_to_table[ref.alias] = ref.table
            self.from_order.append(ref.alias)

    def owner(self, col: ColumnRef) -> str:
        """Alias owning the column; raises on unknown/ambiguous references."""
        if col.alias is not None:
            table = self.alias_to_table.get(col.alias)
            if table is None:
                raise SqlError(f"unknown table alias {col.alias!r}", self.sql, col.pos)
            if col.name not in self.catalog.columns(table):
                raise SqlError(
                    f"unknown column {col.alias}.{col.name} (table {table!r} has "
                    f"{', '.join(self.catalog.columns(table))})",
                    self.sql,
                    col.pos,
                )
            return col.alias
        owners = [
            a
            for a in self.from_order
            if col.name in self.catalog.columns(self.alias_to_table[a])
        ]
        if not owners:
            raise SqlError(f"unknown column {col.name!r}", self.sql, col.pos)
        if len(owners) > 1:
            raise SqlError(
                f"ambiguous column {col.name!r} (in "
                + ", ".join(self.alias_to_table[a] for a in owners)
                + ") — qualify it",
                self.sql,
                col.pos,
            )
        return owners[0]


# -----------------------------------------------------------------------------
# Condition classification + predicate building
# -----------------------------------------------------------------------------

@dataclasses.dataclass
class _Cond:
    """Resolved condition: sides are (alias, column) pairs or an int."""

    cond: Condition
    left_owner: str
    right_owner: Optional[str]  # None when right is a literal

    @property
    def cross(self) -> bool:
        return self.right_owner is not None and self.right_owner != self.left_owner


def _resolve_conditions(conds: Sequence[Condition], res: _Resolver) -> List[_Cond]:
    out = []
    for c in conds:
        if c.op == "ne":
            raise SqlError("'<>' is not supported by the oblivious operators",
                           res.sql, c.pos)
        lo = res.owner(c.left)
        ro = res.owner(c.right) if isinstance(c.right, ColumnRef) else None
        out.append(_Cond(c, lo, ro))
    return out


def _bool_conjuncts(expr: Optional[BoolExpr]) -> List[BoolExpr]:
    """Top-level conjunct list of a WHERE tree (the parser flattens ANDs)."""
    if expr is None:
        return []
    if isinstance(expr, AndExpr):
        return list(expr.terms)
    return [expr]


def _expr_columns(expr: BoolExpr) -> List[ColumnRef]:
    if isinstance(expr, Condition):
        cols = [expr.left]
        if isinstance(expr.right, ColumnRef):
            cols.append(expr.right)
        return cols
    out: List[ColumnRef] = []
    for t in expr.terms:
        out.extend(_expr_columns(t))
    return out


def _expr_pos(expr: BoolExpr) -> int:
    if isinstance(expr, Condition):
        return expr.pos
    return min(_expr_pos(t) for t in expr.terms)


def _pred_from_cond(cond: Condition, to_phys) -> Predicate:
    """Condition AST -> executable Predicate; ``to_phys(ColumnRef) -> str``
    supplies the physical column name for the target scope."""
    if not isinstance(cond.right, ColumnRef):
        op, val = cond.op, int(cond.right)
        if op == "ge":  # integer domain: x >= v  <=>  x > v-1
            op, val = "gt", val - 1
        return Predicate(to_phys(cond.left), op, val)
    l, r, op = cond.left, cond.right, cond.op
    if op in ("gt", "ge"):  # normalize to lt/le by swapping sides
        l, r, op = r, l, {"gt": "lt", "ge": "le"}[op]
    return Predicate(to_phys(l), op, f"col:{to_phys(r)}")


def _pred_tree(expr: BoolExpr, to_phys) -> Pred:
    if isinstance(expr, Condition):
        return _pred_from_cond(expr, to_phys)
    terms = tuple(_pred_tree(t, to_phys) for t in expr.terms)
    return normalize_pred(And(terms) if isinstance(expr, AndExpr) else Or(terms))


def _single_table_predicate(c: _Cond, res: _Resolver) -> Predicate:
    # single-table predicates use bare source column names (leaf scope)
    return _pred_from_cond(c.cond, lambda col: col.name)


def _leaf(alias: str, preds: List[Pred], res: _Resolver) -> _SubPlan:
    table = res.alias_to_table[alias]
    node: PlanNode = Scan(table)
    if preds:
        node = Filter(node, tuple(preds))
    return _SubPlan(node, Schema.for_table(alias, res.catalog.columns(table)))


def _attach_join(
    tree: _SubPlan, leaf: _SubPlan, conds: List[_Cond], res: _Resolver
) -> _SubPlan:
    """Join ``leaf`` onto ``tree`` using every condition now in scope: the
    first equality becomes ``on``, one more le/eq (correctly oriented) becomes
    ``theta``, anything left becomes a post-join Filter."""
    tree_aliases = tree.schema.aliases
    on: Optional[Tuple[str, str]] = None
    theta: Optional[Tuple[str, str, str]] = None
    leftovers: List[_Cond] = []

    for c in sorted(conds, key=lambda c: (c.cond.op != "eq", c.cond.pos)):
        cond = c.cond
        l_in_tree = c.left_owner in tree_aliases
        if cond.op == "eq":
            l, r = (cond.left, cond.right) if l_in_tree else (cond.right, cond.left)
            pair = (
                tree.schema.physical(res.owner(l), l.name),
                leaf.schema.physical(res.owner(r), r.name),
            )
            if on is None:
                on = pair
            elif theta is None:
                theta = (pair[0], "eq", pair[1])
            else:
                leftovers.append(c)
            continue
        op = cond.op
        l, r = cond.left, cond.right
        if op in ("gt", "ge"):  # normalize to lt/le by swapping sides
            l, r, op = r, l, {"gt": "lt", "ge": "le"}[op]
            l_in_tree = not l_in_tree
        if op == "le" and theta is None and l_in_tree:
            theta = (
                tree.schema.physical(res.owner(l), l.name),
                "le",
                leaf.schema.physical(res.owner(r), r.name),
            )
        else:
            leftovers.append(c)

    if on is None:
        raise SqlError(
            f"join with {'/'.join(sorted(leaf.schema.aliases))} requires an "
            "equality condition (cartesian products are not supported)",
            res.sql,
        )
    merged = tree.schema.merge(leaf.schema)
    node: PlanNode = Join(tree.node, leaf.node, on, theta=theta)
    if leftovers:
        to_phys = lambda col: merged.physical(res.owner(col), col.name)
        preds = [_pred_from_cond(c.cond, to_phys) for c in leftovers]
        node = Filter(node, tuple(preds))
    return _SubPlan(node, merged)


def _build_in_order(
    order: Sequence[str],
    leaves: Dict[str, _SubPlan],
    cross: List[_Cond],
    res: _Resolver,
) -> _SubPlan:
    tree = leaves[order[0]]
    pending = list(cross)
    for alias in order[1:]:
        in_scope = [
            c
            for c in pending
            if {c.left_owner, c.right_owner}
            <= (tree.schema.aliases | {alias})
            and alias in (c.left_owner, c.right_owner)
        ]
        pending = [c for c in pending if c not in in_scope]
        tree = _attach_join(tree, leaves[alias], in_scope, res)
    if pending:
        c = pending[0]
        raise SqlError(f"condition {c.cond} could not be attached to any join",
                       res.sql, c.cond.pos)
    return tree


def _reorder_pool(
    pool: List[str], cross: List[_Cond], leaves: Dict[str, _SubPlan],
    res: _Resolver, cost_model: CostModel,
) -> _SubPlan:
    """Cost-based left-deep join ordering for a comma-FROM pool: enumerate
    connected permutations (FROM order first, so ties keep the user's order)
    and keep the cheapest tree under the cost model."""
    if len(pool) == 1:
        return leaves[pool[0]]
    if len(pool) > MAX_REORDER_TABLES:
        raise SqlError(
            f"comma-FROM join pools are limited to {MAX_REORDER_TABLES} tables "
            "(use explicit JOIN ... ON to fix the order)",
            res.sql,
        )
    equi_edges = {
        frozenset((c.left_owner, c.right_owner)) for c in cross if c.cond.op == "eq"
    }

    def connected(prefix_set: frozenset, nxt: str) -> bool:
        return any(frozenset((a, nxt)) in equi_edges for a in prefix_set)

    best: Optional[Tuple[float, _SubPlan]] = None
    for perm in itertools.permutations(pool):
        ok = all(
            connected(frozenset(perm[:i]), perm[i]) for i in range(1, len(perm))
        )
        if not ok:
            continue
        try:
            tree = _build_in_order(perm, leaves, cross, res)
        except SqlError:
            continue
        score = cost_model.plan_bytes(tree.node)
        if best is None or score < best[0]:
            best = (score, tree)
    if best is None:
        raise SqlError(
            "tables in FROM are not connected by equality join conditions",
            res.sql,
        )
    return best[1]


# -----------------------------------------------------------------------------
# Terminal operators
# -----------------------------------------------------------------------------

def _having_operand(operand, node, keys, phys, sql, pos):
    """HAVING operand -> a ColumnRef over the aggregate *output* schema.
    Aggregate expressions (COUNT(*)/SUM(col)) and bare alias references
    rewrite to the aggregate's output column; anything else must be a
    grouping column."""
    if isinstance(operand, CountStar):
        if not isinstance(node, GroupByCount):
            raise SqlError(
                "HAVING COUNT(*) requires a COUNT(*) aggregate", sql, pos
            )
        return ColumnRef(None, node.count_name)
    if isinstance(operand, SumItem):
        if not isinstance(node, GroupBySum) or phys(operand.col) != node.col:
            raise SqlError(
                "HAVING SUM(col) must name the selected SUM aggregate",
                sql, pos,
            )
        return ColumnRef(None, node.name)
    if isinstance(operand, (AvgItem, MinItem, MaxItem, CountDistinctItem)):
        raise SqlError(
            "HAVING supports COUNT(*)/SUM(col) aggregates only", sql, pos
        )
    agg_name = (
        node.count_name if isinstance(node, GroupByCount) else node.name
    )
    if operand.alias is None and operand.name == agg_name:
        return ColumnRef(None, agg_name)  # bare aggregate alias
    p = phys(operand)
    if p not in keys:
        raise SqlError(
            f"HAVING column {operand} is not in the GROUP BY output",
            sql, operand.pos,
        )
    return ColumnRef(None, p)


def _having_expr(expr: BoolExpr, conv) -> BoolExpr:
    """Rewrite every operand of a HAVING boolean tree via ``conv``."""
    if isinstance(expr, Condition):
        left = conv(expr.left, expr.pos)
        right = (
            expr.right if isinstance(expr.right, int)
            else conv(expr.right, expr.pos)
        )
        return Condition(left, expr.op, right, expr.pos)
    terms = tuple(_having_expr(t, conv) for t in expr.terms)
    return AndExpr(terms) if isinstance(expr, AndExpr) else OrExpr(terms)


def _apply_terminals(
    stmt: SelectStmt, sub: _SubPlan, res: _Resolver, sql: str
) -> PlanNode:
    node = sub.node

    def phys(col: ColumnRef) -> str:
        return sub.schema.physical(res.owner(col), col.name)

    aggs = [i for i in stmt.items
            if isinstance(i, (CountStar, CountDistinctItem, SumItem, AvgItem,
                              MinItem, MaxItem))]
    plain = [i for i in stmt.items if isinstance(i, ColumnRef)]

    count_name: Optional[str] = None
    if stmt.group_by:
        keys = tuple(phys(k) for k in stmt.group_by)
        if len(aggs) != 1 or not isinstance(
            aggs[0], (CountStar, SumItem, AvgItem)
        ):
            raise SqlError(
                "GROUP BY queries must select exactly one COUNT(*), SUM(col) "
                "or AVG(col) (plus the grouping columns)", sql,
            )
        if any(phys(c) not in keys for c in plain):
            raise SqlError(
                "GROUP BY queries may only select the grouping columns and "
                "the aggregate", sql,
            )
        agg = aggs[0]
        if isinstance(agg, CountStar):
            count_name = agg.alias or "cnt"
            node = GroupByCount(node, keys, count_name=count_name)
        elif isinstance(agg, SumItem):
            node = GroupBySum(node, keys, phys(agg.col), name=agg.alias or "sum")
        else:
            node = GroupByAvg(node, keys, phys(agg.col), name=agg.alias or "avg")
    elif aggs and not plain:
        if len(stmt.items) != 1:
            raise SqlError("only a single aggregate per query is supported", sql)
        item = stmt.items[0]
        if isinstance(item, CountStar):
            node = CountValid(node)
        elif isinstance(item, CountDistinctItem):
            node = CountDistinct(node, phys(item.col))
        elif isinstance(item, SumItem):
            node = Sum(node, phys(item.col), name=item.alias or "sum")
        elif isinstance(item, MinItem):
            node = Min(node, phys(item.col), name=item.alias or "min")
        elif isinstance(item, MaxItem):
            node = Max(node, phys(item.col), name=item.alias or "max")
        else:
            node = Avg(node, phys(item.col), name=item.alias or "avg")
    elif stmt.distinct:
        if len(stmt.items) != 1 or not isinstance(stmt.items[0], ColumnRef):
            raise SqlError("DISTINCT supports exactly one selected column", sql)
        node = Distinct(node, phys(stmt.items[0]))
    elif aggs:
        raise SqlError("aggregates cannot be mixed with plain columns "
                       "without GROUP BY", sql)
    elif plain:
        # plain SELECT list -> free Project (narrows payload + reveal)
        cols = []
        for c in plain:
            p = phys(c)
            if p not in cols:
                cols.append(p)
        node = Project(node, tuple(cols))

    if stmt.having is not None:
        if not stmt.group_by:
            raise SqlError("HAVING requires GROUP BY", sql)
        if isinstance(node, GroupByAvg):
            raise SqlError(
                "HAVING over AVG(col) is unsupported (the average exists "
                "only post-reveal; filter on SUM or COUNT instead)", sql,
            )
        conv = lambda op, pos: _having_operand(op, node, keys, phys, sql, pos)
        mapped = _having_expr(stmt.having, conv)
        # the Having predicate names the aggregate output schema directly
        node = Having(node, _pred_tree(mapped, lambda col: col.name))

    if stmt.order_by is not None:
        if lookup(type(node)).singleton:
            raise SqlError(
                "ORDER BY is meaningless over a bare aggregate (single row)", sql
            )
        if isinstance(stmt.order_by, CountStar):
            if count_name is None:
                raise SqlError("ORDER BY COUNT(*) requires GROUP BY", sql)
            order_col = count_name
        elif (
            count_name is not None
            and stmt.order_by.alias is None
            and stmt.order_by.name == count_name
        ):
            order_col = count_name
        else:
            order_col = phys(stmt.order_by)
            if count_name is not None and order_col not in keys:
                # the GroupByCount output carries only the keys and the count
                raise SqlError(
                    f"ORDER BY {stmt.order_by} is not in the GROUP BY output "
                    f"(order by a grouping column or COUNT(*))",
                    sql,
                    stmt.order_by.pos,
                )
            if isinstance(node, Project) and order_col not in node.cols:
                raise SqlError(
                    f"ORDER BY {stmt.order_by} must appear in the SELECT list",
                    sql,
                    stmt.order_by.pos,
                )
        node = OrderBy(node, order_col, descending=stmt.order_desc, limit=stmt.limit)
    elif stmt.limit is not None:
        raise SqlError("LIMIT requires ORDER BY", sql)
    return node


# -----------------------------------------------------------------------------
# Entry points
# -----------------------------------------------------------------------------

def default_cost_model(catalog: Catalog, noise=None, calibration=None) -> CostModel:
    """Catalog-derived cost model. ``calibration`` (see
    :class:`repro.state.calibration.CalibrationStore`) replaces the static
    selectivity defaults with observed revealed sizes, so comma-FROM join
    reordering improves as the engine discloses — calibrated reorder."""
    return CostModel(
        table_sizes={t: catalog.size(t) for t in catalog.tables},
        table_cols={t: len(cols) for t, cols in catalog.tables.items()},
        noise=noise,
        calibration=calibration,
    )


def compile_logical(
    sql: str,
    catalog: Catalog = HEALTHLNK_CATALOG,
    *,
    cost_model: Optional[CostModel] = None,
    reorder_joins: bool = True,
) -> PlanNode:
    """SQL -> optimized logical plan (no Resizers): parse, resolve, push
    predicates below joins, order joins, attach terminals, schema-check."""
    stmt = parse(sql)
    res = _Resolver(stmt, catalog, sql)
    where_conjuncts = _bool_conjuncts(stmt.where)
    plain_conds = [c for c in where_conjuncts if isinstance(c, Condition)]
    or_trees = [c for c in where_conjuncts if not isinstance(c, Condition)]
    conds = _resolve_conditions(
        plain_conds + [c for j in stmt.joins for c in j.conds], res
    )
    # predicate pushdown: single-table conditions land on their base scans,
    # in SQL appearance order; single-table OR-trees push down as predicate
    # trees, multi-table OR-trees become post-join Filters
    per_alias: Dict[str, List[Tuple[int, Pred]]] = {a: [] for a in res.from_order}
    cross: List[_Cond] = []
    for c in sorted(conds, key=lambda c: c.cond.pos):
        if c.cross:
            cross.append(c)
        else:
            per_alias[c.left_owner].append(
                (c.cond.pos, _single_table_predicate(c, res))
            )
    post_join: List[Tuple[int, BoolExpr]] = []
    for expr in or_trees:
        owners = {res.owner(col) for col in _expr_columns(expr)}
        pos = _expr_pos(expr)
        if len(owners) == 1:
            tree = _pred_tree(expr, lambda col: col.name)
            per_alias[owners.pop()].append((pos, tree))
        else:
            post_join.append((pos, expr))
    leaves = {
        a: _leaf(a, [p for _, p in sorted(per_alias[a], key=lambda t: t[0])], res)
        for a in res.from_order
    }

    if stmt.joins:
        order = [stmt.tables[0].alias] + [j.table.alias for j in stmt.joins]
        sub = _build_in_order(order, leaves, cross, res)
    else:
        pool = [t.alias for t in stmt.tables]
        if reorder_joins and len(pool) > 1:
            cm = cost_model or default_cost_model(catalog)
            sub = _reorder_pool(pool, cross, leaves, res, cm)
        else:
            sub = _build_in_order(pool, leaves, cross, res)

    if post_join:
        to_phys = lambda col: sub.schema.physical(res.owner(col), col.name)
        trees = tuple(
            _pred_tree(e, to_phys) for _, e in sorted(post_join, key=lambda t: t[0])
        )
        sub = _SubPlan(Filter(sub.node, trees), sub.schema)

    plan = _apply_terminals(stmt, sub, res, sql)
    try:
        # registry schema propagation: the typed column set must resolve all
        # the way to the root before the plan is allowed near the engine
        infer_schema(plan, catalog)
    except SchemaError as e:  # pragma: no cover — resolver should catch first
        raise SqlError(str(e), sql) from e
    return plan


def compile_query(
    sql: str,
    catalog: Catalog = HEALTHLNK_CATALOG,
    *,
    placement: str = "none",
    noise=None,
    cfg_factory: Optional[Callable[[PlanNode], Optional[ResizerConfig]]] = None,
    addition: str = "parallel",
    cost_model: Optional[CostModel] = None,
    reorder_joins: bool = True,
    join_algo: Optional[str] = None,
    config: Optional[RuntimeConfig] = None,
) -> PlanNode:
    """SQL -> fully Resizer-placed physical plan.

    ``noise`` (a NoiseStrategy) builds a constant ResizerConfig factory;
    pass ``cfg_factory`` instead for per-node configs. ``placement`` follows
    :func:`repro.plan.policies.insert_resizers`; ``cost_based`` placement uses
    ``cost_model`` (defaulting to one derived from the catalog sizes).

    ``join_algo`` picks the physical join algorithm per join node
    (:func:`repro.plan.policies.select_join_algorithms`); it defaults to
    ``config.join_algo`` when an explicit :class:`RuntimeConfig` is given,
    else to :func:`repro.config.current_config`'s value. The rewrite only
    fires for catalogs that declare key multiplicity bounds, so plans over
    the bare schema catalog are byte-stable.
    """
    if join_algo is None and config is not None:
        join_algo = config.join_algo
    plan = compile_logical(
        sql, catalog, cost_model=cost_model, reorder_joins=reorder_joins
    )
    plan = select_join_algorithms(
        plan,
        cost_model=cost_model or default_cost_model(catalog),
        catalog=catalog,
        mode=join_algo,
    )
    if placement == "none":
        return plan
    if cfg_factory is None:
        if noise is None:
            raise ValueError("placement != 'none' requires noise= or cfg_factory=")
        cfg = ResizerConfig(noise=noise, addition=addition)
        cfg_factory = lambda _node: cfg
    cm = cost_model
    if placement == "cost_based" and cm is None:
        cm = default_cost_model(catalog, noise=noise)
    return insert_resizers(plan, cfg_factory, placement=placement, cost_model=cm)


def plan_fingerprint(plan: PlanNode) -> str:
    """Stable structural identity of a plan (cache keys, accountant
    signatures): the pretty-printed tree fully determines operators,
    predicates, join conditions, and resizer configs."""
    return plan.pretty()


# -----------------------------------------------------------------------------
# Prepared statements: literal masking + re-binding
# -----------------------------------------------------------------------------

def _map_pred_literals(pred: Pred, fn) -> Pred:
    """Rebuild a predicate tree, passing each literal int through ``fn``."""
    if isinstance(pred, Predicate):
        if isinstance(pred.value, str) and pred.value.startswith("col:"):
            return pred
        return dataclasses.replace(pred, value=fn(pred.value))
    terms = tuple(_map_pred_literals(t, fn) for t in pred.terms)
    return type(pred)(terms)


def _map_plan_literals(plan: PlanNode, fn) -> PlanNode:
    """Rebuild a plan, passing every predicate literal through ``fn`` in a
    deterministic (pre-order, DFS) traversal. Resize wrappers carry no
    literals, so a logical plan and its Resizer-placed twin visit literals
    in the same order."""
    new_children = [_map_plan_literals(c, fn) for c in plan.children()]
    node = plan.replace_children(new_children)
    pred = getattr(node, "pred", None)
    if pred is not None:
        node.pred = _map_pred_literals(pred, fn)
    return node


def plan_params(plan: PlanNode) -> Tuple:
    """Predicate literals in traversal order (the prepared-statement
    parameter vector). Read-only: visits the same (children-first, then own
    predicates, leaves in DFS order) positions :func:`_map_plan_literals`
    rebuilds, without copying the tree — this runs on every service submit."""
    params: List = []

    def collect_pred(pred: Pred) -> None:
        if isinstance(pred, Predicate):
            if not (isinstance(pred.value, str) and pred.value.startswith("col:")):
                params.append(pred.value)
            return
        for t in pred.terms:
            collect_pred(t)

    def walk(node: PlanNode) -> None:
        for c in node.children():
            walk(c)
        pred = getattr(node, "pred", None)
        if pred is not None:
            collect_pred(pred)

    walk(plan)
    return tuple(params)


def plan_template(plan: PlanNode) -> PlanNode:
    """The plan with every predicate literal replaced by ``?`` — the shared
    prepared-statement template (not executable; bind first)."""
    return _map_plan_literals(plan, lambda v: "?")


def template_fingerprint(plan: PlanNode) -> str:
    """Fingerprint of the literal-masked plan: equal for any two plans that
    differ only in predicate constants."""
    return plan_fingerprint(plan_template(plan))


def bind_params(plan: PlanNode, params: Sequence) -> PlanNode:
    """Re-bind a cached (template-compatible) plan with fresh literals, in
    the same traversal order :func:`plan_params` uses. The input plan is not
    mutated (it may be cache-shared)."""
    it = iter(params)

    def put(_v):
        try:
            return next(it)
        except StopIteration:
            raise ValueError("bind_params: fewer params than plan literals")

    out = _map_plan_literals(plan, put)
    leftover = sum(1 for _ in it)
    if leftover:
        raise ValueError(
            f"bind_params: {leftover} params left over — plan/template mismatch"
        )
    return out
