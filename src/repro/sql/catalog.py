"""Table catalog: the schema (and optional sizes) the SQL compiler binds to.

A plan executes against whatever tables the :class:`~repro.engine.Engine` was
given; the compiler only needs column names for resolution and row counts for
the cost model. ``Catalog.from_tables`` derives both from a live table dict.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = ["Catalog", "HEALTHLNK_CATALOG"]


@dataclasses.dataclass(frozen=True)
class Catalog:
    tables: Dict[str, List[str]]  # table name -> ordered column names
    sizes: Optional[Dict[str, int]] = None  # table name -> row count

    def columns(self, table: str) -> List[str]:
        return self.tables[table]

    def size(self, table: str, default: int = 1000) -> int:
        if self.sizes and table in self.sizes:
            return self.sizes[table]
        return default

    @classmethod
    def from_tables(cls, tables) -> "Catalog":
        """Derive a catalog from ``{name: SecretTable}`` (column order is the
        table's own dict order, matching what operators will see)."""
        return cls(
            tables={name: list(t.cols) for name, t in tables.items()},
            sizes={name: t.n for name, t in tables.items()},
        )


# Column order mirrors data/healthlnk.py's dict construction order.
HEALTHLNK_CATALOG = Catalog(
    tables={
        "diagnoses": ["pid", "icd9", "diag", "time", "major_icd9"],
        "medications": ["pid", "med", "dosage", "time"],
        "demographics": ["pid", "zip"],
    }
)
