"""Table catalog: the schema (and optional sizes) the SQL compiler binds to.

A plan executes against whatever tables the :class:`~repro.engine.Engine` was
given; the compiler only needs column names for resolution and row counts for
the cost model. ``Catalog.from_tables`` derives both from a live table dict.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = ["Catalog", "HEALTHLNK_CATALOG"]


@dataclasses.dataclass(frozen=True)
class Catalog:
    tables: Dict[str, List[str]]  # table name -> ordered column names
    sizes: Optional[Dict[str, int]] = None  # table name -> row count
    # table -> column -> public upper bound on per-key duplicate count. This
    # is *declared metadata* (like a schema's uniqueness constraint), not a
    # data-dependent measurement: the planner may only pick the sort-merge
    # join when the build side's key has a finite declared bound, because the
    # merge emits at most ``fanout`` matches per probe row.
    multiplicity: Optional[Dict[str, Dict[str, int]]] = None

    def columns(self, table: str) -> List[str]:
        return self.tables[table]

    def size(self, table: str, default: int = 1000) -> int:
        if self.sizes and table in self.sizes:
            return self.sizes[table]
        return default

    def key_multiplicity(self, table: str, col: str) -> Optional[int]:
        """Declared max duplicates of ``col`` in ``table`` (None = unbounded)."""
        if self.multiplicity and table in self.multiplicity:
            return self.multiplicity[table].get(col)
        return None

    @classmethod
    def from_tables(cls, tables, multiplicity=None) -> "Catalog":
        """Derive a catalog from ``{name: SecretTable}`` (column order is the
        table's own dict order, matching what operators will see)."""
        return cls(
            tables={name: list(t.cols) for name, t in tables.items()},
            sizes={name: t.n for name, t in tables.items()},
            multiplicity=multiplicity,
        )


# Column order mirrors data/healthlnk.py's dict construction order.
HEALTHLNK_CATALOG = Catalog(
    tables={
        "diagnoses": ["pid", "icd9", "diag", "time", "major_icd9"],
        "medications": ["pid", "med", "dosage", "time"],
        "demographics": ["pid", "zip"],
    }
)
