"""SQL tokenizer for the Reflex dialect (DESIGN.md §9).

Dependency-free: a hand-rolled scanner producing ``Token(kind, value, pos)``
triples. Keywords are case-insensitive; identifiers keep their case (the
HealthLnK catalog is lower-case). Literals are integers only — strings enter
the MPC engine dictionary-encoded (data/healthlnk.py), so the dialect never
sees a quoted string.
"""
from __future__ import annotations

import dataclasses
from typing import List

__all__ = ["Token", "SqlError", "tokenize", "KEYWORDS"]

KEYWORDS = {
    "select",
    "distinct",
    "count",
    "sum",
    "avg",
    "min",
    "max",
    "from",
    "join",
    "on",
    "where",
    "and",
    "or",
    "group",
    "having",
    "order",
    "by",
    "asc",
    "desc",
    "limit",
    "as",
}

_PUNCT = {
    "<=": "LE",
    ">=": "GE",
    "<>": "NE",
    "!=": "NE",
    "=": "EQ",
    "<": "LT",
    ">": "GT",
    "(": "LPAREN",
    ")": "RPAREN",
    ",": "COMMA",
    ".": "DOT",
    "*": "STAR",
    ";": "SEMI",
}


class SqlError(ValueError):
    """Lex/parse/compile error with a position-annotated message.

    ``str(e)`` renders the offending SQL with a caret under the error
    position so parser tests (and users) see exactly where things broke.
    """

    def __init__(self, message: str, sql: str = "", pos: int = -1):
        self.message = message
        self.sql = sql
        self.pos = pos
        super().__init__(self._render())

    def _render(self) -> str:
        if not self.sql or self.pos < 0:
            return self.message
        line_start = self.sql.rfind("\n", 0, self.pos) + 1
        line_end = self.sql.find("\n", self.pos)
        line = self.sql[line_start : line_end if line_end != -1 else len(self.sql)]
        caret = " " * (self.pos - line_start) + "^"
        return f"{self.message} (at position {self.pos})\n  {line}\n  {caret}"


@dataclasses.dataclass(frozen=True)
class Token:
    kind: str  # keyword name (upper), IDENT, INT, or a punct kind
    value: str
    pos: int

    def __repr__(self) -> str:  # compact in parser error paths
        return f"{self.kind}({self.value!r}@{self.pos})"


def tokenize(sql: str) -> List[Token]:
    out: List[Token] = []
    i, n = 0, len(sql)
    while i < n:
        c = sql[i]
        if c.isspace():
            i += 1
            continue
        if c == "-" and sql[i : i + 2] == "--":  # line comment
            j = sql.find("\n", i)
            i = n if j == -1 else j + 1
            continue
        two = sql[i : i + 2]
        if two in _PUNCT:
            out.append(Token(_PUNCT[two], two, i))
            i += 2
            continue
        if c in _PUNCT:
            out.append(Token(_PUNCT[c], c, i))
            i += 1
            continue
        if c.isdigit():
            j = i
            while j < n and sql[j].isdigit():
                j += 1
            if j < n and (sql[j].isalpha() or sql[j] == "_"):
                raise SqlError(f"malformed number {sql[i:j + 1]!r}", sql, i)
            out.append(Token("INT", sql[i:j], i))
            i = j
            continue
        if c.isalpha() or c == "_":
            j = i
            while j < n and (sql[j].isalnum() or sql[j] == "_"):
                j += 1
            word = sql[i:j]
            low = word.lower()
            kind = low.upper() if low in KEYWORDS else "IDENT"
            out.append(Token(kind, word, i))
            i = j
            continue
        raise SqlError(f"unexpected character {c!r}", sql, i)
    out.append(Token("EOF", "", n))
    return out
