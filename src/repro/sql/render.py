"""Plan -> SQL rendering (the inverse of compile, for compiler-shaped trees).

Supports the plan shapes the compiler itself emits: left-deep ``Join`` trees
over ``Filter(Scan)`` / ``Scan`` leaves, with an optional terminal chain of
GroupByCount / Distinct / CountValid / CountDistinct and OrderBy. Joins are
rendered as explicit ``JOIN ... ON`` (which the compiler honors in written
order), so ``compile_logical(render_sql(plan)) == plan`` for those shapes —
the hypothesis round-trip property in tests/test_sql_properties.py.

``Resize`` nodes are not renderable (SQL has no resizer syntax; placement is
a compilation policy) — render the logical plan before placement.
"""
from __future__ import annotations

from typing import List, Tuple

from ..ops.filter import Predicate
from ..plan.nodes import (
    CountDistinct,
    CountValid,
    Distinct,
    Filter,
    GroupByCount,
    Join,
    OrderBy,
    PlanNode,
    Resize,
    Scan,
)
from .catalog import Catalog, HEALTHLNK_CATALOG
from .compile import Schema

__all__ = ["render_sql"]

_OP_SYM = {"eq": "=", "lt": "<", "le": "<=", "gt": ">"}


class _Renderer:
    def __init__(self, catalog: Catalog):
        self.catalog = catalog
        self.aliases: List[Tuple[str, str]] = []  # (alias, table)
        self.filters: List[str] = []  # WHERE conjuncts in DFS order
        self.joins: List[str] = []  # "JOIN <table> <alias> ON ..." clauses

    # -- join tree ------------------------------------------------------------
    def walk(self, node: PlanNode) -> Schema:
        if isinstance(node, Scan):
            alias = f"t{len(self.aliases)}"
            self.aliases.append((alias, node.table))
            if node.table not in self.catalog.tables:
                raise ValueError(f"table {node.table!r} not in catalog")
            return Schema.for_table(alias, self.catalog.columns(node.table))
        if isinstance(node, Filter):
            child = node.child
            if isinstance(child, Scan):
                schema = self.walk(child)
                alias = self.aliases[-1][0]
                for p in node.predicates:
                    self.filters.append(self._leaf_pred(alias, p))
                return schema
            # post-join filter: qualify through the merged schema
            schema = self.walk(child)
            for p in node.predicates:
                self.filters.append(self._merged_pred(schema, p))
            return schema
        if isinstance(node, Join):
            left = self.walk(node.left)
            right = self.walk(node.right)
            right_alias = self.aliases[-1][0]
            right_table = self.aliases[-1][1]
            conds = [
                f"{self._qual(left, node.on[0])} = {self._qual(right, node.on[1])}"
            ]
            if node.theta is not None:
                lcol, op, rcol = node.theta
                conds.append(
                    f"{self._qual(left, lcol)} {_OP_SYM[op]} {self._qual(right, rcol)}"
                )
            self.joins.append(
                f"JOIN {right_table} {right_alias} ON " + " AND ".join(conds)
            )
            return left.merge(right)
        if isinstance(node, Resize):
            raise ValueError(
                "Resize nodes have no SQL form — render the logical plan "
                "(before insert_resizers)"
            )
        raise ValueError(f"cannot render node {node.describe()} inside FROM")

    def _qual(self, schema: Schema, phys: str) -> str:
        alias, col = schema.entries[phys]
        return f"{alias}.{col}"

    def _leaf_pred(self, alias: str, p: Predicate) -> str:
        if isinstance(p.value, str) and p.value.startswith("col:"):
            return f"{alias}.{p.column} {_OP_SYM[p.op]} {alias}.{p.value[4:]}"
        return f"{alias}.{p.column} {_OP_SYM[p.op]} {int(p.value)}"

    def _merged_pred(self, schema: Schema, p: Predicate) -> str:
        if isinstance(p.value, str) and p.value.startswith("col:"):
            return (
                f"{self._qual(schema, p.column)} {_OP_SYM[p.op]} "
                f"{self._qual(schema, p.value[4:])}"
            )
        return f"{self._qual(schema, p.column)} {_OP_SYM[p.op]} {int(p.value)}"


def render_sql(plan: PlanNode, catalog: Catalog = HEALTHLNK_CATALOG) -> str:
    """Render a compiler-shaped plan back to SQL text (see module docstring)."""
    # Peel the terminal chain (outermost first).
    order_by: OrderBy | None = None
    if isinstance(plan, OrderBy):
        order_by, plan = plan, plan.child

    head = "*"
    group_by = None
    if isinstance(plan, GroupByCount):
        group_by = plan
        plan = plan.child
    elif isinstance(plan, Distinct):
        head_node, plan = plan, plan.child
    elif isinstance(plan, CountValid):
        head_node, plan = plan, plan.child
    elif isinstance(plan, CountDistinct):
        head_node, plan = plan, plan.child
    else:
        head_node = None

    r = _Renderer(catalog)
    schema = r.walk(plan)

    if group_by is not None:
        key = r._qual(schema, group_by.key)
        head = f"{key}, COUNT(*) AS {group_by.count_name}"
    elif isinstance(head_node, Distinct):
        head = f"DISTINCT {r._qual(schema, head_node.col)}"
    elif isinstance(head_node, CountValid):
        head = "COUNT(*)"
    elif isinstance(head_node, CountDistinct):
        head = f"COUNT(DISTINCT {r._qual(schema, head_node.col)})"

    first_alias, first_table = r.aliases[0]
    parts = [f"SELECT {head}", f"FROM {first_table} {first_alias}"]
    parts.extend(r.joins)
    if r.filters:
        parts.append("WHERE " + " AND ".join(r.filters))
    if group_by is not None:
        parts.append(f"GROUP BY {r._qual(schema, group_by.key)}")
    if order_by is not None:
        if group_by is not None and order_by.col == group_by.count_name:
            key = "COUNT(*)"
        else:
            key = r._qual(schema, order_by.col)
        parts.append(f"ORDER BY {key} {'DESC' if order_by.descending else 'ASC'}")
        if order_by.limit is not None:
            parts.append(f"LIMIT {order_by.limit}")
    return " ".join(parts)
