"""Plan -> SQL rendering (the inverse of compile, for compiler-shaped trees).

Supports the plan shapes the compiler itself emits: left-deep ``Join`` trees
over ``Filter(Scan)`` / ``Scan`` leaves (with predicate trees rendered back
to AND/OR/parenthesized conditions), an optional terminal head node
(GroupByCount / Distinct / CountValid / CountDistinct / Sum / Avg / Project)
with an optional ``Having`` above it, and an OrderBy, so
``compile_logical(render_sql(plan)) == plan`` for those
shapes — the hypothesis round-trip property in tests/test_sql_properties.py.

The renderer is a *driver* over the operator registry
(:mod:`repro.plan.registry`): it never names node classes. Each node's
``OperatorDef`` declares where it may appear (``sql_shape``) and supplies the
hook that renders it (``render_rel`` for the FROM/WHERE subtree,
``render_head`` for the SELECT head, ``render_order`` for ORDER BY keys).
Adding an operator means registering those hooks — this module does not
change.

``Resize`` nodes are not renderable (SQL has no resizer syntax; placement is
a compilation policy) — render the logical plan before placement.
"""
from __future__ import annotations

from typing import List, Tuple

from ..plan.nodes import PlanNode
from ..plan.registry import lookup
from .catalog import Catalog, HEALTHLNK_CATALOG
from .compile import Schema

__all__ = ["render_sql"]


class _Renderer:
    """Rendering state handed to the registry hooks: alias bookkeeping, the
    WHERE conjunct list, and JOIN clauses, plus Schema helpers."""

    def __init__(self, catalog: Catalog):
        self.catalog = catalog
        self.aliases: List[Tuple[str, str]] = []  # (alias, table)
        self.filters: List[str] = []  # WHERE conjuncts in DFS order
        self.joins: List[str] = []  # "JOIN <table> <alias> ON ..." clauses

    def walk(self, node: PlanNode) -> Schema:
        d = lookup(type(node))
        if d.render_rel is None:
            if d.sql_shape == "none":
                raise ValueError(
                    f"{node.label} nodes have no SQL form — render the "
                    "logical plan (before insert_resizers)"
                )
            raise ValueError(f"cannot render node {node.describe()} inside FROM")
        return d.render_rel(self, node)

    def schema_for_table(self, alias: str, columns) -> Schema:
        return Schema.for_table(alias, columns)

    def qual(self, schema: Schema, phys: str) -> str:
        alias, col = schema.entries[phys]
        return f"{alias}.{col}"


def render_sql(plan: PlanNode, catalog: Catalog = HEALTHLNK_CATALOG) -> str:
    """Render a compiler-shaped plan back to SQL text (see module docstring)."""
    # Peel the terminal chain (outermost first):
    # [OrderBy] [Having] [head] relational*
    order_by = None
    if lookup(type(plan)).sql_shape == "order":
        order_by, plan = plan, plan.child

    having_node = None
    having_def = lookup(type(plan))
    if having_def.sql_shape == "having":
        having_node, plan = plan, plan.child

    head_node = None
    head_def = lookup(type(plan))
    if head_def.sql_shape == "head":
        head_node, plan = plan, plan.child
    if having_node is not None and head_node is None:
        raise ValueError("HAVING requires a GROUP BY head beneath it")

    r = _Renderer(catalog)
    schema = r.walk(plan)

    head = "*"
    group_clause = None
    if head_node is not None:
        head, group_clause = head_def.render_head(r, head_node, schema)

    first_alias, first_table = r.aliases[0]
    parts = [f"SELECT {head}", f"FROM {first_table} {first_alias}"]
    parts.extend(r.joins)
    if r.filters:
        parts.append("WHERE " + " AND ".join(r.filters))
    if group_clause is not None:
        parts.append(group_clause)
    if having_node is not None:
        parts.append(
            having_def.render_having(r, having_node, head_node, schema)
        )
    if order_by is not None:
        key = lookup(type(order_by)).render_order(r, order_by, head_node, schema)
        parts.append(f"ORDER BY {key} {'DESC' if order_by.descending else 'ASC'}")
        if order_by.limit is not None:
            parts.append(f"LIMIT {order_by.limit}")
    return " ".join(parts)
