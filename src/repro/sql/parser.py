"""Recursive-descent parser for the Reflex SQL dialect (DESIGN.md §9).

Grammar (keywords case-insensitive, integer literals only):

    query      := SELECT select_list FROM from_clause
                  [WHERE bool_expr]
                  [GROUP BY column (',' column)*]
                  [HAVING bool_expr]              -- operands may be aggregates
                  [ORDER BY order_key [ASC|DESC]]
                  [LIMIT int] [';']
    select_list:= '*' | DISTINCT column | item (',' item)*
    item       := column | COUNT '(' '*' ')' [AS ident]
                | COUNT '(' DISTINCT column ')' [AS ident]
                | SUM '(' column ')' [AS ident]
                | AVG '(' column ')' [AS ident]
                | MIN '(' column ')' [AS ident]
                | MAX '(' column ')' [AS ident]
    from_clause:= table_ref (',' table_ref)*                -- reorderable pool
                | table_ref (JOIN table_ref ON cond (AND cond)*)*  -- fixed order
    table_ref  := ident [AS] [ident]
    bool_expr  := bool_and (OR bool_and)*         -- AND binds tighter than OR
    bool_and   := bool_prim (AND bool_prim)*
    bool_prim  := '(' bool_expr ')' | cond
    cond       := operand op operand      op := = | < | <= | > | >= | <>
    operand    := column | int
                | COUNT '(' '*' ')' | SUM '(' column ')'   -- HAVING only
                | AVG '(' column ')' | MIN '(' column ')'
                | MAX '(' column ')'
    column     := ident | ident '.' ident
    order_key  := column | COUNT '(' '*' ')'

The two FROM styles may not be mixed: comma-FROM hands the optimizer a
reorderable table pool, while explicit ``JOIN ... ON`` chains are honored as
written (so hand-tuned plans stay byte-stable through the compiler). JOIN ON
conditions stay pure conjunctions (the join operator needs an extractable
equality); disjunctions belong in WHERE, where the compiler turns them into
predicate trees.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

from .lexer import SqlError, Token, tokenize

__all__ = [
    "ColumnRef",
    "Condition",
    "AndExpr",
    "OrExpr",
    "BoolExpr",
    "TableRef",
    "JoinClause",
    "CountStar",
    "CountDistinctItem",
    "SumItem",
    "AvgItem",
    "MinItem",
    "MaxItem",
    "SelectStmt",
    "parse",
]


@dataclasses.dataclass(frozen=True)
class ColumnRef:
    alias: Optional[str]  # table alias qualifier, None if bare
    name: str
    pos: int = dataclasses.field(default=0, compare=False)

    def __str__(self) -> str:
        return f"{self.alias}.{self.name}" if self.alias else self.name


@dataclasses.dataclass(frozen=True)
class Condition:
    """left OP right; right is a ColumnRef or an int literal. Normalized so a
    literal (if any) is on the right and op is one of eq|lt|le|gt|ge|ne.

    Inside HAVING, either side may also be an aggregate item (CountStar,
    SumItem, ...) referencing the GROUP BY output."""

    left: Union[ColumnRef, "CountStar", "SumItem", "AvgItem", "MinItem", "MaxItem"]
    op: str
    right: Union[ColumnRef, int, "CountStar", "SumItem", "AvgItem", "MinItem", "MaxItem"]
    pos: int = dataclasses.field(default=0, compare=False)

    @property
    def is_column_pair(self) -> bool:
        return isinstance(self.right, ColumnRef)

    def __str__(self) -> str:
        sym = {"eq": "=", "lt": "<", "le": "<=", "gt": ">", "ge": ">=", "ne": "<>"}
        return f"{self.left} {sym[self.op]} {self.right}"


@dataclasses.dataclass(frozen=True)
class AndExpr:
    """Conjunction of boolean subtrees (flattened)."""

    terms: Tuple["BoolExpr", ...]


@dataclasses.dataclass(frozen=True)
class OrExpr:
    """Disjunction of boolean subtrees (flattened)."""

    terms: Tuple["BoolExpr", ...]


BoolExpr = Union[Condition, AndExpr, OrExpr]


@dataclasses.dataclass(frozen=True)
class TableRef:
    table: str
    alias: str
    pos: int = dataclasses.field(default=0, compare=False)


@dataclasses.dataclass(frozen=True)
class JoinClause:
    table: TableRef
    conds: Tuple[Condition, ...]


@dataclasses.dataclass(frozen=True)
class CountStar:
    alias: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class CountDistinctItem:
    col: ColumnRef
    alias: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class SumItem:
    col: ColumnRef
    alias: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class AvgItem:
    col: ColumnRef
    alias: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class MinItem:
    col: ColumnRef
    alias: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class MaxItem:
    col: ColumnRef
    alias: Optional[str] = None


SelectItem = Union[
    ColumnRef, CountStar, CountDistinctItem, SumItem, AvgItem, MinItem, MaxItem
]


@dataclasses.dataclass(frozen=True)
class SelectStmt:
    items: Tuple[SelectItem, ...]  # empty tuple == SELECT *
    distinct: bool
    tables: Tuple[TableRef, ...]  # comma-FROM pool (>= 1)
    joins: Tuple[JoinClause, ...]  # explicit JOIN chain (fixed order)
    where: Optional[BoolExpr]  # boolean tree (AND/OR), None when absent
    group_by: Tuple[ColumnRef, ...]  # () when absent; >1 = composite key
    order_by: Optional[Union[ColumnRef, CountStar]]
    order_desc: bool
    limit: Optional[int]
    having: Optional[BoolExpr] = None  # post-aggregation filter, None when absent


_OPS = {"EQ": "eq", "LT": "lt", "LE": "le", "GT": "gt", "GE": "ge", "NE": "ne"}
_FLIP = {"eq": "eq", "ne": "ne", "lt": "gt", "le": "ge", "gt": "lt", "ge": "le"}
_AGG_ITEMS = {"COUNT": None, "SUM": SumItem, "AVG": AvgItem,
              "MIN": MinItem, "MAX": MaxItem}


class _Parser:
    def __init__(self, sql: str):
        self.sql = sql
        self.toks = tokenize(sql)
        self.i = 0
        # inside HAVING, comparison operands may be aggregate expressions
        self._agg_operands = False

    # -- token plumbing -------------------------------------------------------
    @property
    def cur(self) -> Token:
        return self.toks[self.i]

    def advance(self) -> Token:
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept(self, kind: str) -> Optional[Token]:
        if self.cur.kind == kind:
            return self.advance()
        return None

    def expect(self, kind: str, what: str = "") -> Token:
        if self.cur.kind != kind:
            want = what or kind
            got = self.cur.value or "end of input"
            raise SqlError(f"expected {want}, got {got!r}", self.sql, self.cur.pos)
        return self.advance()

    def error(self, msg: str) -> SqlError:
        return SqlError(msg, self.sql, self.cur.pos)

    # -- grammar --------------------------------------------------------------
    def parse(self) -> SelectStmt:
        self.expect("SELECT", "SELECT")
        distinct = bool(self.accept("DISTINCT"))
        items = self._select_list()
        self.expect("FROM", "FROM")
        tables, joins = self._from_clause()
        where: Optional[BoolExpr] = None
        if self.accept("WHERE"):
            where = self._bool_expr()
        group_by: Tuple[ColumnRef, ...] = ()
        if self.accept("GROUP"):
            self.expect("BY", "BY after GROUP")
            keys = [self._column()]
            while self.accept("COMMA"):
                keys.append(self._column())
            group_by = tuple(keys)
        having: Optional[BoolExpr] = None
        if self.cur.kind == "HAVING":
            if not group_by:
                raise self.error("HAVING requires GROUP BY")
            self.advance()
            self._agg_operands = True
            try:
                having = self._bool_expr()
            finally:
                self._agg_operands = False
        order_by, order_desc = None, False
        if self.accept("ORDER"):
            self.expect("BY", "BY after ORDER")
            if self.cur.kind == "COUNT":
                self.advance()
                self.expect("LPAREN", "'('")
                self.expect("STAR", "'*' inside COUNT")
                self.expect("RPAREN", "')'")
                order_by = CountStar()
            else:
                order_by = self._column()
            if self.accept("DESC"):
                order_desc = True
            else:
                self.accept("ASC")
        limit = None
        if self.accept("LIMIT"):
            limit = int(self.expect("INT", "integer LIMIT").value)
        self.accept("SEMI")
        self.expect("EOF", "end of query")
        return SelectStmt(
            items=items,
            distinct=distinct,
            tables=tables,
            joins=joins,
            where=where,
            group_by=group_by,
            order_by=order_by,
            order_desc=order_desc,
            limit=limit,
            having=having,
        )

    def _select_list(self) -> Tuple[SelectItem, ...]:
        if self.accept("STAR"):
            return ()
        items: List[SelectItem] = [self._select_item()]
        while self.accept("COMMA"):
            items.append(self._select_item())
        return tuple(items)

    def _select_item(self) -> SelectItem:
        if self.cur.kind == "COUNT":
            self.advance()
            self.expect("LPAREN", "'(' after COUNT")
            if self.accept("STAR"):
                self.expect("RPAREN", "')'")
                return CountStar(alias=self._opt_alias())
            if self.accept("DISTINCT"):
                col = self._column()
                self.expect("RPAREN", "')'")
                return CountDistinctItem(col, alias=self._opt_alias())
            raise self.error("COUNT supports only COUNT(*) and COUNT(DISTINCT col)")
        if self.cur.kind in ("SUM", "AVG", "MIN", "MAX"):
            cls = _AGG_ITEMS[self.advance().kind]
            self.expect("LPAREN", "'(' after aggregate")
            col = self._column()
            self.expect("RPAREN", "')'")
            return cls(col, alias=self._opt_alias())
        return self._column()

    def _opt_alias(self) -> Optional[str]:
        if self.accept("AS"):
            return self.expect("IDENT", "alias identifier").value
        return None

    def _column(self) -> ColumnRef:
        t = self.expect("IDENT", "column name")
        if self.accept("DOT"):
            c = self.expect("IDENT", "column name after '.'")
            return ColumnRef(t.value, c.value, t.pos)
        return ColumnRef(None, t.value, t.pos)

    def _table_ref(self) -> TableRef:
        t = self.expect("IDENT", "table name")
        alias = t.value
        if self.accept("AS"):
            alias = self.expect("IDENT", "table alias").value
        elif self.cur.kind == "IDENT":
            alias = self.advance().value
        return TableRef(t.value, alias, t.pos)

    def _from_clause(self) -> Tuple[Tuple[TableRef, ...], Tuple[JoinClause, ...]]:
        tables = [self._table_ref()]
        joins: List[JoinClause] = []
        while True:
            if self.accept("COMMA"):
                if joins:
                    raise self.error(
                        "cannot mix comma-FROM with explicit JOIN ... ON"
                    )
                tables.append(self._table_ref())
            elif self.accept("JOIN"):
                if len(tables) > 1:
                    raise self.error(
                        "cannot mix comma-FROM with explicit JOIN ... ON"
                    )
                ref = self._table_ref()
                self.expect("ON", "ON after JOIN table")
                joins.append(JoinClause(ref, self._conjunction()))
            else:
                break
        return tuple(tables), tuple(joins)

    def _conjunction(self) -> Tuple[Condition, ...]:
        """AND-only condition list (JOIN ... ON; see module docstring)."""
        conds = [self._condition()]
        while self.accept("AND"):
            if self.cur.kind == "LPAREN":
                raise self.error(
                    "parenthesized/OR conditions are not allowed in JOIN ON "
                    "(move them to WHERE)"
                )
            conds.append(self._condition())
        return tuple(conds)

    # -- boolean expressions (WHERE) ------------------------------------------
    def _bool_expr(self) -> BoolExpr:
        terms = [self._bool_and()]
        while self.accept("OR"):
            terms.append(self._bool_and())
        return _flatten(OrExpr, terms) if len(terms) > 1 else terms[0]

    def _bool_and(self) -> BoolExpr:
        terms = [self._bool_prim()]
        while self.accept("AND"):
            terms.append(self._bool_prim())
        return _flatten(AndExpr, terms) if len(terms) > 1 else terms[0]

    def _bool_prim(self) -> BoolExpr:
        if self.accept("LPAREN"):
            e = self._bool_expr()
            self.expect("RPAREN", "')'")
            return e
        return self._condition()

    def _condition(self) -> Condition:
        pos = self.cur.pos
        left = self._operand()
        if self.cur.kind not in _OPS:
            raise self.error(
                f"expected comparison operator, got {self.cur.value or 'end of input'!r}"
            )
        op = _OPS[self.advance().kind]
        right = self._operand()
        if isinstance(left, int):
            if isinstance(right, int):
                raise SqlError(
                    "condition must reference at least one column", self.sql, pos
                )
            left, right, op = right, left, _FLIP[op]
        return Condition(left, op, right, pos)

    def _operand(self) -> Union[ColumnRef, int]:
        if self.cur.kind == "INT":
            return int(self.advance().value)
        if self._agg_operands and self.cur.kind in _AGG_ITEMS:
            kind = self.advance().kind
            self.expect("LPAREN", f"'(' after {kind}")
            if kind == "COUNT":
                self.expect("STAR", "'*' inside COUNT (HAVING supports COUNT(*) only)")
                self.expect("RPAREN", "')'")
                return CountStar()
            col = self._column()
            self.expect("RPAREN", "')'")
            return _AGG_ITEMS[kind](col)
        return self._column()


def _flatten(cls, terms: List[BoolExpr]) -> BoolExpr:
    flat: List[BoolExpr] = []
    for t in terms:
        if isinstance(t, cls):
            flat.extend(t.terms)
        else:
            flat.append(t)
    return cls(tuple(flat))


def parse(sql: str) -> SelectStmt:
    """Parse one SELECT statement into a :class:`SelectStmt` AST."""
    return _Parser(sql).parse()
