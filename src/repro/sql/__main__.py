"""CLI: parse/compile SQL against the HealthLnK catalog.

    python -m repro.sql --check            # goldens + dialect execution smoke
    python -m repro.sql "SELECT ..."       # pretty-print the compiled plan
    python -m repro.sql --explain ["SQL"]  # plan tree + cost estimates
    python -m repro.sql --explain-analyze ["SQL"]
                                           # execute on synthetic HealthLnK
                                           # data: estimates vs actuals per
                                           # node (+ resizer trim outcomes)
    python -m repro.sql --explain-analyze --networked ["SQL"]
                                           # same, but executed on a 3-party
                                           # loopback mesh via ReflexClient
    python -m repro.sql --explain-analyze --networked --trace-out PATH ["SQL"]
                                           # also write the merged distributed
                                           # trace (JSONL + Chrome trace JSON)

``--explain`` / ``--explain-analyze`` with no SQL run every golden query in
``data/queries.py`` (DESIGN.md §14.4 documents the output format; every
printed value passes the repro.obs.redact disclosure audit).

``--check`` is the CI smoke step, in two phases:

1. every golden SQL string (the four HealthLnK queries *and* the dialect-
   growth goldens) must compile to a plan structurally equal to its
   hand-compiled twin in data/queries.py;
2. one query per new dialect feature (PROJECT-narrowed join, SUM, AVG,
   MIN/MAX sort-head, OR-predicate, 2-column GROUP BY) is compiled AND
   executed on a tiny synthetic dataset and checked against the plaintext
   oracle. Under
   ``REPRO_USE_PALLAS=1`` (the CI kernel-parity job) this drives the Pallas
   kernels in interpret mode.

Exits non-zero on any mismatch.
"""
from __future__ import annotations

import sys


def check() -> int:
    from ..data.queries import all_query_plans, all_query_sql
    from .compile import compile_logical, plan_fingerprint

    plans = all_query_plans()
    failures = 0
    for name, sql_text in all_query_sql().items():
        try:
            compiled = compile_logical(sql_text)
        except Exception as e:  # noqa: BLE001 — report and keep checking
            print(f"FAIL {name}: {type(e).__name__}: {e}")
            failures += 1
            continue
        if compiled != plans[name]:
            print(f"FAIL {name}: compiled plan differs from hand-compiled plan")
            print("  compiled:\n" + plan_fingerprint(compiled))
            print("  expected:\n" + plan_fingerprint(plans[name]))
            failures += 1
        else:
            print(f"OK   {name}")
    failures += _check_dialect_execution()
    failures += _check_sortmerge_execution()
    return 1 if failures else 0


def _check_dialect_execution() -> int:
    """Compile + execute one query per new dialect operator on a tiny
    dataset and compare against the plaintext oracle."""
    import jax

    from ..data.healthlnk import generate_healthlnk, plaintext_oracle
    from ..data.queries import DIALECT_QUERIES, QUERY_SQL
    from ..engine.executor import Engine
    from .compile import compile_logical

    tables, plain = generate_healthlnk(n=8, seed=3, aspirin_frac=0.5)
    eng = Engine(tables, key=jax.random.PRNGKey(2))
    failures = 0
    for name in DIALECT_QUERIES:
        try:
            out, report = eng.execute(compile_logical(QUERY_SQL[name]))
            rows = out.reveal_true_rows()
            oracle = plaintext_oracle(name, plain)
            if name == "projection_join":
                got = sorted(zip(rows["pid"].tolist(), rows["dosage"].tolist()))
                ok = sorted(set(got)) == oracle and set(rows) == {"pid", "dosage"}
            elif name == "dosage_sum":
                ok = int(rows["total"][0]) == oracle
            elif name == "dosage_avg":
                got_avg = int(rows["avg_dosage_sum"][0]) // max(
                    int(rows["avg_dosage_cnt"][0]), 1
                )
                ok = got_avg == oracle["avg"]
            elif name == "dosage_min":
                ok = int(rows["lo"][0]) == oracle
            elif name == "dosage_max":
                ok = int(rows["hi"][0]) == oracle
            elif name == "heart_or_circulatory":
                ok = int(rows["cnt"][0]) == oracle
            elif name == "med_dosage_sum":
                got = {
                    int(k): int(v)
                    for k, v in zip(rows["med"], rows["total"])
                }
                ok = got == oracle
            elif name == "med_dosage_avg":
                got = {
                    int(k): {"sum": int(s), "cnt": int(c), "avg": int(s) // max(int(c), 1)}
                    for k, s, c in zip(
                        rows["med"], rows["mean_sum"], rows["mean_cnt"]
                    )
                }
                ok = got == oracle
            elif name == "repeat_diagnoses":
                got = {
                    int(k): int(v)
                    for k, v in zip(rows["major_icd9"], rows["cnt"])
                }
                ok = got == oracle
            else:  # diag_breakdown
                got = {
                    (int(a), int(b)): int(c)
                    for a, b, c in zip(
                        rows["major_icd9"], rows["diag"], rows["cnt"]
                    )
                }
                ok = got == oracle
            # every plan node must have produced a ledger entry
            ok = ok and len(report.nodes) >= 2
            if ok:
                print(f"OK   exec {name}")
            else:
                print(f"FAIL exec {name}: result mismatch vs plaintext oracle")
                failures += 1
        except Exception as e:  # noqa: BLE001
            print(f"FAIL exec {name}: {type(e).__name__}: {e}")
            failures += 1
    return failures


def _check_sortmerge_execution() -> int:
    """Force the sort-merge physical join on one golden join query and check
    its revealed rows match the product join and the plaintext oracle."""
    import jax
    import numpy as np

    from ..data.healthlnk import generate_healthlnk, plaintext_oracle
    from ..data.queries import QUERY_SQL
    from ..engine.executor import Engine
    from ..plan.nodes import JoinSortMerge
    from .catalog import Catalog
    from .compile import compile_query

    name = "dosage_study"
    try:
        tables, plain = generate_healthlnk(n=8, seed=3, aspirin_frac=0.5)
        # declare the observed per-key duplicate bound so the planner may
        # pick the sort-merge algorithm (a real deployment declares this as
        # schema metadata)
        mult = {
            t: {"pid": int(np.bincount(cols["pid"]).max())}
            for t, cols in plain.items()
        }
        catalog = Catalog.from_tables(tables, multiplicity=mult)
        eng = Engine(tables, key=jax.random.PRNGKey(2))
        results = {}
        for mode in ("product", "sortmerge"):
            plan = compile_query(QUERY_SQL[name], catalog, join_algo=mode)
            has_sm = any(
                isinstance(n, JoinSortMerge) for n in _walk_nodes(plan)
            )
            if (mode == "sortmerge") != has_sm:
                print(f"FAIL exec {name} [{mode}]: algorithm selection "
                      f"did not produce the expected physical join")
                return 1
            out, _ = eng.execute(plan)
            results[mode] = sorted(out.reveal_true_rows()["pid"].tolist())
        oracle = sorted(set(plaintext_oracle(name, plain)))
        if results["product"] == results["sortmerge"] == oracle:
            print(f"OK   exec {name} [sortmerge == product == oracle]")
            return 0
        print(f"FAIL exec {name} [sortmerge]: {results} vs oracle {oracle}")
        return 1
    except Exception as e:  # noqa: BLE001
        print(f"FAIL exec {name} [sortmerge]: {type(e).__name__}: {e}")
        return 1


def _walk_nodes(plan):
    yield plan
    for c in plan.children():
        yield from _walk_nodes(c)


def explain(argv, analyze: bool) -> int:
    """EXPLAIN [ANALYZE] the given SQL — or every golden query when no SQL is
    given — against a small synthetic HealthLnK dataset (the same generator
    the CI smoke uses, so the CLI needs no external state). With
    ``--networked``, EXPLAIN ANALYZE executes on a 3-party loopback mesh
    through the same client facade (actuals come from real wire exchanges).
    ``--trace-out PATH`` (ANALYZE only) runs the queries under a tracer and
    writes the trace — in networked mode the merged distributed trace with
    all three parties' spans — as JSONL to PATH, plus a Chrome trace-event
    file at PATH + ".chrome.json" for chrome://tracing / Perfetto."""
    from ..data.healthlnk import generate_healthlnk
    from ..data.queries import all_query_sql
    from ..obs import trace as obs_trace
    from ..obs.distributed import write_chrome_trace
    from ..runtime import ReflexClient

    networked = "--networked" in argv
    argv = [a for a in argv if a != "--networked"]
    trace_out = None
    if "--trace-out" in argv:
        i = argv.index("--trace-out")
        if i + 1 >= len(argv):
            print("--trace-out requires a PATH argument")
            return 1
        trace_out = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    tables, _ = generate_healthlnk(n=16, seed=3, aspirin_frac=0.5)
    if networked:
        client = ReflexClient.networked(tables, key_seed=2)
    else:
        import jax

        client = ReflexClient.in_process(tables, key=jax.random.PRNGKey(2))
    queries = (
        {"query": " ".join(argv)} if argv else all_query_sql()
    )
    tracer = obs_trace.Tracer() if (trace_out and analyze) else None
    import contextlib

    failures = 0
    with tracer if tracer is not None else contextlib.nullcontext():
        for name, sql_text in queries.items():
            try:
                if analyze:
                    text, _res = client.explain_analyze("explain-cli", sql_text)
                else:
                    text = client.explain(sql_text)
            except Exception as e:  # noqa: BLE001 — report and keep going
                print(f"FAIL {name}: {type(e).__name__}: {e}")
                failures += 1
                continue
            print(text)
            print()
    if tracer is not None:
        with open(trace_out, "w") as f:
            f.write(tracer.to_jsonl())
        write_chrome_trace(
            trace_out + ".chrome.json", tracer.spans, trace_id=tracer.trace_id
        )
        print(f"trace: {len(tracer.spans)} spans -> {trace_out} "
              f"(+ {trace_out}.chrome.json)")
    client.close()
    return 1 if failures else 0


def main(argv) -> int:
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0
    if argv[0] == "--check":
        return check()
    if argv[0] in ("--explain", "--explain-analyze"):
        return explain(argv[1:], analyze=argv[0] == "--explain-analyze")
    from .compile import compile_query

    plan = compile_query(" ".join(argv))
    print(plan.pretty())
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
