"""CLI: parse/compile SQL against the HealthLnK catalog.

    python -m repro.sql --check          # compile the four golden queries
    python -m repro.sql "SELECT ..."     # pretty-print the compiled plan

``--check`` is the CI smoke step: it verifies each golden SQL string parses
and compiles to a plan structurally equal to its hand-compiled twin in
data/queries.py, and exits non-zero on any mismatch.
"""
from __future__ import annotations

import sys


def check() -> int:
    from ..data.queries import all_query_plans, all_query_sql
    from .compile import compile_logical, plan_fingerprint

    plans = all_query_plans()
    failures = 0
    for name, sql_text in all_query_sql().items():
        try:
            compiled = compile_logical(sql_text)
        except Exception as e:  # noqa: BLE001 — report and keep checking
            print(f"FAIL {name}: {type(e).__name__}: {e}")
            failures += 1
            continue
        if compiled != plans[name]:
            print(f"FAIL {name}: compiled plan differs from hand-compiled plan")
            print("  compiled:\n" + plan_fingerprint(compiled))
            print("  expected:\n" + plan_fingerprint(plans[name]))
            failures += 1
        else:
            print(f"OK   {name}")
    return 1 if failures else 0


def main(argv) -> int:
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0
    if argv[0] == "--check":
        return check()
    from .compile import compile_query

    plan = compile_query(" ".join(argv))
    print(plan.pretty())
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
