"""Framed party-to-party transports for the multi-party runtime.

One :class:`Frame` is one length-prefixed message on a *directed link*
``src -> dst``. The wire format (DESIGN.md §16.2)::

    MAGIC  b"RFLX"            4 bytes
    ver    0x01               1 byte
    kind   DATA=0 | CTRL=1    1 byte
    src    party id           1 byte   (0..2 parties, 3 = coordinator)
    dst    party id           1 byte
    seq    uint64 BE          8 bytes  (contiguous per directed link)
    oplen  uint8              1 byte
    blen   uint32 BE          4 bytes  (body length — the ledger's bytes)
    crc    uint32 BE          4 bytes  (crc32 of body)
    op     oplen bytes        (utf-8 ledger op, e.g. "mul", "reveal_k")
    body   blen bytes

Receivers verify magic/version (anything else is a torn or misaligned
frame), the crc (payload corruption), and that ``seq`` is exactly the next
sequence number for the link (reordering/duplication). Violations raise
:class:`repro.errors.TransportError` with a machine-readable ``reason``.

Two implementations share that framing:

* :class:`LoopbackTransport` — an in-process mesh of queues. Frames are
  still encoded to bytes and decoded on receipt, so loopback exercises the
  exact framing/validation path TCP uses (and tests can inject corrupt
  bytes); it is the fast path for in-process party threads.
* :class:`TcpTransport` — one TCP socket per peer pair carrying both
  directions. Dial-side connects with jittered exponential retry/backoff;
  each socket gets a writer thread (sends never block the protocol thread —
  three parties sending simultaneously on a ring cannot deadlock) and a
  reader thread demuxing frames into per-source queues.

Every transport keeps a :class:`WireStats` ledger of its own wire activity
(per-directed-link frames/bytes/latency, rejected inbound frames, dial
retries and backoff sleeps); ``wire_snapshot()`` is the JSON-safe view the
``stats`` control verb ships to the coordinator (DESIGN.md §17).
"""
from __future__ import annotations

import queue
import random
import socket
import struct
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from ..errors import TransportError

__all__ = [
    "Frame",
    "DATA",
    "CTRL",
    "COORD",
    "encode_frame",
    "decode_frame",
    "WireStats",
    "Transport",
    "LoopbackMesh",
    "LoopbackTransport",
    "TcpTransport",
]

MAGIC = b"RFLX"
VERSION = 1
DATA = 0
CTRL = 1
COORD = 3  # the coordinator's id on control links (parties are 0..2)

_HDR = struct.Struct(">4sBBBBQBII")  # magic ver kind src dst seq oplen blen crc


@dataclass
class Frame:
    kind: int
    src: int
    dst: int
    seq: int
    op: str
    body: bytes


def encode_frame(f: Frame) -> bytes:
    op = f.op.encode("utf-8")
    if len(op) > 255:
        raise ValueError(f"op too long: {f.op!r}")
    hdr = _HDR.pack(
        MAGIC, VERSION, f.kind, f.src, f.dst, f.seq,
        len(op), len(f.body), zlib.crc32(f.body) & 0xFFFFFFFF,
    )
    return hdr + op + f.body


def decode_frame(buf: bytes, *, party: Optional[int] = None) -> Frame:
    """Decode one complete frame; raises TransportError on any violation."""
    if len(buf) < _HDR.size:
        raise TransportError(
            f"short frame: {len(buf)} < header {_HDR.size}",
            party=party, reason="torn-frame",
        )
    magic, ver, kind, src, dst, seq, oplen, blen, crc = _HDR.unpack_from(buf)
    if magic != MAGIC or ver != VERSION:
        raise TransportError(
            f"bad magic/version {magic!r}/{ver}", party=party,
            reason="torn-frame",
        )
    if len(buf) != _HDR.size + oplen + blen:
        raise TransportError(
            f"frame length {len(buf)} != header-declared "
            f"{_HDR.size + oplen + blen}",
            party=party, seq=seq, reason="torn-frame",
        )
    op = buf[_HDR.size:_HDR.size + oplen].decode("utf-8")
    body = buf[_HDR.size + oplen:]
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise TransportError(
            f"crc mismatch on {op!r} frame (seq {seq})",
            party=party, peer=src, seq=seq, op=op, reason="torn-frame",
        )
    return Frame(kind=kind, src=src, dst=dst, seq=seq, op=op, body=body)


class _Closed:
    """Inbound-queue sentinel: the link died. Carries the error to raise."""

    def __init__(self, err: TransportError):
        self.err = err


_KIND_NAMES = {DATA: "data", CTRL: "ctrl"}


class WireStats:
    """Per-directed-link wire counters, kept by every transport.

    Plain locked dicts — party processes have no metrics registry; they
    ship :meth:`snapshot` (a JSON-safe dict whose keys come from the public
    telemetry vocabulary, see ``obs/redact.py``) to the coordinator through
    the ``stats`` control verb, and the coordinator's
    :class:`~repro.obs.distributed.WireMetricsPublisher` turns the
    cumulative totals into ``reflex_wire_*`` metric deltas.

    Tracked per (link, kind): frames, body bytes, seconds (send-path time
    for outbound; blocked-on-recv wait for inbound). Plus inbound-frame
    rejections by reason (``crc`` / ``seq`` / ``torn-frame`` / ...), and
    TCP dial retries with the jittered backoff seconds they slept.
    """

    def __init__(self, party: int):
        self.party = party
        self._lock = threading.Lock()
        # (link, kindname) -> [frames, bytes, seconds]
        self._sent: Dict[Tuple[str, str], list] = {}
        self._recv: Dict[Tuple[str, str], list] = {}
        self._rejects: Dict[str, int] = {}
        self._connects: Dict[int, list] = {}  # peer -> [retries, backoff_s]

    @staticmethod
    def _kind(kind: int) -> str:
        return _KIND_NAMES.get(kind, str(kind))

    def record_send(self, dst: int, kind: int, nbytes: int,
                    seconds: float) -> None:
        key = (f"{self.party}->{dst}", self._kind(kind))
        with self._lock:
            st = self._sent.setdefault(key, [0, 0, 0.0])
            st[0] += 1
            st[1] += int(nbytes)
            st[2] += float(seconds)

    def record_recv(self, src: int, kind: int, nbytes: int,
                    wait_seconds: float) -> None:
        key = (f"{src}->{self.party}", self._kind(kind))
        with self._lock:
            st = self._recv.setdefault(key, [0, 0, 0.0])
            st[0] += 1
            st[1] += int(nbytes)
            st[2] += float(wait_seconds)

    def record_reject(self, reason: str) -> None:
        with self._lock:
            self._rejects[reason] = self._rejects.get(reason, 0) + 1

    def record_connect(self, peer: int, retries: int,
                       backoff_seconds: float) -> None:
        with self._lock:
            st = self._connects.setdefault(peer, [0, 0.0])
            st[0] += int(retries)
            st[1] += float(backoff_seconds)

    def snapshot(self, send_seq: Dict[int, int],
                 recv_seq: Dict[int, int]) -> Dict:
        """JSON-safe cumulative totals + the transport's seq watermarks."""
        with self._lock:
            sent = [
                {"link": lk, "kind": kd, "frames": f, "bytes": b,
                 "seconds": s}
                for (lk, kd), (f, b, s) in sorted(self._sent.items())
            ]
            recv = [
                {"link": lk, "kind": kd, "frames": f, "bytes": b,
                 "seconds": s}
                for (lk, kd), (f, b, s) in sorted(self._recv.items())
            ]
            rejects = [
                {"reason": r, "count": c}
                for r, c in sorted(self._rejects.items())
            ]
            connects = [
                {"peer": p, "retries": r, "backoff_seconds": s}
                for p, (r, s) in sorted(self._connects.items())
            ]
        peers = sorted(set(send_seq) | set(recv_seq))
        links = [
            {"link": f"{self.party}<->{p}",
             "sent": int(send_seq.get(p, 0)),
             "recv": int(recv_seq.get(p, 0))}
            for p in peers
        ]
        return {
            "party": self.party,
            "sent": sent,
            "recv": recv,
            "rejects": rejects,
            "connects": connects,
            "links": links,
        }


class Transport:
    """Base: per-directed-link sequence numbering + validation.

    Subclasses implement ``_push(dst, data: bytes)`` (enqueue encoded bytes
    for delivery) and fill ``self._inbox[src]`` queues with raw bytes (or
    :class:`_Closed`). ``send``/``recv`` here do the framing, sequencing,
    and validation once for both implementations.
    """

    def __init__(self, party: int):
        self.party = party
        self._send_seq: Dict[int, int] = {}
        self._recv_seq: Dict[int, int] = {}
        self._inbox: Dict[int, "queue.Queue"] = {}
        self._lock = threading.Lock()
        self.sent_frames = 0
        self.sent_bytes = 0  # body bytes only: the wire-vs-ledger figure
        self.wire = WireStats(party)

    def _inbox_for(self, src: int) -> "queue.Queue":
        with self._lock:
            q = self._inbox.get(src)
            if q is None:
                q = self._inbox[src] = queue.Queue()
            return q

    def send(self, dst: int, op: str, body: bytes, kind: int = DATA) -> None:
        with self._lock:
            seq = self._send_seq.get(dst, 0)
            self._send_seq[dst] = seq + 1
        f = Frame(kind=kind, src=self.party, dst=dst, seq=seq, op=op, body=body)
        t0 = time.perf_counter()
        self._push(dst, encode_frame(f))
        self.wire.record_send(dst, kind, len(body),
                              time.perf_counter() - t0)
        self.sent_frames += 1
        if kind == DATA:
            self.sent_bytes += len(body)

    def recv(self, src: int, timeout: Optional[float] = 30.0) -> Frame:
        q = self._inbox_for(src)
        t0 = time.perf_counter()
        try:
            item = q.get(timeout=timeout)
        except queue.Empty:
            self.wire.record_reject("timeout")
            raise TransportError(
                f"party {self.party}: no frame from {src} within {timeout}s",
                party=self.party, peer=src, reason="timeout",
            ) from None
        wait = time.perf_counter() - t0
        if isinstance(item, _Closed):
            q.put(item)  # subsequent recvs fail the same way
            raise item.err
        try:
            f = decode_frame(item, party=self.party)
        except TransportError as e:
            # finer rejection taxonomy for the wire metrics than the error's
            # stable `reason` vocabulary: crc corruption vs torn framing
            self.wire.record_reject(
                "crc" if "crc mismatch" in str(e) else e.reason
            )
            raise
        if f.src != src:
            self.wire.record_reject("seq")
            raise TransportError(
                f"frame from {f.src} on link {src}->{self.party}",
                party=self.party, peer=src, seq=f.seq, op=f.op,
                reason="bad-seq",
            )
        expect = self._recv_seq.get(src, 0)
        if f.seq != expect:
            self.wire.record_reject("seq")
            raise TransportError(
                f"out-of-order frame from {src}: seq {f.seq}, expected "
                f"{expect}",
                party=self.party, peer=src, seq=f.seq, op=f.op,
                reason="bad-seq",
            )
        self._recv_seq[src] = expect + 1
        self.wire.record_recv(src, f.kind, len(f.body), wait)
        return f

    def wire_snapshot(self) -> Dict:
        """This transport's cumulative wire stats + seq watermarks (the
        per-party payload of the ``stats`` control verb)."""
        with self._lock:
            ss, rs = dict(self._send_seq), dict(self._recv_seq)
        return self.wire.snapshot(ss, rs)

    def _push(self, dst: int, data: bytes) -> None:  # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        pass


# -----------------------------------------------------------------------------
# Loopback: in-process mesh of queues (today's semantics, framed)
# -----------------------------------------------------------------------------

class LoopbackMesh:
    """Shared rendezvous for in-process parties: one byte-queue per directed
    pair. Create one mesh, then one :class:`LoopbackTransport` per
    participant."""

    def __init__(self):
        self._queues: Dict[Tuple[int, int], "queue.Queue"] = {}
        self._lock = threading.Lock()

    def queue_for(self, src: int, dst: int) -> "queue.Queue":
        with self._lock:
            q = self._queues.get((src, dst))
            if q is None:
                q = self._queues[(src, dst)] = queue.Queue()
            return q

    def inject(self, src: int, dst: int, data: bytes) -> None:
        """Deliver raw bytes on a link, bypassing framing — the torn-frame
        and corruption tests use this to simulate a broken peer."""
        self.queue_for(src, dst).put(data)


class LoopbackTransport(Transport):
    def __init__(self, mesh: LoopbackMesh, party: int):
        super().__init__(party)
        self.mesh = mesh
        self._closed = False

    def _push(self, dst: int, data: bytes) -> None:
        if self._closed:
            raise TransportError(
                f"party {self.party}: send on closed transport",
                party=self.party, peer=dst, reason="closed",
            )
        self.mesh.queue_for(self.party, dst).put(data)

    def _inbox_for(self, src: int) -> "queue.Queue":
        # the mesh queue IS the inbox — no copy thread needed in-process
        return self.mesh.queue_for(src, self.party)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # wake peers blocked on us: a closed loopback party delivers the
        # same "peer died" failure a dropped TCP connection would
        err = TransportError(
            f"party {self.party} closed its transport",
            party=self.party, reason="crashed",
        )
        with self.mesh._lock:
            links = [k for k in self.mesh._queues if k[0] == self.party]
        for src, dst in links:
            self.mesh.queue_for(src, dst).put(_Closed(err))


# -----------------------------------------------------------------------------
# TCP: one socket per peer pair, writer thread per socket
# -----------------------------------------------------------------------------

def _read_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly n bytes; b"" on clean EOF at a frame boundary (returns
    short data otherwise so the caller can flag a torn frame)."""
    chunks = []
    got = 0
    while got < n:
        b = sock.recv(min(n - got, 1 << 20))
        if not b:
            break
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


class TcpTransport(Transport):
    """Socket transport: ``listen()`` accepts inbound peers, ``dial(peer)``
    connects outbound with retry/backoff. Either way the socket serves both
    directions of the pair."""

    def __init__(
        self,
        party: int,
        endpoints: Dict[int, Tuple[str, int]],
        *,
        connect_retries: int = 40,
        backoff_s: float = 0.05,
        backoff_cap_s: float = 1.0,
        jitter_seed: Optional[int] = None,
    ):
        super().__init__(party)
        self.endpoints = dict(endpoints)
        self.connect_retries = connect_retries
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        # jittered backoff: parties restarted in lockstep must not hammer
        # the listener in lockstep too (seedable for deterministic tests)
        self._rng = random.Random(jitter_seed)
        self._socks: Dict[int, socket.socket] = {}
        self._outq: Dict[int, "queue.Queue"] = {}
        self._threads: list = []
        self._listener: Optional[socket.socket] = None
        self._closing = False

    # -- link establishment ---------------------------------------------------
    def listen(self) -> Tuple[str, int]:
        host, port = self.endpoints[self.party]
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host, port))
        srv.listen(8)
        self._listener = srv
        self.endpoints[self.party] = srv.getsockname()[:2]
        t = threading.Thread(target=self._accept_loop, daemon=True)
        t.start()
        self._threads.append(t)
        return self.endpoints[self.party]

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return  # listener closed
            # the dialer introduces itself with one hello frame
            try:
                hello = self._read_frame(sock, peer=None)
            except TransportError:
                sock.close()
                continue
            self._register(hello.src, sock)

    def dial(self, peer: int) -> None:
        host, port = self.endpoints[peer]
        delay = self.backoff_s
        last: Optional[Exception] = None
        retries = 0
        slept = 0.0
        for _ in range(self.connect_retries):
            try:
                sock = socket.create_connection((host, port), timeout=5.0)
                sock.settimeout(None)  # connect deadline only — links idle
                break
            except OSError as e:
                last = e
                retries += 1
                # full-range jitter around the exponential schedule
                # (0.5x..1.5x): simultaneous restarts decorrelate instead of
                # colliding on every attempt
                pause = delay * (0.5 + self._rng.random())
                time.sleep(pause)
                slept += pause
                delay = min(delay * 1.6, self.backoff_cap_s)
        else:
            self.wire.record_connect(peer, retries, slept)
            raise TransportError(
                f"party {self.party}: cannot connect to party {peer} at "
                f"{host}:{port} after {self.connect_retries} attempts",
                party=self.party, peer=peer, reason="connect",
            ) from last
        if retries:
            self.wire.record_connect(peer, retries, slept)
        sock.sendall(encode_frame(
            Frame(kind=CTRL, src=self.party, dst=peer, seq=0, op="hello",
                  body=b"")
        ))
        self._register(peer, sock)

    def _register(self, peer: int, sock: socket.socket) -> None:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._lock:
            self._socks[peer] = sock
            outq = self._outq[peer] = queue.Queue()
        tw = threading.Thread(
            target=self._writer_loop, args=(peer, sock, outq), daemon=True
        )
        tr = threading.Thread(
            target=self._reader_loop, args=(peer, sock), daemon=True
        )
        tw.start()
        tr.start()
        self._threads += [tw, tr]

    def wait_for(self, peer: int, timeout: float = 10.0) -> None:
        """Block until an inbound connection from ``peer`` is registered."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if peer in self._socks:
                    return
            time.sleep(0.005)
        raise TransportError(
            f"party {self.party}: no connection from {peer} within {timeout}s",
            party=self.party, peer=peer, reason="connect",
        )

    # -- IO loops -------------------------------------------------------------
    def _writer_loop(self, peer, sock, outq) -> None:
        while True:
            data = outq.get()
            if data is None:
                return
            try:
                sock.sendall(data)
            except OSError:
                return  # reader side reports the failure

    def _read_frame(self, sock, peer) -> Frame:
        try:
            return self._read_frame_inner(sock, peer)
        except OSError as e:
            # socket torn down under the reader (peer reset, local close)
            raise TransportError(
                f"party {self.party}: link to {peer} dropped ({e})",
                party=self.party, peer=peer,
                reason="closed" if self._closing else "crashed",
            ) from e

    def _read_frame_inner(self, sock, peer) -> Frame:
        hdr = _read_exact(sock, _HDR.size)
        if not hdr:
            raise TransportError(
                f"party {self.party}: peer {peer} closed the connection",
                party=self.party, peer=peer,
                reason="closed" if self._closing else "crashed",
            )
        if len(hdr) < _HDR.size:
            raise TransportError(
                f"party {self.party}: torn header from {peer} "
                f"({len(hdr)}/{_HDR.size} bytes)",
                party=self.party, peer=peer, reason="torn-frame",
            )
        magic, ver, kind, src, dst, seq, oplen, blen, crc = _HDR.unpack(hdr)
        if magic != MAGIC or ver != VERSION:
            raise TransportError(
                f"party {self.party}: bad magic/version from {peer}",
                party=self.party, peer=peer, reason="torn-frame",
            )
        rest = _read_exact(sock, oplen + blen)
        if len(rest) < oplen + blen:
            raise TransportError(
                f"party {self.party}: torn body from {peer} "
                f"({len(rest)}/{oplen + blen} bytes)",
                party=self.party, peer=peer, seq=seq, reason="torn-frame",
            )
        return decode_frame(hdr + rest, party=self.party)

    def _reader_loop(self, peer, sock) -> None:
        while True:
            try:
                f = self._read_frame(sock, peer)
            except TransportError as e:
                self._inbox_for(peer).put(_Closed(e))
                return
            # re-encode for the shared validation path in Transport.recv
            # (cheap: header + memoryview of body)
            self._inbox_for(f.src).put(encode_frame(f))

    # -- Transport hooks ------------------------------------------------------
    def _push(self, dst: int, data: bytes) -> None:
        with self._lock:
            outq = self._outq.get(dst)
        if outq is None:
            raise TransportError(
                f"party {self.party}: no link to {dst}",
                party=self.party, peer=dst, reason="closed",
            )
        outq.put(data)

    def close(self) -> None:
        self._closing = True
        with self._lock:
            outqs = list(self._outq.values())
            socks = list(self._socks.values())
            self._outq.clear()
            self._socks.clear()
        for q in outqs:
            q.put(None)
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        for s in socks:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass
