"""Coordinator: drives three party servers and reassembles revealed results.

The coordinator compiles and admits queries exactly like the single-process
service (it IS the service — :class:`RemoteEngine` plugs in below
``AnalyticsService`` via its ``engine_factory`` hook), but execution is
remote: the pickled plan is broadcast to the three party processes, each
runs it over the real data mesh, and the coordinator

1. collects each party's **own share slice** of the output and restacks the
   canonical triple ``(p0's s0, p1's s1, p2's s2)`` — bit-exact iff the
   three processes computed identical triples (every DATA exchange already
   cross-checked slices en route, so a divergence fails at the exact op,
   not here);
2. asserts the three execution reports agree field-for-field on the
   protocol-determined columns (ledger bytes, rounds, oblivious sizes);
3. audits **wire bytes == ledger bytes**: each party's transport counted
   the DATA body bytes it actually sent; that figure must equal the
   exchange log's sum and the report's ledger total.

Any violation raises :class:`~repro.errors.TransportError`, which rides the
service's existing failure path (``charge_failed``: the budget is charged
conservatively for a query that died mid-execution).

Topologies: :func:`launch_loopback_mesh` runs the three party servers on
threads over an in-process :class:`LoopbackMesh` (the fast path for tests
and single-host use); :func:`connect_tcp` dials party processes listening
on TCP (see ``scripts/run_parties.py``).
"""
from __future__ import annotations

import pickle
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..config import RuntimeConfig
from ..engine.executor import Engine, ExecutionReport
from ..errors import TransportError
from ..obs import distributed as obs_dist
from ..obs import trace as obs_trace
from ..ops.table import SecretTable
from ..plan.nodes import PlanNode
from ..plan.registry import lookup
from .party import PartyServer, encode_table
from .transport import (
    COORD,
    CTRL,
    LoopbackMesh,
    LoopbackTransport,
    TcpTransport,
    Transport,
)

__all__ = [
    "Coordinator",
    "RemoteEngine",
    "launch_loopback_mesh",
    "connect_tcp",
]

PARTIES = (0, 1, 2)


class Coordinator:
    """Control-plane client for a 3-party mesh (any transport)."""

    def __init__(self, ctrl: Transport, *, request_timeout: float = 120.0):
        self.ctrl = ctrl
        self.request_timeout = request_timeout
        self._lock = threading.Lock()
        # shipped-exchange-log cap: past this many entries the party reply
        # carries the deterministic summary instead of the full per-op list
        self.exchange_log_cap = 256
        # per-party control-frame clock stamps of the most recent broadcast,
        # on the coordinator's clock — the NTP-style offset inputs (§17)
        self.last_rpc: List[Dict] = []

    # -- control RPC ----------------------------------------------------------
    def _request_all(self, msg: Dict) -> List[Dict]:
        """Broadcast one control message and gather one reply per party."""
        body = pickle.dumps(msg)
        with self._lock:
            rpc = []
            for p in PARTIES:
                t_send = time.time()
                self.ctrl.send(p, msg["type"], body, kind=CTRL)
                rpc.append({"party": p, "t_send": t_send, "t_recv": None})
            replies = []
            for p in PARTIES:
                frame = self.ctrl.recv(p, timeout=self.request_timeout)
                rpc[p]["t_recv"] = time.time()
                replies.append(pickle.loads(frame.body))
            self.last_rpc = rpc
        for p, r in zip(PARTIES, replies):
            if r.get("type") == "error":
                raise TransportError(
                    f"party {p} failed: {r.get('error')}",
                    party=p, reason=r.get("reason", "execution"),
                )
        return replies

    def hello(self) -> None:
        self._request_all({"type": "hello"})

    def load_tables(
        self,
        tables: Dict[str, SecretTable],
        key_seed: int,
        config: Optional[RuntimeConfig] = None,
    ) -> None:
        msg = {
            "type": "load_tables",
            "tables": {n: encode_table(t) for n, t in tables.items()},
            "key_seed": int(key_seed),
            "config": config.to_dict() if config is not None else None,
        }
        self._request_all(msg)

    def execute_plan(
        self,
        plan: PlanNode,
        resize_ctr_base: int,
        trace: Optional[obs_dist.TraceContext] = None,
    ) -> List[Dict]:
        msg = {
            "type": "execute",
            "plan": pickle.dumps(plan),
            "resize_ctr_base": int(resize_ctr_base),
            "exchange_log_cap": int(self.exchange_log_cap),
        }
        if trace is not None:
            msg["trace"] = trace.to_dict()
        return self._request_all(msg)

    def stats(self) -> Dict:
        """Mesh-health snapshot: each party's cumulative wire counters plus
        the coordinator's own control-link view and per-party control RTTs."""
        replies = self._request_all({"type": "stats"})
        rpc = {e["party"]: e for e in self.last_rpc}
        return {
            "parties": [
                {"party": r["party"], "queries": r["queries"],
                 "wire": r["wire"]}
                for r in replies
            ],
            "coordinator": self.ctrl.wire_snapshot(),
            "rtt_seconds": {
                p: round(rpc[p]["t_recv"] - rpc[p]["t_send"], 6)
                for p in PARTIES
                if rpc.get(p, {}).get("t_recv") is not None
            },
        }

    def shutdown(self) -> None:
        try:
            self._request_all({"type": "shutdown"})
        except TransportError:
            pass  # a party that already died cannot say goodbye

    def close(self) -> None:
        self.ctrl.close()


def _post_order(plan: PlanNode) -> List[PlanNode]:
    out: List[PlanNode] = []

    def walk(node: PlanNode) -> None:
        for c in node.children():
            walk(c)
        out.append(node)

    walk(plan)
    return out


class RemoteEngine(Engine):
    """Engine whose ``execute`` dispatches to a 3-party mesh.

    Everything above it — admission, plan cache, scheduler, calibration
    hooks, metrics — is unchanged ``AnalyticsService`` machinery; everything
    below the plan boundary happens in the party processes. Batched
    execution falls back to serial remote passes (slot *i*'s noise counters
    line up with a serial run by construction, so results stay bit-exact
    with the single-process scheduler path)."""

    def __init__(self, tables, coordinator: Coordinator, **kwargs):
        kwargs.setdefault("jit_ops", False)
        if kwargs.get("jit_ops"):
            raise ValueError(
                "networked execution requires jit_ops=False (jit replay "
                "skips the Python protocol bodies and their exchange "
                "boundaries)"
            )
        super().__init__(tables, **kwargs)
        self.coordinator = coordinator
        self.last_wire_audit: List[Dict] = []

    # -- remote execution -----------------------------------------------------
    def execute(self, plan: PlanNode) -> Tuple[SecretTable, ExecutionReport]:
        if self.validate:
            from ..sql.catalog import Catalog
            from ..plan.registry import infer_schema

            infer_schema(plan, Catalog.from_tables(self.tables))
        tr = obs_trace.active_tracer()
        if tr is not None:
            # traced path (DESIGN.md §17): ship (trace_id, parent span) in
            # the execute frame, collect each party's redacted spans from
            # the reply, and merge them — clock-offset-normalized and
            # party-attributed — under this coordinator-side execute span.
            with tr.span("execute", parties=3) as sp:
                ctx = obs_dist.TraceContext(tr.ensure_trace_id(), sp.span_id)
                results = self.coordinator.execute_plan(
                    plan, self._resize_ctr, trace=ctx
                )
                self._audit(results)
                rpc = {e["party"]: e for e in self.coordinator.last_rpc}
                shipments = [
                    {
                        "party": r["party"],
                        "trace_id": r.get("trace_id"),
                        "spans": r.get("spans", []),
                        "clock": r.get("clock", {}),
                        "t_send": rpc[r["party"]]["t_send"],
                        "t_ack": rpc[r["party"]]["t_recv"],
                    }
                    for r in results
                ]
                merged = obs_dist.merge_party_spans(tr, sp, shipments)
                sp.attrs["merged"] = merged
        else:
            results = self.coordinator.execute_plan(plan, self._resize_ctr)
            self._audit(results)
        report = ExecutionReport.from_dict(results[0]["report"])
        out = self._reassemble(results)
        ctr = results[0]["resize_ctr"]
        self._resize_ctr = int(ctr)
        self._last_resize_info = None
        if self.reveal_hook is not None:
            # replay revealed-size feedback from the report: report.nodes is
            # the plan's post-order (the serial _run order), so entries map
            # 1:1 onto plan nodes ("offline"/"wire" extras are telemetry,
            # not revealed sizes)
            for node, stats in zip(_post_order(plan), report.nodes):
                if not lookup(type(node)).provides_resize_info:
                    continue
                info = {
                    k: v
                    for k, v in stats.extra.items()
                    if k not in ("offline", "wire")
                }
                if info and not info.get("skipped"):
                    self.reveal_hook(node, info)
        return out, report

    def execute_batch(
        self, plans: Sequence[PlanNode]
    ) -> List[Tuple[SecretTable, ExecutionReport]]:
        plans = list(plans)
        results = [self.execute(p) for p in plans]
        self.last_batch_stats = {
            "slots": len(plans),
            "stacked_nodes": 0,
            "split_nodes": 0,
            "physical_bytes_per_party": sum(r.total_bytes for _, r in results),
            "physical_rounds": sum(r.total_rounds for _, r in results),
        }
        return results

    # -- verification ---------------------------------------------------------
    def _audit(self, results: List[Dict]) -> None:
        """Cross-party report equality + the wire-vs-ledger byte audit."""
        def ledger_view(r):
            return [
                (
                    n["node"], n["n_ins"], n["n_out"],
                    n["bytes_per_party"], n["rounds"],
                )
                for n in r["report"]["nodes"]
            ]

        base = ledger_view(results[0])
        for r in results[1:]:
            if ledger_view(r) != base:
                raise TransportError(
                    f"party {r['party']} execution report diverges from "
                    f"party 0's (per-node ledger tallies differ)",
                    party=r["party"], reason="divergence",
                )
        if results[0]["exchange_log"] != results[1]["exchange_log"] or \
                results[1]["exchange_log"] != results[2]["exchange_log"]:
            raise TransportError(
                "parties disagree on the exchange log",
                reason="divergence",
            )
        self.last_wire_audit = []
        for r in results:
            ledger_bytes = sum(
                n["bytes_per_party"] for n in r["report"]["nodes"]
            )
            lg = r["exchange_log"]
            if isinstance(lg, dict):  # capped reply: deterministic summary
                log_bytes = lg["bytes"]
                exchanges = lg["entries"]
            else:
                log_bytes = sum(e["bytes"] for e in lg)
                exchanges = len(lg)
            audit = {
                "party": r["party"],
                "ledger_bytes": ledger_bytes,
                "exchange_bytes": log_bytes,
                "wire_bytes": r["wire_bytes"],
                "exchanges": exchanges,
                "stall_seconds": round(r.get("stall_seconds", 0.0), 6),
            }
            self.last_wire_audit.append(audit)
            if not (ledger_bytes == log_bytes == r["wire_bytes"]):
                raise TransportError(
                    f"party {r['party']}: wire bytes {r['wire_bytes']} != "
                    f"exchange-log bytes {log_bytes} != ledger bytes "
                    f"{ledger_bytes}",
                    party=r["party"], reason="divergence",
                )

    @staticmethod
    def _reassemble(results: List[Dict]) -> SecretTable:
        import jax.numpy as jnp
        from ..core.sharing import AShare, BShare

        names = list(results[0]["cols"])
        cols = {}
        for name in names:
            kind = results[0]["cols"][name][0]
            triple = jnp.asarray(
                np.stack([r["cols"][name][1] for r in results])
            )
            cols[name] = AShare(triple) if kind == "a" else BShare(triple)
        valid = BShare(
            jnp.asarray(np.stack([r["valid"] for r in results]))
        )
        return SecretTable(cols, valid)


# -----------------------------------------------------------------------------
# Mesh launchers
# -----------------------------------------------------------------------------

def launch_loopback_mesh(
    *,
    fault_after: Optional[Dict[int, int]] = None,
    exchange_timeout: float = 60.0,
) -> Tuple[Coordinator, List[PartyServer], List[threading.Thread]]:
    """Three party servers on daemon threads over an in-process loopback
    mesh. ``fault_after`` maps party id -> exchange count at which that
    party's driver simulates a crash."""
    mesh = LoopbackMesh()
    servers = []
    threads = []
    for p in PARTIES:
        tr = LoopbackTransport(mesh, p)
        srv = PartyServer(
            p, tr, tr,
            fault_after=(fault_after or {}).get(p),
            exchange_timeout=exchange_timeout,
        )
        th = threading.Thread(target=srv.serve, daemon=True, name=f"party-{p}")
        th.start()
        servers.append(srv)
        threads.append(th)
    coord = Coordinator(LoopbackTransport(mesh, COORD))
    coord.hello()
    return coord, servers, threads


def connect_tcp(
    endpoints: Dict[int, Tuple[str, int]],
    *,
    request_timeout: float = 300.0,
    connect_retries: int = 80,
) -> Coordinator:
    """Dial three party processes listening on TCP (run them with
    ``scripts/run_parties.py``) and return a connected Coordinator."""
    tr = TcpTransport(COORD, endpoints, connect_retries=connect_retries)
    for p in PARTIES:
        tr.dial(p)
    coord = Coordinator(tr, request_timeout=request_timeout)
    coord.hello()
    return coord
