"""PartyServer: one RSS party's execution loop.

A party server owns two transports:

* a **control link** to the coordinator (CTRL frames carrying pickled
  messages: hello / load_tables / execute / stats / shutdown), and
* a **data mesh** to the other two parties (DATA frames: one per ledger
  sync point, driven by :class:`~repro.runtime.exchange.RingExchange`).

On ``execute`` it runs its local :class:`~repro.engine.Engine` over the
shipped plan — eager (``jit_ops=False``: jit re-executions skip the Python
protocol bodies, and with them the exchange boundaries), under the
mesh-wide :class:`~repro.config.RuntimeConfig` the coordinator shipped —
with the ring exchange installed, so every ledger entry is a real framed
wire exchange verified against the peer. It replies with its *own share
slice* of the output columns (party ``p`` contributes canonical share
``s_p``; the coordinator reassembles the triple from three distinct
slices, which is bit-exact only if all three processes computed identical
triples), the execution report, the per-op exchange log (or its capped
deterministic summary) for the wire-vs-ledger audit, the per-query network
stall total, and — when the coordinator shipped a trace context — this
party's redacted spans plus the control-frame clock stamps the coordinator
uses for clock-offset normalization (DESIGN.md §17).

The same class serves both process topologies: ``scripts/run_parties.py``
runs it standalone over :class:`TcpTransport`; the in-process tests run it
on a thread over :class:`LoopbackTransport`. Thread-local engine/ledger/
tracer state means three party threads in one process stay fully isolated.
"""
from __future__ import annotations

import contextlib
import pickle
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

from ..config import RuntimeConfig
from ..core.ledger import exchange_scope
from ..core.sharing import AShare, BShare
from ..engine.executor import Engine
from ..errors import TransportError
from ..obs import trace as obs_trace
from ..ops.table import SecretTable
from .exchange import RingExchange
from .transport import COORD, CTRL, Transport

__all__ = ["PartyServer", "encode_table", "decode_table"]


def encode_table(table: SecretTable) -> Dict:
    """SecretTable -> picklable dict of full canonical share triples (the
    replicated-simulation contract: every party holds the whole triple;
    see DESIGN.md §16.3)."""
    cols = {}
    for name in table.column_names():
        c = table.col(name)  # materializes lazy views
        cols[name] = (
            "a" if isinstance(c, AShare) else "b",
            np.asarray(c.shares),
        )
    return {"cols": cols, "valid": np.asarray(table.valid.shares)}


def decode_table(d: Dict) -> SecretTable:
    import jax.numpy as jnp

    cols = {}
    for name, (kind, arr) in d["cols"].items():
        sh = jnp.asarray(arr)
        cols[name] = AShare(sh) if kind == "a" else BShare(sh)
    return SecretTable(cols, BShare(jnp.asarray(d["valid"])))


class PartyServer:
    def __init__(
        self,
        party: int,
        ctrl: Transport,
        data: Transport,
        *,
        fault_after: Optional[int] = None,
        exchange_timeout: float = 60.0,
    ):
        self.party = party
        self.ctrl = ctrl
        self.data = data
        self.fault_after = fault_after
        self.exchange_timeout = exchange_timeout
        self.engine: Optional[Engine] = None
        self.queries = 0

    # -- control-message helpers ---------------------------------------------
    def _reply(self, msg: Dict) -> None:
        self.ctrl.send(COORD, msg["type"], pickle.dumps(msg), kind=CTRL)

    def _handle_load_tables(self, msg: Dict) -> Dict:
        tables = {name: decode_table(d) for name, d in msg["tables"].items()}
        cfg = (
            RuntimeConfig.from_dict(msg["config"])
            if msg.get("config") is not None
            else None
        )
        self.engine = Engine(
            tables,
            key=jax.random.PRNGKey(int(msg["key_seed"])),
            jit_ops=False,  # exchange boundaries require eager protocol bodies
            config=cfg,
        )
        return {
            "type": "load_ack",
            "party": self.party,
            "tables": sorted(tables),
        }

    def _handle_execute(self, msg: Dict) -> Dict:
        t_recv = time.time()  # control-frame receipt on THIS party's clock
        if self.engine is None:
            return {
                "type": "error",
                "party": self.party,
                "error": "execute before load_tables",
                "reason": "protocol",
            }
        plan = pickle.loads(msg["plan"])
        base = msg.get("resize_ctr_base")
        if base is not None and self.engine._resize_ctr != base:
            # lockstep invariant: every party must fold the same noise
            # counters, or Resize draws diverge silently
            return {
                "type": "error",
                "party": self.party,
                "error": (
                    f"resize counter desync: party at "
                    f"{self.engine._resize_ctr}, coordinator at {base}"
                ),
                "reason": "divergence",
            }
        drv = RingExchange(
            self.data,
            self.party,
            timeout=self.exchange_timeout,
            fault_after=self.fault_after,
        )
        # trace-context propagation (DESIGN.md §17): a traced coordinator
        # ships (trace_id, parent_span_id); this query runs under a fresh
        # per-query tracer carrying that id, and the reply ships the
        # party's redacted spans back for the coordinator-side merge. An
        # untraced execute runs with no tracer at all — zero overhead.
        tctx = msg.get("trace")
        tracer = (
            obs_trace.Tracer(party=self.party, trace_id=tctx["trace_id"])
            if tctx is not None
            else None
        )
        cm = tracer if tracer is not None else contextlib.nullcontext()
        wire_before = self.data.sent_bytes  # counters span queries; audit per
        with cm, exchange_scope(drv):
            out, report = self.engine.execute(plan)
        self.queries += 1
        slices = {}
        for name in out.column_names():
            c = out.col(name)
            slices[name] = (
                "a" if isinstance(c, AShare) else "b",
                np.asarray(c.shares[self.party]),
            )
        # cap the shipped exchange log: large plans produce thousands of
        # per-op entries; past the cap the reply carries the deterministic
        # summary (exact byte/round totals) instead of the full list
        cap = int(msg.get("exchange_log_cap") or 0)
        log = drv.log if not (cap and len(drv.log) > cap) else drv.log_summary()
        reply = {
            "type": "result",
            "party": self.party,
            "cols": slices,
            "valid": np.asarray(out.valid.shares[self.party]),
            "report": report.to_dict(),
            "exchange_log": log,
            "wire_bytes": self.data.sent_bytes - wire_before,
            "stall_seconds": drv.stall_seconds,
            "resize_ctr": self.engine._resize_ctr,
            "clock": {"t_recv": t_recv, "t_reply": time.time()},
        }
        if tracer is not None:
            reply["trace_id"] = tracer.trace_id
            reply["spans"] = [s.to_dict() for s in tracer.spans]
            reply["redactions"] = len(tracer.redactions)
        return reply

    def _handle_stats(self) -> Dict:
        """Mesh-health snapshot for the ``stats`` control verb: this party's
        cumulative wire counters (data mesh + control link) and query count.
        Read-only — never touches engine state."""
        wire = self.data.wire_snapshot()
        if self.ctrl is not self.data:
            extra = self.ctrl.wire_snapshot()
            for k in ("sent", "recv", "rejects", "connects", "links"):
                wire[k] = wire[k] + extra[k]
        return {
            "type": "stats",
            "party": self.party,
            "queries": self.queries,
            "wire": wire,
            "clock": {"t_recv": time.time(), "t_reply": time.time()},
        }

    # -- main loop ------------------------------------------------------------
    def serve(self) -> None:
        """Process control messages until shutdown (or a fatal transport
        failure). Execution errors are reported to the coordinator and the
        loop continues; an injected crash (``fault_after``) tears the whole
        server down the way a dead process would."""
        while True:
            try:
                frame = self.ctrl.recv(COORD, timeout=None)
            except TransportError:
                return  # coordinator is gone; nothing to serve
            msg = pickle.loads(frame.body)
            mtype = msg.get("type")
            try:
                if mtype == "hello":
                    self._reply({"type": "hello_ack", "party": self.party})
                elif mtype == "load_tables":
                    self._reply(self._handle_load_tables(msg))
                elif mtype == "execute":
                    self._reply(self._handle_execute(msg))
                elif mtype == "stats":
                    self._reply(self._handle_stats())
                elif mtype == "shutdown":
                    self._reply({"type": "bye", "party": self.party})
                    return
                else:
                    self._reply({
                        "type": "error",
                        "party": self.party,
                        "error": f"unknown message type {mtype!r}",
                        "reason": "protocol",
                    })
            except TransportError as e:
                if e.reason == "crashed" and self.fault_after is not None:
                    return  # injected crash: die silently, like a real one
                try:
                    self._reply({
                        "type": "error",
                        "party": self.party,
                        "error": str(e),
                        "reason": e.reason,
                    })
                except TransportError:
                    return
            except Exception as e:  # report, keep serving
                self._reply({
                    "type": "error",
                    "party": self.party,
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc(),
                    "reason": "execution",
                })

    def close(self) -> None:
        self.ctrl.close()
        self.data.close()
