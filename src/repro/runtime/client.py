"""ReflexClient: one client API over both execution topologies.

The facade exposes the service verbs — ``submit`` / ``enqueue`` / ``drain``
/ ``explain`` / ``explain_analyze`` / ``status`` — identically whether
queries execute

* **in-process** (:meth:`ReflexClient.in_process`): the classic
  single-process oracle, an :class:`~repro.service.AnalyticsService` over a
  local :class:`~repro.engine.Engine`; or
* **networked** (:meth:`ReflexClient.networked`): the same service stack
  (compiler, plan cache, accountant, scheduler, calibration) with a
  :class:`~repro.runtime.coordinator.RemoteEngine` under it, dispatching
  every engine pass to three party processes over a real transport.

Callers cannot tell the difference by return types: both modes yield the
same ``QueryResult`` / report / status objects, and the networked mode is
bit-exact with the oracle by construction (verified per exchange and
re-audited per query). The only behavioural deltas in networked mode are
pinned constructor arguments: ``jit_ops=False`` (jit replay skips protocol
bodies, hence exchange boundaries) and ``offline="off"`` (the randomness
pool is engine-local; party processes derive material on demand so their
ledgers stay in lockstep).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax

from ..config import RuntimeConfig, current_config
from ..errors import TransportError
from ..obs.distributed import WireMetricsPublisher
from ..ops.table import SecretTable
from ..service.service import AnalyticsService, QueryResult, TenantSession
from .coordinator import Coordinator, RemoteEngine, launch_loopback_mesh

__all__ = ["ReflexClient"]


class ReflexClient:
    """Unified front door for Reflex analytics, any topology.

    Construct via :meth:`in_process` or :meth:`networked`; the instance then
    behaves the same way in both modes. The underlying service remains
    reachable as ``client.service`` for advanced introspection
    (``service.metrics``, ``service.accountant`` …)."""

    def __init__(
        self,
        service: AnalyticsService,
        *,
        coordinator: Optional[Coordinator] = None,
        _own_coordinator: bool = False,
    ):
        self.service = service
        self.coordinator = coordinator
        self._own_coordinator = _own_coordinator
        self._wire_pub: Optional[WireMetricsPublisher] = None

    # -- constructors ----------------------------------------------------------
    @classmethod
    def in_process(cls, tables: Dict[str, SecretTable], **service_kwargs):
        """Single-process execution (the oracle the networked mode is
        checked against). ``service_kwargs`` pass through to
        :class:`AnalyticsService`."""
        return cls(AnalyticsService(tables, **service_kwargs))

    @classmethod
    def networked(
        cls,
        tables: Dict[str, SecretTable],
        *,
        coordinator: Optional[Coordinator] = None,
        key_seed: int = 0,
        config: Optional[RuntimeConfig] = None,
        **service_kwargs,
    ):
        """Three-party execution behind the same verbs.

        With no ``coordinator``, an in-process loopback mesh is launched
        (three party servers on threads — the single-host topology); pass a
        :func:`~repro.runtime.coordinator.connect_tcp` coordinator to drive
        external party processes instead. Either way the client ships the
        share triples, the engine key seed, and the resolved
        :class:`RuntimeConfig` to all parties so the three simulations are
        identical."""
        for banned, why in (
            ("jit_ops", "networked execution requires eager protocol bodies"),
            ("offline", "the randomness pool is engine-local"),
            ("engine_factory", "the networked client installs RemoteEngine"),
        ):
            if service_kwargs.pop(banned, None):
                raise ValueError(f"networked(): {banned} is pinned ({why})")
        own = coordinator is None
        if own:
            coordinator, _servers, _threads = launch_loopback_mesh()
        cfg = config if config is not None else current_config()
        coordinator.load_tables(tables, key_seed=key_seed, config=cfg)

        def factory(tbls, **kw):
            kw["jit_ops"] = False
            return RemoteEngine(tbls, coordinator, **kw)

        svc = AnalyticsService(
            tables,
            key=jax.random.PRNGKey(int(key_seed)),
            jit_ops=False,
            offline="off",
            config=cfg,
            engine_factory=factory,
            **service_kwargs,
        )
        return cls(svc, coordinator=coordinator, _own_coordinator=own)

    # -- mode ------------------------------------------------------------------
    @property
    def mode(self) -> str:
        return "in_process" if self.coordinator is None else "networked"

    # -- the client verbs (identical across modes) -----------------------------
    def submit(self, tenant: str, sql: str) -> QueryResult:
        return self.service.submit(tenant, sql)

    def enqueue(self, tenant: str, sql: str):
        return self.service.enqueue(tenant, sql)

    def drain(self, force: bool = True) -> List[QueryResult]:
        return self.service.drain(force=force)

    def explain(self, sql: str) -> str:
        return self.service.explain(sql)

    def explain_analyze(self, tenant: str, sql: str):
        return self.service.explain_analyze(tenant, sql)

    def status(self) -> Dict:
        st = self.service.status()
        st["runtime"] = {"mode": self.mode}
        if self.coordinator is not None:
            eng = self.service.engine
            st["runtime"]["wire_audit"] = getattr(eng, "last_wire_audit", [])
            st["runtime"]["mesh"] = self._mesh_health()
        return st

    def _mesh_health(self) -> Dict:
        """Pull the ``stats`` control verb, publish the snapshots into this
        service's metrics registry as ``reflex_wire_*`` series, and return a
        compact per-party health summary (liveness, seq watermarks, byte
        totals). Works identically over loopback and TCP meshes."""
        try:
            stats = self.coordinator.stats()
        except TransportError as e:
            return {"ok": False, "reason": e.reason}
        if self._wire_pub is None:
            self._wire_pub = WireMetricsPublisher(self.service.metrics)
        parties = []
        for entry in stats["parties"]:
            self._wire_pub.publish(entry["wire"])
            w = entry["wire"]
            parties.append({
                "party": entry["party"],
                "up": True,
                "queries": entry["queries"],
                "bytes": {
                    "sent": sum(s["bytes"] for s in w["sent"]),
                    "recv": sum(s["bytes"] for s in w["recv"]),
                },
                "links": w["links"],
                "rejects": sum(r["count"] for r in w["rejects"]),
            })
        self._wire_pub.publish(stats["coordinator"])
        for p, rtt in stats["rtt_seconds"].items():
            self._wire_pub.observe_roundtrip(p, rtt)
        return {
            "ok": True,
            "parties": parties,
            "rtt_seconds": stats["rtt_seconds"],
        }

    def session(self, tenant: str) -> TenantSession:
        return self.service.session(tenant)

    def cache_stats(self) -> Dict[str, float]:
        return self.service.cache_stats()

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        """Stop background service work; in networked mode also shut the
        party mesh down (owned loopback meshes are fully torn down; an
        externally provided coordinator is shut down but its processes'
        lifecycle belongs to whoever launched them)."""
        self.service.close()
        if self.coordinator is not None:
            self.coordinator.shutdown()
            self.coordinator.close()

    def __enter__(self) -> "ReflexClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
