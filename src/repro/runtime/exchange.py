"""RingExchange: the bridge from ledger sync points to real wire traffic.

Execution model (DESIGN.md §16.3): every party process runs the SAME
deterministic simulation — same engine key, hence identical canonical share
triples, identical noise draws, and an identical stream of ledger entries.
What differs per party is what crosses the wire: at each top-level
:class:`~repro.core.ledger.CommLedger` entry the installed
:class:`RingExchange` sends exactly ``bytes_per_party`` bytes around the
resharing ring (party ``p`` sends to ``(p+2) % 3`` — its predecessor, the
direction of the mul/AND resharing hop — and receives from ``(p+1) % 3``)
and blocks until the matching frame arrives, so the wire carries the
ledger's byte count op-for-op and the parties advance in lockstep.

Frame bodies are *verifiable*: when the protocol layer handed the ledger a
``payload`` (the canonical 3-share array at that sync point — mul/AND
reshares, reveal openings), the body is this party's own share slice and the
receiver checks it bit-for-bit against the slice it derived locally — any
cross-process divergence (different keys, different plan, nondeterminism)
fails loudly as ``TransportError(reason="divergence")`` at the exact op.
Entries without a payload (fused circuit rounds, jit-replay tallies) carry a
deterministic PRF-style filler derived from (src, op, link seq) that the
receiver reproduces and checks the same way.

``fault_after`` (die after N exchanges) exists for the party-crash tests:
the driver closes the transport mid-query, so peers observe a dropped link,
not a tidy farewell.
"""
from __future__ import annotations

import hashlib
import time
from typing import List, Optional

import numpy as np

from ..errors import TransportError
from .transport import DATA, Transport

__all__ = ["RingExchange"]


def _filler(src: int, op: str, seq: int, nbytes: int) -> bytes:
    """Deterministic pseudo-random body both link ends can derive: a SHA-256
    counter stream keyed by the link-visible (src, op, seq) identity."""
    out = bytearray()
    ctr = 0
    seed = f"{src}|{op}|{seq}".encode()
    while len(out) < nbytes:
        out += hashlib.sha256(seed + ctr.to_bytes(8, "big")).digest()
        ctr += 1
    return bytes(out[:nbytes])


def _payload_body(payload, share_idx: int, nbytes: int, src: int, op: str,
                  seq: int) -> bytes:
    """One party's share slice of a canonical (3, ...) payload, normalized to
    exactly ``nbytes`` (the ledger's logical byte count — padded with filler
    when the in-memory dtype is wider than the ring's logical width,
    truncated when narrower; both ends apply the same rule, so verification
    is unaffected)."""
    arr = np.asarray(payload)
    raw = np.ascontiguousarray(arr[share_idx]).tobytes()
    if len(raw) >= nbytes:
        return raw[:nbytes]
    return raw + _filler(src, op + "#pad", seq, nbytes - len(raw))


class RingExchange:
    """Exchange driver installed via :func:`repro.core.ledger.exchange_scope`
    on a party's execution thread."""

    def __init__(
        self,
        transport: Transport,
        party: int,
        *,
        timeout: float = 60.0,
        fault_after: Optional[int] = None,
    ):
        self.transport = transport
        self.party = party
        self.send_to = (party + 2) % 3  # the resharing hop's direction
        self.recv_from = (party + 1) % 3
        self.timeout = timeout
        self.fault_after = fault_after
        self.count = 0
        # per-exchange (op, wire bytes, rounds) — the coordinator audits this
        # against the execution report's ledger tallies op by op
        self.log: List[dict] = []
        self.wire_bytes = 0
        # network stall: seconds this party spent blocked waiting for the
        # inbound frame at sync points (everything else is local compute).
        # Per-party, never audited for equality — clocks and schedulers
        # differ across processes even when the simulation is identical.
        self.stall_seconds = 0.0

    def exchange(self, op: str, rounds: int, nbytes, payload=None) -> None:
        nbytes = int(nbytes)
        if self.fault_after is not None and self.count >= self.fault_after:
            # simulate a party dying mid-protocol: drop every link, then
            # fail the local execution
            self.transport.close()
            raise TransportError(
                f"party {self.party}: injected crash after "
                f"{self.count} exchanges",
                party=self.party, op=op, reason="crashed",
            )
        seq = self.count
        if payload is not None:
            body = _payload_body(
                payload, self.party, nbytes, self.party, op, seq
            )
            expect = _payload_body(
                payload, self.recv_from, nbytes, self.recv_from, op, seq
            )
        else:
            body = _filler(self.party, op, seq, nbytes)
            expect = _filler(self.recv_from, op, seq, nbytes)
        self.transport.send(self.send_to, op, body, kind=DATA)
        t0 = time.perf_counter()
        got = self.transport.recv(self.recv_from, timeout=self.timeout)
        self.stall_seconds += time.perf_counter() - t0
        if got.op != op:
            raise TransportError(
                f"party {self.party}: exchange {seq} expected op {op!r}, "
                f"peer {self.recv_from} sent {got.op!r} — parties diverged",
                party=self.party, peer=self.recv_from, seq=seq, op=op,
                reason="divergence",
            )
        if len(got.body) != nbytes or got.body != expect:
            raise TransportError(
                f"party {self.party}: exchange {seq} ({op}) body mismatch "
                f"({len(got.body)} bytes vs expected {nbytes}) — parties "
                f"diverged",
                party=self.party, peer=self.recv_from, seq=seq, op=op,
                reason="divergence",
            )
        self.count += 1
        self.wire_bytes += nbytes
        self.log.append({"op": op, "bytes": nbytes, "rounds": int(rounds)})

    def by_op(self) -> dict:
        agg: dict = {}
        for e in self.log:
            a = agg.setdefault(e["op"], {"bytes": 0, "exchanges": 0})
            a["bytes"] += e["bytes"]
            a["exchanges"] += 1
        return agg

    def log_summary(self) -> dict:
        """Compact deterministic form of the exchange log for capped execute
        replies: exact byte/round/entry totals plus the per-op aggregation
        and the first few entries. Pure functions of the full log, so the
        summaries of lockstepped parties are equal iff their logs are —
        the coordinator's cross-party equality audit keeps working."""
        return {
            "summary": True,
            "entries": len(self.log),
            "bytes": self.wire_bytes,
            "rounds": sum(e["rounds"] for e in self.log),
            "by_op": self.by_op(),
            "head": self.log[:8],
        }
