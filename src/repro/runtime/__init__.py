"""Multi-party runtime: real processes, real sockets, one client API.

Layers (DESIGN.md §16):

* :mod:`~repro.runtime.transport` — length-prefixed CRC-checked framing
  over loopback queues or TCP, with per-link sequence numbers.
* :mod:`~repro.runtime.exchange` — the ring-exchange driver that turns
  every :class:`~repro.core.ledger.CommLedger` sync point into a verified
  wire exchange.
* :mod:`~repro.runtime.party` — one RSS party's server loop.
* :mod:`~repro.runtime.coordinator` — drives three parties, audits
  wire-vs-ledger bytes, reassembles results (:class:`RemoteEngine`).
* :mod:`~repro.runtime.client` — :class:`ReflexClient`, the unified facade
  over in-process and networked execution.
"""
from .client import ReflexClient
from .coordinator import (
    Coordinator,
    RemoteEngine,
    connect_tcp,
    launch_loopback_mesh,
)
from .exchange import RingExchange
from .party import PartyServer, decode_table, encode_table
from .transport import (
    COORD,
    CTRL,
    DATA,
    Frame,
    LoopbackMesh,
    LoopbackTransport,
    TcpTransport,
    Transport,
    decode_frame,
    encode_frame,
)

__all__ = [
    "ReflexClient",
    "Coordinator",
    "RemoteEngine",
    "connect_tcp",
    "launch_loopback_mesh",
    "RingExchange",
    "PartyServer",
    "encode_table",
    "decode_table",
    "Transport",
    "LoopbackMesh",
    "LoopbackTransport",
    "TcpTransport",
    "Frame",
    "encode_frame",
    "decode_frame",
    "DATA",
    "CTRL",
    "COORD",
]
