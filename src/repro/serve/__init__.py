from .serve_step import make_prefill_step, make_serve_step, prefill  # noqa: F401
from .batching import BucketedBatcher  # noqa: F401
