"""Serving steps: prefill (build caches from a prompt) and decode (one token).

``serve_step`` is what the decode_32k / long_500k dry-run cells lower: one new
token against a KV cache of the shape's length. Caches are group-stacked to
match the scan-over-layers parameter layout.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import decode_step, forward
from ..models.lm import _apply_block, _embed_inputs, apply_norm  # noqa: F401

__all__ = ["prefill", "make_prefill_step", "make_serve_step"]


def prefill(cfg, params, batch) -> Tuple[jax.Array, Dict]:
    """Forward over the prompt, returning logits and decode caches."""
    x, positions = _embed_inputs(cfg, params, batch)

    def group_body(x, group_params):
        caches = {}
        for pos in range(cfg.pattern_period):
            x, _, c = _apply_block(
                cfg,
                group_params[str(pos)],
                cfg.block_pattern[pos],
                x,
                positions,
                return_cache=True,
            )
            caches[str(pos)] = c
        return x, caches

    if cfg.scan_layers:
        x, caches = jax.lax.scan(group_body, x, params["layers"])
    else:
        outs = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda p: p[g], params["layers"])
            x, c = group_body(x, gp)
            outs.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, -1:] @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, caches


def make_prefill_step(cfg) -> Callable:
    def prefill_step(params, batch):
        logits, _ = forward(cfg, params, batch)
        return logits[:, -1:]

    return prefill_step


def make_serve_step(cfg) -> Callable:
    def serve_step(params, caches, batch):
        return decode_step(cfg, params, caches, batch)

    return serve_step
