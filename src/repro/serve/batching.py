"""Bucketed continuous batching — the Resizer's reveal-and-trim bucketing
reused on plaintext serving shapes (DESIGN.md §5).

Incoming requests of ragged lengths are padded up to bucket boundaries
(powers of two by default) so the number of compiled (batch, len) shapes is
bounded — the same disclosure/performance dial as the MPC engine's bucketed
trim, minus the privacy semantics."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["BucketedBatcher", "next_bucket"]


def next_bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray


class BucketedBatcher:
    """Groups pending requests into (bucket_len, batch) lots."""

    def __init__(
        self,
        len_buckets: Sequence[int] = (128, 256, 512, 1024, 2048, 4096),
        batch_buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
        pad_id: int = 0,
    ):
        self.len_buckets = tuple(len_buckets)
        self.batch_buckets = tuple(batch_buckets)
        self.pad_id = pad_id
        self.pending: List[Request] = []
        self._next_rid = 0

    def submit(self, tokens: np.ndarray) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append(Request(rid, np.asarray(tokens)))
        return rid

    def next_batch(self, max_batch: int = 32) -> Tuple[Dict, List[int]]:
        """Pops up to max_batch requests sharing a length bucket; returns the
        padded batch dict and the request ids (order preserved)."""
        if not self.pending:
            return {}, []
        # group by bucket; serve the fullest bucket first
        by_bucket: Dict[int, List[Request]] = {}
        for r in self.pending:
            b = next_bucket(len(r.tokens), self.len_buckets)
            by_bucket.setdefault(b, []).append(r)
        bucket, reqs = max(by_bucket.items(), key=lambda kv: len(kv[1]))
        reqs = reqs[:max_batch]
        batch_n = next_bucket(len(reqs), self.batch_buckets)
        ids = {r.rid for r in reqs}
        self.pending = [r for r in self.pending if r.rid not in ids]

        toks = np.full((batch_n, bucket), self.pad_id, np.int32)
        mask = np.zeros((batch_n, bucket), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : len(r.tokens)] = r.tokens
            mask[i, : len(r.tokens)] = 1
        batch = {"tokens": toks, "mask": mask}
        return batch, [r.rid for r in reqs]

    @property
    def n_pending(self) -> int:
        return len(self.pending)
