"""Terminal aggregates: COUNT(*), COUNT(DISTINCT col), SUM(col), AVG(col),
MIN(col), MAX(col).

These produce 1-row tables. Additions are local under arithmetic sharing, so
after a bit2a (2 rounds) / b2a (2 rounds) conversion the reduction is free —
the reason analytics-over-MPC is dominated by the *relational* operators, not
the final aggregation.

AVG is the (sum, count) pair as arithmetic shares: secure division is
disproportionately expensive in MPC, and every comparable engine (Conclave's
aggregation backends, SPECIAL) reveals sum and count and divides in the
clear. The service layer derives ``avg = sum // count`` at reveal time.

MIN/MAX are a sort-head over the existing bitonic machinery: invalid rows
sink past the extremum via the ORDER BY sentinel keying, so the head row of
the sorted table IS the answer (and is itself invalid when no true rows
exist — MIN over an empty selection reveals no row at all).
"""
from __future__ import annotations

from ..core.circuits import b2a, bit2a
from ..core.prf import PRFSetup
from ..core.sharing import mul
from .distinct import oblivious_distinct
from .table import SecretTable

__all__ = [
    "count_valid",
    "count_distinct",
    "sum_column",
    "avg_column",
    "min_column",
    "max_column",
]


def count_valid(table: SecretTable, prf: PRFSetup, name: str = "cnt") -> SecretTable:
    """COUNT(*) over true rows -> 1-row table with an arithmetic count."""
    bits = bit2a(table.valid, prf.fold(701))
    total = bits.sum(axis=0)
    one = total.map_shares(lambda s: s[:, None])
    from ..core.sharing import const_b

    return SecretTable({name: one}, const_b(1, (1,)))


def count_distinct(
    table: SecretTable, col: str, prf: PRFSetup, name: str = "cnt"
) -> SecretTable:
    d = oblivious_distinct(table, col, prf)
    return count_valid(d, prf, name)


def sum_column(
    table: SecretTable, col: str, prf: PRFSetup, name: str = "sum"
) -> SecretTable:
    """SUM(col) over true rows: mask by validity (1 mult) then local-reduce."""
    vals = b2a(table.bshare_col(col, prf), prf.fold(711))
    bits = bit2a(table.valid, prf.fold(712))
    masked = mul(vals, bits, prf.fold(713))
    total = masked.sum(axis=0)
    one = total.map_shares(lambda s: s[:, None])
    from ..core.sharing import const_b

    return SecretTable({name: one}, const_b(1, (1,)))


def _extreme_column(
    table: SecretTable, col: str, prf: PRFSetup, name: str, descending: bool
) -> SecretTable:
    """Sort-head extremum: one oblivious sort on ``col`` (invalid rows keyed
    to the far sentinel so they sink past every true row), then a public
    1-row head slice. The head row's validity bit is the \"selection was
    non-empty\" bit, so an empty selection reveals nothing.

    Only the aggregated column (plus validity) rides the bitonic network —
    every other payload column would be sorted just to be discarded by the
    1-row head, multiplying the sort's comparison traffic by the width."""
    from .orderby import oblivious_orderby

    slim = SecretTable({col: table.cols[col]}, table.valid)
    out = oblivious_orderby(slim, col, prf, descending=descending, limit=1)
    return SecretTable({name: out.cols[col]}, out.valid)


def min_column(
    table: SecretTable, col: str, prf: PRFSetup, name: str = "min"
) -> SecretTable:
    """MIN(col) over true rows -> 1-row table with a boolean-share word."""
    return _extreme_column(table, col, prf, name, descending=False)


def max_column(
    table: SecretTable, col: str, prf: PRFSetup, name: str = "max"
) -> SecretTable:
    """MAX(col) over true rows -> 1-row table with a boolean-share word."""
    return _extreme_column(table, col, prf, name, descending=True)


def avg_column(
    table: SecretTable, col: str, prf: PRFSetup, name: str = "avg"
) -> SecretTable:
    """AVG(col) over true rows -> 1-row table carrying ``{name}_sum`` and
    ``{name}_cnt`` arithmetic shares (division happens post-reveal; see
    module docstring)."""
    vals = b2a(table.bshare_col(col, prf), prf.fold(721))
    bits = bit2a(table.valid, prf.fold(722))
    masked = mul(vals, bits, prf.fold(723))
    total = masked.sum(axis=0).map_shares(lambda s: s[:, None])
    cnt = bits.sum(axis=0).map_shares(lambda s: s[:, None])
    from ..core.sharing import const_b

    return SecretTable(
        {f"{name}_sum": total, f"{name}_cnt": cnt}, const_b(1, (1,))
    )
