"""Terminal aggregates: COUNT(*), COUNT(DISTINCT col), SUM(col), AVG(col).

These produce 1-row tables. Additions are local under arithmetic sharing, so
after a bit2a (2 rounds) / b2a (2 rounds) conversion the reduction is free —
the reason analytics-over-MPC is dominated by the *relational* operators, not
the final aggregation.

AVG is the (sum, count) pair as arithmetic shares: secure division is
disproportionately expensive in MPC, and every comparable engine (Conclave's
aggregation backends, SPECIAL) reveals sum and count and divides in the
clear. The service layer derives ``avg = sum // count`` at reveal time.
"""
from __future__ import annotations

from ..core.circuits import b2a, bit2a
from ..core.prf import PRFSetup
from ..core.sharing import mul
from .distinct import oblivious_distinct
from .table import SecretTable

__all__ = ["count_valid", "count_distinct", "sum_column", "avg_column"]


def count_valid(table: SecretTable, prf: PRFSetup, name: str = "cnt") -> SecretTable:
    """COUNT(*) over true rows -> 1-row table with an arithmetic count."""
    bits = bit2a(table.valid, prf.fold(701))
    total = bits.sum(axis=0)
    one = total.map_shares(lambda s: s[:, None])
    from ..core.sharing import const_b

    return SecretTable({name: one}, const_b(1, (1,)))


def count_distinct(
    table: SecretTable, col: str, prf: PRFSetup, name: str = "cnt"
) -> SecretTable:
    d = oblivious_distinct(table, col, prf)
    return count_valid(d, prf, name)


def sum_column(
    table: SecretTable, col: str, prf: PRFSetup, name: str = "sum"
) -> SecretTable:
    """SUM(col) over true rows: mask by validity (1 mult) then local-reduce."""
    vals = b2a(table.bshare_col(col, prf), prf.fold(711))
    bits = bit2a(table.valid, prf.fold(712))
    masked = mul(vals, bits, prf.fold(713))
    total = masked.sum(axis=0)
    one = total.map_shares(lambda s: s[:, None])
    from ..core.sharing import const_b

    return SecretTable({name: one}, const_b(1, (1,)))


def avg_column(
    table: SecretTable, col: str, prf: PRFSetup, name: str = "avg"
) -> SecretTable:
    """AVG(col) over true rows -> 1-row table carrying ``{name}_sum`` and
    ``{name}_cnt`` arithmetic shares (division happens post-reveal; see
    module docstring)."""
    vals = b2a(table.bshare_col(col, prf), prf.fold(721))
    bits = bit2a(table.valid, prf.fold(722))
    masked = mul(vals, bits, prf.fold(723))
    total = masked.sum(axis=0).map_shares(lambda s: s[:, None])
    cnt = bits.sum(axis=0).map_shares(lambda s: s[:, None])
    from ..core.sharing import const_b

    return SecretTable(
        {f"{name}_sum": total, f"{name}_cnt": cnt}, const_b(1, (1,))
    )
