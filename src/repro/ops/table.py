"""SecretTable: a relation under 3-party replicated secret sharing.

Columns are XOR-shared 32-bit words (:class:`BShare`) — the comparison-friendly
representation (Secrecy-style). Aggregate columns produced by GroupBy live as
arithmetic shares (:class:`AShare`) and are converted lazily (``a2b``) when a
downstream operator needs to compare or sort on them.

``valid`` is the secret single-bit column marking true output tuples (§2.2 of
the paper). The *public* row count ``n`` is the oblivious size N.

Lazy columns
------------
A column may also be a :class:`LazyGather` — a deferred row-gather view
``value = base[index]`` of a physical base column, with a *public* index map.
The oblivious join produces these instead of materializing every payload
column at the |R1| x |R2| Cartesian size: the N1*N2-row table then costs
O(N1*N2) (the valid column + index maps) instead of O(N1*N2 * cols), and the
next Resizer gathers only the S surviving rows from the base tables
(DESIGN.md §7.2). Gathers with public indices compose lazily
(``gather_rows``); the first operator that needs the physical shares
(``col`` / ``bshare_col``) materializes in place.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.circuits import a2b
from ..core.prf import PRFSetup
from ..core.sharing import AShare, BShare, share_b, reveal_a, reveal_b

Share = Union[AShare, BShare]

__all__ = ["SecretTable", "LazyGather", "gather_log", "reset_gather_log", "table_nbytes"]


# Instrumentation: every physical gather realized from a LazyGather records
# its output row count here (tests assert payload is never expanded to the
# product-grid size before trim; the benchmarks report peak realized rows).
# Thread-local (concurrent engines must not interleave) and bounded (a
# serving session materializes lazy columns on every query, forever).
_GATHER_LOG_MAX = 4096
_GATHER_STATE = threading.local()


def _gather_log() -> "deque":
    if not hasattr(_GATHER_STATE, "log"):
        _GATHER_STATE.log = deque(maxlen=_GATHER_LOG_MAX)
    return _GATHER_STATE.log


def gather_log() -> List[int]:
    return list(_gather_log())


def reset_gather_log() -> None:
    _gather_log().clear()


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LazyGather:
    """Deferred row-gather view of a base column: ``value = base[index]``.

    ``index`` is public (it encodes only *structure* — e.g. the Cartesian
    product layout row -> (i, j) — never data). Composing a further public
    gather stays lazy; padding or any share-level access materializes.
    """

    base: Share
    index: jnp.ndarray  # (n,) public int32 row map into base

    # -- pytree ---------------------------------------------------------------
    def tree_flatten(self):
        return (self.base, self.index), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- structure ------------------------------------------------------------
    @property
    def shape(self):
        return tuple(self.index.shape) + self.base.shape[1:]

    @property
    def size(self) -> int:
        s = 1
        for d in self.shape:
            s *= d
        return s

    @property
    def ring(self):
        return self.base.ring

    # -- lazy ops -------------------------------------------------------------
    def take(self, indices, axis: int = 0) -> "LazyGather":
        if axis != 0:
            raise ValueError("LazyGather only supports row (axis 0) gathers")
        return LazyGather(self.base, jnp.take(self.index, indices, axis=0))

    def gather(self, rows) -> Share:
        """Materialize only the given output rows: ``base[index[rows]]`` —
        the Resizer's trim-time path (O(S) rows, never the full view)."""
        idx = jnp.take(self.index, jnp.asarray(rows), axis=0)
        _gather_log().append(int(idx.shape[0]))
        return self.base.take(idx, axis=0)

    def materialize(self) -> Share:
        _gather_log().append(int(self.index.shape[0]))
        return self.base.take(self.index, axis=0)

    def pad_rows(self, n_rows: int) -> Share:
        return self.materialize().pad_rows(n_rows)

    def nbytes(self) -> int:
        """Actual backing-store footprint: base shares + public index map."""
        return int(self.base.shares.nbytes) + int(self.index.nbytes)


Column = Union[AShare, BShare, LazyGather]


def table_nbytes(table: "SecretTable") -> int:
    """Physical bytes held by a table (share arrays + lazy index maps) —
    the benchmarks' intermediate-size metric. Aliased buffers (e.g. the one
    product-layout index map shared by every LazyGather of the same side)
    are counted once."""
    seen = set()
    total = 0

    def add(arr) -> None:
        nonlocal total
        if id(arr) not in seen:
            seen.add(id(arr))
            total += int(arr.nbytes)

    add(table.valid.shares)
    for c in table.cols.values():
        if isinstance(c, LazyGather):
            add(c.base.shares)
            add(c.index)
        else:
            add(c.shares)
    return total


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SecretTable:
    cols: Dict[str, Column]
    valid: BShare  # (n,) single-bit

    # -- pytree ---------------------------------------------------------------
    def tree_flatten(self):
        # Preserve insertion order: protocols derive per-column PRF folds from
        # dict position (e.g. bitonic_sort's select gates), so a table that
        # round-trips through a jax transform (vmap in the batched engine
        # pass, jit) must reconstruct with the same column order it was
        # built with — sorting here would silently re-key that randomness.
        names = tuple(self.cols)
        return tuple(self.cols[k] for k in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(dict(zip(names, children[:-1])), children[-1])

    # -- structure ------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.valid.shape[0]

    @property
    def width_bytes(self) -> int:
        """Plaintext row width in bytes (columns + valid bit word)."""
        return 4 * (len(self.cols) + 1)

    def column_names(self):
        return list(self.cols)

    def lazy_names(self):
        return [k for k, v in self.cols.items() if isinstance(v, LazyGather)]

    def select_columns(self, names) -> "SecretTable":
        return SecretTable({k: self.cols[k] for k in names}, self.valid)

    def rename(self, mapping: Dict[str, str]) -> "SecretTable":
        return SecretTable(
            {mapping.get(k, k): v for k, v in self.cols.items()}, self.valid
        )

    def with_prefix(self, prefix: str) -> "SecretTable":
        return SecretTable(
            {f"{prefix}.{k}" if "." not in k else k: v for k, v in self.cols.items()},
            self.valid,
        )

    def gather_rows(self, idx) -> "SecretTable":
        """Public row gather; lazy columns compose (stay lazy)."""
        return SecretTable(
            {k: v.take(idx, axis=0) for k, v in self.cols.items()},
            self.valid.take(idx, axis=0),
        )

    def pad_rows(self, n_rows: int) -> "SecretTable":
        """Pad with rows whose shares are all-zero: value 0, valid 0 — a valid
        sharing of an invalid filler tuple. (Materializes lazy columns: filler
        shares cannot be represented as a base-row view.)"""
        return SecretTable(
            {k: v.pad_rows(n_rows) for k, v in self.cols.items()},
            self.valid.pad_rows(n_rows),
        )

    def col(self, name: str) -> Share:
        """Column as physical shares — first direct access materializes a
        lazy column in place (cached for later operators)."""
        c = self.cols[name]
        if isinstance(c, LazyGather):
            c = c.materialize()
            self.cols[name] = c
        return c

    def bshare_col(self, name: str, prf: PRFSetup) -> BShare:
        """Column as BShare, converting from AShare if necessary."""
        col = self.col(name)
        if isinstance(col, AShare):
            return a2b(col, prf)
        return col

    # -- I/O (data-owner side / test oracle) ----------------------------------
    @classmethod
    def from_plaintext(
        cls,
        data: Dict[str, np.ndarray],
        key: jax.Array,
        valid: Optional[np.ndarray] = None,
    ) -> "SecretTable":
        n = len(next(iter(data.values())))
        keys = jax.random.split(key, len(data) + 1)
        cols = {
            name: share_b(np.asarray(vals, dtype=np.uint32), k)
            for (name, vals), k in zip(data.items(), keys[:-1])
        }
        v = np.ones(n, dtype=np.uint32) if valid is None else np.asarray(valid, np.uint32)
        return cls(cols, share_b(v, keys[-1]))

    def reveal(self) -> Dict[str, np.ndarray]:
        """Open everything (tests / final results only)."""
        out = {}
        for k in self.cols:
            v = self.col(k)
            out[k] = np.asarray(reveal_a(v) if isinstance(v, AShare) else reveal_b(v))
        out["_valid"] = np.asarray(reveal_b(self.valid)) & 1
        return out

    def reveal_true_rows(self) -> Dict[str, np.ndarray]:
        d = self.reveal()
        mask = d.pop("_valid").astype(bool)
        return {k: v[mask] for k, v in d.items()}
