"""SecretTable: a relation under 3-party replicated secret sharing.

Columns are XOR-shared 32-bit words (:class:`BShare`) — the comparison-friendly
representation (Secrecy-style). Aggregate columns produced by GroupBy live as
arithmetic shares (:class:`AShare`) and are converted lazily (``a2b``) when a
downstream operator needs to compare or sort on them.

``valid`` is the secret single-bit column marking true output tuples (§2.2 of
the paper). The *public* row count ``n`` is the oblivious size N.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.circuits import a2b
from ..core.prf import PRFSetup
from ..core.sharing import AShare, BShare, share_b, reveal_a, reveal_b

Share = Union[AShare, BShare]

__all__ = ["SecretTable"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SecretTable:
    cols: Dict[str, Share]
    valid: BShare  # (n,) single-bit

    # -- pytree ---------------------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.cols))
        return tuple(self.cols[k] for k in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(dict(zip(names, children[:-1])), children[-1])

    # -- structure ------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.valid.shape[0]

    @property
    def width_bytes(self) -> int:
        """Plaintext row width in bytes (columns + valid bit word)."""
        return 4 * (len(self.cols) + 1)

    def column_names(self):
        return list(self.cols)

    def select_columns(self, names) -> "SecretTable":
        return SecretTable({k: self.cols[k] for k in names}, self.valid)

    def rename(self, mapping: Dict[str, str]) -> "SecretTable":
        return SecretTable(
            {mapping.get(k, k): v for k, v in self.cols.items()}, self.valid
        )

    def with_prefix(self, prefix: str) -> "SecretTable":
        return SecretTable(
            {f"{prefix}.{k}" if "." not in k else k: v for k, v in self.cols.items()},
            self.valid,
        )

    def gather_rows(self, idx) -> "SecretTable":
        return SecretTable(
            {k: v.take(idx, axis=0) for k, v in self.cols.items()},
            self.valid.take(idx, axis=0),
        )

    def pad_rows(self, n_rows: int) -> "SecretTable":
        """Pad with rows whose shares are all-zero: value 0, valid 0 — a valid
        sharing of an invalid filler tuple."""
        return SecretTable(
            {k: v.pad_rows(n_rows) for k, v in self.cols.items()},
            self.valid.pad_rows(n_rows),
        )

    def bshare_col(self, name: str, prf: PRFSetup) -> BShare:
        """Column as BShare, converting from AShare if necessary."""
        col = self.cols[name]
        if isinstance(col, AShare):
            return a2b(col, prf)
        return col

    # -- I/O (data-owner side / test oracle) ----------------------------------
    @classmethod
    def from_plaintext(
        cls,
        data: Dict[str, np.ndarray],
        key: jax.Array,
        valid: Optional[np.ndarray] = None,
    ) -> "SecretTable":
        n = len(next(iter(data.values())))
        keys = jax.random.split(key, len(data) + 1)
        cols = {
            name: share_b(np.asarray(vals, dtype=np.uint32), k)
            for (name, vals), k in zip(data.items(), keys[:-1])
        }
        v = np.ones(n, dtype=np.uint32) if valid is None else np.asarray(valid, np.uint32)
        return cls(cols, share_b(v, keys[-1]))

    def reveal(self) -> Dict[str, np.ndarray]:
        """Open everything (tests / final results only)."""
        out = {}
        for k, v in self.cols.items():
            out[k] = np.asarray(reveal_a(v) if isinstance(v, AShare) else reveal_b(v))
        out["_valid"] = np.asarray(reveal_b(self.valid)) & 1
        return out

    def reveal_true_rows(self) -> Dict[str, np.ndarray]:
        d = self.reveal()
        mask = d.pop("_valid").astype(bool)
        return {k: v[mask] for k, v in d.items()}
