"""Fully-oblivious SQL operators (validity-column convention).

Every operator consumes and produces a :class:`~repro.ops.table.SecretTable`
whose public size depends only on its input sizes (never on data): Filter
keeps N rows, Join produces N1*N2 rows, GroupBy keeps N rows with group
representatives marked valid, etc. The hidden ``valid`` column marks true
output tuples — exactly the paper's §2.2 definition. The Resizer
(:mod:`repro.core.resizer`) is the only component that ever changes a public
size.
"""
from .table import SecretTable  # noqa: F401
from .filter import And, Or, Predicate, oblivious_filter  # noqa: F401
from .join import oblivious_join  # noqa: F401
from .join_sortmerge import oblivious_join_sortmerge  # noqa: F401
from .groupby import (  # noqa: F401
    oblivious_groupby_avg,
    oblivious_groupby_count,
    oblivious_groupby_sum,
)
from .orderby import oblivious_orderby  # noqa: F401
from .distinct import oblivious_distinct  # noqa: F401
from .aggregate import (  # noqa: F401
    avg_column,
    count_distinct,
    count_valid,
    max_column,
    min_column,
    sum_column,
)
