"""Oblivious DISTINCT: sort by the column, keep the first row of each run."""
from __future__ import annotations

import jax.numpy as jnp

from ..core.prf import PRFSetup
from ..core.sharing import BShare, select
from ..core.sort import bitonic_sort_narrow
from .groupby import SENTINEL, pad_pow2, segment_starts
from .table import SecretTable

__all__ = ["oblivious_distinct"]


def oblivious_distinct(table: SecretTable, col: str, prf: PRFSetup) -> SecretTable:
    """valid' marks exactly one row per distinct value of ``col`` among valid
    rows. Output size == input size (fully oblivious)."""
    table = pad_pow2(table)
    keyb = table.bshare_col(col, prf)
    vmask = table.valid.lsb_mask()
    sentinel = BShare(jnp.zeros_like(keyb.shares)).xor_public(
        jnp.full(keyb.shape, SENTINEL, dtype=keyb.ring.dtype)
    )
    sort_key = select(vmask, keyb, sentinel, prf.fold(671))

    cols = {"__sk": sort_key, "__valid": table.valid}
    cols.update({k: table.bshare_col(k, prf) for k in table.cols})
    cols = bitonic_sort_narrow(cols, "__sk", prf)
    valid = cols.pop("__valid")
    cols.pop("__sk")

    first = segment_starts(cols[col], valid, prf)
    return SecretTable(cols, first)
