"""Oblivious sort-merge equi-join — breaks the Cartesian compare ceiling.

The product join (:mod:`repro.ops.join`) evaluates one secure equality per
(i, j) pair: O(N1*N2) compare work no matter how selective the join is. This
module implements the sort-based alternative (ORQ-style): tag both inputs with
an origin bit, sort the *union* by ``(key, origin)`` with the existing bitonic
network — O((N1+N2) log^2 (N1+N2)) compare-exchange stages — then derive the
valid column with an oblivious segmented propagation pass over neighbors.

Layout after the union sort (build rows sort before probe rows inside each
key segment, because origin_build = 0 < 1 = origin_probe)::

    [ ...  k k k | k' k' ... ]      key segments (boundaries via one eq vs.
      b b  p p p   b  p            the row above); b = build row, p = probe

Each *probe* row then needs the payload of the matching *build* rows in its
segment. A Kogge-Stone segmented copy-last scan propagates the payload of the
rank-r valid build row forward within its segment (log2 N levels, 3 rounds
each); output copy r marks a probe row valid iff its segment contains at
least r+1 valid build rows. ``fanout`` — a *public* upper bound on build-side
key multiplicity (from catalog metadata) — bounds the number of copies, so
the output has ``fanout * pow2(N1+N2)`` rows instead of ``N1*N2``. With
``fanout=1`` (unique build keys, the PK-FK case) this is a single pass.

Correctness contract: results are identical to the product join *post-trim*
(same set of valid rows, same values on them) provided ``fanout`` really
bounds the number of valid build rows per key — the planner only selects this
algorithm when the catalog declares such a bound.

Narrowing: only ``(key, origin, row-index)`` ride the sorting network; all
payload columns and the valid bit are gathered once post-sort through the
sorted index — a secret permutation — via shuffle-and-reveal
(:func:`repro.core.shuffle.apply_secret_perm`).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from ..core.circuits import a2b, and_bit, eq, eq_public, le
from ..core.ledger import fused_scope
from ..core.prf import PRFSetup
from ..core.sharing import BShare, and_, const_b, select
from ..core.shuffle import apply_secret_perm
from ..core.sort import bitonic_sort
from .groupby import _shift_down, segmented_count
from .join import _disambiguate
from .table import SecretTable

__all__ = ["oblivious_join_sortmerge"]


def _pow2_ceil(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _union_col(col: BShare, before: int, n: int) -> BShare:
    """Place ``col`` at row offset ``before`` of an n-row union column; all
    other rows are zero shares (value 0, and always invalid)."""
    after = n - before - col.shape[0]
    return col.map_shares(
        lambda s: jnp.pad(s, [(0, 0), (before, after)] + [(0, 0)] * (s.ndim - 2))
    )


def _rows(col: BShare, d: int, fill: int) -> BShare:
    """Shift the scan state down by ``d`` along the union-row axis (value
    axis 1 of a (copies, n, ...) share); out-of-range rows read ``fill``."""

    def sh(s):
        pad = jnp.zeros(s.shape[:2] + (d,) + s.shape[3:], s.dtype)
        return jnp.concatenate([pad, s[:, :, :-d]], axis=2)

    out = col.map_shares(sh)
    fills = jnp.zeros(col.shape, dtype=col.ring.dtype).at[:, :d].set(fill)
    return out.xor_public(fills)


def _bcast(col: BShare, copies: int) -> BShare:
    """(n,) -> (copies, n) view (public replication, free)."""
    return col.map_shares(
        lambda s: jnp.broadcast_to(s[:, None, :], (3, copies) + s.shape[1:])
    )


def _empty_like(left: SecretTable, right: SecretTable) -> SecretTable:
    cols: Dict[str, BShare] = {}
    z = jnp.zeros((3, 0), dtype=jnp.uint32)
    for name in left.cols:
        cols[name] = BShare(z)
    for name in right.cols:
        cols[_disambiguate(cols, name)] = BShare(z)
    return SecretTable(cols, BShare(z))


def oblivious_join_sortmerge(
    left: SecretTable,
    right: SecretTable,
    on: Tuple[str, str],
    prf: PRFSetup,
    theta: Optional[Tuple[str, str, str]] = None,
    fanout: int = 1,
    build: str = "left",
) -> SecretTable:
    """Equi-join ``left.on[0] == right.on[1]`` via union sort + segmented
    propagation; output size = fanout * pow2(n1 + n2).

    ``build`` names the side whose rows are propagated ("left"/"right");
    ``fanout`` must publicly bound that side's valid rows per key value.
    ``theta`` is the same optional (left_col, op, right_col) extra predicate
    the product join accepts, op in {"le", "eq"}.
    """
    if build not in ("left", "right"):
        raise ValueError(f"build side must be 'left' or 'right', got {build!r}")
    if fanout < 1:
        raise ValueError(f"fanout must be >= 1, got {fanout}")
    if left.n == 0 or right.n == 0:
        return _empty_like(left, right)

    p = prf.fold(520)
    if build == "left":
        btab, ptab, bkey, pkey = left, right, on[0], on[1]
    else:
        btab, ptab, bkey, pkey = right, left, on[1], on[0]
    nb, nprobe = btab.n, ptab.n
    n = _pow2_ceil(nb + nprobe)

    # ---- union: build rows first, then probe rows, then padding -------------
    ukey = BShare.concat(
        [btab.bshare_col(bkey, p), ptab.bshare_col(pkey, p)]
    ).pad_rows(n)
    origin = const_b(
        jnp.concatenate(
            [
                jnp.zeros(nb, dtype=jnp.uint32),
                jnp.ones(nprobe, dtype=jnp.uint32),
                jnp.zeros(n - nb - nprobe, dtype=jnp.uint32),
            ]
        ),
        (n,),
    )
    uvalid = BShare.concat([btab.valid, ptab.valid]).pad_rows(n)

    payload: Dict[str, BShare] = {"__valid": uvalid}
    bnames = list(btab.cols)
    pnames = list(ptab.cols)
    for name in bnames:
        payload[f"b.{name}"] = _union_col(btab.bshare_col(name, p), 0, n)
    for name in pnames:
        payload[f"p.{name}"] = _union_col(ptab.bshare_col(name, p), nb, n)

    # ---- sort the narrow network (key, origin, row index) -------------------
    net = {
        "__key": ukey,
        "__orig": origin,
        "__idx": const_b(jnp.arange(n, dtype=jnp.uint32), (n,)),
    }
    net = bitonic_sort(net, ["__key", "__orig"], p.fold(1))
    moved = apply_secret_perm(payload, net["__idx"], p.fold(2))
    key_s, orig_s = net["__key"], net["__orig"]
    valid_s = moved["__valid"]

    # ---- segment boundaries & build-row markers -----------------------------
    e = eq(key_s, _shift_down(key_s), p.fold(3))
    e = e.and_public(jnp.ones(n, dtype=e.ring.dtype).at[0].set(0))
    bnd = e.xor_public(e.ring.const(1))  # row 0 always starts a segment
    not_orig = orig_s.xor_public(orig_s.ring.const(1))
    defined = and_bit(not_orig, valid_s, p.fold(4))

    if fanout > 1:
        # rank of each valid build row within its key segment (1-based),
        # then one-hot it across the fanout copies with a single batched
        # public equality
        rank = segmented_count(defined, bnd, p.fold(5))
        rank_b = a2b(rank, p.fold(6))
        rk = _bcast(rank_b, fanout)
        wanted = (jnp.arange(fanout, dtype=jnp.uint32) + 1)[:, None]
        hit = eq_public(rk, jnp.broadcast_to(wanted, (fanout, n)), p.fold(7))
        g = and_bit(_bcast(defined, fanout), hit, p.fold(8))
    else:
        g = defined.reshape(1, n)

    # ---- segmented copy-last propagation of the build payload ---------------
    wb = max(len(bnames), 1)
    if bnames:
        pack = BShare.stack([moved[f"b.{c}"] for c in bnames], axis=1)  # (n, Wb)
    else:
        pack = const_b(0, (n, 1))
    v = _bcast(pack, fanout)  # (fanout, n, Wb)
    f = _bcast(bnd, fanout)  # (fanout, n)
    levels = max(n.bit_length() - 1, 0)
    ps = p.fold(9)
    with fused_scope("sortmerge_scan", rounds=3 * levels):
        d, lvl = 1, 0
        while d < n:
            gl = _rows(g, d, 0)
            vl = _rows(v, d, 0)
            fl = _rows(f, d, 1)
            ng = g.xor_public(g.ring.const(1))
            nf = f.xor_public(f.ring.const(1))
            nfl = fl.xor_public(fl.ring.const(1))
            u = and_(ng, nf, ps.fold(4 * lvl))
            # f | fl shares u's round (independent ANDs)
            f = and_(nf, nfl, ps.fold(4 * lvl + 1)).xor_public(f.ring.const(1))
            t = and_(u, gl, ps.fold(4 * lvl + 2))
            tm = t.lsb_mask().map_shares(
                lambda s: jnp.broadcast_to(s[..., None], s.shape + (wb,))
            )
            v = select(tm, vl, v, ps.fold(4 * lvl + 3))
            g = g ^ t  # t is disjoint from g (t requires g = 0)
            d *= 2
            lvl += 1

    # ---- output validity ----------------------------------------------------
    ov = and_bit(orig_s, valid_s, p.fold(10))  # probe row with a true tuple
    out_valid = and_bit(_bcast(ov, fanout), g, p.fold(11))
    if theta is not None:
        tcol_l, top, tcol_r = theta
        if top not in ("le", "eq"):
            raise ValueError(f"unsupported theta op {top}")
        if build == "left":
            xl = v[:, :, bnames.index(tcol_l)]
            xr = _bcast(moved[f"p.{tcol_r}"], fanout)
        else:
            xl = _bcast(moved[f"p.{tcol_l}"], fanout)
            xr = v[:, :, bnames.index(tcol_r)]
        extra = le(xl, xr, p.fold(12)) if top == "le" else eq(xl, xr, p.fold(12))
        out_valid = and_bit(out_valid, extra, p.fold(13))

    # ---- assemble: fanout copies stacked row-major --------------------------
    def flat(col: BShare) -> BShare:  # (fanout, n) -> (fanout * n,)
        return col.map_shares(lambda s: s.reshape((3, fanout * n) + s.shape[3:]))

    build_out = {name: flat(v[:, :, i]) for i, name in enumerate(bnames)}
    probe_out = {name: flat(_bcast(moved[f"p.{name}"], fanout)) for name in pnames}
    lcols, rcols = (build_out, probe_out) if build == "left" else (probe_out, build_out)
    cols: Dict[str, BShare] = {}
    for name in left.cols:
        cols[name] = lcols[name]
    for name in right.cols:
        cols[_disambiguate(cols, name)] = rcols[name]
    return SecretTable(cols, flat(out_valid))
