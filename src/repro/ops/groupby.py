"""Oblivious GroupBy with COUNT aggregate (single or composite key).

Pipeline (Secrecy-style; the paper notes GroupBy "includes sorting as a
pre-operation"):

1. Build sort keys that send invalid rows to the end (select valid ? key :
   SENTINEL — one AND per key column).
2. Bitonic-sort the table by them (O(log^2 N) stages; composite keys compare
   lexicographically inside each compare-exchange).
3. Mark segment starts (one vectorized equality per key column against the
   row above, ANDed for composite keys).
4. Segmented Kogge-Stone prefix-scan of the valid bits in *arithmetic*
   sharing — additions are free; each of the log2 N levels costs 2 ring
   multiplications (value-carry and flag-OR).
5. Mark each group's last row as the representative: it carries the group's
   COUNT; all other rows stay in the table as invalid fillers (output size ==
   input size, fully oblivious).

Sentinel caveat: group keys must be < 0xFFFFFFFE (documented; dictionary
encodings in the workloads are small ints).
"""
from __future__ import annotations

from typing import List, Sequence, Union

import jax.numpy as jnp

from ..core.circuits import and_bit, b2a, bit2a, eq, or_bit
from ..core.prf import PRFSetup
from ..core.sharing import AShare, BShare, mul, select
from ..core.sort import bitonic_sort_narrow
from .table import SecretTable

__all__ = [
    "oblivious_groupby_count",
    "oblivious_groupby_sum",
    "oblivious_groupby_avg",
    "segment_starts",
    "segmented_count",
    "segmented_reduce",
    "pad_pow2",
]

SENTINEL = 0xFFFFFFFE


def pad_pow2(table: SecretTable) -> SecretTable:
    """Pad to a power-of-two row count (bitonic networks require it). Padding
    rows are all-zero shares: value 0, valid 0 — they sort to the sentinel
    block like any other invalid row."""
    n = table.n
    if n & (n - 1) == 0:
        return table
    return table.pad_rows(1 << n.bit_length())


def _shift_down(col, fill: int = 0):
    """Row i gets row i-1's shares; row 0 gets ``fill`` (public constant)."""
    return col.map_shares(
        lambda s: jnp.concatenate(
            [jnp.full(s.shape[:1] + (1,) + s.shape[2:], 0, s.dtype), s[:, :-1]], axis=1
        )
    ).xor_public(jnp.zeros(col.shape, dtype=col.ring.dtype).at[0].set(fill))


def _shift_up(col, fill: int = 0):
    return col.map_shares(
        lambda s: jnp.concatenate(
            [s[:, 1:], jnp.full(s.shape[:1] + (1,) + s.shape[2:], 0, s.dtype)], axis=1
        )
    ).xor_public(jnp.zeros(col.shape, dtype=col.ring.dtype).at[-1].set(fill))


def segment_starts(
    key: Union[BShare, Sequence[BShare]], valid: BShare, prf: PRFSetup
) -> BShare:
    """start_i = valid_i AND (i == 0 OR key_i != key_{i-1}), where composite
    keys (a sequence of columns) compare equal iff every column does."""
    keys: List[BShare] = [key] if isinstance(key, BShare) else list(key)
    e = eq(keys[0], _shift_down(keys[0]), prf.fold(601))
    for i, k in enumerate(keys[1:]):
        ei = eq(k, _shift_down(k), prf.fold(603).fold(2 * i))
        e = and_bit(e, ei, prf.fold(603).fold(2 * i + 1))
    # row 0 always starts a segment: force e_0 = 0 with a public mask
    n = keys[0].shape[0]
    m = jnp.ones(n, dtype=keys[0].ring.dtype).at[0].set(0)
    e = e.and_public(m)
    not_e = e.xor_public(e.ring.const(1))
    return and_bit(valid, not_e, prf.fold(602))


def _shift_a(x: AShare, d: int, fill: int) -> AShare:
    s = x.shares
    pad = jnp.zeros(s.shape[:1] + (d,) + s.shape[2:], s.dtype)
    shifted = jnp.concatenate([pad, s[:, :-d]], axis=1)
    out = AShare(shifted)
    fills = jnp.zeros(x.shape, dtype=s.dtype).at[:d].set(fill)
    return out.add_public(fills)


def segmented_reduce(vals: AShare, f: AShare, prf: PRFSetup) -> AShare:
    """Segmented inclusive prefix-sum of arithmetic ``vals``.

    Kogge-Stone over the associative combine
    (V, F) o (Vl, Fl) = (V + Vl * (1 - F), F OR Fl); log2(N) levels x 2 ring
    multiplications. ``f`` is the arithmetic {0,1} segment-start flag; it may
    have one fewer trailing dim than ``vals`` (broadcast across lanes) so a
    (sum, count) pair reduces in a single scan.
    """
    n = vals.shape[0]
    d = 1
    lvl = 0
    while d < n:
        vl = _shift_a(vals, d, 0)
        fl = _shift_a(f, d, 1)  # out-of-range neighbors act as boundaries
        keep = -f + 1  # (1 - F): local
        vals = vals + mul(vl, keep, prf.fold(620 + lvl))
        fmul = mul(f, fl, prf.fold(640 + lvl))
        f = f + fl - fmul  # OR
        d *= 2
        lvl += 1
    return vals


def segmented_count(valid: BShare, start: BShare, prf: PRFSetup) -> AShare:
    """Segmented inclusive prefix-sum of the valid bits (count within group)."""
    v = bit2a(valid, prf.fold(611))
    f = bit2a(start, prf.fold(612))
    return segmented_reduce(v, f, prf)


def _masked_sort_keys(table: SecretTable, key_cols, prf: PRFSetup):
    """Sentinel-masked sort keys: select(valid ? key : SENTINEL) per key
    column, so invalid rows sink to the sorted suffix. Returns the sort-key
    column dict and its names in key order."""
    vmask = table.valid.lsb_mask()
    sort_names = []
    cols: dict = {}
    for i, kc in enumerate(key_cols):
        keyb = table.bshare_col(kc, prf)
        sentinel = BShare(jnp.zeros_like(keyb.shares)).xor_public(
            jnp.full(keyb.shape, SENTINEL, dtype=keyb.ring.dtype)
        )
        name = "__sk" if i == 0 else f"__sk{i}"
        # key 0 keeps the historical tag; extra keys branch off a sub-chain
        # (651, i) so no tag collides with the 661/662 boundary gates below
        p = prf.fold(651) if i == 0 else prf.fold(651).fold(i)
        cols[name] = select(vmask, keyb, sentinel, p)
        sort_names.append(name)
    return cols, sort_names


def _representatives(valid: BShare, start: BShare, prf: PRFSetup) -> BShare:
    """Mark the last row of each valid segment (it carries the aggregate)."""
    nxt_start = _shift_up(start, fill=1)
    nxt_valid = _shift_up(valid, fill=0)
    not_nxt_valid = nxt_valid.xor_public(nxt_valid.ring.const(1))
    boundary = or_bit(
        nxt_start.and_public(nxt_start.ring.const(1)),
        not_nxt_valid.and_public(not_nxt_valid.ring.const(1)),
        prf.fold(661),
    )
    return and_bit(valid, boundary, prf.fold(662))


def oblivious_groupby_count(
    table: SecretTable,
    key_col: Union[str, Sequence[str]],
    prf: PRFSetup,
    count_name: str = "cnt",
) -> SecretTable:
    key_cols = [key_col] if isinstance(key_col, str) else list(key_col)
    table = pad_pow2(table)

    # Narrow sort: only the masked keys + the valid bit enter the network.
    # The masked keys double as the output key columns — they equal the raw
    # keys on every valid row, and only valid representatives ever surface.
    cols, sort_names = _masked_sort_keys(table, key_cols, prf)
    cols["__valid"] = table.valid

    cols = bitonic_sort_narrow(cols, sort_names, prf)
    valid = cols.pop("__valid")
    keys_sorted = [cols[name] for name in sort_names]

    start = segment_starts(keys_sorted, valid, prf)
    cnt = segmented_count(valid, start, prf)
    rep = _representatives(valid, start, prf)

    out_cols: dict = {kc: ks for kc, ks in zip(key_cols, keys_sorted)}
    out_cols[count_name] = cnt
    return SecretTable(out_cols, rep)


def _groupby_agg(
    table: SecretTable,
    key_col: Union[str, Sequence[str]],
    val_col: str,
    prf: PRFSetup,
    with_count: bool,
):
    """Shared sort + segmented-scan core of GROUP BY SUM / AVG. Returns
    (sorted key cols by name, per-row aggregate AShare(s), representative
    valid bits)."""
    key_cols = [key_col] if isinstance(key_col, str) else list(key_col)
    table = pad_pow2(table)

    cols, sort_names = _masked_sort_keys(table, key_cols, prf)
    cols["__valid"] = table.valid
    cols["__val"] = table.bshare_col(val_col, prf)

    cols = bitonic_sort_narrow(cols, sort_names, prf)
    valid = cols.pop("__valid")
    val_b = cols.pop("__val")
    keys_sorted = [cols[name] for name in sort_names]

    start = segment_starts(keys_sorted, valid, prf)
    va = b2a(val_b, prf.fold(663))
    vbit = bit2a(valid, prf.fold(664))
    masked = mul(va, vbit, prf.fold(665))  # invalid rows contribute 0
    f = bit2a(start, prf.fold(612))
    if with_count:
        # (sum, count) reduce in one scan: stack as a 2-wide lane, broadcast f
        pair = AShare.stack([masked, vbit], axis=1)
        agg = segmented_reduce(pair, AShare(f.shares[..., None]), prf.fold(617))
        aggs = [agg[:, 0], agg[:, 1]]
    else:
        aggs = [segmented_reduce(masked, f, prf.fold(617))]
    rep = _representatives(valid, start, prf)
    out_keys = dict(zip(key_cols, keys_sorted))
    return out_keys, aggs, rep


def oblivious_groupby_sum(
    table: SecretTable,
    key_col: Union[str, Sequence[str]],
    val_col: str,
    prf: PRFSetup,
    name: str = "sum",
) -> SecretTable:
    out_cols, (total,), rep = _groupby_agg(table, key_col, val_col, prf, False)
    out_cols[name] = total
    return SecretTable(out_cols, rep)


def oblivious_groupby_avg(
    table: SecretTable,
    key_col: Union[str, Sequence[str]],
    val_col: str,
    prf: PRFSetup,
    name: str = "avg",
) -> SecretTable:
    """Per-group (sum, count) pair; the division happens post-reveal
    (same convention as the scalar AVG aggregate)."""
    out_cols, (total, cnt), rep = _groupby_agg(table, key_col, val_col, prf, True)
    out_cols[f"{name}_sum"] = total
    out_cols[f"{name}_cnt"] = cnt
    return SecretTable(out_cols, rep)
