"""Oblivious Filter.

Evaluates a conjunction of predicates over secret-shared columns and ANDs the
result into the validity column. The output table has the *same* public size
as the input (an oblivious Filter cannot physically shrink its input — the
paper's motivating example); only a downstream Resizer may trim it.

Cost: one comparison circuit per term (eq: 5 rounds, lt/le: 5-6 rounds) plus
one AND per conjunction (Filter_1 = 1 equality, Filter_4 = 4 equalities + 3
ANDs — matching the paper's Fig. 7 workloads).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Union

from ..core.circuits import eq, eq_public, gt_public, le_public, lt, lt_public, and_bit
from ..core.prf import PRFSetup
from ..core.sharing import BShare
from .table import SecretTable

__all__ = ["Predicate", "oblivious_filter"]


@dataclasses.dataclass(frozen=True)
class Predicate:
    """column OP value — value may be a public constant or another column
    name (prefixed with ``col:``)."""

    column: str
    op: str  # eq | lt | le | gt
    value: Union[int, str]

    def evaluate(self, table: SecretTable, prf: PRFSetup, tag: int) -> BShare:
        x = table.bshare_col(self.column, prf)
        p = prf.fold(tag)
        if isinstance(self.value, str) and self.value.startswith("col:"):
            y = table.bshare_col(self.value[4:], prf)
            if self.op == "eq":
                return eq(x, y, p)
            if self.op == "lt":
                return lt(x, y, p)
            if self.op == "le":
                return _bit(lt(y, x, p))  # NOT (y < x)
            raise ValueError(self.op)
        c = int(self.value)
        if self.op == "eq":
            return eq_public(x, c, p)
        if self.op == "lt":
            return lt_public(x, c, p)
        if self.op == "le":
            return le_public(x, c, p)
        if self.op == "gt":
            return gt_public(x, c, p)
        raise ValueError(f"unknown predicate op {self.op}")


def _bit(b: BShare) -> BShare:
    return b.xor_public(b.ring.const(1))


def oblivious_filter(
    table: SecretTable, predicates: Sequence[Predicate], prf: PRFSetup
) -> SecretTable:
    """valid' = valid AND p_1 AND ... AND p_k. Output size == input size."""
    acc = None
    for i, pred in enumerate(predicates):
        b = pred.evaluate(table, prf, 400 + i)
        acc = b if acc is None else and_bit(acc, b, prf.fold(430 + i))
    if acc is None:
        return table
    new_valid = and_bit(table.valid, acc, prf.fold(449))
    return SecretTable(dict(table.cols), new_valid)
