"""Oblivious Filter over a predicate *tree* (AND / OR / parenthesized).

Evaluates a boolean combination of comparison predicates over secret-shared
columns and ANDs the result into the validity column. The output table has the
*same* public size as the input (an oblivious Filter cannot physically shrink
its input — the paper's motivating example); only a downstream Resizer may
trim it.

Predicate trees are dataclasses: :class:`Predicate` leaves combined by
:class:`And` / :class:`Or`. A plain sequence of predicates is accepted
everywhere a tree is (it normalizes to a conjunction), so the historical
``Sequence[Predicate]`` call shape keeps working.

Cost: one comparison circuit per leaf (eq: 5 rounds, lt/le: 5-6 rounds) plus
one AND or OR gate per combining edge (Filter_1 = 1 equality, Filter_4 = 4
equalities + 3 ANDs — matching the paper's Fig. 7 workloads; OR costs the
same as AND under replicated sharing: a OR b = NOT(NOT a AND NOT b) is one
AND plus local XORs).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple, Union

from ..core.circuits import (
    and_bit,
    eq,
    eq_public,
    gt_public,
    le_public,
    lt,
    lt_public,
    or_bit,
)
from ..core.prf import PRFSetup
from ..core.sharing import BShare
from .table import SecretTable

__all__ = [
    "Predicate",
    "And",
    "Or",
    "Pred",
    "normalize_pred",
    "pred_leaves",
    "render_pred",
    "oblivious_filter",
]


@dataclasses.dataclass(frozen=True)
class Predicate:
    """column OP value — value may be a public constant, another column
    name (prefixed with ``col:``), or the placeholder ``"?"`` in a prepared
    plan template (templates are never executed; bind first)."""

    column: str
    op: str  # eq | lt | le | gt
    value: Union[int, str]

    def evaluate(self, table: SecretTable, prf: PRFSetup, tag: int) -> BShare:
        x = table.bshare_col(self.column, prf)
        p = prf.fold(tag)
        if isinstance(self.value, str) and self.value.startswith("col:"):
            y = table.bshare_col(self.value[4:], prf)
            if self.op == "eq":
                return eq(x, y, p)
            if self.op == "lt":
                return lt(x, y, p)
            if self.op == "le":
                return _bit(lt(y, x, p))  # NOT (y < x)
            raise ValueError(self.op)
        c = int(self.value)
        if self.op == "eq":
            return eq_public(x, c, p)
        if self.op == "lt":
            return lt_public(x, c, p)
        if self.op == "le":
            return le_public(x, c, p)
        if self.op == "gt":
            return gt_public(x, c, p)
        raise ValueError(f"unknown predicate op {self.op}")


@dataclasses.dataclass(frozen=True)
class And:
    """Conjunction of predicate subtrees (flattened, >= 2 terms)."""

    terms: Tuple["Pred", ...]


@dataclasses.dataclass(frozen=True)
class Or:
    """Disjunction of predicate subtrees (flattened, >= 2 terms)."""

    terms: Tuple["Pred", ...]


Pred = Union[Predicate, And, Or]


def normalize_pred(pred) -> Pred:
    """Canonical tree: sequences become conjunctions, single-term And/Or
    collapse, nested same-type combiners flatten. Canonical form makes
    dataclass equality (and hence plan fingerprints) independent of how the
    tree was spelled."""
    if isinstance(pred, Predicate):
        return pred
    if isinstance(pred, (And, Or)):
        kind = type(pred)
        flat: list = []
        for t in pred.terms:
            t = normalize_pred(t)
            if isinstance(t, kind):
                flat.extend(t.terms)
            else:
                flat.append(t)
        if len(flat) == 1:
            return flat[0]
        return kind(tuple(flat))
    if isinstance(pred, Sequence) and not isinstance(pred, (str, bytes)):
        return normalize_pred(And(tuple(pred)))
    raise TypeError(f"cannot normalize predicate {pred!r}")


def pred_leaves(pred: Pred) -> Tuple[Predicate, ...]:
    """Leaf predicates in DFS order."""
    if isinstance(pred, Predicate):
        return (pred,)
    out: list = []
    for t in pred.terms:
        out.extend(pred_leaves(t))
    return tuple(out)


def render_pred(pred: Pred, fmt=None) -> str:
    """SQL-precedence rendering (AND binds tighter than OR; Or subtrees are
    parenthesized inside And). ``fmt(leaf)`` renders a leaf; the default is
    the fingerprint form ``"col op value"`` — for a flat conjunction this is
    byte-identical to the historical ``" AND ".join(...)`` Filter label."""
    if fmt is None:
        fmt = lambda p: f"{p.column} {p.op} {p.value}"
    if isinstance(pred, Predicate):
        return fmt(pred)
    if isinstance(pred, And):
        parts = [
            f"({render_pred(t, fmt)})" if isinstance(t, Or) else render_pred(t, fmt)
            for t in pred.terms
        ]
        return " AND ".join(parts)
    if isinstance(pred, Or):
        return " OR ".join(render_pred(t, fmt) for t in pred.terms)
    raise TypeError(f"cannot render predicate {pred!r}")


def _bit(b: BShare) -> BShare:
    return b.xor_public(b.ring.const(1))


def _eval_tree(pred: Pred, table: SecretTable, prf: PRFSetup, state: dict) -> BShare:
    """Recursive evaluation with deterministic PRF tags: leaf i (DFS order)
    uses tag 400+i — identical to the historical flat path — and combining
    gate g folds (430, g) for AND / (470, g) for OR."""
    if isinstance(pred, Predicate):
        i = state["leaf"]
        state["leaf"] += 1
        return pred.evaluate(table, prf, 400 + i)
    acc = None
    for t in pred.terms:
        b = _eval_tree(t, table, prf, state)
        if acc is None:
            acc = b
            continue
        g = state["gate"]
        state["gate"] += 1
        if isinstance(pred, And):
            acc = and_bit(acc, b, prf.fold(430).fold(g))
        else:
            acc = or_bit(acc, b, prf.fold(470).fold(g))
    return acc


def oblivious_filter(
    table: SecretTable, predicates, prf: PRFSetup
) -> SecretTable:
    """valid' = valid AND eval(tree). Output size == input size.

    ``predicates`` is a predicate tree (:data:`Pred`) or a sequence of
    :class:`Predicate` (implicit conjunction)."""
    tree = normalize_pred(predicates)
    if isinstance(tree, And) and not tree.terms:
        return table
    acc = _eval_tree(tree, table, prf, {"leaf": 0, "gate": 0})
    if acc is None:
        return table
    new_valid = and_bit(table.valid, acc, prf.fold(449))
    return SecretTable(dict(table.cols), new_valid)
