"""Oblivious equi-join (nested-loop / Cartesian product), lazy-materializing.

The fully-oblivious join returns a secret-shared result *in the size of the
Cartesian product* |R1| x |R2| (paper §1, citing Secrecy): row r = (i, j)
carries both sides' columns and
``valid = valid1[i] AND valid2[j] AND (key1[i] == key2[j])``.

Cost: one vectorized equality over N1*N2 lanes (5 rounds) + 2 ANDs. This
ballooning is precisely what makes the Resizer valuable: trimming the join
output from N1*N2 to S = T + eta shrinks every downstream operator.

Materialization strategy (DESIGN.md §7.2): only the ``valid`` column is ever
computed at the product size — tile-by-tile, gathering the *base* key/valid
columns per tile through the public product-layout index maps and running the
(fused) equality kernel on each tile, so peak temporary memory is
O(N1*N2 + tile). Payload columns are carried as :class:`LazyGather`
(base-column, index-map) views and expanded only at the next Resizer's
reveal-and-trim (S rows) or on first direct column access — join memory drops
from O(N1*N2 * cols) to O(N1*N2 + S * cols). The communication ledger is
unchanged: the tiled equality logs the same per-lane bytes and the same round
count as one product-wide circuit (independent tiles share rounds), matching
the eager path's tally exactly.

An optional extra predicate ("theta" part, e.g. ``d.time <= m.time`` in the
Aspirin Count query) is evaluated on the product and ANDed in.

``lazy=False`` keeps the original expand-everything path (the benchmarks'
baseline).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from ..config import current_config
from ..core.circuits import and_bit, eq, le
from ..core.ledger import fused_scope
from ..core.prf import PRFSetup
from ..core.sharing import BShare
from .table import LazyGather, SecretTable

__all__ = ["oblivious_join"]


def _disambiguate(cols: dict, name: str) -> str:
    out_name = name
    suffix = 0
    while out_name in cols:
        suffix += 1
        out_name = f"r{suffix}.{name}"
    return out_name


def _as_lazy(col, idx: jnp.ndarray) -> LazyGather:
    """View ``col`` through the product index map; composes if ``col`` is
    itself a lazy view (join-after-join)."""
    if isinstance(col, LazyGather):
        return LazyGather(col.base, jnp.take(col.index, idx, axis=0))
    return LazyGather(col, idx)


def oblivious_join(
    left: SecretTable,
    right: SecretTable,
    on: Tuple[str, str],
    prf: PRFSetup,
    theta: Optional[Tuple[str, str, str]] = None,
    lazy: bool = True,
    tile: Optional[int] = None,
) -> SecretTable:
    """Equi-join ``left.on[0] == right.on[1]``; output size = n1 * n2.

    ``theta``: optional extra condition (left_col, op, right_col) with
    op in {"le", "eq"} evaluated obliviously on the product.

    ``tile`` (product-grid rows per valid-computation tile) bounds temporary
    memory at O(tile) share words while the public index maps stay O(N1*N2);
    default is ``RuntimeConfig.join_tile``.
    """
    if not lazy:
        return _eager_join(left, right, on, prf, theta)

    n1, n2 = left.n, right.n
    total = n1 * n2
    tile = max(1, tile if tile is not None else current_config().join_tile)
    lk, rk = on

    # Public product layout: row r = (i * n2 + j).
    li = jnp.repeat(jnp.arange(n1, dtype=jnp.int32), n2)
    ri = jnp.tile(jnp.arange(n2, dtype=jnp.int32), n1)

    # Base columns the valid circuit needs (N1 / N2 sized, never expanded).
    lkey = left.bshare_col(lk, prf)
    rkey = right.bshare_col(rk, prf)
    lvalid, rvalid = left.valid, right.valid
    tl = tr = None
    if theta is not None:
        tcol_l, top, tcol_r = theta
        if top not in ("le", "eq"):
            raise ValueError(f"unsupported theta op {top}")
        tl = left.bshare_col(tcol_l, prf)
        tr = right.bshare_col(tcol_r, prf)

    # Round count of the product-wide circuit (tiles are independent and
    # share rounds; see module docstring).
    levels = lkey.ring.bits.bit_length() - 1
    rounds = levels + 2  # eq + AND(valid1, valid2) + AND(match)
    if theta is not None:
        rounds += (1 + levels if top == "le" else levels) + 1

    valid_tiles = [BShare(jnp.zeros((3, 0), dtype=lvalid.shares.dtype))]
    with fused_scope("join_valid", rounds=rounds):
        for t0 in range(0, total, tile):
            sl = slice(t0, min(t0 + tile, total))
            p = prf.fold(500).fold(t0 // tile)  # fresh randomness per tile
            lit, rit = li[sl], ri[sl]
            match = eq(lkey.take(lit), rkey.take(rit), p.fold(501))
            both = and_bit(lvalid.take(lit), rvalid.take(rit), p.fold(502))
            v = and_bit(both, match, p.fold(503))
            if theta is not None:
                xl, xr = tl.take(lit), tr.take(rit)
                extra = (
                    le(xl, xr, p.fold(504)) if top == "le" else eq(xl, xr, p.fold(504))
                )
                v = and_bit(v, extra, p.fold(505))
            valid_tiles.append(v)
    # The empty seed tile keeps the n1*n2 == 0 edge well-formed (the loop
    # body never runs; the eager path likewise returns an empty table).
    valid = valid_tiles[1] if len(valid_tiles) == 2 else BShare.concat(valid_tiles)

    # Payload: (base-table, index-map) views — nothing expanded.
    cols: dict = {}
    for name, col in left.cols.items():
        cols[name] = _as_lazy(col, li)
    for name, col in right.cols.items():
        cols[_disambiguate(cols, name)] = _as_lazy(col, ri)
    return SecretTable(cols, valid)


def _eager_join(
    left: SecretTable,
    right: SecretTable,
    on: Tuple[str, str],
    prf: PRFSetup,
    theta: Optional[Tuple[str, str, str]] = None,
) -> SecretTable:
    """The original expand-everything join: every payload column is
    materialized at the full |R1| x |R2| size before any trimming."""
    n1, n2 = left.n, right.n
    lk, rk = on

    # Broadcast to the product grid then flatten: row r = (i * n2 + j).
    def expand_left(col):
        return col.map_shares(
            lambda s: s[:, :, None].repeat(n2, axis=2).reshape(s.shape[0], n1 * n2)
        )

    def expand_right(col):
        return col.map_shares(
            lambda s: s[:, None, :].repeat(n1, axis=1).reshape(s.shape[0], n1 * n2)
        )

    cols = {}
    for name in left.cols:
        cols[name] = expand_left(left.col(name))
    for name in right.cols:
        # Disambiguate collisions (engine usually prefixes table aliases).
        cols[_disambiguate(cols, name)] = expand_right(right.col(name))

    lkey = expand_left(left.bshare_col(lk, prf))
    rkey = expand_right(right.bshare_col(rk, prf))
    match = eq(lkey, rkey, prf.fold(501))

    lvalid = expand_left(left.valid)
    rvalid = expand_right(right.valid)
    both = and_bit(lvalid, rvalid, prf.fold(502))
    valid = and_bit(both, match, prf.fold(503))

    if theta is not None:
        tcol_l, op, tcol_r = theta
        xl = expand_left(left.bshare_col(tcol_l, prf))
        xr = expand_right(right.bshare_col(tcol_r, prf))
        if op == "le":
            extra = le(xl, xr, prf.fold(504))
        elif op == "eq":
            extra = eq(xl, xr, prf.fold(504))
        else:
            raise ValueError(f"unsupported theta op {op}")
        valid = and_bit(valid, extra, prf.fold(505))

    return SecretTable(cols, valid)
