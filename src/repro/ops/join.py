"""Oblivious equi-join (nested-loop / Cartesian product).

The fully-oblivious join returns a secret-shared result *in the size of the
Cartesian product* |R1| x |R2| (paper §1, citing Secrecy): row (i, j) carries
both sides' columns and
``valid = valid1[i] AND valid2[j] AND (key1[i] == key2[j])``.

Cost: one vectorized equality over N1*N2 lanes (5 rounds) + 2 ANDs. This
ballooning is precisely what makes the Resizer valuable: trimming the join
output from N1*N2 to S = T + eta shrinks every downstream operator.

An optional extra predicate ("theta" part, e.g. ``d.time <= m.time`` in the
Aspirin Count query) is evaluated on the product and ANDed in.
"""
from __future__ import annotations

from typing import Optional, Tuple

from ..core.circuits import and_bit, eq, le
from ..core.prf import PRFSetup
from .table import SecretTable

__all__ = ["oblivious_join"]


def oblivious_join(
    left: SecretTable,
    right: SecretTable,
    on: Tuple[str, str],
    prf: PRFSetup,
    theta: Optional[Tuple[str, str, str]] = None,
) -> SecretTable:
    """Equi-join ``left.on[0] == right.on[1]``; output size = n1 * n2.

    ``theta``: optional extra condition (left_col, op, right_col) with
    op in {"le", "eq"} evaluated obliviously on the product.
    """
    n1, n2 = left.n, right.n
    lk, rk = on

    # Broadcast to the product grid then flatten: row r = (i * n2 + j).
    def expand_left(col):
        return col.map_shares(
            lambda s: s[:, :, None].repeat(n2, axis=2).reshape(s.shape[0], n1 * n2)
        )

    def expand_right(col):
        return col.map_shares(
            lambda s: s[:, None, :].repeat(n1, axis=1).reshape(s.shape[0], n1 * n2)
        )

    cols = {}
    for name, col in left.cols.items():
        cols[name] = expand_left(col)
    for name, col in right.cols.items():
        # Disambiguate collisions (engine usually prefixes table aliases).
        out_name = name
        suffix = 0
        while out_name in cols:
            suffix += 1
            out_name = f"r{suffix}.{name}"
        cols[out_name] = expand_right(col)

    lkey = expand_left(left.bshare_col(lk, prf))
    rkey = expand_right(right.bshare_col(rk, prf))
    match = eq(lkey, rkey, prf.fold(501))

    lvalid = expand_left(left.valid)
    rvalid = expand_right(right.valid)
    both = and_bit(lvalid, rvalid, prf.fold(502))
    valid = and_bit(both, match, prf.fold(503))

    if theta is not None:
        tcol_l, op, tcol_r = theta
        xl = expand_left(left.bshare_col(tcol_l, prf))
        xr = expand_right(right.bshare_col(tcol_r, prf))
        if op == "le":
            extra = le(xl, xr, prf.fold(504))
        elif op == "eq":
            extra = eq(xl, xr, prf.fold(504))
        else:
            raise ValueError(f"unsupported theta op {op}")
        valid = and_bit(valid, extra, prf.fold(505))

    return SecretTable(cols, valid)
