"""Oblivious ORDER BY (+ optional LIMIT).

Sorts by a column; invalid rows are keyed to a sentinel so they sink to the
end (ascending) / bottom (descending). LIMIT k is a *public* head-slice of the
sorted oblivious table — it reveals nothing beyond the (public) constant k,
and is only semantically complete when the number of true rows is <= k or the
operator is terminal (the engine enforces this the same way the paper's
hand-compiled plans do).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..core.prf import PRFSetup
from ..core.sharing import BShare, select
from ..core.sort import bitonic_sort_narrow
from .table import SecretTable

__all__ = ["oblivious_orderby"]


def oblivious_orderby(
    table: SecretTable,
    col: str,
    prf: PRFSetup,
    descending: bool = False,
    limit: Optional[int] = None,
) -> SecretTable:
    from .groupby import pad_pow2

    table = pad_pow2(table)
    keyb = table.bshare_col(col, prf)
    vmask = table.valid.lsb_mask()
    sentinel_val = 0 if descending else 0xFFFFFFFE
    sentinel = BShare(jnp.zeros_like(keyb.shares)).xor_public(
        jnp.full(keyb.shape, sentinel_val, dtype=keyb.ring.dtype)
    )
    sort_key = select(vmask, keyb, sentinel, prf.fold(681))

    cols = {"__sk": sort_key, "__valid": table.valid}
    for k in table.cols:
        if k != col:
            cols[k] = table.bshare_col(k, prf)
    cols = bitonic_sort_narrow(cols, "__sk", prf, descending=descending)
    valid = cols.pop("__valid")
    # the sort key doubles as the (masked) column value for valid rows
    out_cols = dict(cols)
    out_cols[col] = out_cols.pop("__sk")

    out = SecretTable(out_cols, valid)
    if limit is not None and limit < out.n:
        out = out.gather_rows(jnp.arange(limit))
    return out
