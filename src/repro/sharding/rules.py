"""Logical-axis -> mesh-axis sharding rules (DP / TP / EP / SP).

The production mesh is ("data", "model") single-pod or ("pod", "data",
"model") multi-pod; "pod" composes with "data" for batch (DP) sharding.

Parameter rules are name-based with divisibility-checked fallbacks: each
parameter name maps to a priority list of tensor axes (negative, counted from
the end so the scan-over-layers group axis is transparent); the first axis
whose size divides the model-axis extent gets "model". This yields:

* TP     — attention heads / FFN hidden / vocab on "model"
* EP     — MoE expert axis on "model" when n_experts % model == 0
           (arctic 128e), else TP inside the expert FFN (mixtral 8e on a
           16-way model axis)
* DP     — batch axes on ("pod", "data")
* SP     — long-context KV cache sequence axis on "data" when batch < data
* ZeRO-1 — optimizer moments additionally sharded over "data" on the largest
           still-unsharded divisible axis
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "make_param_specs",
    "zero1_specs",
    "batch_specs",
    "cache_specs",
    "data_axes",
]

# parameter name -> tensor-axis priority (negative indices, end-anchored)
_RULES = {
    "embed": (-2,),
    "lm_head": (-1,),
    "w_q": (-2, -1),
    "w_k": (-2, -1),
    "w_v": (-2, -1),
    "w_o": (-3, -1),
    "w_uq": (-2, -1),
    "w_uk": (-2, -1),
    "w_uv": (-2, -1),
    "w_dq": (-1,),
    "w_dkv": (-1,),
    "w_kr": (),
    "router": (-1,),
    "w_gate": (-1,),  # mlp (D,F); moe handled by ndim below
    "w_up": (-1,),
    "w_down": (-2,),
    "w_gate_branch": (-1,),
    "w_x_branch": (-1,),
    "w_input_gate": (-1,),
    "w_rec_gate": (-1,),
    "w_out": (-2,),
    "conv_w": (),
    "lam_logit": (),
    "w_i": (),
    "w_f": (),
    "b_f": (),
    "w_z": (-2, -1),
    "r_z": (-1,),
    "r_i": (-1,),
    "r_f": (-1,),
    "r_o": (-1,),
    "scale": (),
}
_MOE_RULES = {  # (E, D, F) / (E, F, D): expert axis first, fallback TP
    "w_gate": (-3, -1),
    "w_up": (-3, -1),
    "w_down": (-3, -2),
}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _model_extent(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _leaf_name(path) -> str:
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str) and not k.isdigit():
            return k
    return ""


def _spec_for(name: str, shape, mesh: Mesh, in_moe: bool) -> P:
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES and len(shape) >= 3) else _RULES
    prio = rules.get(name, ())
    m = _model_extent(mesh)
    axes: list = [None] * len(shape)
    for ax in prio:
        idx = len(shape) + ax
        if 0 <= idx < len(shape) and shape[idx] % m == 0 and shape[idx] >= m:
            axes[idx] = "model"
            break
    return P(*axes)


_MLA_RANK_RULES = {  # shard the latent rank (contraction) axis instead of
    # per-head features: turns per-head feature shards into a single psum
    "w_uq": (-3,),
    "w_uk": (-3,),
    "w_uv": (-3,),
    "w_dq": (-1,),
    "w_dkv": (-1,),
}


def make_param_specs(cfg, params_tree, mesh: Mesh) -> Dict:
    """PartitionSpec tree matching the (possibly group-stacked) params."""
    mla_rank = getattr(cfg, "mla_shard", "feature") == "rank"

    def spec(path, leaf):
        name = _leaf_name(path)
        joined = "/".join(str(getattr(p, "key", "")) for p in path)
        in_moe = "ffn" in joined and cfg.ffn_type == "moe" and "dense_residual" not in joined
        if mla_rank and name in _MLA_RANK_RULES:
            m = _model_extent(mesh)
            shape = leaf.shape
            axes: list = [None] * len(shape)
            for ax in _MLA_RANK_RULES[name]:
                idx = len(shape) + ax
                if 0 <= idx < len(shape) and shape[idx] % m == 0 and shape[idx] >= m:
                    axes[idx] = "model"
                    break
            return P(*axes)
        return _spec_for(name, leaf.shape, mesh, in_moe)

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def zero1_specs(param_specs, params_tree, mesh: Mesh):
    """Optimizer-moment specs: params' specs + 'data' on the largest
    still-unsharded divisible axis (ZeRO-1 state sharding)."""
    d = mesh.shape.get("data", 1)

    def add_data(spec: P, leaf):
        shape = leaf.shape
        axes = list(spec) + [None] * (len(shape) - len(spec))
        best, best_size = None, 0
        for i, s in enumerate(shape):
            if axes[i] is None and s % d == 0 and s >= d and s > best_size:
                best, best_size = i, s
        if best is not None and best_size >= 2 * d:
            axes[best] = "data"
        return P(*axes)

    return jax.tree_util.tree_map(add_data, param_specs, params_tree)


def batch_specs(cfg, batch_tree, mesh: Mesh) -> Dict:
    """Batch inputs: leading batch axis over (pod, data) when divisible."""
    dp = data_axes(mesh)
    dp_extent = 1
    for a in dp:
        dp_extent *= mesh.shape[a]

    def spec(leaf):
        if leaf.shape and leaf.shape[0] % dp_extent == 0 and leaf.shape[0] >= dp_extent:
            return P(dp)
        return P()

    return jax.tree_util.tree_map(spec, batch_tree)


def cache_specs(cfg, cache_tree, mesh: Mesh) -> Dict:
    """KV / recurrent caches. Leading axis is the scan group axis (never
    sharded); then (batch, seq/cap, heads, dh). Priority: batch -> DP;
    else cache sequence axis -> 'data' (SP for long-context, batch=1);
    heads/feature axis -> 'model' when divisible."""
    dp = data_axes(mesh)
    dp_extent = 1
    for a in dp:
        dp_extent *= mesh.shape[a]
    m = _model_extent(mesh)
    data_extent = mesh.shape.get("data", 1)

    def spec(leaf):
        shape = leaf.shape
        if len(shape) <= 1:  # (G,) scalars like idx
            return P()
        axes: list = [None] * len(shape)
        # axis 1 = batch
        if shape[1] % dp_extent == 0 and shape[1] >= dp_extent:
            axes[1] = dp
        elif len(shape) >= 3 and shape[2] % data_extent == 0 and shape[2] >= 4 * data_extent:
            axes[2] = "data"  # SP over the cache length
        # last axis / heads axis on model
        for i in range(len(shape) - 1, 1, -1):
            if axes[i] is None and shape[i] % m == 0 and shape[i] >= m:
                axes[i] = "model"
                break
        return P(*axes)

    return jax.tree_util.tree_map(spec, cache_tree)
