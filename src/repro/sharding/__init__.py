from .rules import (  # noqa: F401
    batch_specs,
    cache_specs,
    data_axes,
    make_param_specs,
    zero1_specs,
)
