"""Batched serving example: bucketed continuous batching (the Resizer's
reveal-and-trim bucketing on plaintext shapes) + prefill + greedy decode.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch stablelm-1.6b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_params
from repro.serve import BucketedBatcher, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batcher = BucketedBatcher(len_buckets=(16, 32, 64), batch_buckets=(1, 2, 4, 8))
    for _ in range(args.requests):
        plen = int(rng.integers(5, 30))
        batcher.submit(rng.integers(0, cfg.vocab_size, plen))

    print(f"serving {args.requests} ragged requests via bucketed batching")
    while batcher.n_pending:
        batch, ids = batcher.next_batch(max_batch=8)
        toks = jnp.asarray(batch["tokens"])
        b, plen = toks.shape
        t0 = time.perf_counter()
        logits, caches = prefill(cfg, params, {"tokens": toks})
        out_tokens = [jnp.argmax(logits[:, -1], axis=-1)]
        for _ in range(args.new_tokens - 1):
            lg, caches = decode_step(
                cfg, params, caches, {"tokens": out_tokens[-1][:, None]}
            )
            out_tokens.append(jnp.argmax(lg[:, 0], axis=-1))
        dt = time.perf_counter() - t0
        gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
        tps = b * args.new_tokens / dt
        print(
            f"  lot: bucket=({b},{plen}) reqs={ids} {dt:.2f}s "
            f"({tps:.1f} tok/s) first-gen={gen[:len(ids), :6].tolist()}"
        )
    print("done")


if __name__ == "__main__":
    main()
