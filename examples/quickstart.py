"""Quickstart: secure collaborative analytics with Reflex in ~40 lines.

Three data owners upload secret-shared rows; the engine runs an oblivious
Filter -> Join, inserts a Resizer after the join (Beta(2,6) noise, parallel
addition), and reveals only the final result + the noisy intermediate size.
The finale re-asks the same question through :class:`repro.runtime.
ReflexClient` — first in-process, then against a real 3-party mesh — and
shows both answers (and their communication ledgers) are identical.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.crt import crt_rounds
from repro.core.noise import BetaNoise
from repro.core.resizer import ResizerConfig
from repro.engine import Engine
from repro.ops import Predicate, SecretTable
from repro.plan import insert_resizers
from repro.plan.nodes import Distinct, Filter, Join, Scan
from repro.runtime import ReflexClient


def main():
    rng = np.random.default_rng(7)
    n = 48
    # --- data owners share their private tables (dictionary-encoded) -------
    patients = {
        "pid": rng.integers(0, 12, n).astype(np.uint32),
        "icd9": rng.choice([390, 401, 414], n).astype(np.uint32),
    }
    meds = {
        "pid2": rng.integers(0, 12, n).astype(np.uint32),
        "med": rng.choice([1, 2, 3], n).astype(np.uint32),
    }
    tables = {
        "diagnoses": SecretTable.from_plaintext(patients, jax.random.PRNGKey(0)),
        "medications": SecretTable.from_plaintext(meds, jax.random.PRNGKey(1)),
    }

    # --- a hand-compiled plan, then Resizers inserted by policy ------------
    plan = Distinct(
        Join(
            Filter(Scan("diagnoses"), [Predicate("icd9", "eq", 414)]),
            Filter(Scan("medications"), [Predicate("med", "eq", 1)]),
            ("pid", "pid2"),
        ),
        "pid",
    )
    noise = BetaNoise(2, 6)
    plan = insert_resizers(
        plan, lambda node: ResizerConfig(noise=noise, addition="parallel"),
        placement="all_internal",
    )
    print(plan.pretty(), "\n")

    # --- execute -------------------------------------------------------------
    eng = Engine(tables, key=jax.random.PRNGKey(42))
    out, report = eng.execute(plan)
    print(report.summary())

    pids = sorted(set(out.reveal_true_rows()["pid"].tolist()))
    print("\npatients on aspirin with icd9=414:", pids)

    # --- what did we disclose? ----------------------------------------------
    for s in report.nodes:
        if s.node.startswith("Resize"):
            e = s.extra
            print(
                f"\ndisclosure at {s.node}: S={e['s']} (true T={e['t']}, hidden) — "
                f"CRT: attacker needs ~{crt_rounds(noise, 'parallel', e['n'], e['t']):.0f} "
                "equivalent repetitions to pin T within +-1"
            )

    # --- the same study through the unified client, both topologies ---------
    # ReflexClient speaks SQL and hides the execution topology: in_process
    # runs the single-process oracle; networked ships shares to three party
    # processes (here: an in-process loopback mesh) and every comm-ledger
    # sync point becomes a real, verified wire exchange.
    sql = (
        "SELECT DISTINCT d.pid FROM diagnoses d, medications m "
        "WHERE d.pid = m.pid2 AND d.icd9 = 414 AND m.med = 1"
    )
    local = ReflexClient.in_process(tables)
    res_local = local.submit("quickstart", sql)
    with ReflexClient.networked(tables, key_seed=0) as networked:
        res_net = networked.submit("quickstart", sql)
        audit = networked.service.engine.last_wire_audit
    same = all(
        np.array_equal(res_local.rows[c], res_net.rows[c])
        for c in res_local.rows
    )
    print(
        f"\nReflexClient: in-process and 3-party answers identical: {same}"
    )
    for a in audit:
        print(
            f"  party {a['party']}: {a['exchanges']} exchanges, "
            f"{a['wire_bytes']} wire bytes == {a['ledger_bytes']} ledger bytes"
        )


if __name__ == "__main__":
    main()
