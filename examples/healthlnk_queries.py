"""HealthLnK workloads end-to-end, SQL edition: the paper's four queries
(Table 2) submitted as SQL strings through the unified
:class:`~repro.runtime.ReflexClient` facade (over the multi-tenant
AnalyticsService) — parse -> optimize -> Resizer placement -> execute,
with plan-cache and CRT-budget telemetry, result validation against the
plaintext oracle, and a runtime + communication comparison across
fully-oblivious / Reflex / revealed placements (the Fig. 8 experiment,
interactive edition). Ends with the batched-admission demo: many tenants'
identical queries enqueued and drained as ONE stacked engine pass
(DESIGN.md §11), with bit-identical results and amortized rounds.

Run:  PYTHONPATH=src python examples/healthlnk_queries.py [n_rows]
"""
import sys
import time

import jax

from repro.core.noise import NoTrim, RevealNoise, TruncatedLaplace
from repro.data import generate_healthlnk, plaintext_oracle
from repro.data.queries import QUERY_SQL
from repro.runtime import ReflexClient
from repro.service import PrivacyAccountant


def check(qname, result, oracle):
    """Validate one query result against its plaintext oracle.

    Every query is genuinely checked — the old generic version fell through
    to an unvalidated "(table)" True for comorbidity / diag_breakdown /
    SUM / AVG (which is what hid the projection_join pair-oracle mismatch
    until PR 4 added the pair branch)."""
    rows = result.rows
    if qname == "comorbidity":
        shown = {int(v): int(c) for v, c in zip(rows["major_icd9"], rows["cnt"])}
        # the sort is on COUNT(*) alone, so the LIMIT boundary may break
        # count-ties differently than the oracle's (count, value) order.
        # Require: count multiset matches; every value strictly above the
        # boundary count appears with its exact count (only boundary TIES
        # may substitute); and any overlap agrees exactly
        boundary = min(oracle.values(), default=0)
        ok = (
            sorted(shown.values()) == sorted(oracle.values())
            and all(shown.get(v) == c
                    for v, c in oracle.items() if c > boundary)
            and all(shown[v] == c for v, c in oracle.items() if v in shown)
        )
        return shown, ok
    if qname == "diag_breakdown":
        shown = {
            (int(a), int(b)): int(c)
            for a, b, c in zip(rows["major_icd9"], rows["diag"], rows["cnt"])
        }
        return shown, shown == oracle
    if qname == "dosage_sum":
        shown = int(rows["total"][0])
        return shown, shown == oracle
    if qname == "dosage_avg":
        shown = {k: int(rows[k][0]) for k in ("avg_dosage_sum",
                                              "avg_dosage_cnt", "avg_dosage")}
        ok = (shown["avg_dosage_sum"] == oracle["sum"]
              and shown["avg_dosage_cnt"] == oracle["cnt"]
              and shown["avg_dosage"] == oracle["avg"])
        return shown["avg_dosage"], ok
    if qname == "med_dosage_sum":
        shown = {int(k): int(v) for k, v in zip(rows["med"], rows["total"])}
        return shown, shown == oracle
    if qname == "repeat_diagnoses":
        shown = {int(k): int(v)
                 for k, v in zip(rows["major_icd9"], rows["cnt"])}
        return shown, shown == oracle
    if qname == "med_dosage_avg":
        # the service's post_reveal already folded (sum, cnt) -> mean
        shown = {int(k): int(v) for k, v in zip(rows["med"], rows["mean"])}
        return shown, shown == {k: v["avg"] for k, v in oracle.items()}
    if qname == "projection_join":
        # the oracle is the sorted (pid, dosage) pair set
        shown = sorted({(int(p), int(v))
                        for p, v in zip(rows["pid"], rows["dosage"])})
        return shown, shown == oracle
    if qname in ("dosage_min", "dosage_max"):
        col = "lo" if qname == "dosage_min" else "hi"
        if oracle is None:  # empty selection: nothing may be revealed
            return None, len(rows[col]) == 0
        shown = int(rows[col][0])
        return shown, shown == oracle
    if "cnt" in rows and len(rows["cnt"]) == 1:
        shown = int(rows["cnt"][0])
        return shown, shown == oracle
    shown = sorted(set(rows["pid"].tolist()))
    return shown, shown == oracle


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 24
    tables, plain = generate_healthlnk(
        n=n, seed=3, aspirin_frac=0.35, icd_heart_frac=0.3
    )
    tlap = TruncatedLaplace(eps=0.5, delta=5e-5, sensitivity=max(n // 8, 1))
    modes = {
        "fully_oblivious": dict(noise=NoTrim(), placement="none"),
        "reflex": dict(noise=tlap, placement="all_internal"),
        "revealed": dict(noise=RevealNoise(), placement="all_internal"),
    }
    print(
        f"{'query':<16}{'mode':<18}{'sec':>8}{'MiB/party':>12}{'rounds':>9}"
        f"{'cache':>7}  result"
    )
    for mode, cfg in modes.items():
        svc = ReflexClient.in_process(
            tables,
            accountant=PrivacyAccountant(policy="escalate"),
            key=jax.random.PRNGKey(5),
            **cfg,
        )
        session = svc.session("example")
        for qname, sql in QUERY_SQL.items():
            res = session.submit(sql)
            shown, ok = check(qname, res, plaintext_oracle(qname, plain))
            print(
                f"{qname:<16}{mode:<18}{res.report.total_seconds:>8.2f}"
                f"{res.report.total_bytes / 2**20:>12.3f}"
                f"{res.report.total_rounds:>9}"
                f"{'hit' if res.cache_hit else 'miss':>7}"
                f"  {'OK' if ok else 'MISMATCH'} {shown}"
            )
        # resubmit the first query: the plan cache serves it, and the
        # accountant keeps charging the CRT budget per disclosure
        res = session.submit(QUERY_SQL["comorbidity"])
        stats = svc.cache_stats()
        print(
            f"  [{mode}] plan-cache hit rate {stats['hit_rate']:.0%} "
            f"({stats['hits']}/{stats['hits'] + stats['misses']}), "
            f"escalations {svc.service.accountant.escalation_count}"
        )
    # a fresh service under a tight budget: watch the escalation ladder fire
    print("\nescalation-ladder demo (fresh tight-budget service):")
    svc = ReflexClient.in_process(
        tables,
        noise=TruncatedLaplace(eps=2.0, sensitivity=1),
        addition="sequential",
        placement="after_joins",
        accountant=PrivacyAccountant(policy="escalate"),
        key=jax.random.PRNGKey(7),
    )
    session = svc.session("attacker")
    for i in range(6):
        res = session.submit(QUERY_SQL["dosage_study"])
        note = (
            "escalated -> " + res.escalations[-1]["to"].split("|")[0]
            if res.escalations
            else "ok"
        )
        print(f"  submit {i + 1}: {note}")
    for st in svc.service.accountant.status():
        print(
            f"  {st['strategy'].split('|')[0]:<60} observed {st['observed']}"
            f"/{st['budget']}"
        )

    # batched admission: 8 tenants ask the same GROUP BY — the scheduler
    # buckets them and the engine answers all of them with one stacked pass
    print("\nbatched-admission demo (8 tenants, one engine pass):")
    sql = "SELECT major_icd9, COUNT(*) AS c FROM diagnoses GROUP BY major_icd9"
    tenants = [f"clinic_{i}" for i in range(8)]
    mk = lambda seed: ReflexClient.in_process(
        tables, noise=NoTrim(), placement="none", jit_ops=True,
        key=jax.random.PRNGKey(seed), batch_wait_s=60.0,
    )
    svc_serial = mk(5)
    svc_serial.submit("warm", sql)
    t0 = time.perf_counter()
    serial = [svc_serial.submit(t, sql) for t in tenants]
    t_serial = time.perf_counter() - t0

    svc_batch = mk(5)
    for t in tenants:  # warm drain: compiles the 8-slot batched programs
        svc_batch.session(t).enqueue(sql)
    svc_batch.drain()
    t0 = time.perf_counter()  # include enqueue: same work the serial timer sees
    for t in tenants:
        svc_batch.session(t).enqueue(sql)
    results = svc_batch.drain()
    t_batch = time.perf_counter() - t0
    same = all(
        all((rs.rows[c] == rb.rows[c]).all() for c in rs.rows)
        for rs, rb in zip(serial, results)
    )
    bs = svc_batch.service.engine.last_batch_stats
    print(
        f"  serial {len(tenants)/t_serial:7.1f} q/s   "
        f"batched {len(results)/t_batch:7.1f} q/s   "
        f"({t_serial/t_batch:.2f}x, results identical: {same})"
    )
    print(
        f"  physical pass: {bs['slots']} slots, {bs['stacked_nodes']} stacked "
        f"ops, {bs['physical_rounds']} rounds total vs "
        f"{sum(r.report.total_rounds for r in results)} if run serially"
    )
    print(f"  scheduler: {svc_batch.service.scheduler.stats}")


if __name__ == "__main__":
    main()
