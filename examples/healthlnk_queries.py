"""HealthLnK workloads end-to-end, SQL edition: the paper's four queries
(Table 2) submitted as SQL strings through the multi-tenant
:class:`AnalyticsService` — parse -> optimize -> Resizer placement -> execute,
with plan-cache and CRT-budget telemetry, result validation against the
plaintext oracle, and a runtime + communication comparison across
fully-oblivious / Reflex / revealed placements (the Fig. 8 experiment,
interactive edition).

Run:  PYTHONPATH=src python examples/healthlnk_queries.py [n_rows]
"""
import sys

import jax

from repro.core.noise import NoTrim, RevealNoise, TruncatedLaplace
from repro.data import generate_healthlnk, plaintext_oracle
from repro.data.queries import QUERY_SQL
from repro.service import AnalyticsService, PrivacyAccountant


def check(result, oracle):
    rows = result.rows
    if "cnt" in rows and len(rows["cnt"]) == 1:
        shown = int(rows["cnt"][0])
        return shown, (shown == oracle if isinstance(oracle, int) else True)
    if "pid" in rows:
        shown = sorted(set(rows["pid"].tolist()))
        return shown, shown == oracle
    return "(table)", True


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 24
    tables, plain = generate_healthlnk(
        n=n, seed=3, aspirin_frac=0.35, icd_heart_frac=0.3
    )
    tlap = TruncatedLaplace(eps=0.5, delta=5e-5, sensitivity=max(n // 8, 1))
    modes = {
        "fully_oblivious": dict(noise=NoTrim(), placement="none"),
        "reflex": dict(noise=tlap, placement="all_internal"),
        "revealed": dict(noise=RevealNoise(), placement="all_internal"),
    }
    print(
        f"{'query':<16}{'mode':<18}{'sec':>8}{'MiB/party':>12}{'rounds':>9}"
        f"{'cache':>7}  result"
    )
    for mode, cfg in modes.items():
        svc = AnalyticsService(
            tables,
            accountant=PrivacyAccountant(policy="escalate"),
            key=jax.random.PRNGKey(5),
            **cfg,
        )
        session = svc.session("example")
        for qname, sql in QUERY_SQL.items():
            res = session.submit(sql)
            shown, ok = check(res, plaintext_oracle(qname, plain))
            print(
                f"{qname:<16}{mode:<18}{res.report.total_seconds:>8.2f}"
                f"{res.report.total_bytes / 2**20:>12.3f}"
                f"{res.report.total_rounds:>9}"
                f"{'hit' if res.cache_hit else 'miss':>7}"
                f"  {'OK' if ok else 'MISMATCH'} {shown}"
            )
        # resubmit the first query: the plan cache serves it, and the
        # accountant keeps charging the CRT budget per disclosure
        res = session.submit(QUERY_SQL["comorbidity"])
        stats = svc.cache_stats()
        print(
            f"  [{mode}] plan-cache hit rate {stats['hit_rate']:.0%} "
            f"({stats['hits']}/{stats['hits'] + stats['misses']}), "
            f"escalations {svc.accountant.escalation_count}"
        )
    # a fresh service under a tight budget: watch the escalation ladder fire
    print("\nescalation-ladder demo (fresh tight-budget service):")
    svc = AnalyticsService(
        tables,
        noise=TruncatedLaplace(eps=2.0, sensitivity=1),
        addition="sequential",
        placement="after_joins",
        accountant=PrivacyAccountant(policy="escalate"),
        key=jax.random.PRNGKey(7),
    )
    session = svc.session("attacker")
    for i in range(6):
        res = session.submit(QUERY_SQL["dosage_study"])
        note = (
            "escalated -> " + res.escalations[-1]["to"].split("|")[0]
            if res.escalations
            else "ok"
        )
        print(f"  submit {i + 1}: {note}")
    for st in svc.accountant.status():
        print(
            f"  {st['strategy'].split('|')[0]:<60} observed {st['observed']}"
            f"/{st['budget']}"
        )


if __name__ == "__main__":
    main()
