"""HealthLnK workloads end-to-end: the paper's four queries (Table 2) under
fully-oblivious / sort&cut / Reflex / revealed execution, with result
validation against the plaintext oracle and a runtime + communication
comparison table (the Fig. 8 experiment, interactive edition).

Run:  PYTHONPATH=src python examples/healthlnk_queries.py [n_rows]
"""
import sys
import time

import jax

from repro.core.noise import RevealNoise, TruncatedLaplace
from repro.core.resizer import ResizerConfig
from repro.data import all_query_plans, generate_healthlnk, plaintext_oracle
from repro.engine import Engine
from repro.plan import insert_resizers


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 24
    tables, plain = generate_healthlnk(n=n, seed=3, aspirin_frac=0.35, icd_heart_frac=0.3)
    tlap = TruncatedLaplace(eps=0.5, delta=5e-5, sensitivity=max(n // 8, 1))
    modes = {
        "fully_oblivious": None,
        "sortcut": ResizerConfig(noise=tlap, addition="sequential", use_sort=True),
        "reflex": ResizerConfig(noise=tlap, addition="parallel"),
        "revealed": ResizerConfig(noise=RevealNoise()),
    }
    print(f"{'query':<16}{'mode':<18}{'sec':>8}{'MiB/party':>12}{'rounds':>9}  result")
    for qname, plan in all_query_plans().items():
        oracle = plaintext_oracle(qname, plain)
        for mode, cfg in modes.items():
            p = plan if cfg is None else insert_resizers(
                plan, lambda _: cfg, placement="all_internal"
            )
            eng = Engine(tables, key=jax.random.PRNGKey(5))
            t0 = time.perf_counter()
            out, rep = eng.execute(p)
            dt = time.perf_counter() - t0
            res = out.reveal_true_rows()
            if "cnt" in res and len(res["cnt"]) == 1:
                shown = int(res["cnt"][0])
                ok = shown == oracle if isinstance(oracle, int) else True
            elif "pid" in res:
                shown = sorted(set(res["pid"].tolist()))
                ok = shown == oracle
            else:
                shown, ok = "(table)", True
            print(
                f"{qname:<16}{mode:<18}{dt:>8.2f}{rep.total_bytes/2**20:>12.3f}"
                f"{rep.total_rounds:>9}  {'OK' if ok else 'MISMATCH'} {shown}"
            )


if __name__ == "__main__":
    main()
