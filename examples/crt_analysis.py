"""Security analysis walkthrough: the Cardinality Recovery Threshold.

Compares noise strategies on (a) expected filler overhead (performance) and
(b) CRT rounds to recover T (security), then runs the Monte-Carlo attacker
to validate Eq. (1) empirically — the paper's §5.4 in one script.

Run:  PYTHONPATH=src python examples/crt_analysis.py
"""
import jax
import numpy as np

from repro.core.crt import attacker_estimate, crt_rounds, sigma_s2
from repro.core.noise import BetaNoise, ConstantNoise, TruncatedLaplace

N, T = 100_000, 5_000  # oblivious size, true size (T = 5% N)


def main():
    strategies = {
        "tlap narrow (b=2)": TruncatedLaplace(0.5, 5e-5, 1.0),
        "tlap wide (b=2rootN)": TruncatedLaplace(0.5, 5e-5, float(np.sqrt(N))),
        "beta(2,6)": BetaNoise(2, 6),
        "const 10% (caveat!)": ConstantNoise(0.1),
    }
    print(f"N={N}, T={T}; err=+-1 tuple at 99.9% confidence\n")
    print(f"{'strategy':<22}{'addition':<12}{'E[eta]':>10}{'sigma_S^2':>14}{'CRT rounds':>12}")
    for name, s in strategies.items():
        for add in ("sequential", "parallel"):
            r = crt_rounds(s, add, N, T)
            print(
                f"{name:<22}{add:<12}{s.mean(N, T):>10.0f}"
                f"{sigma_s2(s, add, N, T):>14.1f}{r:>12.0f}"
            )
    print(
        "\nTakeaways (paper §5.4): parallel > sequential at equal noise; "
        "Beta-Binomial > TLap; zero-variance strategies are recovered in 1 round."
    )

    # empirical attacker
    noise = TruncatedLaplace(0.5, 5e-5, 10.0)
    for frac in (0.1, 1.0, 4.0):
        r_star = crt_rounds(noise, "sequential", N, T, err=1.0)
        r = max(int(frac * r_star), 1)
        est = attacker_estimate(noise, "sequential", N, T, r, jax.random.PRNGKey(0))
        print(
            f"attacker with r={r:>6} observations ({frac:>3}x CRT): "
            f"T_hat={est['t_hat']:.1f} (true {T}), |err|={est['abs_err']:.2f}"
        )


if __name__ == "__main__":
    main()
