"""End-to-end training driver (thin wrapper over repro.launch.train).

Trains a reduced same-family config of any assigned architecture on the
synthetic resumable pipeline, with atomic async checkpoints and auto-resume —
kill it mid-run and start it again to see fault tolerance in action.

Run:  PYTHONPATH=src python examples/train_lm.py --arch stablelm-1.6b \
          --reduced --steps 200 --ckpt-dir /tmp/reflex_ckpt
On a TPU pod, drop --reduced and add the production mesh via launch/dryrun's
sharding rules (same code path).
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "stablelm-1.6b", "--reduced",
        "--steps", "120", "--batch", "8", "--seq", "64",
        "--ckpt-dir", "/tmp/reflex_ckpt", "--ckpt-every", "40", "--ckpt-async",
    ]
    sys.exit(main(argv))
