#!/usr/bin/env python
"""3-process runtime smoke test (the CI `runtime-smoke` job).

Launches three real party processes on localhost TCP, drives two golden
queries through :class:`~repro.runtime.ReflexClient` in networked mode —
one resized join (``dosage_study``) and one sort-merge join
(``projection_join`` under ``join_algo="sortmerge"``) — and fails on any
divergence from the single-process oracle:

* result rows must match bit-for-bit,
* per-node ledger tallies must match,
* each party's wire bytes must equal its exchange-log bytes and the
  report's ledger bytes (audited inside RemoteEngine; re-printed here).

Exit code 0 = all checks passed.

Usage::

    PYTHONPATH=src python scripts/runtime_smoke.py [--base-port 9700] [--n 64]
"""
import argparse
import os
import subprocess
import sys

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--base-port", type=int, default=9700)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()

    from repro.config import RuntimeConfig
    from repro.data.healthlnk import generate_healthlnk
    from repro.data.queries import QUERY_SQL
    from repro.runtime import ReflexClient, connect_tcp

    cfg = RuntimeConfig(join_algo="sortmerge")
    goldens = ["dosage_study", "projection_join"]

    here = os.path.dirname(os.path.abspath(__file__))
    procs = [
        subprocess.Popen(
            [
                sys.executable, os.path.join(here, "run_parties.py"),
                "--party", str(p), "--base-port", str(args.base_port),
            ],
            env=dict(os.environ),
        )
        for p in range(3)
    ]
    try:
        coord = connect_tcp(
            {p: ("127.0.0.1", args.base_port + p) for p in range(3)}
        )
        print("[smoke] coordinator connected to 3 party processes")

        tables, _ = generate_healthlnk(n=args.n, seed=args.seed)
        oracle_tables, _ = generate_healthlnk(n=args.n, seed=args.seed)
        client = ReflexClient.networked(
            tables, coordinator=coord, key_seed=0, config=cfg
        )
        oracle = ReflexClient.in_process(
            oracle_tables, offline="off", config=cfg
        )

        failures = 0
        for name in goldens:
            sql = QUERY_SQL[name]
            want = oracle.submit("smoke", sql)
            got = client.submit("smoke", sql)
            ok = set(want.rows) == set(got.rows) and all(
                np.array_equal(want.rows[k], got.rows[k]) for k in want.rows
            )
            wd, gd = want.report.to_dict(), got.report.to_dict()
            ok = ok and wd["total_bytes"] == gd["total_bytes"] \
                and wd["total_rounds"] == gd["total_rounds"]
            audit = client.service.engine.last_wire_audit
            for a in audit:
                ok = ok and (
                    a["ledger_bytes"] == a["exchange_bytes"] == a["wire_bytes"]
                )
            status = "OK" if ok else "DIVERGED"
            failures += 0 if ok else 1
            print(
                f"[smoke] {name}: {status} "
                f"rows={len(next(iter(got.rows.values()), []))} "
                f"ledger_bytes={gd['total_bytes']} "
                f"wire={[a['wire_bytes'] for a in audit]}"
            )
        client.close()
        oracle.close()
        if failures:
            print(f"[smoke] FAILED: {failures} golden(s) diverged")
            return 1
        print("[smoke] all goldens bit-exact; wire bytes == ledger bytes")
        return 0
    finally:
        for pr in procs:
            if pr.poll() is None:
                pr.terminate()
        for pr in procs:
            pr.wait(timeout=30)


if __name__ == "__main__":
    sys.exit(main())
