#!/usr/bin/env python
"""Launch RSS party server(s) over TCP.

One process per party (the production topology)::

    PYTHONPATH=src python scripts/run_parties.py --party 0 &
    PYTHONPATH=src python scripts/run_parties.py --party 1 &
    PYTHONPATH=src python scripts/run_parties.py --party 2 &

or a compose-style launcher that forks all three and waits::

    PYTHONPATH=src python scripts/run_parties.py --party all

Parties listen on ``base_port + party`` and build the pair mesh among
themselves (party p dials every lower-numbered party; higher-numbered
parties dial in). The coordinator (see ``repro.runtime.connect_tcp`` /
``scripts/runtime_smoke.py``) dials all three and ships tables, the engine
key seed, and the mesh-wide RuntimeConfig — party processes hold no data
until then.

Each server runs until the coordinator sends ``shutdown`` (or its stdin
pipeline is torn down). See scripts/compose.yaml for the service layout.
"""
import argparse
import os
import signal
import subprocess
import sys


def serve_one(party: int, host: str, base_port: int) -> None:
    from repro.runtime import PartyServer, TcpTransport

    endpoints = {p: (host, base_port + p) for p in range(3)}
    tr = TcpTransport(party, endpoints)
    bound = tr.listen()
    print(f"[party {party}] listening on {bound[0]}:{bound[1]}", flush=True)
    for q in range(3):
        if q < party:
            tr.dial(q)
    for q in range(3):
        if q > party:
            tr.wait_for(q, timeout=60.0)
    print(f"[party {party}] mesh up; serving", flush=True)
    server = PartyServer(party, tr, tr)
    try:
        server.serve()
    finally:
        server.close()
    print(f"[party {party}] shut down", flush=True)


def launch_all(host: str, base_port: int) -> int:
    """Compose-style launcher: three party processes, torn down together."""
    env = dict(os.environ)
    procs = [
        subprocess.Popen(
            [
                sys.executable, os.path.abspath(__file__),
                "--party", str(p), "--host", host,
                "--base-port", str(base_port),
            ],
            env=env,
        )
        for p in range(3)
    ]

    def tear_down(*_sig):
        for pr in procs:
            if pr.poll() is None:
                pr.terminate()

    signal.signal(signal.SIGINT, tear_down)
    signal.signal(signal.SIGTERM, tear_down)
    rc = 0
    for pr in procs:
        rc = max(rc, pr.wait())
    return rc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--party", required=True,
                    help="party id 0..2, or 'all' to fork the full mesh")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--base-port", type=int, default=9600,
                    help="party p listens on base-port + p (default 9600)")
    args = ap.parse_args()
    if args.party == "all":
        return launch_all(args.host, args.base_port)
    serve_one(int(args.party), args.host, args.base_port)
    return 0


if __name__ == "__main__":
    sys.exit(main())
