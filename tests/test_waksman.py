"""Waksman network routing (MP-SPDZ's shuffle substrate): any permutation
must be exactly realized; switch count matches the closed form."""
import numpy as np
import pytest

from repro.core.waksman import apply_network, n_switches, route


@pytest.mark.parametrize("n", [2, 4, 8, 16, 64])
def test_route_random_perms(n):
    rng = np.random.default_rng(n)
    for _ in range(10):
        perm = rng.permutation(n)
        out = apply_network(route(perm), np.arange(n))
        np.testing.assert_array_equal(out, perm)


def test_identity_and_reverse():
    for n in (4, 16):
        np.testing.assert_array_equal(
            apply_network(route(np.arange(n)), np.arange(n)), np.arange(n)
        )
        rev = np.arange(n)[::-1]
        np.testing.assert_array_equal(apply_network(route(rev), np.arange(n)), rev)


def test_switch_count_closed_form():
    for m in range(1, 8):
        n = 1 << m
        assert n_switches(n) == n * m - n + 1
