"""Framed transports: wire format, sequencing, failure taxonomy — over both
the loopback mesh and real TCP sockets."""
import socket
import threading
import time

import pytest

from repro.errors import ReflexError, TransportError
from repro.runtime import (
    COORD,
    CTRL,
    DATA,
    Frame,
    LoopbackMesh,
    LoopbackTransport,
    TcpTransport,
    decode_frame,
    encode_frame,
)

# -----------------------------------------------------------------------------
# Frame codec
# -----------------------------------------------------------------------------


def test_frame_round_trip():
    f = Frame(kind=DATA, src=0, dst=2, seq=7, op="mul", body=b"\x01" * 33)
    g = decode_frame(encode_frame(f))
    assert (g.kind, g.src, g.dst, g.seq, g.op, g.body) == (
        DATA, 0, 2, 7, "mul", b"\x01" * 33,
    )


def test_frame_round_trip_empty_body_and_ctrl():
    f = Frame(kind=CTRL, src=3, dst=1, seq=0, op="hello", body=b"")
    g = decode_frame(encode_frame(f))
    assert g.kind == CTRL and g.op == "hello" and g.body == b""


def test_decode_rejects_bad_magic():
    buf = bytearray(encode_frame(Frame(DATA, 0, 1, 0, "mul", b"xy")))
    buf[:4] = b"NOPE"
    with pytest.raises(TransportError) as ei:
        decode_frame(bytes(buf))
    assert ei.value.reason == "torn-frame"


def test_decode_rejects_truncated_frame():
    buf = encode_frame(Frame(DATA, 0, 1, 0, "mul", b"hello world"))
    with pytest.raises(TransportError) as ei:
        decode_frame(buf[:-3])
    assert ei.value.reason == "torn-frame"


def test_decode_rejects_corrupt_body_crc():
    buf = bytearray(encode_frame(Frame(DATA, 0, 1, 0, "mul", b"hello")))
    buf[-1] ^= 0xFF
    with pytest.raises(TransportError) as ei:
        decode_frame(bytes(buf))
    assert ei.value.reason == "torn-frame"


def test_decode_rejects_overlong_op():
    with pytest.raises(ValueError):
        encode_frame(Frame(DATA, 0, 1, 0, "x" * 300, b""))


def test_transport_error_is_typed():
    e = TransportError("boom", party=1, peer=2, seq=9, op="mul",
                       reason="bad-seq")
    assert isinstance(e, ReflexError) and isinstance(e, RuntimeError)
    assert (e.party, e.peer, e.seq, e.op, e.reason) == (1, 2, 9, "mul",
                                                        "bad-seq")


# -----------------------------------------------------------------------------
# Loopback semantics (shared validation path)
# -----------------------------------------------------------------------------


def make_pair():
    mesh = LoopbackMesh()
    return mesh, LoopbackTransport(mesh, 0), LoopbackTransport(mesh, 1)


def test_loopback_send_recv_orders_frames():
    _, a, b = make_pair()
    for i in range(5):
        a.send(1, "mul", bytes([i]) * 4)
    for i in range(5):
        f = b.recv(0, timeout=1.0)
        assert f.seq == i and f.body == bytes([i]) * 4
    assert a.sent_frames == 5 and a.sent_bytes == 20


def test_loopback_sent_bytes_counts_data_only():
    _, a, b = make_pair()
    a.send(1, "hello", b"\x00" * 100, kind=CTRL)
    a.send(1, "mul", b"\x00" * 7, kind=DATA)
    b.recv(0, timeout=1.0)
    b.recv(0, timeout=1.0)
    assert a.sent_bytes == 7  # the wire-vs-ledger figure excludes control


def test_loopback_recv_timeout():
    _, _a, b = make_pair()
    with pytest.raises(TransportError) as ei:
        b.recv(0, timeout=0.05)
    assert ei.value.reason == "timeout"


def test_out_of_order_frame_rejected():
    mesh, a, b = make_pair()
    # skip seq 0: craft seq 1 directly onto the wire
    mesh.inject(0, 1, encode_frame(Frame(DATA, 0, 1, 1, "mul", b"zz")))
    with pytest.raises(TransportError) as ei:
        b.recv(0, timeout=1.0)
    assert ei.value.reason == "bad-seq" and ei.value.seq == 1


def test_duplicated_frame_rejected():
    mesh, a, b = make_pair()
    buf = encode_frame(Frame(DATA, 0, 1, 0, "mul", b"zz"))
    mesh.inject(0, 1, buf)
    mesh.inject(0, 1, buf)  # replay
    assert b.recv(0, timeout=1.0).seq == 0
    with pytest.raises(TransportError) as ei:
        b.recv(0, timeout=1.0)
    assert ei.value.reason == "bad-seq"


def test_torn_frame_rejected_on_recv():
    mesh, _a, b = make_pair()
    buf = encode_frame(Frame(DATA, 0, 1, 0, "mul", b"full frame body"))
    mesh.inject(0, 1, buf[: len(buf) - 4])
    with pytest.raises(TransportError) as ei:
        b.recv(0, timeout=1.0)
    assert ei.value.reason == "torn-frame"


def test_misrouted_frame_rejected():
    mesh, _a, b = make_pair()
    # frame stamped src=2 arriving on the 0->1 link
    mesh.inject(0, 1, encode_frame(Frame(DATA, 2, 1, 0, "mul", b"zz")))
    with pytest.raises(TransportError) as ei:
        b.recv(0, timeout=1.0)
    assert ei.value.reason == "bad-seq"


def test_closed_loopback_peer_raises_crashed_and_sticks():
    _, a, b = make_pair()
    a.send(1, "mul", b"ok")
    assert b.recv(0, timeout=1.0).op == "mul"
    a.close()
    for _ in range(2):  # sticky: every later recv fails the same way
        with pytest.raises(TransportError) as ei:
            b.recv(0, timeout=1.0)
        assert ei.value.reason == "crashed"
    with pytest.raises(TransportError) as ei:
        a.send(1, "mul", b"more")
    assert ei.value.reason == "closed"


# -----------------------------------------------------------------------------
# TCP
# -----------------------------------------------------------------------------


def tcp_pair(base_port):
    eps = {0: ("127.0.0.1", base_port), 1: ("127.0.0.1", base_port + 1)}
    a = TcpTransport(0, eps)
    eps[0] = a.listen()  # resolve the OS-assigned port before b copies eps
    b = TcpTransport(1, eps)
    b.dial(0)
    a.wait_for(1, timeout=10.0)
    return a, b


def test_tcp_round_trip_both_directions():
    a, b = tcp_pair(0)  # port 0: OS-assigned, collision-free
    try:
        for i in range(10):
            b.send(0, "mul", bytes([i]) * 16)
        for i in range(10):
            f = a.recv(1, timeout=10.0)
            assert f.seq == i and f.body == bytes([i]) * 16
        a.send(1, "reveal", b"result", kind=DATA)
        assert b.recv(0, timeout=10.0).op == "reveal"
    finally:
        a.close()
        b.close()


def test_tcp_large_frame_survives_segmentation():
    a, b = tcp_pair(0)
    try:
        body = bytes(range(256)) * 4096  # 1 MiB >> socket buffers
        b.send(0, "mul", body)
        assert a.recv(1, timeout=30.0).body == body
    finally:
        a.close()
        b.close()


def test_tcp_dial_retries_until_listener_appears():
    # reserve a free port, then bring the listener up only after the dialer
    # has already burned a few refused attempts
    probe = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    eps = {0: ("127.0.0.1", port), 1: ("127.0.0.1", 0)}
    a = TcpTransport(0, eps)
    b = TcpTransport(1, eps, connect_retries=300, backoff_s=0.02)

    def listen_late():
        time.sleep(0.25)
        a.listen()

    t = threading.Thread(target=listen_late)
    t.start()
    b.dial(0)  # backoff loop must ride out the listener-less window
    t.join()
    a.wait_for(1, timeout=10.0)
    try:
        b.send(0, "mul", b"late but delivered")
        assert a.recv(1, timeout=10.0).body == b"late but delivered"
    finally:
        a.close()
        b.close()


def test_tcp_dial_gives_up_with_connect_reason():
    # a bound-then-closed port: nothing will ever accept
    probe = TcpTransport(0, {0: ("127.0.0.1", 0)})
    addr = probe.listen()
    probe.close()
    t = TcpTransport(1, {0: addr, 1: ("127.0.0.1", 0)},
                     connect_retries=3, backoff_s=0.01)
    with pytest.raises(TransportError) as ei:
        t.dial(0)
    assert ei.value.reason == "connect" and ei.value.peer == 0


def test_tcp_peer_crash_surfaces_as_crashed_link():
    a, b = tcp_pair(0)
    try:
        b.send(0, "mul", b"last words")
        assert a.recv(1, timeout=10.0).body == b"last words"
        b.close()  # peer process dies
        with pytest.raises(TransportError) as ei:
            a.recv(1, timeout=10.0)
        assert ei.value.reason in ("crashed", "closed")
    finally:
        a.close()
