"""Unit tests: RSS share algebra and the interactive gates."""
import jax
import numpy as np

from repro.core.ledger import measure_comm
from repro.core.prf import zero_share_add, zero_share_xor
from repro.core.ring import RING32
from repro.core.sharing import (
    and_,
    const_a,
    const_b,
    mul,
    or_,
    reveal_a,
    reveal_b,
    select,
    share_a,
    share_b,
)

rng = np.random.default_rng(0)


def _u32(n):
    return rng.integers(0, 2**32, size=(n,), dtype=np.uint32)


def test_share_reveal_roundtrip(prf, key):
    x = _u32(257)
    assert (np.asarray(reveal_a(share_a(x, key))) == x).all()
    assert (np.asarray(reveal_b(share_b(x, key))) == x).all()


def test_shares_individually_uniformish(key):
    # no single share leg should equal the secret (they're masked)
    x = np.zeros(4096, dtype=np.uint32)
    sh = share_a(x, key)
    for i in range(3):
        leg = np.asarray(sh.shares[i])
        assert (leg != 0).mean() > 0.99


def test_linear_ops(prf, key):
    x, y = _u32(64), _u32(64)
    xa, ya = share_a(x, key), share_a(y, jax.random.fold_in(key, 1))
    assert (np.asarray(reveal_a(xa + ya)) == x + y).all()
    assert (np.asarray(reveal_a(xa - ya)) == x - y).all()
    assert (np.asarray(reveal_a(xa.add_public(7))) == x + 7).all()
    assert (np.asarray(reveal_a(xa.mul_public(3))) == x * 3).all()
    assert (np.asarray(reveal_a(-xa)) == (0 - x.astype(np.uint64)).astype(np.uint32)).all()
    assert (np.asarray(reveal_a(xa.sum())) == x.sum(dtype=np.uint32)).all()
    assert (np.asarray(reveal_a(xa.cumsum())) == np.cumsum(x, dtype=np.uint32)).all()


def test_mul_and_gates(prf, key):
    x, y = _u32(128), _u32(128)
    xa, ya = share_a(x, key), share_a(y, jax.random.fold_in(key, 1))
    assert (np.asarray(reveal_a(mul(xa, ya, prf))) == x * y).all()
    xb, yb = share_b(x, key), share_b(y, jax.random.fold_in(key, 1))
    assert (np.asarray(reveal_b(and_(xb, yb, prf))) == (x & y)).all()
    assert (np.asarray(reveal_b(or_(xb, yb, prf))) == (x | y)).all()


def test_select(prf, key):
    x, y = _u32(64), _u32(64)
    bits = rng.integers(0, 2, 64).astype(np.uint32)
    xb, yb = share_b(x, key), share_b(y, jax.random.fold_in(key, 1))
    bb = share_b(bits, jax.random.fold_in(key, 2))
    out = reveal_b(select(bb.lsb_mask(), xb, yb, prf))
    assert (np.asarray(out) == np.where(bits == 1, x, y)).all()


def test_zero_sharings(prf):
    za = zero_share_add(prf, (100,), RING32)
    assert (np.asarray(za[0] + za[1] + za[2]) == 0).all()
    zx = zero_share_xor(prf, (100,), RING32)
    assert (np.asarray(zx[0] ^ zx[1] ^ zx[2]) == 0).all()
    # fresh counters give fresh randomness
    za2 = zero_share_add(prf.fold(1), (100,), RING32)
    assert not (np.asarray(za[0]) == np.asarray(za2[0])).all()


def test_const_shares():
    assert (np.asarray(reveal_a(const_a(5, (4,)))) == 5).all()
    assert (np.asarray(reveal_b(const_b(5, (4,)))) == 5).all()


def test_mul_comm_cost(prf, key):
    x = share_a(_u32(64), key)
    c = measure_comm(lambda a: mul(a, a, prf), x)
    assert c == {"bytes_per_party": 64 * 4, "rounds": 1}


def test_structural_ops(key):
    x = _u32(24)
    xa = share_a(x, key)
    assert (np.asarray(reveal_a(xa.reshape(4, 6))) == x.reshape(4, 6)).all()
    assert (np.asarray(reveal_a(xa[3:7])) == x[3:7]).all()
    idx = np.array([3, 1, 2])
    assert (np.asarray(reveal_a(xa.take(idx))) == x[idx]).all()
    padded = xa.pad_rows(30)
    r = np.asarray(reveal_a(padded))
    assert (r[:24] == x).all() and (r[24:] == 0).all()
