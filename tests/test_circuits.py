"""Unit + property tests for boolean circuits (comparisons, conversions)."""
import jax
import numpy as np

from repro.core.circuits import (
    a2b,
    b2a,
    bit2a,
    eq,
    eq_public,
    gt_public,
    ks_add,
    le,
    le_public,
    lt,
    lt_public,
)
from repro.core.ledger import measure_comm
from repro.core.prf import setup_prf
from repro.core.sharing import reveal_a, reveal_b, share_a, share_b

PRF = setup_prf(jax.random.PRNGKey(1))
rng = np.random.default_rng(1)


def _pairs(n=96):
    x = rng.integers(0, 2**32, n, dtype=np.uint32)
    y = rng.integers(0, 2**32, n, dtype=np.uint32)
    y[: n // 3] = x[: n // 3]  # force equal cases
    return x, y


def _b(x, tag=0):
    return share_b(x, jax.random.PRNGKey(100 + tag))


def test_eq_lt_le():
    x, y = _pairs()
    xb, yb = _b(x, 0), _b(y, 1)
    assert (np.asarray(reveal_b(eq(xb, yb, PRF))) == (x == y)).all()
    assert (np.asarray(reveal_b(lt(xb, yb, PRF))) == (x < y)).all()
    assert (np.asarray(reveal_b(le(xb, yb, PRF))) == (x <= y)).all()


def test_public_comparisons():
    x, y = _pairs()
    xb = _b(x, 0)
    assert (np.asarray(reveal_b(eq_public(xb, y, PRF))) == (x == y)).all()
    assert (np.asarray(reveal_b(lt_public(xb, y, PRF))) == (x < y)).all()
    assert (np.asarray(reveal_b(le_public(xb, y, PRF))) == (x <= y)).all()
    assert (np.asarray(reveal_b(gt_public(xb, y, PRF))) == (x > y)).all()


def test_ks_add_and_conversions():
    x, y = _pairs()
    xb, yb = _b(x, 0), _b(y, 1)
    assert (np.asarray(reveal_b(ks_add(xb, yb, PRF))) == x + y).all()
    assert (np.asarray(reveal_a(b2a(xb, PRF))) == x).all()
    xa = share_a(x, jax.random.PRNGKey(7))
    assert (np.asarray(reveal_b(a2b(xa, PRF))) == x).all()
    bits = (x & 1).astype(np.uint32)
    assert (np.asarray(reveal_a(bit2a(_b(bits, 2), PRF))) == bits).all()


def test_narrow_width_comparison():
    x = rng.integers(0, 2**16, 64, dtype=np.uint32)
    c = int(rng.integers(0, 2**16))
    xb = _b(x, 3)
    got = np.asarray(reveal_b(lt_public(xb, c, PRF, width=16)))
    assert (got == (x < c)).all()


def test_circuit_round_counts():
    """Table 1 / DESIGN.md complexity table."""
    x, y = _pairs(32)
    xb, yb = _b(x, 0), _b(y, 1)
    assert measure_comm(lambda a, b: eq(a, b, PRF), xb, yb)["rounds"] == 5
    assert measure_comm(lambda a, b: lt(a, b, PRF), xb, yb)["rounds"] == 6
    assert measure_comm(lambda a: lt_public(a, 5, PRF), xb)["rounds"] == 5
    assert measure_comm(lambda a, b: ks_add(a, b, PRF), xb, yb)["rounds"] == 6
    assert measure_comm(lambda a: b2a(a, PRF), xb)["rounds"] == 2
    assert measure_comm(lambda a: a2b(a, PRF), share_a(x, jax.random.PRNGKey(0)))[
        "rounds"
    ] == 12


def test_comm_bytes_linear_in_n():
    for n in (64, 128, 256):
        x = rng.integers(0, 2**32, n, dtype=np.uint32)
        xb = _b(x, 6)
        c = measure_comm(lambda a: eq_public(a, 3, PRF), xb)
        assert c["bytes_per_party"] == 5 * 4 * n  # 5 AND-words/lane
