"""Sort-merge oblivious equi-join (ISSUE 6): bit-exact post-trim parity with
the product join on every join golden, cost-based algorithm selection (with
the REPRO_JOIN_ALGO override), fingerprint stability across the physical
flip, and the sort-narrowing ledger win.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ledger import CommLedger
from repro.core.prf import setup_prf
from repro.core.shuffle import apply_secret_perm
from repro.core.sort import bitonic_sort, bitonic_sort_narrow
from repro.core.sharing import const_b, share_b
from repro.data import generate_healthlnk
from repro.data.queries import QUERY_SQL
from repro.engine import Engine
from repro.ops import oblivious_join, oblivious_join_sortmerge
from repro.ops.table import SecretTable
from repro.plan import Join, JoinSortMerge, Scan, select_join_algorithms
from repro.plan.cost import CostModel
from repro.sql import Catalog, compile_logical, compile_query, plan_fingerprint

JOIN_GOLDENS = ("dosage_study", "aspirin_count", "three_join", "projection_join")


# -----------------------------------------------------------------------------
# Helpers
# -----------------------------------------------------------------------------

def _share_table(cols, valid, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(cols) + 1)
    shared = {
        name: share_b(jnp.asarray(v, dtype=jnp.uint32), k)
        for (name, v), k in zip(cols.items(), keys[:-1])
    }
    return SecretTable(
        shared, share_b(jnp.asarray(valid, dtype=jnp.uint32), keys[-1])
    )


def _true_rows(table, prf):
    """Sorted multiset of revealed true rows (column order fixed by name)."""
    opened = {}
    for name in table.cols:
        s = np.asarray(table.bshare_col(name, prf).shares)
        opened[name] = s[0] ^ s[1] ^ s[2]
    v = np.asarray(table.valid.shares)
    valid = (v[0] ^ v[1] ^ v[2]) & 1
    names = sorted(opened)
    return sorted(
        tuple(int(opened[n][i]) for n in names)
        for i in range(len(valid))
        if valid[i]
    )


def _mult_catalog(tables, plain):
    """Catalog with the observed per-key pid multiplicity declared — what a
    deployment's schema metadata would assert."""
    mult = {
        t: {"pid": int(np.bincount(cols["pid"]).max())}
        for t, cols in plain.items()
    }
    return Catalog.from_tables(tables, multiplicity=mult)


def _join_nodes(plan, t):
    found = [plan] if type(plan) is t else []
    for c in plan.children():
        found.extend(_join_nodes(c, t))
    return found


# -----------------------------------------------------------------------------
# Direct operator parity (the correctness oracle)
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("build", ["left", "right"])
def test_sortmerge_matches_product_with_duplicates(build):
    prf = setup_prf(jax.random.PRNGKey(0))
    left = _share_table(
        {"k": [1, 2, 3, 2, 9], "a": [10, 20, 30, 40, 50]},
        [1, 1, 1, 1, 0],
        seed=1,
    )
    right = _share_table(
        {"k": [2, 2, 5, 1], "b": [100, 200, 300, 400]}, [1, 1, 0, 1], seed=2
    )
    prod = oblivious_join(left, right, ("k", "k"), prf.fold(7))
    sm = oblivious_join_sortmerge(
        left, right, ("k", "k"), prf.fold(7), fanout=2, build=build
    )
    assert _true_rows(sm, prf) == _true_rows(prod, prf)


def test_sortmerge_theta_and_empty_match():
    prf = setup_prf(jax.random.PRNGKey(1))
    left = _share_table({"k": [1, 2, 2], "t": [5, 5, 50]}, [1, 1, 1], seed=3)
    right = _share_table({"k": [2, 2, 1], "t": [10, 3, 1]}, [1, 1, 1], seed=4)
    prod = oblivious_join(
        left, right, ("k", "k"), prf.fold(7), theta=("t", "le", "t")
    )
    sm = oblivious_join_sortmerge(
        left, right, ("k", "k"), prf.fold(7), theta=("t", "le", "t"), fanout=2
    )
    assert _true_rows(sm, prf) == _true_rows(prod, prf)

    nomatch = _share_table({"k": [7, 8], "t": [0, 0]}, [1, 1], seed=5)
    sm0 = oblivious_join_sortmerge(left, nomatch, ("k", "k"), prf.fold(8))
    assert _true_rows(sm0, prf) == []


def test_sortmerge_fanout_too_small_misses_matches_is_bounded_by_contract():
    """fanout is a *public contract*: with fanout=1 but 2 valid duplicate
    build rows, the merge keeps exactly one match per probe row (the contract
    violation is a planner bug, not silent corruption elsewhere)."""
    prf = setup_prf(jax.random.PRNGKey(2))
    left = _share_table({"k": [2, 2], "a": [1, 2]}, [1, 1], seed=6)
    right = _share_table({"k": [2], "b": [5]}, [1], seed=7)
    sm = oblivious_join_sortmerge(
        left, right, ("k", "k"), prf.fold(7), fanout=1, build="left"
    )
    assert len(_true_rows(sm, prf)) == 1  # one of the two matches survives


# -----------------------------------------------------------------------------
# End-to-end golden parity: product vs sort-merge through the engine
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("name", JOIN_GOLDENS)
def test_join_goldens_bit_exact_across_algorithms(name):
    tables, plain = generate_healthlnk(n=8, seed=3, aspirin_frac=0.5)
    catalog = _mult_catalog(tables, plain)
    prf_probe = setup_prf(jax.random.PRNGKey(9))
    results = {}
    for mode in ("product", "sortmerge"):
        plan = compile_query(QUERY_SQL[name], catalog, join_algo=mode)
        joins = _join_nodes(plan, JoinSortMerge)
        assert bool(joins) == (mode == "sortmerge")
        eng = Engine(tables, key=jax.random.PRNGKey(2))
        out, _ = eng.execute(plan)
        results[mode] = _true_rows(out, prf_probe)
    assert results["sortmerge"] == results["product"]


def test_fingerprint_stable_across_algorithm_flip():
    """The physical flip must not move plan fingerprints (accountant
    signatures + plan cache keys are derived from them)."""
    tables, plain = generate_healthlnk(n=8, seed=3)
    catalog = _mult_catalog(tables, plain)
    sql = QUERY_SQL["dosage_study"]
    fps = {
        mode: plan_fingerprint(compile_query(sql, catalog, join_algo=mode))
        for mode in ("product", "sortmerge", "auto")
    }
    assert fps["product"] == fps["sortmerge"] == fps["auto"]


# -----------------------------------------------------------------------------
# Algorithm selection: cost crossover + env override + applicability gate
# -----------------------------------------------------------------------------

def _two_table_catalog(n):
    return Catalog(
        tables={"l": ["k", "a"], "r": ["k", "b"]},
        sizes={"l": n, "r": n},
        multiplicity={"l": {"k": 4}, "r": {"k": 4}},
    )


def _cost_model(catalog):
    return CostModel(
        table_sizes={t: catalog.size(t) for t in catalog.tables},
        table_cols={t: len(c) for t, c in catalog.tables.items()},
    )


@pytest.mark.parametrize(
    "n,expect", [(2**8, Join), (2**11, JoinSortMerge), (2**14, JoinSortMerge)]
)
def test_auto_selection_crossover(n, expect):
    catalog = _two_table_catalog(n)
    plan = Join(Scan("l"), Scan("r"), ("k", "k"))
    chosen = select_join_algorithms(
        plan, cost_model=_cost_model(catalog), catalog=catalog, mode="auto"
    )
    assert type(chosen) is expect


def test_env_override_flips_selection(monkeypatch):
    catalog = _two_table_catalog(2**11)
    plan = Join(Scan("l"), Scan("r"), ("k", "k"))
    cm = _cost_model(catalog)
    monkeypatch.setenv("REPRO_JOIN_ALGO", "product")
    assert type(select_join_algorithms(plan, cm, catalog)) is Join
    monkeypatch.setenv("REPRO_JOIN_ALGO", "sortmerge")
    assert type(select_join_algorithms(plan, cm, catalog)) is JoinSortMerge
    monkeypatch.delenv("REPRO_JOIN_ALGO")
    assert type(select_join_algorithms(plan, cm, catalog)) is JoinSortMerge

    monkeypatch.setenv("REPRO_JOIN_ALGO", "bogus")
    with pytest.raises(ValueError, match="bogus"):
        select_join_algorithms(plan, cm, catalog)


def test_no_multiplicity_means_no_rewrite():
    """Without a declared key bound the sort-merge join is inapplicable —
    the default HealthLnK catalog plans are byte-stable."""
    plan = compile_logical(QUERY_SQL["dosage_study"])
    forced = select_join_algorithms(plan, catalog=None, mode="sortmerge")
    assert not _join_nodes(forced, JoinSortMerge)


def test_sortmerge_build_side_has_smaller_bound():
    catalog = Catalog(
        tables={"l": ["k", "a"], "r": ["k", "b"]},
        sizes={"l": 64, "r": 64},
        multiplicity={"l": {"k": 8}, "r": {"k": 2}},
    )
    plan = Join(Scan("l"), Scan("r"), ("k", "k"))
    chosen = select_join_algorithms(
        plan, cost_model=_cost_model(catalog), catalog=catalog, mode="sortmerge"
    )
    assert isinstance(chosen, JoinSortMerge)
    assert chosen.build == "right" and chosen.fanout == 2


# -----------------------------------------------------------------------------
# Sort narrowing: only key + permutation index ride the network
# -----------------------------------------------------------------------------

def test_narrow_sort_matches_wide_sort_and_saves_bytes():
    n, width = 64, 16
    rng = np.random.default_rng(0)
    cols_plain = {"key": rng.integers(0, 32, n)}
    for i in range(width):
        cols_plain[f"p{i}"] = rng.integers(0, 1000, n)

    def shared():
        keys = jax.random.split(jax.random.PRNGKey(5), width + 1)
        return {
            name: share_b(jnp.asarray(v, dtype=jnp.uint32), k)
            for (name, v), k in zip(cols_plain.items(), keys)
        }

    prf = setup_prf(jax.random.PRNGKey(3))
    with CommLedger() as led_wide:
        wide = bitonic_sort(shared(), "key", prf.fold(1))
    with CommLedger() as led_narrow:
        narrow = bitonic_sort_narrow(shared(), "key", prf.fold(1))

    def opened(cols):
        out = {}
        for name, c in cols.items():
            s = np.asarray(c.shares)
            out[name] = (s[0] ^ s[1] ^ s[2]).tolist()
        return out

    ow, on = opened(wide), opened(narrow)
    assert ow["key"] == on["key"]
    # same (key -> payload multiset) relation row for row: both sorts are
    # keyed identically, so the full row tuples must agree as multisets
    rows_w = sorted(zip(*(ow[k] for k in sorted(ow))))
    rows_n = sorted(zip(*(on[k] for k in sorted(on))))
    assert rows_w == rows_n
    # the narrowing is the point: the wide sort pays the whole payload a
    # select per compare-exchange stage (stages(n) times), the narrow one
    # pays key+index in-network plus one O(n) permutation application
    assert led_narrow.tally()["bytes_per_party"] < 0.6 * led_wide.tally()[
        "bytes_per_party"
    ]


def test_apply_secret_perm_applies_permutation():
    n = 16
    prf = setup_prf(jax.random.PRNGKey(4))
    perm = np.random.default_rng(1).permutation(n).astype(np.uint32)
    pi = const_b(jnp.asarray(perm), (n,))
    payload = {
        "x": share_b(jnp.arange(n, dtype=jnp.uint32), jax.random.PRNGKey(8)),
        "y": share_b(
            jnp.arange(n, dtype=jnp.uint32) * 3, jax.random.PRNGKey(9)
        ),
    }
    moved = apply_secret_perm(payload, pi, prf.fold(2))
    for name, base in (("x", 1), ("y", 3)):
        s = np.asarray(moved[name].shares)
        got = (s[0] ^ s[1] ^ s[2]).tolist()
        assert got == (perm * base).tolist()


# -----------------------------------------------------------------------------
# Property test (nightly profile): random keys / dups / empty matches
# -----------------------------------------------------------------------------

try:  # tier-1 runs without hypothesis; the nightly CI profile exercises this
    from hypothesis import given, settings, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        lkeys=st.lists(st.integers(0, 5), min_size=1, max_size=8),
        rkeys=st.lists(st.integers(0, 5), min_size=1, max_size=8),
        data=st.data(),
    )
    def test_sortmerge_equals_product_property(lkeys, rkeys, data):
        lvalid = data.draw(
            st.lists(
                st.integers(0, 1), min_size=len(lkeys), max_size=len(lkeys)
            )
        )
        rvalid = data.draw(
            st.lists(
                st.integers(0, 1), min_size=len(rkeys), max_size=len(rkeys)
            )
        )
        build = data.draw(st.sampled_from(["left", "right"]))
        prf = setup_prf(jax.random.PRNGKey(11))
        left = _share_table(
            {"k": lkeys, "a": list(range(len(lkeys)))}, lvalid, seed=12
        )
        right = _share_table(
            {"k": rkeys, "b": list(range(100, 100 + len(rkeys)))},
            rvalid,
            seed=13,
        )
        bkeys, bvalid = (lkeys, lvalid) if build == "left" else (rkeys, rvalid)
        counts = {}
        for k, v in zip(bkeys, bvalid):
            if v:
                counts[k] = counts.get(k, 0) + 1
        fanout = max(counts.values(), default=1)
        prod = oblivious_join(left, right, ("k", "k"), prf.fold(7))
        sm = oblivious_join_sortmerge(
            left, right, ("k", "k"), prf.fold(7), fanout=fanout, build=build
        )
        assert _true_rows(sm, prf) == _true_rows(prod, prf)
