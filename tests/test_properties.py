"""Hypothesis property tests for circuits, operators, Resizer, sort, Waksman.

Collected only when ``hypothesis`` is installed (see requirements-dev.txt);
the deterministic tests for the same modules live in their own files and run
everywhere. Keeping the property suite in one guarded module lets the tier-1
command collect on a bare ``requirements.txt`` environment.
"""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.circuits import b2a, eq_public, lt_public
from repro.core.noise import BetaNoise
from repro.core.prf import setup_prf
from repro.core.resizer import Resizer, ResizerConfig
from repro.core.sharing import reveal_a, reveal_b, share_b
from repro.core.sort import bitonic_sort
from repro.core.waksman import apply_network, route
from repro.ops import SecretTable, oblivious_groupby_count, oblivious_join

PRF = setup_prf(jax.random.PRNGKey(1))
rng = np.random.default_rng(1)


def _b(x, tag=0):
    return share_b(x, jax.random.PRNGKey(100 + tag))


def _table(data, valid=None, seed=0):
    return SecretTable.from_plaintext(data, jax.random.PRNGKey(seed), valid=valid)


# -- circuits -----------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=40),
    st.integers(0, 2**32 - 2),
)
def test_property_compare_matches_plaintext(vals, c):
    x = np.array(vals, dtype=np.uint32)
    xb = _b(x, 4)
    assert (np.asarray(reveal_b(lt_public(xb, c, PRF))) == (x < c)).all()
    assert (np.asarray(reveal_b(eq_public(xb, c, PRF))) == (x == c)).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=40))
def test_property_b2a_roundtrip(vals):
    x = np.array(vals, dtype=np.uint32)
    assert (np.asarray(reveal_a(b2a(_b(x, 5), PRF))) == x).all()


# -- operators ----------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.integers(0, 5), min_size=2, max_size=24),
    st.lists(st.integers(0, 5), min_size=2, max_size=12),
)
def test_property_join_count_matches_plaintext(lk, rk):
    l = {"k": np.array(lk, dtype=np.uint32)}
    r = {"k2": np.array(rk, dtype=np.uint32)}
    out = oblivious_join(_table(l, seed=8), _table(r, seed=9), ("k", "k2"), PRF)
    got = int(out.reveal()["_valid"].sum())
    want = sum(1 for a in lk for b in rk if a == b)
    assert got == want


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=1, max_size=32))
def test_property_groupby_total_equals_rows(ks):
    k = np.array(ks, dtype=np.uint32)
    out = oblivious_groupby_count(_table({"k": k}, seed=10), "k", PRF)
    got = out.reveal()
    mask = got["_valid"].astype(bool)
    assert got["cnt"][mask].sum() == len(ks)  # counts partition the rows
    assert mask.sum() == len(set(ks))  # one representative per group


# -- resizer ------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(10, 60), st.floats(0.05, 0.9))
def test_property_s_bounds(n, sel):
    vals = rng.integers(0, 100, n).astype(np.uint32)
    valid = (rng.random(n) < sel).astype(np.uint32)
    tab = SecretTable.from_plaintext({"v": vals}, jax.random.PRNGKey(5), valid=valid)
    t = int(valid.sum())
    out, info = Resizer(ResizerConfig(noise=BetaNoise(2, 6)))(
        tab, PRF, jax.random.PRNGKey(6)
    )
    assert t <= info["s"] <= n  # T <= S = T + eta <= N (paper §3.2)


# -- sort ---------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6))
def test_property_sort_is_permutation(logn):
    n = 1 << logn
    k = rng.integers(0, 50, n).astype(np.uint32)
    cols = {"k": share_b(k, jax.random.PRNGKey(9))}
    out = bitonic_sort(cols, "k", PRF)
    ks = np.asarray(reveal_b(out["k"]))
    assert sorted(ks.tolist()) == sorted(k.tolist())
    assert (np.diff(ks.astype(np.int64)) >= 0).all()


# -- Waksman routing ----------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_property_routing(logn, seed):
    n = 1 << logn
    perm = np.random.default_rng(seed).permutation(n)
    payload = np.random.default_rng(seed + 1).integers(0, 1000, n)
    out = apply_network(route(perm), payload)
    np.testing.assert_array_equal(out, payload[perm])
