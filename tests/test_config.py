"""RuntimeConfig: the single env parse site, the use_config resolution
order, and its threading through Engine / compile_query / AnalyticsService."""
import dataclasses

import jax
import pytest

from repro.config import (
    DEFAULT_JOIN_TILE,
    RuntimeConfig,
    current_config,
    use_config,
)
from repro.data import generate_healthlnk
from repro.data.queries import QUERY_SQL
from repro.engine import Engine
from repro.kernels import fusion_enabled, kernels_enabled, override_fusion
from repro.sql.compile import compile_query


# -----------------------------------------------------------------------------
# Parsing + validation
# -----------------------------------------------------------------------------


def test_defaults():
    cfg = RuntimeConfig()
    assert cfg == RuntimeConfig(
        use_pallas=False, fuse_circuits=True,
        join_tile=DEFAULT_JOIN_TILE, join_algo="auto",
    )


def test_from_env_parses_all_flags():
    cfg = RuntimeConfig.from_env({
        "REPRO_USE_PALLAS": "1",
        "REPRO_FUSE_CIRCUITS": "0",
        "REPRO_JOIN_TILE": "128",
        "REPRO_JOIN_ALGO": "sortmerge",
    })
    assert cfg == RuntimeConfig(
        use_pallas=True, fuse_circuits=False,
        join_tile=128, join_algo="sortmerge",
    )


def test_from_env_empty_is_defaults():
    assert RuntimeConfig.from_env({}) == RuntimeConfig()


def test_from_env_rejects_non_integer_tile():
    with pytest.raises(ValueError, match="REPRO_JOIN_TILE"):
        RuntimeConfig.from_env({"REPRO_JOIN_TILE": "huge"})


@pytest.mark.parametrize("bad", [
    {"join_algo": "hash"},
    {"join_tile": 0},
    {"join_tile": -5},
])
def test_validation_rejects_bad_values(bad):
    with pytest.raises(ValueError):
        RuntimeConfig(**bad)


def test_frozen():
    with pytest.raises(dataclasses.FrozenInstanceError):
        RuntimeConfig().use_pallas = True


def test_wire_round_trip_ignores_unknown_keys():
    cfg = RuntimeConfig(join_algo="product", join_tile=64)
    d = cfg.to_dict()
    d["from_the_future"] = 1  # forward compatibility across mesh versions
    assert RuntimeConfig.from_dict(d) == cfg


# -----------------------------------------------------------------------------
# Resolution order: override block > use_config > env fallback
# -----------------------------------------------------------------------------


def test_current_config_env_fallback_tracks_env(monkeypatch):
    monkeypatch.delenv("REPRO_JOIN_ALGO", raising=False)
    assert current_config().join_algo == "auto"
    monkeypatch.setenv("REPRO_JOIN_ALGO", "product")
    assert current_config().join_algo == "product"
    monkeypatch.setenv("REPRO_JOIN_ALGO", "sortmerge")
    assert current_config().join_algo == "sortmerge"


def test_use_config_beats_env(monkeypatch):
    monkeypatch.setenv("REPRO_JOIN_ALGO", "product")
    with use_config(RuntimeConfig(join_algo="sortmerge")):
        assert current_config().join_algo == "sortmerge"
    assert current_config().join_algo == "product"


def test_use_config_nests():
    with use_config(RuntimeConfig(join_tile=4)):
        with use_config(RuntimeConfig(join_tile=8)):
            assert current_config().join_tile == 8
        assert current_config().join_tile == 4


def test_use_config_none_is_noop(monkeypatch):
    monkeypatch.setenv("REPRO_JOIN_ALGO", "product")
    with use_config(None):
        assert current_config().join_algo == "product"


def test_kernel_gates_consume_config(monkeypatch):
    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    with use_config(RuntimeConfig(use_pallas=True, fuse_circuits=False)):
        assert kernels_enabled() is True
        assert fusion_enabled() is False
        # block-scoped override is still the strongest layer
        with override_fusion(True):
            assert fusion_enabled() is True


# -----------------------------------------------------------------------------
# Acceptance by Engine / compile_query
# -----------------------------------------------------------------------------


@pytest.fixture(scope="module")
def data():
    return generate_healthlnk(n=8, seed=3, aspirin_frac=0.5)


def test_engine_accepts_config_and_applies_it_during_execute(data):
    tables, _ = data
    cfg = RuntimeConfig(join_algo="product", join_tile=2)
    eng = Engine(tables, key=jax.random.PRNGKey(2), config=cfg)
    assert eng.config is cfg
    plan = compile_query(QUERY_SQL["dosage_study"])
    out, report = eng.execute(plan)
    assert out.n > 0 and report.nodes
    # identical run under the default config: results must agree (the knobs
    # select strategy, never semantics)
    eng2 = Engine(tables, key=jax.random.PRNGKey(2))
    out2, _ = eng2.execute(compile_query(QUERY_SQL["dosage_study"]))
    assert out.reveal_true_rows()["pid"].tolist() == \
        out2.reveal_true_rows()["pid"].tolist()


def test_compile_query_uses_config_join_algo(data):
    tables, plain = data
    import numpy as np

    from repro.plan.nodes import JoinSortMerge
    from repro.sql.catalog import Catalog

    mult = {
        t: {"pid": int(np.bincount(cols["pid"]).max())}
        for t, cols in plain.items()
    }
    catalog = Catalog.from_tables(tables, multiplicity=mult)

    def walk(n):
        yield n
        for c in n.children():
            yield from walk(c)

    plan = compile_query(
        QUERY_SQL["dosage_study"], catalog,
        config=RuntimeConfig(join_algo="sortmerge"),
    )
    assert any(isinstance(n, JoinSortMerge) for n in walk(plan))
    plan = compile_query(
        QUERY_SQL["dosage_study"], catalog,
        config=RuntimeConfig(join_algo="product"),
    )
    assert not any(isinstance(n, JoinSortMerge) for n in walk(plan))
    # an explicit join_algo kwarg wins over the config
    plan = compile_query(
        QUERY_SQL["dosage_study"], catalog, join_algo="sortmerge",
        config=RuntimeConfig(join_algo="product"),
    )
    assert any(isinstance(n, JoinSortMerge) for n in walk(plan))
