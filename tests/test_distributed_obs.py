"""Distributed observability (DESIGN.md §17): cross-party trace propagation
and merge, wire-level metrics, network-attributed EXPLAIN ANALYZE, and the
``stats`` mesh-health verb — plus the hard invariant that tracing a
networked query changes NOTHING about its execution (bit-identical shares
and per-node ledger tallies vs an untraced run)."""
import json

import numpy as np
import pytest

from repro.core.noise import NoTrim
from repro.data import generate_healthlnk
from repro.errors import TransportError
from repro.obs import Tracer, redact
from repro.obs.distributed import (
    TraceContext,
    WireMetricsPublisher,
    chrome_trace,
    clock_offset,
    merge_party_spans,
    new_trace_id,
)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Span
from repro.runtime import (
    DATA,
    Frame,
    LoopbackMesh,
    LoopbackTransport,
    ReflexClient,
    TcpTransport,
    encode_frame,
)

GROUP_SQL = (
    "SELECT major_icd9, COUNT(*) AS c FROM diagnoses GROUP BY major_icd9"
)


@pytest.fixture(scope="module")
def tables():
    t, _ = generate_healthlnk(n=16, seed=3, aspirin_frac=0.5)
    return t


@pytest.fixture(scope="module")
def mesh_clients(tables):
    """Two identically seeded loopback meshes: one driven untraced, one
    always driven under a Tracer — their executions must stay bit-exact."""
    mk = lambda: ReflexClient.networked(
        tables, key_seed=2, noise=NoTrim(), placement="none"
    )
    plain, traced = mk(), mk()
    yield plain, traced
    plain.close()
    traced.close()


# -----------------------------------------------------------------------------
# Pure pieces: trace context, clock offset, chrome export
# -----------------------------------------------------------------------------


def test_new_trace_id_shape_and_uniqueness():
    ids = {new_trace_id() for _ in range(64)}
    assert len(ids) == 64
    assert all(len(i) == 16 and int(i, 16) >= 0 for i in ids)


def test_trace_context_roundtrip():
    ctx = TraceContext("ab" * 8, parent_span_id=7)
    assert TraceContext.from_dict(ctx.to_dict()) == ctx
    assert TraceContext.from_dict({"trace_id": "x"}).parent_span_id is None


def test_clock_offset_recovers_true_skew():
    # party clock ahead of the coordinator's by delta, symmetric one-way
    # delay d: the NTP midpoint recovers delta exactly
    delta, d = 5.0, 0.3
    t_send, t_ack = 100.0, 100.0 + 2 * d
    t_recv = t_send + d + delta
    t_reply = t_recv  # instantaneous handling
    assert clock_offset(t_send, t_recv, t_reply, t_ack) == pytest.approx(delta)


def test_chrome_trace_event_shape():
    spans = [
        Span(name="execute", span_id=1, parent_id=None, ts=10.0,
             seconds=0.5, attrs={}),
        Span(name="node[Scan]", span_id=2, parent_id=1, ts=10.1,
             seconds=0.2, attrs={"party": 1}),
    ]
    doc = chrome_trace(spans, trace_id="cafe" * 4)
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert len(events) == 2
    assert doc["otherData"]["trace_id"] == "cafe" * 4
    by_name = {e["name"]: e for e in events}
    # the coordinator rides tid 0, party p rides tid p+1; ts is relative us
    assert by_name["execute"]["tid"] == 0
    assert by_name["node[Scan]"]["tid"] == 2
    assert by_name["execute"]["ts"] == 0
    assert by_name["node[Scan]"]["ts"] == pytest.approx(0.1e6)
    assert by_name["node[Scan]"]["dur"] == pytest.approx(0.2e6)


# -----------------------------------------------------------------------------
# Merge semantics
# -----------------------------------------------------------------------------


def _shipment(party, trace_id, spans, *, skew=0.0):
    return {
        "party": party,
        "trace_id": trace_id,
        "spans": spans,
        "clock": {"t_recv": 100.0 + skew, "t_reply": 100.1 + skew},
        "t_send": 100.0,
        "t_ack": 100.1,
    }


def test_merge_rejects_foreign_trace_id():
    stray = {"name": "node[Scan]", "span_id": 1, "parent_id": None,
             "ts": 100.0, "seconds": 0.1, "attrs": {"party": 0}}
    with Tracer() as tr:
        tid = tr.ensure_trace_id()
        with tr.span("execute") as sp:
            with pytest.raises(ValueError, match="trace"):
                merge_party_spans(
                    tr, sp, [_shipment(0, "not-the-trace", [stray])]
                )
        assert tid == tr.trace_id


def test_merge_re_audits_party_attrs():
    """A misbehaving party cannot smuggle a secret-keyed attr into the
    merged trace: the coordinator re-runs the deny-list audit on arrival."""
    bad = {"name": "node[Resize]", "span_id": 1, "parent_id": None,
           "ts": 100.0, "seconds": 0.1, "attrs": {"t": 999}}
    with Tracer() as tr:
        tid = tr.ensure_trace_id()
        with tr.span("execute") as sp:
            with pytest.raises(redact.RedactionError):
                merge_party_spans(tr, sp, [_shipment(1, tid, [bad])])


def test_merge_reparents_renumbers_and_normalizes_clock():
    party_spans = [
        {"name": "node[Scan]", "span_id": 1, "parent_id": None,
         "ts": 107.0, "seconds": 0.2, "attrs": {"party": 2}},
        {"name": "node[Count]", "span_id": 2, "parent_id": 1,
         "ts": 107.1, "seconds": 0.1, "attrs": {"party": 2}},
    ]
    with Tracer() as tr:
        tid = tr.ensure_trace_id()
        with tr.span("execute") as sp:
            # party clock runs 7s ahead (t_recv=107 vs send/ack 100..100.1)
            n = merge_party_spans(
                tr, sp, [_shipment(2, tid, party_spans, skew=7.0)]
            )
        assert n == 2
    merged = {s.name: s for s in tr.spans if s.name.startswith("node[")}
    root, child = merged["node[Scan]"], merged["node[Count]"]
    assert root.parent_id == sp.span_id  # re-parented under execute
    assert child.parent_id == root.span_id  # sibling linkage preserved
    assert root.span_id != 1 and child.span_id != 2  # renumbered
    assert "clock_offset_s" in root.attrs
    # normalized onto the coordinator clock: 107 - ~7 ≈ 100
    assert abs(root.ts - 100.0) < 0.2


# -----------------------------------------------------------------------------
# End to end over the loopback mesh
# -----------------------------------------------------------------------------


def _tallies(res):
    return [
        (s.node, s.n_ins, s.n_out, s.bytes_per_party, s.rounds)
        for s in res.report.nodes
    ]


def test_traced_networked_run_bit_identical_to_untraced(mesh_clients):
    plain, traced = mesh_clients
    want = plain.submit("alice", GROUP_SQL)
    with Tracer():
        got = traced.submit("alice", GROUP_SQL)
    assert _tallies(want) == _tallies(got)
    assert set(want.rows) == set(got.rows)
    for k in want.rows:
        assert np.array_equal(want.rows[k], got.rows[k])


def test_merged_trace_spans_three_parties_under_one_id(mesh_clients):
    _plain, traced = mesh_clients
    with Tracer() as tr:
        traced.submit("alice", GROUP_SQL)
    lines = [json.loads(ln) for ln in tr.to_jsonl().splitlines()]
    assert {s["trace_id"] for s in lines} == {tr.trace_id}
    parties = {
        s["attrs"]["party"] for s in lines if "party" in s["attrs"]
    }
    assert parties == {0, 1, 2}
    # parent linkage: every non-root parent resolves inside the trace, and
    # every party span hangs (transitively) under the coordinator's execute
    ids = {s["span_id"]: s for s in lines}
    assert len(ids) == len(lines)  # renumbering left no collisions
    execute = next(s for s in lines if s["name"] == "execute")
    assert execute["attrs"]["merged"] > 0
    for s in lines:
        if s["parent_id"] is not None:
            assert s["parent_id"] in ids
        if "party" in s["attrs"]:
            hop = s
            while hop["parent_id"] is not None:
                hop = ids[hop["parent_id"]]
            # party chains terminate at the coordinator's root via execute
            assert hop["parent_id"] is None


def test_party_shipped_spans_survive_disclosure_audit(mesh_clients):
    _plain, traced = mesh_clients
    with Tracer() as tr:
        traced.submit("alice", GROUP_SQL)
    party_spans = [s for s in tr.spans if "party" in s.attrs]
    assert party_spans
    for s in party_spans:
        redact.assert_emittable(s.attrs, where=f"merged span {s.name}")


def test_networked_explain_analyze_net_attribution(mesh_clients):
    plain, _traced = mesh_clients
    text, _res = plain.explain_analyze("alice", GROUP_SQL)
    lines = text.splitlines()
    assert "net stall" in lines[1]
    trailer = lines[-1]
    assert trailer.startswith("wire:")
    for p in range(3):
        assert f"p{p}:" in trailer and "stall" in trailer


def test_in_process_explain_analyze_has_no_wire_trailer(tables):
    import jax

    client = ReflexClient.in_process(
        tables, noise=NoTrim(), placement="none", key=jax.random.PRNGKey(2)
    )
    text, res = client.explain_analyze("alice", GROUP_SQL)
    assert "net stall" in text.splitlines()[1]
    assert "wire:" not in text
    # the column renders "-" for every node in-process (no wire extras)
    assert len(text.splitlines()) == len(res.report.nodes) + 3
    client.close()


def test_status_reports_mesh_health_and_publishes_wire_metrics(mesh_clients):
    plain, _traced = mesh_clients
    plain.submit("alice", GROUP_SQL)
    st = plain.status()
    mesh = st["runtime"]["mesh"]
    assert mesh["ok"] is True
    assert [p["party"] for p in mesh["parties"]] == [0, 1, 2]
    for p in mesh["parties"]:
        assert p["up"] and p["queries"] >= 1
        assert p["bytes"]["sent"] > 0 and p["links"]
    snap = plain.service.metrics.snapshot()
    wire = snap["reflex_wire_bytes_total"]
    assert wire["kind"] == "counter"
    label_parties = {s["labels"].get("party") for s in wire["samples"]}
    assert {"0", "1", "2"} <= label_parties
    assert all(s["value"] > 0 for s in wire["samples"])


def test_in_process_status_has_no_mesh_section(tables):
    import jax

    client = ReflexClient.in_process(tables, key=jax.random.PRNGKey(2))
    assert "mesh" not in client.status()["runtime"]
    client.close()


def test_repeated_status_pulls_do_not_double_count(mesh_clients):
    plain, _traced = mesh_clients
    plain.submit("alice", GROUP_SQL)
    plain.status()

    def data_bytes():
        snap = plain.service.metrics.snapshot()
        return sum(
            s["value"]
            for s in snap["reflex_wire_bytes_total"]["samples"]
            if s["labels"].get("kind") == "data"
        )

    first = data_bytes()
    plain.status()  # no queries in between: only ctrl traffic moves
    assert data_bytes() == first


def test_exchange_log_cap_keeps_audit_exact(mesh_clients):
    plain, _traced = mesh_clients
    old = plain.coordinator.exchange_log_cap
    try:
        plain.coordinator.exchange_log_cap = 1  # force the summary path
        res = plain.submit("alice", GROUP_SQL)
        audit = plain.service.engine.last_wire_audit
        assert [a["party"] for a in audit] == [0, 1, 2]
        total = sum(s.bytes_per_party for s in res.report.nodes)
        for a in audit:
            assert a["exchanges"] > 1  # genuinely capped, totals still exact
            assert a["ledger_bytes"] == a["exchange_bytes"] == a["wire_bytes"]
            assert a["ledger_bytes"] == total
            assert a["stall_seconds"] >= 0.0
    finally:
        plain.coordinator.exchange_log_cap = old


def test_wire_publisher_is_delta_safe():
    reg = MetricsRegistry()
    pub = WireMetricsPublisher(reg)
    snap = {
        "party": 1,
        "sent": [{"link": "1->0", "kind": "data", "frames": 4, "bytes": 256,
                  "seconds": 0.01}],
        "recv": [{"link": "2->1", "kind": "data", "frames": 4, "bytes": 256,
                  "seconds": 0.02}],
        "rejects": [{"reason": "crc", "count": 2}],
        "connects": [{"peer": 0, "retries": 3, "backoff_seconds": 0.05}],
        "links": [{"link": "1<->0", "sent": 4, "recv": 0}],
    }
    pub.publish(snap)
    pub.publish(snap)  # identical re-pull: counters must not advance

    def val(name, **labels):
        for s in reg.snapshot()[name]["samples"]:
            if all(s["labels"].get(k) == v for k, v in labels.items()):
                return s["value"]
        raise AssertionError(f"no sample {labels} in {name}")

    assert val("reflex_wire_bytes_total", party="1", link="1->0") == 256
    assert val("reflex_wire_frames_total", party="1", link="1->0") == 4
    # inbound entries feed the wait counter only — each link's frames are
    # counted once mesh-wide, by the sender
    assert val(
        "reflex_wire_recv_wait_seconds_total", party="1", link="2->1"
    ) == pytest.approx(0.02)
    assert val("reflex_wire_rejects_total", party="1", reason="crc") == 2
    assert val("reflex_wire_connect_retries_total", party="1", peer="0") == 3
    # grown totals advance by the delta only
    snap["sent"][0]["bytes"] = 300
    pub.publish(snap)
    assert val("reflex_wire_bytes_total", party="1", link="1->0") == 300


def test_rejected_frames_counted_in_wire_stats():
    mesh = LoopbackMesh()
    a = LoopbackTransport(mesh, 0)
    b = LoopbackTransport(mesh, 1)
    a.send(1, "mul", b"ok")
    assert b.recv(0, timeout=1.0).body == b"ok"
    buf = encode_frame(Frame(DATA, 0, 1, 9, "mul", b"skip"))  # bad seq
    mesh.inject(0, 1, buf)
    with pytest.raises(TransportError):
        b.recv(0, timeout=1.0)
    torn = encode_frame(Frame(DATA, 0, 1, 1, "mul", b"torn apart"))
    mesh.inject(0, 1, torn[:-4])
    with pytest.raises(TransportError):
        b.recv(0, timeout=1.0)
    snap = b.wire_snapshot()
    rejects = {r["reason"]: r["count"] for r in snap["rejects"]}
    assert rejects.get("seq") == 1
    assert rejects.get("torn-frame") == 1
    recv_data = [e for e in snap["recv"] if e["kind"] == "data"]
    assert recv_data and recv_data[0]["frames"] == 1  # only the good frame


@pytest.fixture()
def dead_endpoint():
    """A port that refuses every connect for the test's duration: bound but
    never listening (and held, so the OS cannot hand it out as an ephemeral
    port — which would let a dialer self-connect)."""
    import socket

    sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    sock.bind(("127.0.0.1", 0))
    yield sock.getsockname()
    sock.close()


def test_tcp_dial_failure_counts_retries_and_jittered_backoff(dead_endpoint):
    t = TcpTransport(1, {0: dead_endpoint, 1: ("127.0.0.1", 0)},
                     connect_retries=3, backoff_s=0.01, jitter_seed=7)
    with pytest.raises(TransportError) as ei:
        t.dial(0)
    assert ei.value.reason == "connect"
    snap = t.wire_snapshot()
    connects = {c["peer"]: c for c in snap["connects"]}
    assert connects[0]["retries"] == 3
    assert connects[0]["backoff_seconds"] > 0.0


def test_tcp_backoff_jitter_seeded_and_decorrelated(dead_endpoint):
    """The dialer sleeps ``delay * (0.5 + rng.random())`` per refused
    attempt: identical seeds replay the identical backoff schedule, while
    different seeds decorrelate simultaneous reconnect storms."""

    def failed_dial_backoff(seed):
        t = TcpTransport(1, {0: dead_endpoint, 1: ("127.0.0.1", 0)},
                         connect_retries=3, backoff_s=0.01, jitter_seed=seed)
        with pytest.raises(TransportError):
            t.dial(0)
        return t.wire_snapshot()["connects"][0]["backoff_seconds"]

    assert failed_dial_backoff(7) == failed_dial_backoff(7)
    assert failed_dial_backoff(7) != failed_dial_backoff(8)
