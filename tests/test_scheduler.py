"""Query admission batching (DESIGN.md §11): batched-vs-serial parity,
accountant isolation, deadline flush, and serial fallback for non-batchable
plans."""
import jax
import numpy as np
import pytest

from repro.core.noise import ConstantNoise, NoTrim, TruncatedLaplace
from repro.data import generate_healthlnk
from repro.plan.registry import plan_batchable
from repro.service import AnalyticsService, PrivacyAccountant, QueryScheduler
from repro.service.accountant import _SigState

JOIN_SQL = (
    "SELECT DISTINCT d.pid FROM diagnoses d, medications m "
    "WHERE d.pid = m.pid AND d.icd9 = 390 AND m.med = 1"
)
GROUP_SQL = "SELECT major_icd9, COUNT(*) AS c FROM diagnoses GROUP BY major_icd9"
PROJECT_SQL = "SELECT pid, icd9 FROM diagnoses WHERE icd9 = 390"


@pytest.fixture(scope="module")
def data():
    return generate_healthlnk(n=8, seed=3, aspirin_frac=0.5, icd_heart_frac=0.4)


def make_service(tables, noise, placement="after_joins", **kw):
    kw.setdefault("batch_wait_s", 60.0)  # tests flush explicitly
    return AnalyticsService(
        tables,
        noise=noise,
        addition="sequential",
        placement=placement,
        accountant=PrivacyAccountant(policy="escalate"),
        key=jax.random.PRNGKey(9),
        **kw,
    )


def assert_result_parity(serial, batched):
    """Bit-exact result + per-node ledger parity (seconds excluded: wall
    time is the one thing batching is supposed to change; the offline
    hit/miss attribution is excluded too — pool temperature varies with
    execution grouping while the material itself stays bit-identical)."""
    assert len(serial) == len(batched)
    for rs, rb in zip(serial, batched):
        assert set(rs.rows) == set(rb.rows)
        for c in rs.rows:
            np.testing.assert_array_equal(rs.rows[c], rb.rows[c])
        # shares, not just revealed values, must match the serial run
        for c in rs.table.cols:
            np.testing.assert_array_equal(
                np.asarray(rs.table.col(c).shares),
                np.asarray(rb.table.col(c).shares),
            )
        ds, db = rs.report.to_dict(), rb.report.to_dict()
        assert len(ds["nodes"]) == len(db["nodes"])
        for ns, nb in zip(ds["nodes"], db["nodes"]):
            for field in ("node", "n_in", "n_ins", "n_out", "bytes_per_party",
                          "rounds"):
                assert ns[field] == nb[field], (field, ns, nb)
            strip = lambda e: {k: v for k, v in e.items() if k != "offline"}
            assert strip(ns["extra"]) == strip(nb["extra"]), (ns, nb)
        assert ds["total_bytes"] == db["total_bytes"]
        assert ds["total_rounds"] == db["total_rounds"]


# -----------------------------------------------------------------------------
# Batched-vs-serial parity
# -----------------------------------------------------------------------------

def test_batched_matches_serial_fully_stacked(data):
    """No Resizers: the whole plan runs as one vmapped pass; every slot's
    shares, rows, and per-node (bytes, rounds) equal the serial run's."""
    tables, _ = data
    K = 3
    svc_s = make_service(tables, NoTrim(), placement="none")
    serial = [svc_s.submit(f"t{i}", GROUP_SQL) for i in range(K)]

    svc_b = make_service(tables, NoTrim(), placement="none")
    tickets = [svc_b.enqueue(f"t{i}", GROUP_SQL) for i in range(K)]
    results = svc_b.drain()
    assert [t.batched for t in tickets] == [True] * K
    assert all(r.batch_slots == K for r in results)
    assert_result_parity(serial, results)
    bs = svc_b.engine.last_batch_stats
    assert bs["slots"] == K and bs["stacked_nodes"] >= 1
    assert bs["split_nodes"] == 0


def test_batched_matches_serial_through_resize_divergence(data):
    """With Resizers, each slot draws its own fresh noise (counter parity
    with serial submission order); divergent trim sizes split the batch and
    the per-slot tail still reproduces serial execution bit-exactly."""
    tables, _ = data
    K = 3
    noise = TruncatedLaplace(eps=0.5, sensitivity=4)
    svc_s = make_service(tables, noise)
    serial = [svc_s.submit(f"t{i}", JOIN_SQL) for i in range(K)]

    svc_b = make_service(tables, noise)
    for i in range(K):
        svc_b.enqueue(f"t{i}", JOIN_SQL)
    results = svc_b.drain()
    assert_result_parity(serial, results)
    # the resize infos (noisy revealed sizes) per slot match serial exactly
    s_sizes = [
        [n.extra.get("s") for n in r.report.nodes if n.node.startswith("Resize")]
        for r in serial
    ]
    b_sizes = [
        [n.extra.get("s") for n in r.report.nodes if n.node.startswith("Resize")]
        for r in results
    ]
    assert s_sizes == b_sizes
    # noise counters advanced identically
    assert svc_s.engine._resize_ctr == svc_b.engine._resize_ctr


def test_batch_then_serial_continues_counter_stream(data):
    """A serial submit after a drained batch folds the counter a serial-only
    service would have used for its (K+1)-th query."""
    tables, _ = data
    noise = TruncatedLaplace(eps=0.5, sensitivity=4)
    svc_s = make_service(tables, noise)
    serial = [svc_s.submit(f"t{i}", JOIN_SQL) for i in range(3)]

    svc_b = make_service(tables, noise)
    svc_b.enqueue("a", JOIN_SQL)
    svc_b.enqueue("b", JOIN_SQL)
    batched = svc_b.drain()
    tail = svc_b.submit("c", JOIN_SQL)
    assert_result_parity(serial, batched + [tail])


# -----------------------------------------------------------------------------
# Accountant isolation
# -----------------------------------------------------------------------------

def test_accountant_charges_each_slot_individually(data):
    """K batched same-signature queries consume K observations — batching
    must never merge CRT observations across tenants."""
    tables, _ = data
    K = 3
    svc = make_service(tables, TruncatedLaplace(eps=0.5, sensitivity=4))
    for i in range(K):
        svc.enqueue(f"t{i}", JOIN_SQL)
    svc.drain()
    (sig,) = svc.accountant.status()
    assert sig["observed"] == K


def test_accountant_does_not_cross_charge_between_tenants(data):
    """Tenant A's batched query spends nothing from tenant B's (different-
    signature) budget, even when both ride the same drain window."""
    tables, _ = data
    svc = make_service(tables, TruncatedLaplace(eps=0.5, sensitivity=4))
    svc.enqueue("alice", JOIN_SQL)
    svc.enqueue("bob", JOIN_SQL.replace("390", "414"))  # distinct signature
    results = svc.drain()
    assert len(results) == 2
    sigs = svc.accountant.status()
    assert len(sigs) == 2
    assert all(s["observed"] == 1 for s in sigs)


def test_window_admission_group_prevents_joint_overdraw(data):
    """Two queued same-signature queries with one remaining observation:
    the second must escalate at admission (exactly as a serial admit/record
    interleaving would), even though neither has recorded yet."""
    tables, _ = data
    svc = make_service(tables, ConstantNoise(0.2))
    aq = svc._admit("probe", JOIN_SQL)
    (resize,) = [
        n for n in _walk(aq.admitted) if type(n).__name__ == "Resize"
    ]
    sig = svc.accountant.signature(resize)
    svc.accountant._state[sig] = _SigState(observed=2, budget=3, n=64, t=4)

    svc.enqueue("alice", JOIN_SQL)  # spends the last remaining observation
    svc.enqueue("bob", JOIN_SQL)  # must escalate at admission
    results = svc.drain()
    noises = [
        [n.extra.get("skipped", False) for n in r.report.nodes
         if n.node.startswith("Resize")]
        for r in results
    ]
    assert noises[0] == [False]  # alice's resize really trimmed
    assert noises[1] == [True]  # bob's escalated to NoTrim (const has no rung)
    assert svc.accountant._state[sig].observed == 3  # never overdrawn


def _walk(plan):
    yield plan
    for c in plan.children():
        yield from _walk(c)


def test_refused_query_rolls_back_window_reservations(data):
    """A refused admit must not leak its partial reservations into the shared
    admission window — repeated refusals would otherwise shrink every other
    signature's effective budget forever."""
    from repro.service import QueryRefused

    tables, _ = data
    svc = AnalyticsService(
        tables, noise=ConstantNoise(0.2), addition="sequential",
        placement="all_internal",  # filter resizes + join resize per query
        accountant=PrivacyAccountant(policy="refuse"),
        key=jax.random.PRNGKey(9), batch_wait_s=60.0,
    )
    aq = svc._admit("probe", JOIN_SQL)
    join_resize = [
        n for n in _walk(aq.admitted) if type(n).__name__ == "Resize"
    ][-1]  # root-most resize (the join's)
    sig = svc.accountant.signature(join_resize)
    svc.accountant._state[sig] = _SigState(observed=1, budget=1, n=64, t=4)

    for _ in range(5):
        with pytest.raises(QueryRefused):
            svc.enqueue("mallory", JOIN_SQL)
    assert svc.scheduler._planned == {}  # nothing leaked
    # the filter-resize signatures are untouched: a cheap filter query with
    # its own budget must still be admitted
    svc.enqueue("alice", "SELECT pid FROM diagnoses WHERE icd9 = 390")
    (res,) = svc.drain()
    assert res.rows is not None


def test_demux_failure_charges_slot_and_keeps_siblings(data, monkeypatch):
    """If one slot's record() fails after the batched pass ran, that slot's
    disclosure is still charged (conservatively) to the accountant, its
    siblings' results are still delivered, the error propagates, and the
    shared admission window ends empty."""
    tables, _ = data
    K = 3
    svc = make_service(tables, TruncatedLaplace(eps=0.5, sensitivity=4))
    real_record = svc.accountant.record
    calls = {"n": 0}

    def flaky_record(plan, report):
        calls["n"] += 1
        if calls["n"] == 2:  # second slot's record blows up
            raise RuntimeError("record exploded")
        return real_record(plan, report)

    monkeypatch.setattr(svc.accountant, "record", flaky_record)
    for i in range(K):
        svc.enqueue(f"t{i}", JOIN_SQL)
    with pytest.raises(RuntimeError, match="record exploded"):
        svc.drain()
    results = svc.drain()  # siblings were finalized before the raise
    assert len(results) == K - 1
    assert svc.scheduler._planned == {}  # reservations fully released
    # 2 recorded + 1 conservatively charged = K observations on the signature
    (sig,) = svc.accountant.status()
    assert sig["observed"] == K


def test_batch_stats_shape_is_stable_across_fallbacks(data):
    """`engine.last_batch_stats` carries the full physical-tally shape for
    batch-of-1 and non-batchable drains too, not only vmapped passes."""
    tables, _ = data
    svc = make_service(tables, NoTrim(), placement="none")
    svc.enqueue("a", GROUP_SQL)  # batch of one -> serial fallback
    (res,) = svc.drain()
    bs = svc.engine.last_batch_stats
    assert bs["slots"] == 1 and bs["stacked_nodes"] == 0
    assert bs["split_nodes"] == 0
    assert bs["physical_rounds"] == res.report.total_rounds
    assert bs["physical_bytes_per_party"] == res.report.total_bytes


# -----------------------------------------------------------------------------
# Flush policy
# -----------------------------------------------------------------------------

def test_full_bucket_flushes_immediately(data):
    tables, _ = data
    svc = make_service(tables, NoTrim(), placement="none", batch_max=2)
    svc.enqueue("a", GROUP_SQL)
    assert svc.scheduler.n_pending == 1
    svc.enqueue("b", GROUP_SQL)  # bucket full -> barrier-free flush
    assert svc.scheduler.n_pending == 0
    assert svc.scheduler.stats["full_flushes"] == 1
    assert len(svc.drain()) == 2


def test_deadline_flushes_partial_bucket(data):
    tables, _ = data
    svc = make_service(tables, NoTrim(), placement="none")
    now = [0.0]
    svc.scheduler = QueryScheduler(
        svc, max_batch=8, max_wait_s=0.5, clock=lambda: now[0]
    )
    svc.enqueue("a", GROUP_SQL)
    assert svc.drain(force=False) == []  # deadline not reached
    assert svc.scheduler.n_pending == 1
    now[0] = 1.0
    results = svc.drain(force=False)
    assert len(results) == 1 and results[0].batch_slots == 1
    assert svc.scheduler.stats["deadline_flushes"] == 1


def test_any_submit_path_flushes_expired_buckets(data):
    """The deadline is checked on every submit — including ones that take
    the serial-fallback path — so a lone aged bucket cannot starve behind a
    stream of non-batchable queries."""
    tables, _ = data
    svc = make_service(tables, NoTrim(), placement="none")
    now = [0.0]
    svc.scheduler = QueryScheduler(
        svc, max_batch=8, max_wait_s=0.5, clock=lambda: now[0]
    )
    svc.enqueue("a", GROUP_SQL)
    now[0] = 1.0  # bucket is past its deadline
    svc.enqueue("b", "SELECT COUNT(*) FROM medications")  # serial fallback
    assert svc.scheduler.n_pending == 0  # the aged bucket flushed first
    assert svc.scheduler.stats["deadline_flushes"] == 1
    assert len(svc.drain()) == 2


def test_mixed_shapes_bucket_separately(data):
    """Different fingerprints never share an engine pass; each bucket
    executes with only its own slots."""
    tables, _ = data
    svc = make_service(tables, NoTrim(), placement="none")
    svc.enqueue("a", GROUP_SQL)
    svc.enqueue("b", PROJECT_SQL)
    svc.enqueue("c", GROUP_SQL)
    assert svc.scheduler.n_buckets == 2
    results = svc.drain()
    assert [r.batch_slots for r in results] == [2, 1, 2]
    assert svc.scheduler.stats["batches"] == 2


# -----------------------------------------------------------------------------
# Non-batchable fallback
# -----------------------------------------------------------------------------

def test_singleton_aggregate_falls_back_to_serial(data):
    tables, plain = data
    svc = make_service(tables, NoTrim(), placement="none")
    count_sql = "SELECT COUNT(*) FROM medications WHERE dosage = 325"
    assert not plan_batchable(svc.compile(count_sql)[0])
    t = svc.enqueue("alice", count_sql)
    assert not t.batched
    assert svc.scheduler.stats["serial_fallbacks"] == 1
    assert svc.scheduler.n_pending == 0  # executed immediately, no bucket
    (res,) = svc.drain()
    assert res.batch_slots == 1
    m = plain["medications"]
    assert int(res.rows["cnt"][0]) == int((m["dosage"] == 325).sum())


def test_mixed_batchable_and_fallback_results_in_ticket_order(data):
    tables, _ = data
    svc = make_service(tables, NoTrim(), placement="none")
    svc.enqueue("a", GROUP_SQL)
    svc.enqueue("b", "SELECT COUNT(*) FROM medications")
    svc.enqueue("c", GROUP_SQL)
    results = svc.drain()
    assert [r.sql for r in results] == [
        GROUP_SQL, "SELECT COUNT(*) FROM medications", GROUP_SQL,
    ]
