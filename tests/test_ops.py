"""Integration tests: oblivious operators vs. plaintext oracles."""
import collections

import jax
import numpy as np

from repro.core.prf import setup_prf
from repro.ops import (
    Predicate,
    SecretTable,
    count_distinct,
    count_valid,
    oblivious_distinct,
    oblivious_filter,
    oblivious_groupby_count,
    oblivious_join,
    oblivious_orderby,
    sum_column,
)

PRF = setup_prf(jax.random.PRNGKey(3))
rng = np.random.default_rng(3)


def _table(data, valid=None, seed=0):
    return SecretTable.from_plaintext(data, jax.random.PRNGKey(seed), valid=valid)


def test_filter_oblivious_size_invariant():
    n = 48
    t = {"a": rng.integers(0, 4, n).astype(np.uint32)}
    tab = _table(t)
    out = oblivious_filter(tab, [Predicate("a", "eq", 2)], PRF)
    assert out.n == n  # no physical shrink
    got = out.reveal()
    assert (got["_valid"] == (t["a"] == 2)).all()


def test_filter_multi_predicate():
    n = 64
    t = {
        "a": rng.integers(0, 4, n).astype(np.uint32),
        "b": rng.integers(0, 100, n).astype(np.uint32),
        "c": rng.integers(0, 100, n).astype(np.uint32),
    }
    tab = _table(t)
    preds = [
        Predicate("a", "eq", 1),
        Predicate("b", "lt", 60),
        Predicate("c", "gt", 10),
        Predicate("b", "le", "col:c"),
    ]
    out = oblivious_filter(tab, preds, PRF)
    want = (t["a"] == 1) & (t["b"] < 60) & (t["c"] > 10) & (t["b"] <= t["c"])
    assert (out.reveal()["_valid"] == want).all()


def test_join_is_cartesian_sized_and_correct():
    n1, n2 = 12, 9
    l = {"pid": rng.integers(0, 5, n1).astype(np.uint32), "x": np.arange(n1, dtype=np.uint32)}
    r = {"pid2": rng.integers(0, 5, n2).astype(np.uint32), "y": np.arange(n2, dtype=np.uint32)}
    out = oblivious_join(_table(l, seed=1), _table(r, seed=2), ("pid", "pid2"), PRF)
    assert out.n == n1 * n2
    got = out.reveal_true_rows()
    want = sorted(
        (int(l["pid"][i]), int(l["x"][i]), int(r["y"][j]))
        for i in range(n1)
        for j in range(n2)
        if l["pid"][i] == r["pid2"][j]
    )
    assert sorted(zip(got["pid"].tolist(), got["x"].tolist(), got["y"].tolist())) == want


def test_join_respects_input_validity():
    n1, n2 = 8, 8
    l = {"pid": np.arange(n1, dtype=np.uint32) % 4}
    r = {"pid2": np.arange(n2, dtype=np.uint32) % 4}
    lv = np.zeros(n1, dtype=np.uint32); lv[:2] = 1
    out = oblivious_join(_table(l, valid=lv, seed=3), _table(r, seed=4), ("pid", "pid2"), PRF)
    got = out.reveal_true_rows()
    assert set(got["pid"].tolist()) <= {0, 1}


def test_groupby_count():
    n = 40
    k = rng.integers(0, 6, n).astype(np.uint32)
    valid = (rng.random(n) < 0.75).astype(np.uint32)
    out = oblivious_groupby_count(_table({"k": k}, valid=valid, seed=5), "k", PRF)
    got = out.reveal()
    mask = got["_valid"].astype(bool)
    res = dict(zip(got["k"][mask].tolist(), got["cnt"][mask].tolist()))
    want = dict(collections.Counter(k[valid.astype(bool)].tolist()))
    assert res == want


def test_orderby_limit():
    n = 50
    v = rng.integers(0, 500, n).astype(np.uint32)
    valid = (rng.random(n) < 0.6).astype(np.uint32)
    out = oblivious_orderby(_table({"v": v}, valid=valid, seed=6), "v", PRF,
                            descending=True, limit=8)
    got = out.reveal()
    kept = got["v"][got["_valid"].astype(bool)]
    want = np.sort(v[valid.astype(bool)])[::-1][:8]
    assert (kept == want[: len(kept)]).all()


def test_distinct_and_aggregates():
    n = 36
    pid = rng.integers(0, 9, n).astype(np.uint32)
    valid = (rng.random(n) < 0.8).astype(np.uint32)
    tab = _table({"pid": pid}, valid=valid, seed=7)
    uniq = set(pid[valid.astype(bool)].tolist())

    d = oblivious_distinct(tab, "pid", PRF)
    assert sorted(d.reveal_true_rows()["pid"].tolist()) == sorted(uniq)

    assert int(count_distinct(tab, "pid", PRF).reveal()["cnt"][0]) == len(uniq)
    assert int(count_valid(tab, PRF).reveal()["cnt"][0]) == valid.sum()
    assert int(sum_column(tab, "pid", PRF).reveal()["sum"][0]) == pid[valid.astype(bool)].sum()
