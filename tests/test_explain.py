"""EXPLAIN / EXPLAIN ANALYZE, the disclosure audit across every telemetry
surface, traced-vs-untraced parity, and the stats-view drift guard (ISSUE 7)."""
import jax
import pytest

from repro.core.noise import ConstantNoise, NoTrim
from repro.data import generate_healthlnk
from repro.data.queries import all_query_sql
from repro.obs import Tracer, explain_text, redact
from repro.obs.explain import _trim_note
from repro.plan.nodes import Resize
from repro.service import AnalyticsService, PrivacyAccountant
from repro.sql.compile import default_cost_model


@pytest.fixture(scope="module")
def data():
    return generate_healthlnk(n=8, seed=3, aspirin_frac=0.5)


def make_service(tables, **kw):
    kw.setdefault("noise", ConstantNoise(4))
    kw.setdefault("addition", "sequential")
    kw.setdefault("placement", "after_joins")
    kw.setdefault("accountant", PrivacyAccountant())
    kw.setdefault("key", jax.random.PRNGKey(9))
    return AnalyticsService(tables, **kw)


# -----------------------------------------------------------------------------
# EXPLAIN / EXPLAIN ANALYZE
# -----------------------------------------------------------------------------

def _node_count(plan):
    return 1 + sum(_node_count(c) for c in plan.children())


def test_explain_renders_estimates_without_execution(data):
    tables, _ = data
    svc = make_service(tables)
    sql = "SELECT COUNT(*) AS c FROM diagnoses WHERE icd9 < 300"
    text = svc.explain(sql)
    lines = text.splitlines()
    assert lines[0] == f"EXPLAIN {sql}"
    assert "est.rows" in lines[1] and "act.rows" in lines[1]
    # no execution: actual columns are placeholders, nothing was disclosed
    assert all("-" in ln for ln in lines[2:])
    assert svc.stats["queries"] == 0
    assert svc.accountant.status() == []


def test_explain_analyze_every_golden_query(data):
    """Acceptance: EXPLAIN ANALYZE renders estimated-vs-actual for every
    node of every golden query, with one line per plan node plus TOTAL."""
    tables, _ = data
    svc = make_service(tables)
    for name, sql in all_query_sql().items():
        text, res = svc.explain_analyze("goldens", sql)
        lines = text.splitlines()
        n_nodes = _node_count(res.plan)
        # title + header + one line per node + TOTAL
        assert len(lines) == n_nodes + 3, f"{name}: wrong line count"
        assert lines[0] == f"EXPLAIN ANALYZE {sql}"
        body = lines[2:-1]
        assert len(body) == len(res.report.nodes)
        for ln in body:
            cols = ln.split()
            assert len(cols) >= 5, f"{name}: missing columns in {ln!r}"
        # actual seconds/rounds totals match the report
        total = lines[-1]
        assert total.startswith("TOTAL")
        assert f"{res.report.total_rounds}" in total


def test_explain_analyze_shows_trim_outcome(data):
    tables, _ = data
    svc = make_service(tables)
    sql = (
        "SELECT DISTINCT d.pid FROM diagnoses d, medications m "
        "WHERE d.pid = m.pid AND m.med = 1"
    )
    text, res = svc.explain_analyze("alice", sql)
    rz_stats = [s for s in res.report.nodes if s.node.startswith("Resize")]
    assert rz_stats, "placement should have inserted a Resize after the join"
    s_val = rz_stats[0].extra["s"]
    (rz_line,) = [ln for ln in text.splitlines() if "Resize" in ln]
    assert f"S={s_val}" in rz_line


def test_explain_analyze_rejects_foreign_report(data):
    tables, _ = data
    svc = make_service(tables)
    _, res = svc.explain_analyze("alice", "SELECT COUNT(*) FROM diagnoses")
    cm = default_cost_model(svc.catalog)
    other, _, _ = svc.compile(
        "SELECT COUNT(*) FROM diagnoses WHERE icd9 < 300"
    )
    with pytest.raises(ValueError, match="not this plan's report"):
        explain_text(other, cost_model=cm, report=res.report)


def test_cli_explain_verbs_run():
    from repro.sql.__main__ import main

    assert main(["--explain", "SELECT COUNT(*) FROM diagnoses"]) == 0
    assert main(
        ["--explain-analyze", "SELECT COUNT(*) FROM diagnoses WHERE icd9 < 300"]
    ) == 0


# -----------------------------------------------------------------------------
# Disclosure audit: no secret reaches any span, metric, or EXPLAIN line
# -----------------------------------------------------------------------------

def _walk_attr_keys(obj):
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield k
            yield from _walk_attr_keys(v)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            yield from _walk_attr_keys(v)


def test_no_secret_reaches_spans_metrics_or_explain(data):
    """ConstantNoise(4) pins S = T + 4, so T is trivially recoverable from
    S — which is exactly why T itself must never appear: the audit asserts
    every emitted key is in the PUBLIC allow-list, on every surface."""
    tables, _ = data
    svc = make_service(tables)
    sql = (
        "SELECT DISTINCT d.pid FROM diagnoses d, medications m "
        "WHERE d.pid = m.pid AND m.med = 1"
    )
    with Tracer() as tr:
        text, res = svc.explain_analyze("alice", sql)

    # ground truth: the engine-side resize info DOES hold the secrets
    rz = [s for s in res.report.nodes if s.node.startswith("Resize")][0]
    assert "t" in rz.extra and ("eta" in rz.extra or "p" in rz.extra)
    with pytest.raises(redact.RedactionError):
        redact.assert_emittable(rz.extra)

    # 1. spans: only allow-listed keys, and the dropped secrets were counted
    for sp in tr.spans:
        for key in _walk_attr_keys(sp.attrs):
            assert key in redact.PUBLIC_KEYS, f"span {sp.name} leaked {key!r}"
    assert set(tr.redactions) & redact.SECRET_KEYS

    # 2. metrics: every label name on every metric is allow-listed
    snap = svc.metrics_snapshot()
    for name, metric in snap.items():
        for ln in metric["labelnames"]:
            assert ln in redact.PUBLIC_KEYS, f"metric {name} leaked {ln!r}"
    # and no sample label VALUE carries the raw fingerprint's subplan text
    for s in snap["reflex_privacy_budget_remaining"]["samples"]:
        assert len(s["labels"]["sig"]) == 12  # hash, not the fingerprint

    # 3. EXPLAIN ANALYZE: the resize column shows the revealed S only
    t_true = int(rz.extra["t"])
    s_public = int(rz.extra["s"])
    (rz_line,) = [ln for ln in text.splitlines() if "Resize" in ln]
    assert f"S={s_public}" in rz_line
    assert f"S={t_true}" not in rz_line
    assert "eta" not in text and " t=" not in text


def test_trim_note_redacts_adversarial_extra():
    # a hostile extra dict stuffed with secrets renders only the public part
    fake = Resize.__new__(Resize)
    txt = _trim_note(fake, {"t": 7, "eta": 3, "p": 0.5, "s": 10, "s_padded": 16})
    assert txt == "S=10 pad->16"
    txt2 = _trim_note(fake, {"t": 7, "skipped": True, "s": 64})
    assert "skipped" in txt2 and "7" not in txt2


def test_notrim_discloses_nothing_in_explain(data):
    tables, _ = data
    svc = make_service(tables, noise=NoTrim())
    sql = (
        "SELECT DISTINCT d.pid FROM diagnoses d, medications m "
        "WHERE d.pid = m.pid AND m.med = 1"
    )
    text, _res = svc.explain_analyze("alice", sql)
    (rz_line,) = [ln for ln in text.splitlines() if "Resize" in ln]
    assert "trim skipped" in rz_line and "S=" not in rz_line


# -----------------------------------------------------------------------------
# Tracing is free: traced == untraced, field by field
# -----------------------------------------------------------------------------

def test_traced_batched_run_has_exact_ledger_parity(data):
    """Acceptance: tracing must not perturb execution — per-node ledger
    tallies of a traced batched service pass equal an untraced run of the
    identical service bit for bit (spans only *observe* the ledger)."""
    tables, _ = data
    sql = "SELECT major_icd9, COUNT(*) AS c FROM diagnoses GROUP BY major_icd9"

    def run(traced: bool):
        svc = make_service(
            tables, noise=NoTrim(), placement="none", batch_wait_s=60.0
        )
        for t in ("a", "b", "c"):
            svc.enqueue(t, sql)
        if traced:
            with Tracer() as tr:
                res = svc.drain()
            assert tr.find("batch.flush") and tr.find("execute")
        else:
            res = svc.drain()
        return [
            [
                (s.node, s.n_ins, s.n_out, s.bytes_per_party, s.rounds)
                for s in r.report.nodes
            ]
            for r in res
        ], [r.rows for r in res]

    plain_nodes, plain_rows = run(traced=False)
    traced_nodes, traced_rows = run(traced=True)
    assert traced_nodes == plain_nodes
    for a, b in zip(plain_rows, traced_rows):
        assert set(a) == set(b)
        for k in a:
            assert a[k].tolist() == b[k].tolist()


# -----------------------------------------------------------------------------
# Legacy stats dict == metrics registry (no drift possible)
# -----------------------------------------------------------------------------

def test_stats_dict_is_view_over_registry(data):
    tables, _ = data
    svc = make_service(tables)
    alice, bob = svc.session("alice"), svc.session("bob")
    sql = "SELECT COUNT(*) AS c FROM diagnoses WHERE icd9 < 300"
    alice.submit(sql)
    alice.submit(sql)
    bob.submit("SELECT COUNT(*) AS c FROM diagnoses WHERE icd9 < 500")
    assert svc.stats["per_tenant"] == {"alice": 2, "bob": 1}
    assert svc.stats["queries"] == 3
    assert svc.stats["plan_cache_hits"] == 2
    assert svc.stats["plan_cache_misses"] == 1
    assert svc.stats["plan_cache_rebinds"] == 1  # fresh literal on a hit
    # the registry IS the backing store: counters agree exactly
    q = svc.metrics.get("reflex_queries_total")
    assert q.value(tenant="alice") == 2 and q.value(tenant="bob") == 1
    pc = svc.metrics.get("reflex_plan_cache_lookups_total")
    assert pc.value(status="hit") == 2
    assert pc.value(status="rebind") == 1
    # and the exposition carries the same figures
    text = svc.render_metrics()
    assert 'reflex_queries_total{tenant="alice"} 2.0' in text
