"""Engine reporting satellites: per-node resize info (no stale reuse across
nodes or runs), ExecutionReport.to_json, TruncatedLaplace moments caching."""
import json

import jax
import numpy as np
import pytest

from repro.core.noise import BetaNoise, TruncatedLaplace
from repro.core.resizer import ResizerConfig
from repro.data import generate_healthlnk
from repro.engine import Engine
from repro.ops.filter import Predicate
from repro.plan.nodes import Filter, Join, Resize, Scan


@pytest.fixture(scope="module")
def tables():
    return generate_healthlnk(n=12, seed=1)[0]


def _two_resize_plan():
    """Two Resize nodes with different input sizes (12 and 144): stale-info
    reuse would report the first node's info on the second."""
    d = Resize(
        Filter(Scan("diagnoses"), [Predicate("icd9", "eq", 414)]),
        ResizerConfig(noise=BetaNoise(2, 6)),
    )
    return Resize(
        Join(d, Scan("medications"), ("pid", "pid")),
        ResizerConfig(noise=BetaNoise(2, 6)),
    )


def test_resize_info_is_per_node(tables):
    eng = Engine(tables, key=jax.random.PRNGKey(0))
    _, rep = eng.execute(_two_resize_plan())
    infos = [s for s in rep.nodes if s.node.startswith("Resize")]
    assert len(infos) == 2
    assert infos[0].extra["n"] == 12  # first resizer saw the filtered scan
    assert infos[1].extra["n"] == infos[0].n_out * 12  # second saw the join
    assert infos[0].extra != infos[1].extra
    # nothing lingers for the next run
    assert eng._last_resize_info is None


def test_resize_info_not_reused_across_runs(tables):
    eng = Engine(tables, key=jax.random.PRNGKey(0))
    eng.execute(_two_resize_plan())
    # a plan whose Resize is NoTrim-free but... run a plain plan: no resize
    _, rep2 = eng.execute(Filter(Scan("diagnoses"), [Predicate("icd9", "eq", 1)]))
    assert all(not s.node.startswith("Resize") for s in rep2.nodes)
    assert eng._last_resize_info is None


def test_join_reports_all_input_sizes(tables):
    """Regression (ISSUE 3): n_in recorded only children[0].n, so joins
    underreported their right input. n_ins carries every child size; n_in
    stays the first for backward compat."""
    eng = Engine(tables, key=jax.random.PRNGKey(0))
    plan = Join(
        Filter(Scan("diagnoses"), [Predicate("icd9", "eq", 414)]),
        Scan("medications"),
        ("pid", "pid"),
    )
    _, rep = eng.execute(plan)
    (join,) = [s for s in rep.nodes if s.node.startswith("Join")]
    assert join.n_ins == [12, 12]
    assert join.n_in == join.n_ins[0]
    assert join.n_out == 144
    (scan_d, scan_m) = [s for s in rep.nodes if s.node.startswith("Scan")]
    assert scan_d.n_ins == [] and scan_d.n_in == 0
    blob = rep.to_dict()
    (join_d,) = [n for n in blob["nodes"] if n["node"].startswith("Join")]
    assert join_d["n_ins"] == [12, 12]


def test_report_to_json_round_trips(tables):
    eng = Engine(tables, key=jax.random.PRNGKey(0))
    _, rep = eng.execute(_two_resize_plan())
    blob = json.loads(rep.to_json())
    assert blob["total_bytes"] == rep.total_bytes
    assert blob["total_rounds"] == rep.total_rounds
    assert len(blob["nodes"]) == len(rep.nodes)
    for nd, s in zip(blob["nodes"], rep.nodes):
        assert nd["node"] == s.node
        assert nd["bytes_per_party"] == s.bytes_per_party
    # every extra value made it through JSON-safe coercion
    rz = [n for n in blob["nodes"] if n["node"].startswith("Resize")]
    assert all(isinstance(n["extra"]["s"], int) for n in rz)


def test_tlap_moments_cached():
    tl = TruncatedLaplace(eps=0.5, delta=5e-5, sensitivity=3)
    assert tl.integrations == 0
    m1 = tl.mean(1000, 10)
    assert tl.integrations == 1  # one grid integration
    v1 = tl.var(1000, 10)
    m2 = tl.mean(5000, 99)  # moments don't depend on (n, t)
    assert tl.integrations == 1  # ...and none of these re-integrated
    assert m1 == m2 and v1 == tl.var(1, 0)
    # a differently-calibrated instance integrates on its own
    tl2 = TruncatedLaplace(eps=0.25, delta=5e-5, sensitivity=3)
    assert tl2.mean(1000, 10) != m1
    assert tl.integrations == 1 and tl2.integrations == 1
    # cached moments match a fresh computation exactly
    fresh = TruncatedLaplace(eps=0.5, delta=5e-5, sensitivity=3)
    np.testing.assert_allclose([m1, v1], [fresh.mean(0, 0), fresh.var(0, 0)])
