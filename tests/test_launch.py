"""Launch layer: roofline HLO parsing, mesh rules, sharding specs, and the
subprocess-level fault-tolerance drill (simulated failure + auto-resume)."""
import os
import subprocess
import sys

import pytest

from repro.launch.roofline import (
    Roofline,
    parse_collectives,
    shape_bytes,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_shape_bytes():
    assert shape_bytes("bf16[4,8]{1,0}") == 64
    assert shape_bytes("f32[10]") == 40
    assert shape_bytes("(bf16[2,2]{1,0}, s32[4])") == 8 + 16
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("token[]") == 0


def test_parse_collectives_synthetic():
    hlo = """
HloModule test
ENTRY main {
  %p0 = bf16[64,128]{1,0} parameter(0)
  %ar = bf16[64,128]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[128,128]{1,0} all-gather(%ar), dimensions={0}
  %cp.1 = f32[32]{0} constant(0)
  %perm = f32[32]{0} collective-permute(%cp.1), source_target_pairs={{0,1}}
  ROOT %t = (bf16[128,128]{1,0}) tuple(%ag)
}
"""
    st = parse_collectives(hlo)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.count_by_kind["all-gather"] == 1
    assert st.count_by_kind["collective-permute"] == 1
    assert st.bytes_by_kind["all-reduce"] == 64 * 128 * 2
    assert st.bytes_by_kind["all-gather"] == 64 * 128 * 2  # operand, not output
    assert st.bytes_by_kind["collective-permute"] == 32 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=1e18, hlo_bytes=1e12, collective_bytes=1e15,
        collectives={}, collective_counts={}, model_flops=5e17,
    )
    assert r.t_compute == pytest.approx(1e18 / (256 * 197e12))
    assert r.t_memory == pytest.approx(1e12 / (256 * 819e9))
    assert r.t_collective == pytest.approx(1e15 / (256 * 50e9))
    assert r.bottleneck == "collective"
    assert 0 < r.roofline_fraction < 1


def test_param_sharding_rules_divisibility():
    """Every generated spec must divide the tensor: exercised on a small mesh."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    code = """
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import abstract_params
from repro.sharding import make_param_specs, zero1_specs, cache_specs
from repro.models import init_caches

mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ("mixtral_8x7b", "minicpm3_4b", "xlstm_1_3b", "recurrentgemma_9b"):
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    specs = make_param_specs(cfg, tree, mesh)
    def check(leaf, spec):
        for i, ax in enumerate(spec):
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                ext = 1
                for a in axes: ext *= mesh.shape[a]
                assert leaf.shape[i] % ext == 0, (arch, leaf.shape, spec)
    jax.tree.map(check, tree, specs, is_leaf=lambda x: hasattr(x, "shape"))
    z = zero1_specs(specs, tree, mesh)
    jax.tree.map(check, tree, z, is_leaf=lambda x: hasattr(x, "shape"))
    caches = jax.eval_shape(lambda: init_caches(cfg, 16, 128))
    cs = cache_specs(cfg, caches, mesh)
    jax.tree.map(check, caches, cs, is_leaf=lambda x: hasattr(x, "shape"))
print("SHARDING_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], env=env, capture_output=True, text=True, timeout=300)
    assert "SHARDING_OK" in out.stdout, out.stderr[-2000:]


def test_small_mesh_dryrun_compiles():
    """A miniature (2x4) version of the dry-run pipeline end-to-end in a
    subprocess (8 forced host devices): lower+compile+cost analysis."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import abstract_params
from repro.models.lm import loss_fn
from repro.sharding import make_param_specs, batch_specs
from repro.launch.roofline import parse_collectives
import dataclasses

cfg = dataclasses.replace(get_config("stablelm_1_6b").reduced(), scan_layers=True)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = abstract_params(cfg)
p_specs = make_param_specs(cfg, params, mesh)
p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs, is_leaf=lambda x: isinstance(x, P))
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs(cfg, batch, mesh), is_leaf=lambda x: isinstance(x, P))
fn = jax.jit(lambda p, b: loss_fn(cfg, p, b)[0], in_shardings=(p_sh, b_sh))
with mesh:
    compiled = fn.lower(params, batch).compile()
from repro.launch.roofline import cost_analysis_of
ca = cost_analysis_of(compiled)  # version-tolerant (list vs dict)
st = parse_collectives(compiled.as_text())
assert st.total_bytes > 0, "expected collectives from TP sharding"
print("MINI_DRYRUN_OK", ca.get("flops", 0) > 0, st.count_by_kind)
"""
    out = subprocess.run([sys.executable, "-c", code], env=env, capture_output=True, text=True, timeout=300)
    assert "MINI_DRYRUN_OK" in out.stdout, out.stderr[-2000:]


def test_failure_and_resume_drill(tmp_path):
    """Kill training at step 6 (simulated node failure), relaunch, verify it
    resumes from the checkpoint and finishes with the same final loss as an
    uninterrupted run."""
    env = dict(os.environ, PYTHONPATH=SRC)
    base = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "stablelm-1.6b", "--reduced", "--batch", "2", "--seq", "16",
        "--steps", "10", "--ckpt-every", "5", "--log-every", "1",
    ]
    # uninterrupted reference
    ref = subprocess.run(
        base + ["--ckpt-dir", str(tmp_path / "ref")],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert ref.returncode == 0, ref.stderr[-2000:]
    ref_final = [l for l in ref.stdout.splitlines() if l.startswith("final:")][0]

    # interrupted at step 6 (exit 17), then auto-resume
    crash = subprocess.run(
        base + ["--ckpt-dir", str(tmp_path / "ft"), "--simulate-failure", "6"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert crash.returncode == 17
    resume = subprocess.run(
        base + ["--ckpt-dir", str(tmp_path / "ft")],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert resume.returncode == 0, resume.stderr[-2000:]
    assert "[resume] restored step 5" in resume.stdout
    res_final = [l for l in resume.stdout.splitlines() if l.startswith("final:")][0]
    # same last-5-step loss as the uninterrupted run (bitwise pipeline +
    # restored state => identical trajectory)
    assert ref_final.split("loss[last 5]=")[1] == res_final.split("loss[last 5]=")[1]
