"""Lazy-materialization join tests (DESIGN.md §7.2).

The structural guarantee under test: payload columns are NEVER expanded at
the |R1| x |R2| product size — they ride as LazyGather views until the next
Resizer's reveal-and-trim gathers exactly the S surviving rows (or until an
operator's first direct column access) — while values, revealed results, and
ledger tallies stay identical to the eager path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ledger import CommLedger
from repro.core.noise import ConstantNoise
from repro.core.prf import setup_prf
from repro.core.resizer import Resizer, ResizerConfig
from repro.ops import Predicate, SecretTable, oblivious_filter, oblivious_join
from repro.ops.table import (
    LazyGather,
    gather_log,
    reset_gather_log,
    table_nbytes,
)

PRF = setup_prf(jax.random.PRNGKey(6))
rng = np.random.default_rng(6)


def _tables(n1=12, n2=9, extra_cols=0, seed=0):
    l = {
        "pid": rng.integers(0, 5, n1).astype(np.uint32),
        "x": np.arange(n1, dtype=np.uint32),
    }
    r = {
        "pid2": rng.integers(0, 5, n2).astype(np.uint32),
        "y": np.arange(n2, dtype=np.uint32),
    }
    for c in range(extra_cols):
        l[f"lc{c}"] = rng.integers(0, 100, n1).astype(np.uint32)
        r[f"rc{c}"] = rng.integers(0, 100, n2).astype(np.uint32)
    lt = SecretTable.from_plaintext(l, jax.random.PRNGKey(seed + 1))
    rt = SecretTable.from_plaintext(r, jax.random.PRNGKey(seed + 2))
    return l, r, lt, rt


@pytest.mark.parametrize("tile", [7, 1 << 16])
def test_lazy_join_matches_plaintext(tile):
    l, r, lt, rt = _tables()
    out = oblivious_join(lt, rt, ("pid", "pid2"), PRF, tile=tile)
    assert out.n == lt.n * rt.n
    assert all(isinstance(c, LazyGather) for c in out.cols.values())
    got = out.reveal_true_rows()
    want = sorted(
        (int(l["pid"][i]), int(l["x"][i]), int(r["y"][j]))
        for i in range(lt.n)
        for j in range(rt.n)
        if l["pid"][i] == r["pid2"][j]
    )
    assert sorted(zip(got["pid"].tolist(), got["x"].tolist(), got["y"].tolist())) == want


def test_lazy_matches_eager_including_theta():
    _, _, lt, rt = _tables(seed=10)
    for theta in (None, ("x", "le", "y"), ("x", "eq", "y")):
        a = oblivious_join(lt, rt, ("pid", "pid2"), PRF, theta=theta, tile=17)
        b = oblivious_join(lt, rt, ("pid", "pid2"), PRF, theta=theta, lazy=False)
        da, db = a.reveal(), b.reveal()
        assert set(da) == set(db)
        np.testing.assert_array_equal(da["_valid"], db["_valid"])
        for k in da:
            np.testing.assert_array_equal(da[k], db[k])


def test_join_ledger_parity_lazy_vs_eager():
    _, _, lt, rt = _tables(seed=20)
    tallies = {}
    for lazy in (True, False):
        with CommLedger() as led:
            oblivious_join(lt, rt, ("pid", "pid2"), PRF, lazy=lazy, tile=13)
        tallies[lazy] = led.tally()
    assert tallies[True] == tallies[False]


def test_payload_never_materialized_before_trim():
    """The acceptance-criteria guarantee: no payload gather at product size;
    the Resizer realizes exactly S rows per column."""
    _, _, lt, rt = _tables(extra_cols=2, seed=30)
    total = lt.n * rt.n
    joined = oblivious_join(lt, rt, ("pid", "pid2"), PRF)
    reset_gather_log()
    out, info = Resizer(ResizerConfig(noise=ConstantNoise(0.1)))(
        joined, PRF, jax.random.PRNGKey(7)
    )
    log = gather_log()
    assert log, "lazy columns were never gathered"
    assert max(log) == info["s"] < total
    assert out.n == info["s_padded"]
    # post-trim columns are physical shares of the right size
    assert not out.lazy_names()


def test_resize_values_and_ledger_match_eager():
    _, _, lt, rt = _tables(extra_cols=1, seed=40)
    results = {}
    for lazy in (True, False):
        joined = oblivious_join(lt, rt, ("pid", "pid2"), PRF, lazy=lazy)
        with CommLedger() as led:
            out, info = Resizer(ResizerConfig(noise=ConstantNoise(0.1)))(
                joined, PRF, jax.random.PRNGKey(11)
            )
        results[lazy] = (out.reveal_true_rows(), info, led.tally())
    dl, il, tl = results[True]
    de, ie, te = results[False]
    assert il["s"] == ie["s"]
    assert tl == te  # deferred-payload shuffle bytes are still ledgered
    assert set(dl) == set(de)
    for k in dl:
        assert sorted(dl[k].tolist()) == sorted(de[k].tolist())


def test_lazy_footprint_scales_without_cols():
    """O(N1*N2 + S*cols), not O(N1*N2*cols): adding payload columns must not
    grow the lazy join's held bytes by anything close to a product-size
    column (the eager per-column increment)."""
    sizes = {}
    for lazy in (True, False):
        _, _, lt, rt = _tables(n1=32, n2=32, extra_cols=0, seed=50)
        few = table_nbytes(oblivious_join(lt, rt, ("pid", "pid2"), PRF, lazy=lazy))
        _, _, lt, rt = _tables(n1=32, n2=32, extra_cols=4, seed=50)
        many = table_nbytes(oblivious_join(lt, rt, ("pid", "pid2"), PRF, lazy=lazy))
        sizes[lazy] = (few, many)
    product_col_bytes = 3 * 32 * 32 * 4  # one materialized product-size column
    lazy_growth = sizes[True][1] - sizes[True][0]
    eager_growth = sizes[False][1] - sizes[False][0]
    assert eager_growth == 8 * product_col_bytes  # 8 extra expanded columns
    assert lazy_growth < product_col_bytes  # bases only: O(n1 + n2) per col
    assert sizes[True][1] < sizes[False][1] / 3


def test_gather_rows_composes_lazily():
    _, _, lt, rt = _tables(seed=60)
    joined = oblivious_join(lt, rt, ("pid", "pid2"), PRF)
    head = joined.gather_rows(jnp.arange(10))
    assert head.n == 10
    assert all(isinstance(c, LazyGather) for c in head.cols.values())
    full = joined.reveal()
    sub = head.reveal()
    for k in sub:
        np.testing.assert_array_equal(sub[k], full[k][:10])


def test_first_access_materializes_in_place():
    _, _, lt, rt = _tables(seed=70)
    joined = oblivious_join(lt, rt, ("pid", "pid2"), PRF)
    assert isinstance(joined.cols["x"], LazyGather)
    col = joined.col("x")
    assert not isinstance(col, LazyGather)
    assert not isinstance(joined.cols["x"], LazyGather)  # cached
    assert isinstance(joined.cols["y"], LazyGather)  # others untouched


def test_filter_preserves_laziness_of_untouched_cols():
    l, r, lt, rt = _tables(extra_cols=1, seed=80)
    joined = oblivious_join(lt, rt, ("pid", "pid2"), PRF)
    out = oblivious_filter(joined, [Predicate("x", "lt", 6)], PRF)
    assert isinstance(out.cols["y"], LazyGather)
    assert isinstance(out.cols["lc0"], LazyGather)
    got = out.reveal_true_rows()
    want = sorted(
        (int(l["x"][i]), int(r["y"][j]))
        for i in range(lt.n)
        for j in range(rt.n)
        if l["pid"][i] == r["pid2"][j] and l["x"][i] < 6
    )
    assert sorted(zip(got["x"].tolist(), got["y"].tolist())) == want


def test_join_after_join_composes_views():
    """A second join over a lazy table must compose index maps, not stack
    LazyGather-of-LazyGather."""
    _, _, lt, rt = _tables(n1=6, n2=5, seed=90)
    j1 = oblivious_join(lt, rt, ("pid", "pid2"), PRF)
    third = SecretTable.from_plaintext(
        {"pid3": rng.integers(0, 5, 4).astype(np.uint32)}, jax.random.PRNGKey(99)
    )
    j2 = oblivious_join(j1, third, ("pid", "pid3"), PRF)
    assert j2.n == j1.n * third.n
    for c in j2.cols.values():
        assert isinstance(c, LazyGather)
        assert not isinstance(c.base, LazyGather)
    # count parity with the eager path
    e1 = oblivious_join(lt, rt, ("pid", "pid2"), PRF, lazy=False)
    e2 = oblivious_join(e1, third, ("pid", "pid3"), PRF, lazy=False)
    assert int(j2.reveal()["_valid"].sum()) == int(e2.reveal()["_valid"].sum())


def test_empty_input_join():
    """A zero-row side must yield an empty (well-formed) product, matching
    the eager path."""
    _, _, lt, rt = _tables(seed=110)
    empty = SecretTable(
        {"pid2": rt.cols["pid2"].take(jnp.arange(0))},
        rt.valid.take(jnp.arange(0)),
    )
    for lazy in (True, False):
        out = oblivious_join(lt, empty, ("pid", "pid2"), PRF, lazy=lazy)
        assert out.n == 0
        assert out.reveal()["_valid"].shape == (0,)


def test_ashare_payload_matches_eager_through_resize():
    """AShare-backed payload (e.g. a groupby count) must take the eager
    conversion path in the Resizer: same ledger, same output values."""
    from repro.core.sharing import share_a

    _, _, lt, rt = _tables(seed=120)
    acol = share_a(np.arange(lt.n, dtype=np.uint32), jax.random.PRNGKey(121))
    lt.cols["agg"] = acol
    results = {}
    for lazy in (True, False):
        joined = oblivious_join(lt, rt, ("pid", "pid2"), PRF, lazy=lazy)
        with CommLedger() as led:
            out, info = Resizer(ResizerConfig(noise=ConstantNoise(0.1)))(
                joined, PRF, jax.random.PRNGKey(12)
            )
        results[lazy] = (out.reveal_true_rows(), led.tally())
    dl, tl = results[True]
    de, te = results[False]
    assert tl == te
    assert sorted(dl["agg"].tolist()) == sorted(de["agg"].tolist())


def test_sortcut_resizer_materializes_lazy_cols():
    """The sort&cut baseline needs physical columns; it must still be correct
    on a lazy input table."""
    _, _, lt, rt = _tables(seed=100)
    joined = oblivious_join(lt, rt, ("pid", "pid2"), PRF)
    eager = oblivious_join(lt, rt, ("pid", "pid2"), PRF, lazy=False)
    cfg = ResizerConfig(noise=ConstantNoise(0.1), use_sort=True)
    out_l, info_l = Resizer(cfg)(joined, PRF, jax.random.PRNGKey(13))
    out_e, info_e = Resizer(cfg)(eager, PRF, jax.random.PRNGKey(13))
    assert info_l["s"] == info_e["s"]
    dl, de = out_l.reveal_true_rows(), out_e.reveal_true_rows()
    for k in dl:
        assert sorted(dl[k].tolist()) == sorted(de[k].tolist())
