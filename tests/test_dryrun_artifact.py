"""Validates the checked-in multi-pod dry-run artifact (artifacts/dryrun.json)
— the (e) deliverable. Skipped when the artifact hasn't been generated yet
(run: PYTHONPATH=src python -m repro.launch.dryrun)."""
import json
import os

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPE_NAMES, shape_applicable

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(ART), reason="dry-run artifact not generated"
)


@pytest.fixture(scope="module")
def rows():
    return json.load(open(ART))


def test_every_cell_present_and_clean(rows):
    idx = {(r["arch"], r["shape"], r["mesh"]): r for r in rows}
    missing, errors = [], []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPE_NAMES:
            for mesh in ("16x16", "2x16x16"):
                r = idx.get((arch, shape, mesh))
                if r is None:
                    missing.append((arch, shape, mesh))
                    continue
                applicable, _ = shape_applicable(cfg, shape)
                if applicable:
                    if r["status"] != "ok":
                        errors.append((arch, shape, mesh, r.get("error", r["status"])))
                else:
                    assert r["status"] == "skipped", (arch, shape, mesh)
    assert not missing, f"missing cells: {missing}"
    assert not errors, f"failed cells: {errors}"


def test_roofline_terms_sane(rows):
    for r in rows:
        if r["status"] != "ok":
            continue
        assert r["hlo_flops"] > 0, r["arch"]
        assert r["t_compute_s"] > 0 and r["t_memory_s"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        # useful-flops ratio should be a sane fraction (remat <= ~3x waste,
        # decode cells can be tiny because weights dominate flops). MoE
        # baselines use the einsum dispatch whose pathology §Perf documents
        # (0.002 -> fixed by moe_impl="gather"), hence the loose lower bound.
        if r["shape"] == "train_4k":
            assert 0.001 < r["useful_flops_ratio"] <= 1.5, (
                r["arch"], r["shape"], r["useful_flops_ratio"])


def test_multipod_shards_the_pod_axis(rows):
    """512-chip cells must not inflate per-chip collective time by more than
    ~4x vs 256-chip (pod axis participates in sharding, not replication)."""
    idx = {(r["arch"], r["shape"], r["mesh"]): r for r in rows}
    for arch in ARCH_IDS:
        r1 = idx.get((arch, "train_4k", "16x16"))
        r2 = idx.get((arch, "train_4k", "2x16x16"))
        if not r1 or not r2 or "t_collective_s" not in r1 or "t_collective_s" not in r2:
            continue
        if r1["t_collective_s"] > 0:
            assert r2["t_collective_s"] < 6 * r1["t_collective_s"] + 1e-6, arch
