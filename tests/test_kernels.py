"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles
(interpret mode on CPU; BlockSpecs target TPU v5e VMEM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitonic_stage.ops import stage_swap
from repro.kernels.bitonic_stage.ref import bitonic_swap_ref
from repro.kernels.rss_gate.ops import gate
from repro.kernels.rss_gate.ref import rss_gate_ref
from repro.kernels.shuffle_gather.ops import gather_rows

rng = np.random.default_rng(7)


@pytest.mark.parametrize("n", [64, 100, 256, 2048, 4097])
@pytest.mark.parametrize("boolean", [True, False])
def test_rss_gate_sweep(n, boolean):
    xs = rng.integers(0, 2**32, (3, n), dtype=np.uint32)
    ys = rng.integers(0, 2**32, (3, n), dtype=np.uint32)
    al = rng.integers(0, 2**32, (3, n), dtype=np.uint32)
    got = np.asarray(gate(xs, ys, al, boolean=boolean))
    want = np.asarray(rss_gate_ref(xs, ys, al, boolean=boolean))
    np.testing.assert_array_equal(got, want)


def test_rss_gate_multidim():
    xs = rng.integers(0, 2**32, (3, 4, 33), dtype=np.uint32)
    ys = rng.integers(0, 2**32, (3, 4, 33), dtype=np.uint32)
    al = rng.integers(0, 2**32, (3, 4, 33), dtype=np.uint32)
    got = np.asarray(gate(xs, ys, al, boolean=True))
    np.testing.assert_array_equal(got, np.asarray(rss_gate_ref(xs, ys, al, True)))


def test_rss_gate_broadcast_operands():
    """Broadcast-compatible operands ((3,n,2) x against a (3,n,1) y, the
    shape the segmented (sum,count) scan feeds mul) must align per-lane —
    the flattener used to misalign them silently."""
    xs = rng.integers(0, 2**32, (3, 200, 2), dtype=np.uint32)
    ys = rng.integers(0, 2**32, (3, 200, 1), dtype=np.uint32)
    al = rng.integers(0, 2**32, (3, 200, 2), dtype=np.uint32)
    for boolean in (True, False):
        got = np.asarray(gate(xs, ys, al, boolean=boolean))
        want = np.asarray(
            rss_gate_ref(xs, np.broadcast_to(ys, xs.shape), al, boolean)
        )
        np.testing.assert_array_equal(got, want)


def test_rss_gate_preserves_protocol_semantics(prf):
    """Kernel output must be a valid sharing of x*y (sums to the product)."""
    from repro.core.prf import zero_share_add
    from repro.core.ring import RING32

    n = 512
    x = rng.integers(0, 2**16, n, dtype=np.uint32)
    y = rng.integers(0, 2**16, n, dtype=np.uint32)
    from repro.core.sharing import share_a

    xs = share_a(x, jax.random.PRNGKey(0)).shares
    ys = share_a(y, jax.random.PRNGKey(1)).shares
    alpha = zero_share_add(prf, (n,), RING32)
    z = np.asarray(gate(xs, ys, alpha, boolean=False))
    np.testing.assert_array_equal(z[0] + z[1] + z[2], x * y)


@pytest.mark.parametrize("n,c", [(64, 1), (128, 3), (333, 5), (1024, 2)])
def test_shuffle_gather_sweep(n, c):
    t = rng.integers(0, 2**32, (n, c), dtype=np.uint32)
    p = rng.permutation(n).astype(np.int32)
    got = np.asarray(gather_rows(jnp.asarray(t), jnp.asarray(p)))
    np.testing.assert_array_equal(got, t[p])


def test_shuffle_gather_large_falls_back():
    n, c = 4096, 600  # > VMEM_LIMIT -> XLA path
    t = rng.integers(0, 2**32, (n, c), dtype=np.uint32)
    p = rng.permutation(n).astype(np.int32)
    got = np.asarray(gather_rows(jnp.asarray(t), jnp.asarray(p)))
    np.testing.assert_array_equal(got, t[p])


@pytest.mark.parametrize("n,c", [(128, 1), (512, 4), (100, 3)])
def test_bitonic_stage_sweep(n, c):
    mask = rng.integers(0, 2**32, (3, n), dtype=np.uint32)
    own = rng.integers(0, 2**32, (3, c, n), dtype=np.uint32)
    other = rng.integers(0, 2**32, (3, c, n), dtype=np.uint32)
    al = rng.integers(0, 2**32, (3, c, n), dtype=np.uint32)
    got = np.asarray(stage_swap(mask, own, other, al))
    want = np.asarray(bitonic_swap_ref(mask, own, other, al))
    np.testing.assert_array_equal(got, want)


def test_bitonic_stage_swap_semantics():
    """all-ones mask swaps, all-zero mask keeps (on zero alpha)."""
    n, c = 128, 2
    own = rng.integers(0, 2**32, (3, c, n), dtype=np.uint32)
    other = rng.integers(0, 2**32, (3, c, n), dtype=np.uint32)
    zeros = np.zeros((3, c, n), dtype=np.uint32)
    ones = np.zeros((3, n), dtype=np.uint32)
    ones[0] = 0xFFFFFFFF
    got_swap = np.asarray(stage_swap(ones, own, other, zeros))
    # value(out) = value(own) ^ value(own^other) = value(other)
    v = lambda a: a[0] ^ a[1] ^ a[2]
    np.testing.assert_array_equal(v(got_swap), v(other))
    got_keep = np.asarray(stage_swap(np.zeros((3, n), np.uint32), own, other, zeros))
    np.testing.assert_array_equal(v(got_keep), v(own))
