"""End-to-end: the four HealthLnK queries under all execution modes."""
import jax
import numpy as np
import pytest

from repro.core.noise import BetaNoise, RevealNoise, shrinkwrap_default
from repro.core.resizer import ResizerConfig
from repro.data import all_query_plans, generate_healthlnk, plaintext_oracle
from repro.engine import Engine
from repro.plan import insert_resizers
from repro.plan.cost import CostModel


@pytest.fixture(scope="module")
def data():
    return generate_healthlnk(n=24, seed=3, aspirin_frac=0.4, icd_heart_frac=0.3)


def _run(tables, plan, placement, noise=None):
    eng = Engine(tables, key=jax.random.PRNGKey(5))
    noise = noise or BetaNoise(2, 6)
    p = insert_resizers(plan, lambda n: ResizerConfig(noise=noise), placement=placement)
    return eng.execute(p)


def test_comorbidity(data):
    tables, plain = data
    out, rep = _run(tables, all_query_plans()["comorbidity"], "none")
    d = out.reveal()
    mask = d["_valid"].astype(bool)
    got = dict(zip(d["major_icd9"][mask].tolist(), d["cnt"][mask].tolist()))
    vals, counts = np.unique(plain["diagnoses"]["major_icd9"], return_counts=True)
    full = dict(zip(vals.tolist(), counts.tolist()))
    assert all(full[k] == v for k, v in got.items())
    assert sorted(got.values(), reverse=True) == sorted(full.values(), reverse=True)[: len(got)]


@pytest.mark.parametrize("placement", ["none", "all_internal", "after_joins"])
def test_dosage_study_all_modes(data, placement):
    tables, plain = data
    out, rep = _run(tables, all_query_plans()["dosage_study"], placement)
    got = sorted(set(out.reveal_true_rows()["pid"].tolist()))
    assert got == plaintext_oracle("dosage_study", plain)


@pytest.mark.parametrize("placement", ["none", "all_internal"])
def test_aspirin_count(data, placement):
    tables, plain = data
    out, rep = _run(tables, all_query_plans()["aspirin_count"], placement)
    got = int(out.reveal_true_rows()["cnt"][0])
    assert got == plaintext_oracle("aspirin_count", plain)


def test_three_join_with_resizers(data):
    tables, plain = data
    out, rep = _run(tables, all_query_plans()["three_join"], "after_joins")
    got = int(out.reveal_true_rows()["cnt"][0])
    assert got == plaintext_oracle("three_join", plain)


def test_revealed_mode_matches_secretflow_semantics(data):
    tables, plain = data
    out, rep = _run(
        tables, all_query_plans()["dosage_study"], "all_internal", noise=RevealNoise()
    )
    got = sorted(set(out.reveal_true_rows()["pid"].tolist()))
    assert got == plaintext_oracle("dosage_study", plain)
    # resize nodes disclosed the exact true size
    for s in rep.nodes:
        if s.node.startswith("Resize"):
            assert s.extra["s"] == s.extra["t"]


def test_resizers_shrink_intermediates(data):
    tables, plain = data
    _, rep_fo = _run(tables, all_query_plans()["aspirin_count"], "none")
    _, rep_rx = _run(tables, all_query_plans()["aspirin_count"], "all_internal")
    fo_bytes = rep_fo.total_bytes
    rx_bytes = rep_rx.total_bytes
    assert rx_bytes < fo_bytes  # trimming reduces total communication


def test_cost_model_estimates_and_placement():
    plans = all_query_plans()
    cm = CostModel(
        table_sizes={"diagnoses": 1000, "medications": 1000, "demographics": 250},
        table_cols={"diagnoses": 5, "medications": 4, "demographics": 2},
        noise=shrinkwrap_default(),
    )
    fo = cm.plan_bytes(plans["aspirin_count"])
    rx = cm.plan_bytes(
        insert_resizers(
            plans["aspirin_count"],
            lambda n: ResizerConfig(noise=shrinkwrap_default()),
            placement="all_internal",
        )
    )
    assert rx < fo  # the model agrees trimming helps on join-heavy queries

    # cost-based placement inserts at least one resizer on a join query
    p = insert_resizers(
        plans["aspirin_count"],
        lambda n: ResizerConfig(noise=shrinkwrap_default()),
        placement="cost_based",
        cost_model=cm,
    )
    assert "Resize" in p.pretty()
