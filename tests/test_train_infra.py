"""Training infrastructure: optimizer, pipeline determinism, checkpoint
atomicity + async + keep-k, bitwise-identical resume after simulated failure,
elastic restore, bucketed batching."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.models import init_params
from repro.serve.batching import BucketedBatcher, next_bucket
from repro.train import AdamWConfig, Checkpointer, adamw_init, make_train_step
from repro.train.optimizer import adamw_update, lr_schedule


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=1000)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, grads, params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_pipeline_deterministic_and_sharded():
    p = TokenPipeline(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    b1, b2 = p.batch_at(5), p.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p.batch_at(6)["tokens"], b1["tokens"])
    # dp shards partition the batch deterministically
    s0 = TokenPipeline(100, 16, 8, seed=3, dp_rank=0, dp_size=2).batch_at(5)
    s1 = TokenPipeline(100, 16, 8, seed=3, dp_rank=1, dp_size=2).batch_at(5)
    assert s0["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def _tiny_setup(seed=0):
    cfg = get_config("stablelm_1_6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    pipe = TokenPipeline(cfg.vocab_size, 16, 4, seed=7)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    return cfg, params, opt, pipe, step


def _run_steps(params, opt, pipe, step, start, n):
    for s in range(start, start + n):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, opt, m = step(params, opt, batch)
    return params, opt, m


def test_checkpoint_roundtrip_and_gc(tmp_path):
    _, params, opt, _, _ = _tiny_setup()
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save(s, {"params": params, "opt": opt, "meta": {"x": s}})
    assert ck.latest_step() == 3
    assert sorted(os.listdir(tmp_path)) == ["step_00000002", "step_00000003"]  # keep=2
    step, state = ck.restore(None, {"params": params, "opt": opt, "meta": {}})
    assert step == 3 and state["meta"]["x"] == 3
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_bitwise_identical(tmp_path):
    """interrupted-at-3 + resumed == uninterrupted 6 steps."""
    _, p0, o0, pipe, step = _tiny_setup()
    # uninterrupted
    pu, ou, _ = _run_steps(p0, o0, pipe, step, 0, 6)
    # interrupted: 3 steps, checkpoint, 'crash', restore, 3 more
    pa, oa, _ = _run_steps(p0, o0, pipe, step, 0, 3)
    ck = Checkpointer(str(tmp_path))
    ck.save(3, {"params": pa, "opt": oa, "meta": {}})
    del pa, oa
    _, p1, o1, _, _ = _tiny_setup()  # fresh process state
    s, st = ck.restore(None, {"params": p1, "opt": o1, "meta": {}})
    pb, ob, _ = _run_steps(st["params"], st["opt"], pipe, step, s, 3)
    for a, b in zip(jax.tree.leaves(pu), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(tmp_path):
    _, params, opt, _, _ = _tiny_setup()
    ck = Checkpointer(str(tmp_path))
    ck.save_async(5, {"params": params, "opt": opt, "meta": {}})
    ck.wait()
    assert ck.latest_step() == 5


def test_atomicity_no_tmp_left(tmp_path):
    _, params, opt, _, _ = _tiny_setup()
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": params, "opt": opt, "meta": {}})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_grad_accum_matches_large_batch():
    cfg, params, opt, pipe, _ = _tiny_setup()
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    s1 = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=50), 1)
    s2 = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=50), 2)
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    # losses agree; params close (grad-mean over microbatches vs full batch
    # differs only by masked-token weighting)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-3
        )


def test_bucketed_batcher():
    assert next_bucket(100, (128, 256)) == 128
    bb = BucketedBatcher(len_buckets=(8, 16), batch_buckets=(1, 2, 4))
    for n in (5, 6, 7):
        bb.submit(np.arange(n))
    batch, ids = bb.next_batch()
    assert batch["tokens"].shape == (4, 8)  # 3 reqs -> batch bucket 4, len 8
    assert len(ids) == 3 and bb.n_pending == 0
    assert batch["mask"][:3].sum() == 5 + 6 + 7
